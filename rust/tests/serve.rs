//! Serve-path integration tests: the continuous-batching scheduler must
//! not change greedy-lossless outputs under concurrency, must never
//! starve an admitted session, and the TCP front-end must serve
//! interleaved clients.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use hat::backend::reference::ReferenceBackend;
use hat::backend::{ExecBackend, RuntimeStats, Tensor};
use hat::config::{ServeConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::runtime::{ArtifactRegistry, Manifest};
use hat::server::scheduler::{Request, Scheduler};
use hat::server::{generate, serve_listener};
use hat::util::proptest::{cases, forall};
use hat::util::rng::Rng;

fn prompt_of(rng: &mut Rng, len: usize, vocab: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(vocab) as u32).collect()
}

/// N TCP clients with interleaved GENERATEs get byte-identical token
/// streams to serial single-client runs.
#[test]
fn concurrent_tcp_clients_match_serial_runs() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let n_clients = 4usize;
    let serve_cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), serve_cfg, n_clients + 1).unwrap();
    });

    // Serial reference on the same engine configuration as the server.
    let engine = Engine::load_default().unwrap();
    let spec = SpecDecConfig::default();
    let mut rng = Rng::new(11);
    let vocab = engine.spec().vocab;
    let reqs: Vec<(Vec<u32>, usize)> = (0..n_clients)
        .map(|i| (prompt_of(&mut rng, 24 + 17 * i, vocab), 8 + 5 * i))
        .collect();
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&engine, p, *m, &spec).unwrap().reply_line())
        .collect();

    let clients: Vec<_> = reqs
        .into_iter()
        .map(|(prompt, max_new)| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let words: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
                writeln!(stream, "GENERATE {max_new} {}", words.join(" ")).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                writeln!(stream, "QUIT").unwrap();
                line.trim_end().to_string()
            })
        })
        .collect();
    let replies: Vec<String> = clients.into_iter().map(|c| c.join().unwrap()).collect();
    for (i, (got, want)) in replies.iter().zip(&expected).enumerate() {
        assert_eq!(got, want, "client {i}: concurrent stream diverged from serial");
    }

    // A final connection checks the scheduler metrics surfaced via STATS
    // (and consumes the bounded accept loop's last slot).
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "STATS").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "bad STATS reply: {line}");
    for key in [
        "executions=",
        "compile_ms=",
        "requests=4",
        "iterations=",
        "queue_wait_ms=",
        "ttft_ms=",
        "tbt_ms=",
        "accept=",
        "chunk_mean=",
        "batch_mean=",
        "fallbacks=0",
        "g_learned=1",
        "queued=0",
        "live=0",
    ] {
        assert!(line.contains(key), "STATS missing {key}: {line}");
    }
    writeln!(stream, "QUIT").unwrap();
    server.join().unwrap();
}

/// Batched-vs-sequential byte-identity: the scheduler executes same-bucket
/// verify rounds and prefill chunks of concurrent sessions as *one*
/// `run_batch` engine call per group, and every session's stream must
/// still match a serial single-session `generate()` run exactly.  The
/// backend's occupancy counters prove the batching actually happened: a
/// single `run` adds (1 execution, 1 item) while an n-wide `run_batch`
/// adds (1 execution, n items), so mean occupancy > 1 requires grouped
/// calls.
#[test]
fn batched_execution_is_byte_identical_to_sequential() {
    let serial_engine = Engine::synthetic();
    let spec = SpecDecConfig::default();
    let reqs: Vec<(Vec<u32>, usize)> = vec![
        ((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 14),
        ((0u32..75).map(|i| (i * 5 + 2) % 256).collect(), 11),
        ((0u32..33).map(|i| (i * 7 + 5) % 256).collect(), 16),
        ((0u32..52).map(|i| (i * 11 + 3) % 256).collect(), 9),
    ];
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&serial_engine, p, *m, &spec).unwrap().reply_line())
        .collect();

    let engine = Engine::synthetic();
    let cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec, cfg);
    let mut rxs = Vec::new();
    for (p, m) in &reqs {
        let (tx, rx) = mpsc::channel();
        sched.submit(Request {
            prompt: p.clone(),
            max_new: *m,
            reply: tx,
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let mut guard = 0;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 20_000, "scheduler failed to drain");
    }
    for (i, (rx, want)) in rxs.iter().zip(&expected).enumerate() {
        let got = rx.recv().unwrap();
        assert_eq!(&got, want, "session {i}: batched stream diverged from serial");
    }
    // All four prompts are ≥ 16 tokens, so iteration 1 carries four
    // same-bucket prefill chunks — at least that group ran 4-wide.
    let stats = engine.reg.stats();
    assert!(
        stats.mean_batch_occupancy() > 1.0,
        "no batched engine calls observed (occupancy {:.3} over {} executions)",
        stats.mean_batch_occupancy(),
        stats.executions
    );
    assert!(
        sched.stats.batch_occupancy.mean() > 1.0,
        "scheduler never issued a multi-session group"
    );
}

/// Reference backend that rejects every multi-lane `run_batch` call —
/// forces the scheduler's per-lane serial fallback paths.
struct BatchRejectBackend(ReferenceBackend);

impl ExecBackend for BatchRejectBackend {
    fn name(&self) -> &'static str {
        "batch-reject-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.0.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.0.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.run(name, inputs)
    }
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> anyhow::Result<Vec<Vec<Tensor>>> {
        if inputs.len() > 1 {
            anyhow::bail!("injected: this backend rejects multi-lane batches");
        }
        self.0.run_batch(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.0.weight(name)
    }
    fn stats(&self) -> RuntimeStats {
        self.0.stats()
    }
}

/// One poisoned batched call must not take out co-batched sessions: on a
/// backend that rejects every multi-lane `run_batch`, the scheduler
/// degrades each group to per-lane serial calls, every request still
/// completes with the exact serial stream, and the degradation is
/// observable through `ServeStats::fallbacks`.
#[test]
fn scheduler_degrades_to_serial_when_batched_calls_fail() {
    let backend = BatchRejectBackend(ReferenceBackend::synthetic(42));
    let engine = Engine { reg: ArtifactRegistry::with_backend(Box::new(backend)).unwrap() };
    let spec = SpecDecConfig::default();
    let reqs: Vec<(Vec<u32>, usize)> = vec![
        ((0u32..30).map(|i| (i * 3 + 1) % 256).collect(), 10),
        ((0u32..45).map(|i| (i * 5 + 2) % 256).collect(), 8),
        ((0u32..24).map(|i| (i * 7 + 5) % 256).collect(), 12),
    ];
    let clean = Engine::synthetic();
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&clean, p, *m, &spec).unwrap().reply_line())
        .collect();

    let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec, cfg);
    let mut rxs = Vec::new();
    for (p, m) in &reqs {
        let (tx, rx) = mpsc::channel();
        sched.submit(Request {
            prompt: p.clone(),
            max_new: *m,
            reply: tx,
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let mut guard = 0;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 20_000, "scheduler failed to drain");
    }
    for (i, (rx, want)) in rxs.iter().zip(&expected).enumerate() {
        assert_eq!(&rx.recv().unwrap(), want, "session {i} diverged under fallback");
    }
    assert!(sched.stats.fallbacks > 0, "no batched call failed — fallback not exercised");
    assert!(
        sched.stats.batch_occupancy.mean() <= 1.0 + 1e-9,
        "rejected batches must degrade to 1-lane calls"
    );
}

/// The scheduler never starves a session: every admitted request finishes
/// within a bounded number of iterations (each request needs at most one
/// iteration per prefill chunk plus one per decode round, and every
/// iteration advances all pending decode jobs and the head prefill chunk).
#[test]
fn prop_scheduler_never_starves_a_session() {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    forall(cases(12), |rng| {
        let n_reqs = rng.range_usize(2, 6);
        let cfg = ServeConfig {
            max_sessions: rng.range_usize(1, 4),
            prefill_budget: rng.range_usize(32, 256),
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let mut rxs = Vec::new();
        let mut job_bound = 0usize;
        for _ in 0..n_reqs {
            let plen = rng.range_usize(8, 80);
            let max_new = rng.range_usize(2, 24);
            // Worst case: one iteration per 1-token prefill chunk, one per
            // 1-token decode round, plus admission slack.
            job_bound += plen + max_new + 2;
            let (tx, rx) = mpsc::channel();
            sched.submit(Request {
                prompt: prompt_of(rng, plen, vocab),
                max_new,
                reply: tx,
                enqueued: Instant::now(),
            });
            rxs.push((rx, max_new));
        }
        let mut iters = 0usize;
        while sched.has_work() {
            if sched.step() == 0 {
                return Err("scheduler idle with admitted work".into());
            }
            iters += 1;
            if iters > job_bound {
                return Err(format!("not drained after {iters} iterations (bound {job_bound})"));
            }
        }
        for (rx, max_new) in &rxs {
            let line = rx.try_recv().map_err(|_| "request finished without a reply")?;
            if !line.starts_with("OK ") {
                return Err(format!("request failed: {line}"));
            }
            let body = line.strip_prefix("OK ").unwrap();
            let toks = body.split(" | ").next().unwrap();
            let n = toks.split_whitespace().count();
            if n != *max_new {
                return Err(format!("expected {max_new} tokens, got {n}: {line}"));
            }
        }
        Ok(())
    });
}

/// Interleaving across differently-shaped requests is still deterministic:
/// two identical scheduler runs produce identical reply sets.
#[test]
fn scheduler_runs_are_reproducible() {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    let run = || {
        let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let mut rng = Rng::new(5);
        let mut rxs = Vec::new();
        for i in 0..5usize {
            let (tx, rx) = mpsc::channel();
            sched.submit(Request {
                prompt: prompt_of(&mut rng, 10 + 9 * i, vocab),
                max_new: 4 + 3 * i,
                reply: tx,
                enqueued: Instant::now(),
            });
            rxs.push(rx);
        }
        let mut guard = 0;
        while sched.has_work() {
            assert!(sched.step() > 0);
            guard += 1;
            assert!(guard < 10_000);
        }
        rxs.iter().map(|rx| rx.try_recv().unwrap()).collect::<Vec<String>>()
    };
    assert_eq!(run(), run());
}
