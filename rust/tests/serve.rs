//! Serve-path integration tests: the continuous-batching scheduler must
//! not change greedy-lossless outputs under concurrency, must never
//! starve an admitted session, and the TCP front-end must serve
//! interleaved clients.

use std::cell::Cell;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use hat::backend::reference::ReferenceBackend;
use hat::backend::{ExecBackend, RuntimeStats, Tensor};
use hat::config::{PriorityMode, SampleVerify, ServeConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::runtime::{ArtifactRegistry, Manifest};
use hat::server::conn::{ReplySink, MAX_LINE_BYTES};
use hat::server::pools::{PdScheduler, ServeExec};
use hat::server::scheduler::{Request, Scheduler};
use hat::server::{generate, serve_listener};
use hat::util::clock;
use hat::util::proptest::{cases, forall};
use hat::util::rng::Rng;

fn prompt_of(rng: &mut Rng, len: usize, vocab: usize) -> Vec<u32> {
    (0..len).map(|_| rng.below(vocab) as u32).collect()
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A request with a fresh id and its own reply sink.
fn request(prompt: Vec<u32>, max_new: usize) -> (Request, ReplySink) {
    let tx = ReplySink::new();
    (
        Request {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new,
            reply: tx.clone(),
            enqueued: clock::now(),
        },
        tx,
    )
}

/// N TCP clients with interleaved GENERATEs get byte-identical token
/// streams to serial single-client runs.
#[test]
fn concurrent_tcp_clients_match_serial_runs() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let n_clients = 4usize;
    let serve_cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), serve_cfg, n_clients + 1).unwrap();
    });

    // Serial reference on the same engine configuration as the server.
    let engine = Engine::load_default().unwrap();
    let spec = SpecDecConfig::default();
    let mut rng = Rng::new(11);
    let vocab = engine.spec().vocab;
    let reqs: Vec<(Vec<u32>, usize)> = (0..n_clients)
        .map(|i| (prompt_of(&mut rng, 24 + 17 * i, vocab), 8 + 5 * i))
        .collect();
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&engine, p, *m, &spec).unwrap().reply_line())
        .collect();

    let clients: Vec<_> = reqs
        .into_iter()
        .map(|(prompt, max_new)| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let words: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
                writeln!(stream, "GENERATE {max_new} {}", words.join(" ")).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                writeln!(stream, "QUIT").unwrap();
                line.trim_end().to_string()
            })
        })
        .collect();
    let replies: Vec<String> = clients.into_iter().map(|c| c.join().unwrap()).collect();
    for (i, (got, want)) in replies.iter().zip(&expected).enumerate() {
        assert_eq!(got, want, "client {i}: concurrent stream diverged from serial");
    }

    // A final connection checks the scheduler metrics surfaced via STATS
    // (and consumes the bounded accept loop's last slot).
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "STATS").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "bad STATS reply: {line}");
    for key in [
        "executions=",
        "compile_ms=",
        "requests=4",
        "iterations=",
        "queue_wait_ms=",
        "ttft_ms=",
        "tbt_ms=",
        "accept=",
        "accept_hist=",
        "seed=0",
        "chunk_mean=",
        "batch_mean=",
        "fallbacks=0",
        "cancelled=0",
        "failed=0",
        "reaped=0",
        "deadline_expired=0",
        "g_learned=1",
        "queued=0",
        "live=0",
    ] {
        assert!(line.contains(key), "STATS missing {key}: {line}");
    }
    writeln!(stream, "QUIT").unwrap();
    server.join().unwrap();
}

/// Batched-vs-sequential byte-identity: the scheduler executes same-bucket
/// verify rounds and prefill chunks of concurrent sessions as *one*
/// `run_batch` engine call per group, and every session's stream must
/// still match a serial single-session `generate()` run exactly.  The
/// backend's occupancy counters prove the batching actually happened: a
/// single `run` adds (1 execution, 1 item) while an n-wide `run_batch`
/// adds (1 execution, n items), so mean occupancy > 1 requires grouped
/// calls.
#[test]
fn batched_execution_is_byte_identical_to_sequential() {
    let serial_engine = Engine::synthetic();
    let spec = SpecDecConfig::default();
    let reqs: Vec<(Vec<u32>, usize)> = vec![
        ((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 14),
        ((0u32..75).map(|i| (i * 5 + 2) % 256).collect(), 11),
        ((0u32..33).map(|i| (i * 7 + 5) % 256).collect(), 16),
        ((0u32..52).map(|i| (i * 11 + 3) % 256).collect(), 9),
    ];
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&serial_engine, p, *m, &spec).unwrap().reply_line())
        .collect();

    let engine = Engine::synthetic();
    let cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec, cfg);
    let mut rxs = Vec::new();
    for (p, m) in &reqs {
        let (r, rx) = request(p.clone(), *m);
        sched.submit(r);
        rxs.push(rx);
    }
    let mut guard = 0;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 20_000, "scheduler failed to drain");
    }
    for (i, (rx, want)) in rxs.iter().zip(&expected).enumerate() {
        let got = rx.recv().unwrap();
        assert_eq!(&got, want, "session {i}: batched stream diverged from serial");
    }
    // All four prompts are ≥ 16 tokens, so iteration 1 carries four
    // same-bucket prefill chunks — at least that group ran 4-wide.
    let stats = engine.reg.stats();
    assert!(
        stats.mean_batch_occupancy() > 1.0,
        "no batched engine calls observed (occupancy {:.3} over {} executions)",
        stats.mean_batch_occupancy(),
        stats.executions
    );
    assert!(
        sched.stats.batch_occupancy.mean() > 1.0,
        "scheduler never issued a multi-session group"
    );
}

/// Reference backend that rejects every multi-lane `run_batch` call —
/// forces the scheduler's per-lane serial fallback paths.
struct BatchRejectBackend(ReferenceBackend);

impl ExecBackend for BatchRejectBackend {
    fn name(&self) -> &'static str {
        "batch-reject-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.0.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.0.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.run(name, inputs)
    }
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> anyhow::Result<Vec<Vec<Tensor>>> {
        if inputs.len() > 1 {
            anyhow::bail!("injected: this backend rejects multi-lane batches");
        }
        self.0.run_batch(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.0.weight(name)
    }
    fn stats(&self) -> RuntimeStats {
        self.0.stats()
    }
}

/// One poisoned batched call must not take out co-batched sessions: on a
/// backend that rejects every multi-lane `run_batch`, the scheduler
/// degrades each group to per-lane serial calls, every request still
/// completes with the exact serial stream, and the degradation is
/// observable through `ServeStats::fallbacks`.
#[test]
fn scheduler_degrades_to_serial_when_batched_calls_fail() {
    let backend = BatchRejectBackend(ReferenceBackend::synthetic(42));
    let engine =
        Engine::with_registry(ArtifactRegistry::with_backend(Box::new(backend)).unwrap()).unwrap();
    let spec = SpecDecConfig::default();
    let reqs: Vec<(Vec<u32>, usize)> = vec![
        ((0u32..30).map(|i| (i * 3 + 1) % 256).collect(), 10),
        ((0u32..45).map(|i| (i * 5 + 2) % 256).collect(), 8),
        ((0u32..24).map(|i| (i * 7 + 5) % 256).collect(), 12),
    ];
    let clean = Engine::synthetic();
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&clean, p, *m, &spec).unwrap().reply_line())
        .collect();

    let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec, cfg);
    let mut rxs = Vec::new();
    for (p, m) in &reqs {
        let (r, rx) = request(p.clone(), *m);
        sched.submit(r);
        rxs.push(rx);
    }
    let mut guard = 0;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 20_000, "scheduler failed to drain");
    }
    for (i, (rx, want)) in rxs.iter().zip(&expected).enumerate() {
        assert_eq!(&rx.recv().unwrap(), want, "session {i} diverged under fallback");
    }
    assert!(sched.stats.fallbacks > 0, "no batched call failed — fallback not exercised");
    assert!(
        sched.stats.batch_occupancy.mean() <= 1.0 + 1e-9,
        "rejected batches must degrade to 1-lane calls"
    );
}

/// The scheduler never starves a session: every admitted request finishes
/// within a bounded number of iterations (each request needs at most one
/// iteration per prefill chunk plus one per decode round, and every
/// iteration advances all pending decode jobs and the head prefill chunk).
#[test]
fn prop_scheduler_never_starves_a_session() {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    forall(cases(12), |rng| {
        let n_reqs = rng.range_usize(2, 6);
        let cfg = ServeConfig {
            max_sessions: rng.range_usize(1, 4),
            prefill_budget: rng.range_usize(32, 256),
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let mut rxs = Vec::new();
        let mut job_bound = 0usize;
        for _ in 0..n_reqs {
            let plen = rng.range_usize(8, 80);
            let max_new = rng.range_usize(2, 24);
            // Worst case: one iteration per 1-token prefill chunk, one per
            // 1-token decode round, plus admission slack.
            job_bound += plen + max_new + 2;
            let (r, rx) = request(prompt_of(rng, plen, vocab), max_new);
            sched.submit(r);
            rxs.push((rx, max_new));
        }
        let mut iters = 0usize;
        while sched.has_work() {
            if sched.step() == 0 {
                return Err("scheduler idle with admitted work".into());
            }
            iters += 1;
            if iters > job_bound {
                return Err(format!("not drained after {iters} iterations (bound {job_bound})"));
            }
        }
        for (rx, max_new) in &rxs {
            let line = rx.try_recv().map_err(|_| "request finished without a reply")?;
            if !line.starts_with("OK ") {
                return Err(format!("request failed: {line}"));
            }
            let body = line.strip_prefix("OK ").unwrap();
            let toks = body.split(" | ").next().unwrap();
            let n = toks.split_whitespace().count();
            if n != *max_new {
                return Err(format!("expected {max_new} tokens, got {n}: {line}"));
            }
        }
        Ok(())
    });
}

/// Interleaving across differently-shaped requests is still deterministic:
/// two identical scheduler runs produce identical reply sets.
#[test]
fn scheduler_runs_are_reproducible() {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    let run = || {
        let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let mut rng = Rng::new(5);
        let mut rxs = Vec::new();
        for i in 0..5usize {
            let (r, rx) = request(prompt_of(&mut rng, 10 + 9 * i, vocab), 4 + 3 * i);
            sched.submit(r);
            rxs.push(rx);
        }
        let mut guard = 0;
        while sched.has_work() {
            assert!(sched.step() > 0);
            guard += 1;
            assert!(guard < 10_000);
        }
        rxs.iter().map(|rx| rx.try_recv().unwrap()).collect::<Vec<String>>()
    };
    assert_eq!(run(), run());
}

/// Acceptance: a disconnect storm must not deny service to live clients.
/// With `max_sessions = 2`, two long generations whose clients vanish
/// mid-flight hold both slots (plus two more abandoned in the waiting
/// queue); after the disconnects are noticed, the slots are reclaimed —
/// well before the abandoned generations would have finished — and three
/// live short requests all complete with streams byte-identical to
/// serial `generate()`.
#[test]
fn disconnect_storm_reclaims_slots_for_live_clients() {
    let engine = Engine::synthetic();
    let spec = SpecDecConfig::default();
    let cfg = ServeConfig { max_sessions: 2, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec.clone(), cfg);

    const DEAD_MAX_NEW: usize = 400;
    // Two abandoned long generations take both slots.
    let mut slot_holders = Vec::new();
    for i in 0..2u32 {
        let prompt: Vec<u32> = (0u32..60).map(|j| (j * 3 + i + 1) % 256).collect();
        let (r, rx) = request(prompt, DEAD_MAX_NEW);
        let (id, reply) = (r.id, r.reply.clone());
        sched.submit(r);
        drop(rx); // the client is gone
        slot_holders.push((id, reply));
    }
    assert!(sched.step() > 0);
    assert_eq!(sched.live_sessions(), 2, "the storm must hold both slots");

    // Two more die while still waiting for a slot.
    for i in 0..2u32 {
        let (r, rx) = request(vec![i + 1, 40, 7, 9], DEAD_MAX_NEW);
        let reply = r.reply.clone();
        sched.submit(r);
        drop(rx);
        reply.mark_dead(); // their conn threads saw EOF before admission
    }

    // Three live clients queue behind the storm.
    let live_reqs: Vec<(Vec<u32>, usize)> = vec![
        (vec![5, 9, 2, 14], 5),
        (vec![7, 3, 200, 41], 6),
        (vec![11, 13, 17, 19, 23], 4),
    ];
    let expected: Vec<String> = live_reqs
        .iter()
        .map(|(p, m)| generate(&engine, p, *m, &spec).unwrap().reply_line())
        .collect();
    let mut live_rxs = Vec::new();
    for (p, m) in &live_reqs {
        let (r, rx) = request(p.clone(), *m);
        sched.submit(r);
        live_rxs.push(rx);
    }

    // The slot-holders' connection threads notice the disconnects and
    // forward cancels (what handle_conn's reply wait does).
    for (id, reply) in &slot_holders {
        reply.mark_dead();
        assert!(sched.cancel(*id), "slot holder was live and must cancel");
    }

    let mut iters = 0usize;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        iters += 1;
        assert!(iters < 10_000, "scheduler failed to drain");
    }

    for (i, (rx, want)) in live_rxs.iter().zip(&expected).enumerate() {
        let got = rx.recv().unwrap();
        assert_eq!(&got, want, "live client {i} diverged under the storm");
    }
    assert_eq!(sched.stats.cancelled, 2, "both slot holders cancelled");
    assert_eq!(sched.stats.reaped, 2, "both dead waiters reaped before admission");
    assert_eq!(sched.stats.finished, live_reqs.len());
    // Slot reclamation must beat the abandoned generations: each would
    // have needed at least DEAD_MAX_NEW / (max_draft + 1) more decode
    // iterations, so finishing the live work sooner than that proves the
    // slots were reclaimed rather than waited out.
    let abandoned_rounds = DEAD_MAX_NEW / (spec.max_draft + 1);
    assert!(
        iters < abandoned_rounds,
        "live work took {iters} iterations — slots were not reclaimed \
         (one abandoned generation alone needs ≥ {abandoned_rounds})"
    );
}

/// Property: randomly interleave submits, cancels, and scheduler steps.
/// No job may ever drive a session admitted after the job was queued —
/// the slot-reuse hazard the epoch stamp closes.  The hazard is fully
/// observable: a stale decode job reaching a fresh prefilling session
/// panics the step machine, and any cross-session drive corrupts a
/// stream — so no-panic plus byte-identity of every surviving reply *is*
/// the assertion.  Cancelled requests must reply `ERR cancelled`, once.
#[test]
fn prop_slot_epoch_identity_under_cancellation_churn() {
    let engine = Engine::synthetic();
    let spec = SpecDecConfig::default();
    let vocab = engine.spec().vocab;
    let mut total_stale = 0u64;
    forall(cases(10), |rng| {
        let cfg = ServeConfig {
            max_sessions: rng.range_usize(1, 3),
            prefill_budget: rng.range_usize(32, 256),
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, spec.clone(), cfg);
        // (id, prompt, max_new, rx, cancelled)
        let mut items: Vec<(u64, Vec<u32>, usize, ReplySink, bool)> = Vec::new();

        // Deterministic seed of the hazard in every case: the first
        // request is admitted (fresh scheduler, free slot), stepped so it
        // has a queued follow-up job, then cancelled while live — the
        // queued job now carries a dead admission's epoch and must be
        // dropped when a later batch pops it.
        let prompt = prompt_of(rng, 30, vocab);
        let (r0, rx0) = request(prompt.clone(), 16);
        let id0 = r0.id;
        sched.submit(r0);
        sched.step();
        if sched.live_sessions() != 1 {
            return Err("seed request was not admitted by the first step".into());
        }
        if !sched.cancel(id0) {
            return Err("live seed request refused cancellation".into());
        }
        items.push((id0, prompt, 16, rx0, true));

        for _ in 0..rng.range_usize(3, 8) {
            let prompt = prompt_of(rng, rng.range_usize(4, 40), vocab);
            let max_new = rng.range_usize(2, 16);
            let (r, rx) = request(prompt.clone(), max_new);
            let id = r.id;
            sched.submit(r);
            items.push((id, prompt, max_new, rx, false));
            for _ in 0..rng.range_usize(0, 3) {
                sched.step();
            }
            if rng.bool(0.5) {
                let k = rng.below(items.len());
                let (id, _, _, _, cancelled) = &mut items[k];
                if !*cancelled && sched.cancel(*id) {
                    *cancelled = true;
                }
            }
        }
        let mut guard = 0usize;
        while sched.has_work() {
            if sched.step() == 0 {
                return Err("scheduler idle with admitted work".into());
            }
            guard += 1;
            if guard > 20_000 {
                return Err("scheduler failed to drain".into());
            }
        }
        total_stale += sched.stats.stale_dropped;
        for (id, prompt, max_new, rx, cancelled) in &items {
            let line = rx.try_recv().map_err(|_| format!("request {id} got no reply"))?;
            if *cancelled {
                if line != "ERR cancelled" {
                    return Err(format!("cancelled request {id} replied {line:?}"));
                }
                if let Ok(extra) = rx.try_recv() {
                    return Err(format!("cancelled request {id} got a second reply {extra:?}"));
                }
            } else {
                let want = generate(&engine, prompt, *max_new, &spec)
                    .map_err(|e| e.to_string())?
                    .reply_line();
                if line != want {
                    return Err(format!(
                        "surviving request {id} diverged under churn: {line:?}"
                    ));
                }
            }
        }
        Ok(())
    });
    assert!(
        total_stale >= 10,
        "every case seeds one live cancel, so every case must drop at \
         least one stale job (saw {total_stale} across 10 cases)"
    );
}

/// Seeded stochastic sessions are token-identical across scheduler
/// interleavings: with temperature > 0, each concurrently-scheduled
/// session's reply must still equal a serial seeded `generate()` run —
/// in the coupled mode *and* in the rejection mode (the scheduler's
/// per-round draft budget formula matches `generate()`'s, so round
/// shapes — and hence rejection-mode draws — line up too).  This proves
/// the sampler RNG is per-session position-keyed, not per-iteration.
#[test]
fn stochastic_sessions_are_token_identical_across_interleavings() {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    for mode in [SampleVerify::Coupled, SampleVerify::Rejection] {
        let spec = SpecDecConfig {
            temperature: 0.8,
            top_p: 0.95,
            rep_penalty: 1.1,
            seed: 77,
            verify_mode: mode,
            ..SpecDecConfig::default()
        };
        let mut rng = Rng::new(21);
        let reqs: Vec<(Vec<u32>, usize)> = (0..4)
            .map(|i| (prompt_of(&mut rng, 12 + 11 * i, vocab), 6 + 4 * i))
            .collect();
        let expected: Vec<String> = reqs
            .iter()
            .map(|(p, m)| generate(&engine, p, *m, &spec).unwrap().reply_line())
            .collect();

        let cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, spec, cfg);
        let mut rxs = Vec::new();
        for (p, m) in &reqs {
            let (r, rx) = request(p.clone(), *m);
            sched.submit(r);
            rxs.push(rx);
        }
        let mut guard = 0;
        while sched.has_work() {
            assert!(sched.step() > 0, "scheduler idle with pending work");
            guard += 1;
            assert!(guard < 20_000, "scheduler failed to drain");
        }
        for (i, (rx, want)) in rxs.iter().zip(&expected).enumerate() {
            let got = rx.recv().unwrap();
            assert_eq!(&got, want, "session {i} ({mode:?}): interleaved stochastic stream diverged");
        }
        assert_eq!(sched.stats.sampler_seed, 77, "STATS seed must mirror the config");
        assert_eq!(
            sched.stats.accept_hist.iter().sum::<u64>() as usize,
            sched.stats.rounds,
            "every verify round must land in the acceptance histogram"
        );
    }
}

/// PR 5's cancellation-churn oracle, under stochastic sampling: randomly
/// interleaved submits, cancels, and steps with temperature > 0 — every
/// surviving reply must equal the serial seeded `generate()` run, and
/// cancelled requests reply `ERR cancelled` exactly once.  Cancel/reap
/// churn frees and re-admits slots, so passing proves sampler state is
/// per-session (position-keyed), surviving slot reuse and epoch churn.
#[test]
fn prop_stochastic_survivors_match_serial_under_cancellation_churn() {
    let engine = Engine::synthetic();
    let spec = SpecDecConfig {
        temperature: 1.0,
        top_p: 0.9,
        rep_penalty: 1.2,
        seed: 5,
        ..SpecDecConfig::default()
    };
    let vocab = engine.spec().vocab;
    forall(cases(6), |rng| {
        let cfg = ServeConfig {
            max_sessions: rng.range_usize(1, 3),
            prefill_budget: rng.range_usize(32, 256),
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, spec.clone(), cfg);
        let mut items: Vec<(u64, Vec<u32>, usize, ReplySink, bool)> = Vec::new();

        // Seed the slot-reuse hazard: admit, step, cancel while live.
        let prompt = prompt_of(rng, 30, vocab);
        let (r0, rx0) = request(prompt.clone(), 16);
        let id0 = r0.id;
        sched.submit(r0);
        sched.step();
        if sched.live_sessions() != 1 {
            return Err("seed request was not admitted by the first step".into());
        }
        if !sched.cancel(id0) {
            return Err("live seed request refused cancellation".into());
        }
        items.push((id0, prompt, 16, rx0, true));

        for _ in 0..rng.range_usize(3, 6) {
            let prompt = prompt_of(rng, rng.range_usize(4, 40), vocab);
            let max_new = rng.range_usize(2, 16);
            let (r, rx) = request(prompt.clone(), max_new);
            let id = r.id;
            sched.submit(r);
            items.push((id, prompt, max_new, rx, false));
            for _ in 0..rng.range_usize(0, 3) {
                sched.step();
            }
            if rng.bool(0.5) {
                let k = rng.below(items.len());
                let (id, _, _, _, cancelled) = &mut items[k];
                if !*cancelled && sched.cancel(*id) {
                    *cancelled = true;
                }
            }
        }
        let mut guard = 0usize;
        while sched.has_work() {
            if sched.step() == 0 {
                return Err("scheduler idle with admitted work".into());
            }
            guard += 1;
            if guard > 20_000 {
                return Err("scheduler failed to drain".into());
            }
        }
        for (id, prompt, max_new, rx, cancelled) in &items {
            let line = rx.try_recv().map_err(|_| format!("request {id} got no reply"))?;
            if *cancelled {
                if line != "ERR cancelled" {
                    return Err(format!("cancelled request {id} replied {line:?}"));
                }
            } else {
                let want = generate(&engine, prompt, *max_new, &spec)
                    .map_err(|e| e.to_string())?
                    .reply_line();
                if line != want {
                    return Err(format!(
                        "surviving stochastic request {id} diverged under churn: {line:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// TCP-level disconnect reaping: a client that drops its connection
/// mid-generation is noticed by its connection thread and the scheduler
/// cancels the session — observable through the STATS `cancelled`
/// counter from a second connection.
#[test]
fn tcp_disconnect_mid_generation_is_cancelled() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), ServeConfig::default(), 2).unwrap();
    });

    // Client 1: start a long generation, then vanish without reading.
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        let prompt: Vec<String> = (0u32..80).map(|i| ((i * 7 + 3) % 256).to_string()).collect();
        writeln!(stream, "GENERATE 400 {}", prompt.join(" ")).unwrap();
        stream.flush().unwrap();
        // Dropping the stream closes the socket: the conn thread's reply
        // wait sees EOF and forwards the cancel.
    }

    // Client 2: poll STATS until the cancellation lands.
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let deadline = clock::now() + Duration::from_secs(30);
    let mut last = String::new();
    loop {
        assert!(
            clock::now() < deadline,
            "disconnect never cancelled the session; last STATS: {last}"
        );
        writeln!(stream, "STATS").unwrap();
        last.clear();
        reader.read_line(&mut last).unwrap();
        assert!(last.starts_with("OK "), "bad STATS reply: {last}");
        if last.contains("cancelled=1") {
            break;
        }
        clock::sleep(Duration::from_millis(20));
    }
    writeln!(stream, "QUIT").unwrap();
    server.join().unwrap();
}

/// The explicit CANCEL verb: pipelined after a long GENERATE, the
/// pending reply arrives as `ERR cancelled` and the connection stays
/// usable for further commands.
#[test]
fn tcp_cancel_verb_aborts_inflight_generation() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), ServeConfig::default(), 1).unwrap();
    });

    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let prompt: Vec<String> = (0u32..80).map(|i| ((i * 5 + 2) % 256).to_string()).collect();
    writeln!(stream, "GENERATE 400 {}", prompt.join(" ")).unwrap();
    writeln!(stream, "CANCEL").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR cancelled", "GENERATE must reply cancelled");
    // The connection is still live after a cancel.
    writeln!(stream, "STATS").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "bad STATS after cancel: {line}");
    assert!(line.contains("cancelled=1"), "STATS missing the cancel: {line}");
    writeln!(stream, "QUIT").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "OK bye");
    server.join().unwrap();
}

/// Reference backend that *panics* (not `Err`s) on every multi-lane
/// `run_batch` — a simulated backend bug on the batched path.
struct PanicBatchBackend(ReferenceBackend);

impl ExecBackend for PanicBatchBackend {
    fn name(&self) -> &'static str {
        "panic-batch-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.0.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.0.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.run(name, inputs)
    }
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> anyhow::Result<Vec<Vec<Tensor>>> {
        if inputs.len() > 1 {
            panic!("injected backend bug: multi-lane batch dies");
        }
        self.0.run_batch(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.0.weight(name)
    }
    fn stats(&self) -> RuntimeStats {
        self.0.stats()
    }
}

/// A backend that panics instead of failing cleanly must not take the
/// scheduler down: the `catch_unwind` firewalls convert the panic into
/// the same degradation path as a batched `Err` — per-lane serial
/// fallback, every stream byte-identical to serial `generate()`, the
/// degradation observable through `ServeStats::fallbacks`.
#[test]
fn panicking_batched_call_degrades_to_serial_not_a_crash() {
    let backend = PanicBatchBackend(ReferenceBackend::synthetic(42));
    let engine =
        Engine::with_registry(ArtifactRegistry::with_backend(Box::new(backend)).unwrap()).unwrap();
    let spec = SpecDecConfig::default();
    let reqs: Vec<(Vec<u32>, usize)> = vec![
        ((0u32..30).map(|i| (i * 3 + 1) % 256).collect(), 10),
        ((0u32..45).map(|i| (i * 5 + 2) % 256).collect(), 8),
        ((0u32..24).map(|i| (i * 7 + 5) % 256).collect(), 12),
    ];
    let clean = Engine::synthetic();
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&clean, p, *m, &spec).unwrap().reply_line())
        .collect();

    let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec, cfg);
    let mut rxs = Vec::new();
    for (p, m) in &reqs {
        let (r, rx) = request(p.clone(), *m);
        sched.submit(r);
        rxs.push(rx);
    }
    let mut guard = 0;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 20_000, "scheduler failed to drain");
    }
    for (i, (rx, want)) in rxs.iter().zip(&expected).enumerate() {
        assert_eq!(&rx.recv().unwrap(), want, "session {i} diverged under panic fallback");
    }
    assert!(sched.stats.fallbacks > 0, "no batched call panicked — firewall not exercised");
    assert_eq!(sched.stats.finished, reqs.len());
    assert_eq!(sched.stats.failed, 0, "a panic leaked into a lane failure");
}

/// Reference backend whose *first* `device_head` execution panics, then
/// behaves normally — a one-shot backend bug striking mid-session.
struct PanicHeadOnceBackend {
    inner: ReferenceBackend,
    armed: Cell<bool>,
}

impl PanicHeadOnceBackend {
    fn trip(&self, name: &str) {
        if name.starts_with("device_head") && self.armed.replace(false) {
            panic!("injected backend bug: first head execution dies");
        }
    }
}

impl ExecBackend for PanicHeadOnceBackend {
    fn name(&self) -> &'static str {
        "panic-head-once-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.inner.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.inner.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.trip(name);
        self.inner.run(name, inputs)
    }
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> anyhow::Result<Vec<Vec<Tensor>>> {
        self.trip(name);
        self.inner.run_batch(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.inner.weight(name)
    }
    fn stats(&self) -> RuntimeStats {
        self.inner.stats()
    }
}

/// A panic inside one lane's session call must fail *that lane alone*:
/// the first session to complete prefill hits the injected head panic
/// (the head runs inside its `prefill_chunk_finish`) and gets an `ERR`
/// reply naming the panic, while both co-scheduled sessions finish with
/// streams byte-identical to serial `generate()` on a clean engine.
#[test]
fn panicking_lane_fails_alone_and_survivors_match_serial() {
    let backend = PanicHeadOnceBackend {
        inner: ReferenceBackend::synthetic(42),
        armed: Cell::new(true),
    };
    let engine =
        Engine::with_registry(ArtifactRegistry::with_backend(Box::new(backend)).unwrap()).unwrap();
    let spec = SpecDecConfig::default();
    // Equal-length prompts: all three prefill chunks land in one bucket
    // group, so lane order is submit order and the injected panic
    // deterministically strikes request 0's final-chunk head call.
    let reqs: Vec<(Vec<u32>, usize)> = (0..3u32)
        .map(|i| ((0u32..10).map(|j| (j * 7 + i + 3) % 256).collect(), 8))
        .collect();
    let clean = Engine::synthetic();
    let expected: Vec<String> = reqs
        .iter()
        .map(|(p, m)| generate(&clean, p, *m, &spec).unwrap().reply_line())
        .collect();

    let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, spec, cfg);
    let mut rxs = Vec::new();
    for (p, m) in &reqs {
        let (r, rx) = request(p.clone(), *m);
        sched.submit(r);
        rxs.push(rx);
    }
    let mut guard = 0;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 20_000, "scheduler failed to drain");
    }
    let replies: Vec<String> = rxs.iter().map(|rx| rx.recv().unwrap()).collect();
    assert!(
        replies[0].starts_with("ERR ") && replies[0].contains("panic"),
        "the panicking lane must fail with a panic-naming ERR, got {:?}",
        replies[0]
    );
    for i in 1..replies.len() {
        assert_eq!(&replies[i], &expected[i], "surviving session {i} diverged");
    }
    assert_eq!(sched.stats.failed, 1, "exactly the panicking lane fails");
    assert_eq!(sched.stats.finished, 2, "both survivors finish");
}

/// Property: preemption churn under `priority = preempt`.  Each case
/// deterministically forces at least one park (full house plus a waiter,
/// stepped until a victim is swapped out), then randomly interleaves
/// admissions — half of them sharing a system-prompt prefix, so parked,
/// resumed *and* CoW-shared sessions coexist — steps, and cancels that can
/// land on running, waiting or parked sessions.  Every survivor's stream
/// must be byte-identical to a serial `generate()` run, cancelled requests
/// reply `ERR cancelled` exactly once, and after the drain the KV pool
/// must quiesce: zero in-use, refcount-stuck or dedup-stuck blocks.
#[test]
fn prop_preemption_churn_preserves_streams_and_quiesces_pool() {
    let engine = Engine::synthetic();
    let spec = SpecDecConfig::default();
    let vocab = engine.spec().vocab;
    let mut total_preempted = 0u64;
    forall(cases(8), |rng| {
        let max_sessions = rng.range_usize(1, 3);
        let cfg = ServeConfig {
            max_sessions,
            prefill_budget: rng.range_usize(32, 256),
            priority: PriorityMode::Preempt,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, spec.clone(), cfg);
        // (id, prompt, max_new, rx, cancelled)
        let mut items: Vec<(u64, Vec<u32>, usize, ReplySink, bool)> = Vec::new();

        // Fill every slot with a long-running generation, queue one more
        // request, and step until the scheduler parks a victim — each case
        // exercises preempt → swap-out → park before the random churn.
        for _ in 0..max_sessions {
            let prompt = prompt_of(rng, rng.range_usize(12, 32), vocab);
            let max_new = rng.range_usize(24, 48);
            let (r, rx) = request(prompt.clone(), max_new);
            items.push((r.id, prompt, max_new, rx, false));
            sched.submit(r);
        }
        {
            let prompt = prompt_of(rng, rng.range_usize(8, 24), vocab);
            let (r, rx) = request(prompt.clone(), 8);
            items.push((r.id, prompt, 8, rx, false));
            sched.submit(r);
        }
        let mut guard = 0usize;
        while sched.stats.preemptions == 0 {
            if sched.step() == 0 {
                return Err("scheduler idle before any preemption".into());
            }
            guard += 1;
            if guard > 5_000 {
                return Err("no preemption despite a full house and a waiter".into());
            }
        }

        let system = prompt_of(rng, rng.range_usize(24, 56), vocab);
        for _ in 0..rng.range_usize(3, 7) {
            let mut prompt = if rng.bool(0.5) {
                system.clone()
            } else {
                prompt_of(rng, rng.range_usize(6, 30), vocab)
            };
            prompt.extend((0..rng.range_usize(2, 8)).map(|_| rng.below(vocab) as u32));
            let max_new = rng.range_usize(2, 12);
            let (r, rx) = request(prompt.clone(), max_new);
            let id = r.id;
            sched.submit(r);
            items.push((id, prompt, max_new, rx, false));
            for _ in 0..rng.range_usize(0, 4) {
                sched.step();
            }
            if rng.bool(0.4) {
                let k = rng.below(items.len());
                let (id, _, _, _, cancelled) = &mut items[k];
                if !*cancelled && sched.cancel(*id) {
                    *cancelled = true;
                }
            }
        }

        let mut guard = 0usize;
        while sched.has_work() {
            if sched.step() == 0 {
                return Err("scheduler idle with admitted work".into());
            }
            guard += 1;
            if guard > 30_000 {
                return Err("scheduler failed to drain".into());
            }
        }
        total_preempted += sched.stats.preemptions;

        for (id, prompt, max_new, rx, cancelled) in &items {
            let line = rx.try_recv().map_err(|_| format!("request {id} got no reply"))?;
            if *cancelled {
                if line != "ERR cancelled" {
                    return Err(format!("cancelled request {id} replied {line:?}"));
                }
                if let Ok(extra) = rx.try_recv() {
                    return Err(format!("cancelled request {id} got a second reply {extra:?}"));
                }
            } else {
                let want = generate(&engine, prompt, *max_new, &spec)
                    .map_err(|e| e.to_string())?
                    .reply_line();
                if line != want {
                    return Err(format!(
                        "request {id} diverged under preemption churn: {line:?}"
                    ));
                }
            }
        }
        if !engine.kv_pool().quiesced() {
            return Err("drained scheduler left pool blocks in use or shared".into());
        }
        Ok(())
    });
    assert!(total_preempted >= 8, "every case must park at least one session");
}

/// Property: prefill/decode pool-seam churn.  Each case draws a random
/// pool shape (1–2 prefill, 1–3 decode slots) and priority mode over one
/// shared KV pool, then interleaves admissions — half sharing a
/// system-prompt prefix — with step bursts and cancels that can land on
/// prefill-resident, seam-pending or decode-resident sessions.  After
/// every step, no request may be resident in both pools at once.  A
/// second stanza reruns a small fleet under a 2 ms deadline so expiry
/// fires in the pools and at the seam.  Every survivor's stream must be
/// byte-identical to serial `generate()`, cancelled requests reply
/// `ERR cancelled` exactly once, deadline casualties reply
/// `ERR deadline`, and the shared pool must quiesce after each drain.
#[test]
fn prop_pd_pool_churn_preserves_streams_and_quiesces_pool() {
    let pf_engine = Engine::synthetic();
    let dc_engine =
        Engine::with_registry_shared(ArtifactRegistry::synthetic(), pf_engine.kv_pool()).unwrap();
    let spec = SpecDecConfig::default();
    let vocab = pf_engine.spec().vocab;
    let mut total_handoffs = 0u64;
    let mut total_preempted = 0u64;
    let mut total_deadline = 0u64;
    forall(cases(8), |rng| {
        let cfg = ServeConfig {
            prefill_workers: rng.range_usize(1, 2),
            decode_workers: rng.range_usize(1, 3),
            prefill_budget: rng.range_usize(32, 256),
            priority: if rng.bool(0.5) { PriorityMode::Preempt } else { PriorityMode::None },
            ..ServeConfig::default()
        };
        let mut sched = PdScheduler::new(&pf_engine, &dc_engine, spec.clone(), cfg)
            .map_err(|e| e.to_string())?;
        // (id, prompt, max_new, rx, cancelled)
        let mut items: Vec<(u64, Vec<u32>, usize, ReplySink, bool)> = Vec::new();

        let system = prompt_of(rng, rng.range_usize(24, 56), vocab);
        for _ in 0..rng.range_usize(6, 12) {
            let mut prompt = if rng.bool(0.5) {
                system.clone()
            } else {
                prompt_of(rng, rng.range_usize(6, 40), vocab)
            };
            prompt.extend((0..rng.range_usize(2, 8)).map(|_| rng.below(vocab) as u32));
            let max_new = rng.range_usize(1, 14);
            let (r, rx) = request(prompt.clone(), max_new);
            let id = r.id;
            sched.submit(r);
            items.push((id, prompt, max_new, rx, false));
            for _ in 0..rng.range_usize(0, 4) {
                sched.step();
                for (id, _, _, _, _) in &items {
                    if sched.in_prefill(*id) && sched.in_decode(*id) {
                        return Err(format!("request {id} resident in both pools"));
                    }
                }
            }
            if rng.bool(0.35) {
                let k = rng.below(items.len());
                let (id, _, _, _, cancelled) = &mut items[k];
                if !*cancelled && sched.cancel(*id) {
                    *cancelled = true;
                }
            }
        }

        let mut guard = 0usize;
        while sched.has_work() {
            if sched.step() == 0 {
                return Err("pd scheduler idle with admitted work".into());
            }
            for (id, _, _, _, _) in &items {
                if sched.in_prefill(*id) && sched.in_decode(*id) {
                    return Err(format!("request {id} resident in both pools during drain"));
                }
            }
            guard += 1;
            if guard > 30_000 {
                return Err("pd scheduler failed to drain".into());
            }
        }
        total_handoffs += sched.handoffs();
        total_preempted += sched.merged_stats().preemptions;

        for (id, prompt, max_new, rx, cancelled) in &items {
            let line = rx.try_recv().map_err(|_| format!("request {id} got no reply"))?;
            if *cancelled {
                if line != "ERR cancelled" {
                    return Err(format!("cancelled request {id} replied {line:?}"));
                }
                if let Ok(extra) = rx.try_recv() {
                    return Err(format!("cancelled request {id} got a second reply {extra:?}"));
                }
            } else {
                let want = generate(&pf_engine, prompt, *max_new, &spec)
                    .map_err(|e| e.to_string())?
                    .reply_line();
                if line != want {
                    return Err(format!("request {id} diverged across the pool seam: {line:?}"));
                }
            }
        }
        if !pf_engine.kv_pool().quiesced() {
            return Err("drained pd scheduler left pool blocks in use or shared".into());
        }

        // Forced-park stanza: two prefill slots handing off into a single
        // decode slot under preempt priority.  The long stream outlives the
        // starvation bound, so the second handoff always meets a full
        // decode pool and must park it — each case exercises
        // handoff → preempt → park → resume deterministically.
        let park_cfg = ServeConfig {
            prefill_workers: 2,
            decode_workers: 1,
            priority: PriorityMode::Preempt,
            ..ServeConfig::default()
        };
        let mut park = PdScheduler::new(&pf_engine, &dc_engine, spec.clone(), park_cfg)
            .map_err(|e| e.to_string())?;
        let long_prompt = prompt_of(rng, 16, vocab);
        let short_prompt = prompt_of(rng, 16, vocab);
        let (r_long, rx_long) = request(long_prompt.clone(), 64);
        let (r_short, rx_short) = request(short_prompt.clone(), 8);
        park.submit(r_long);
        park.submit(r_short);
        let mut guard = 0usize;
        while park.merged_stats().preemptions == 0 {
            if park.step() == 0 {
                return Err("park stanza idle before any preemption".into());
            }
            guard += 1;
            if guard > 5_000 {
                return Err("two handoffs into one decode slot never parked a victim".into());
            }
        }
        total_preempted += 1;
        let mut guard = 0usize;
        while park.has_work() {
            if park.step() == 0 {
                return Err("park stanza idle with admitted work".into());
            }
            guard += 1;
            if guard > 30_000 {
                return Err("park stanza failed to drain".into());
            }
        }
        for (prompt, max_new, rx) in
            [(&long_prompt, 64usize, &rx_long), (&short_prompt, 8usize, &rx_short)]
        {
            let want = generate(&pf_engine, prompt, max_new, &spec)
                .map_err(|e| e.to_string())?
                .reply_line();
            let line = rx.try_recv().map_err(|_| "park stanza request got no reply".to_string())?;
            if line != want {
                return Err(format!("parked/resumed stream diverged: {line:?}"));
            }
        }
        if !pf_engine.kv_pool().quiesced() {
            return Err("park stanza left pool blocks in use or shared".into());
        }

        // Deadline stanza: a fresh pool pair under a 2 ms deadline.  One
        // stream is stepped live into the decode pool, the rest queue at
        // admission or the seam; sleeping past the deadline must expire
        // whatever has not finished, wherever it is resident.
        let dl_cfg = ServeConfig {
            prefill_workers: 1,
            decode_workers: 1,
            deadline_ms: 2,
            ..ServeConfig::default()
        };
        let mut dl = PdScheduler::new(&pf_engine, &dc_engine, spec.clone(), dl_cfg)
            .map_err(|e| e.to_string())?;
        let mut dl_items: Vec<(u64, Vec<u32>, usize, ReplySink)> = Vec::new();
        {
            let prompt = prompt_of(rng, rng.range_usize(12, 32), vocab);
            let (r, rx) = request(prompt.clone(), 48);
            dl_items.push((r.id, prompt, 48, rx));
            dl.submit(r);
        }
        dl.step();
        dl.step();
        for _ in 0..2 {
            let prompt = prompt_of(rng, rng.range_usize(6, 20), vocab);
            let max_new = rng.range_usize(2, 8);
            let (r, rx) = request(prompt.clone(), max_new);
            dl_items.push((r.id, prompt, max_new, rx));
            dl.submit(r);
        }
        clock::sleep(Duration::from_millis(6));
        let mut guard = 0usize;
        while dl.has_work() {
            dl.step();
            guard += 1;
            if guard > 30_000 {
                return Err("deadline pools failed to drain".into());
            }
        }
        for (id, prompt, max_new, rx) in &dl_items {
            let line = rx.try_recv().map_err(|_| format!("deadline request {id} got no reply"))?;
            if line == "ERR deadline" {
                total_deadline += 1;
            } else {
                let want = generate(&pf_engine, prompt, *max_new, &spec)
                    .map_err(|e| e.to_string())?
                    .reply_line();
                if line != want {
                    return Err(format!("deadline survivor {id} diverged: {line:?}"));
                }
            }
        }
        if !pf_engine.kv_pool().quiesced() {
            return Err("deadline-drained pools left blocks in use or shared".into());
        }
        Ok(())
    });
    assert!(total_handoffs >= 8, "every case must cross the pool seam");
    assert!(total_preempted >= 8, "every case's park stanza must park a victim");
    assert!(total_deadline >= 8, "the 48-token stream must outlive a 2 ms deadline in every case");
}

/// A sender that never terminates its line must be rejected while the
/// line is still arriving — the incremental [`MAX_LINE_BYTES`] frame cap
/// — with `ERR line too long`, after which the connection is closed.
/// The client holds its socket open throughout: termination must come
/// from the server, not from the client giving up.
#[test]
fn oversized_line_is_rejected_incrementally_and_conn_closed() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), ServeConfig::default(), 1).unwrap();
    });
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // One byte past the cap, no newline ever: the reject must fire on
    // byte count alone, mid-line.
    let payload = vec![b'7'; MAX_LINE_BYTES + 1];
    stream.write_all(&payload).unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR line too long");
    line.clear();
    let n = reader.read_line(&mut line).unwrap();
    assert_eq!(n, 0, "server must close after rejecting the oversized line, got {line:?}");
    server.join().unwrap();
}

/// Admission shedding: with `admit_queue = 1` and a single session slot,
/// a GENERATE arriving while another request is already queued is
/// refused with `ERR busy` and counted in `shed_busy` — the queue never
/// grows past the configured bound.
#[test]
fn generate_is_shed_with_err_busy_when_admit_queue_full() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { max_sessions: 1, admit_queue: 1, ..ServeConfig::default() };
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), cfg, 3).unwrap();
    });

    // A: a long generation that holds the single slot.
    let mut a = TcpStream::connect(addr).unwrap();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    let prompt: Vec<String> = (0u32..80).map(|i| ((i * 3 + 5) % 256).to_string()).collect();
    writeln!(a, "GENERATE 400 {}", prompt.join(" ")).unwrap();

    // B: queues behind A.
    let mut b = TcpStream::connect(addr).unwrap();
    let mut b_reader = BufReader::new(b.try_clone().unwrap());
    writeln!(b, "GENERATE 3 5 9 2 14").unwrap();

    // C: wait until B is visibly queued, then a GENERATE must shed.
    let mut c = TcpStream::connect(addr).unwrap();
    let mut c_reader = BufReader::new(c.try_clone().unwrap());
    let deadline = clock::now() + Duration::from_secs(30);
    let mut line = String::new();
    loop {
        assert!(clock::now() < deadline, "B never showed up queued; last STATS: {line}");
        writeln!(c, "STATS").unwrap();
        line.clear();
        c_reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "bad STATS reply: {line}");
        if line.contains(" queued=1 ") {
            break;
        }
        clock::sleep(Duration::from_millis(5));
    }
    writeln!(c, "GENERATE 2 7 7").unwrap();
    line.clear();
    c_reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR busy");
    writeln!(c, "STATS").unwrap();
    line.clear();
    c_reader.read_line(&mut line).unwrap();
    assert!(line.contains("shed_busy=1"), "STATS missing the shed: {line}");
    writeln!(c, "QUIT").unwrap();

    // Unwind: cancel A; B's queued request then takes the slot and
    // completes normally — shedding never touched admitted work.
    writeln!(a, "CANCEL").unwrap();
    line.clear();
    a_reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR cancelled");
    writeln!(a, "QUIT").unwrap();
    line.clear();
    b_reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "B's queued GENERATE must finish: {line}");
    writeln!(b, "QUIT").unwrap();
    drop((a, b, c));
    server.join().unwrap();
}

/// Per-client rate limiting: with a one-token bucket and a refill rate
/// slow enough to add nothing inside the test window, the second
/// GENERATE on a connection is refused with `ERR rate limited` and
/// counted in `rate_limited`.  STATS is never limited.
#[test]
fn second_generate_is_rate_limited_with_one_token_bucket() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { rate_limit_rps: 0.0001, burst: 1, ..ServeConfig::default() };
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), cfg, 1).unwrap();
    });
    let mut stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "GENERATE 2 5 9 2 14").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "first GENERATE must pass the bucket: {line}");
    writeln!(stream, "GENERATE 2 5 9 2 14").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "ERR rate limited");
    writeln!(stream, "STATS").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("rate_limited=1"), "STATS missing the refusal: {line}");
    writeln!(stream, "QUIT").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "OK bye");
    server.join().unwrap();
}

/// A reader that stops draining its socket is dropped once its reply
/// outbox crosses `serve.outbox_lines` — the loop never stalls behind
/// it, and the drop is visible to a live client as `slow_reader_dropped`
/// while that client keeps getting served.
#[test]
fn slow_reader_is_dropped_and_loop_stays_live() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { outbox_lines: 4, ..ServeConfig::default() };
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), cfg, 2).unwrap();
    });

    // The slow reader: flood STATS without ever reading a byte back.
    // Replies fill the kernel buffers, then the bounded outbox, then the
    // server drops the connection (a later write here errors out).
    let mut slow = TcpStream::connect(addr).unwrap();
    let burst = "STATS\n".repeat(64);
    for _ in 0..3_200 {
        if slow.write_all(burst.as_bytes()).is_err() {
            break;
        }
    }

    // A live client observes the drop and stays served throughout.
    let mut live = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(live.try_clone().unwrap());
    let deadline = clock::now() + Duration::from_secs(30);
    let mut line = String::new();
    loop {
        assert!(clock::now() < deadline, "slow reader never dropped; last STATS: {line}");
        writeln!(live, "STATS").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "bad STATS reply: {line}");
        if line.contains("slow_reader_dropped=1") {
            break;
        }
        clock::sleep(Duration::from_millis(5));
    }
    writeln!(live, "QUIT").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line.trim_end(), "OK bye");
    drop(slow);
    server.join().unwrap();
}

/// A slowloris — connected, dribbling bytes of a never-terminated line —
/// must not inflate a live client's time-between-replies: the event loop
/// charges it one non-blocking read per pass and nothing more, so three
/// short generations beside it finish in bounded wall time.
#[test]
fn slowloris_does_not_stall_live_clients() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), ServeConfig::default(), 2).unwrap();
    });

    let stop = Arc::new(AtomicBool::new(false));
    let loris_stop = stop.clone();
    let loris = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        while !loris_stop.load(Ordering::Relaxed) {
            if s.write_all(b"G").is_err() {
                break;
            }
            clock::sleep(Duration::from_millis(2));
        }
    });

    let mut live = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(live.try_clone().unwrap());
    let t0 = clock::now();
    for i in 0..3u32 {
        writeln!(live, "GENERATE 4 {} 9 2 14", i + 5).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "live client starved beside the slowloris: {line}");
    }
    let elapsed = clock::now().saturating_duration_since(t0);
    assert!(
        elapsed < Duration::from_secs(10),
        "three 4-token generations took {elapsed:?} beside a slowloris"
    );
    writeln!(live, "QUIT").unwrap();
    stop.store(true, Ordering::Relaxed);
    loris.join().unwrap();
    server.join().unwrap();
}

/// Scaled-down churn storm (the 10k-connection version lives in the
/// `serve_churn` bench): a few hundred connections from parallel driver
/// threads — a third vanish before sending anything, a third complete a
/// short generation, a third abandon a long one mid-flight — must all be
/// absorbed with every live request served, and the loop must exit once
/// the accept budget is consumed.
#[test]
fn connection_storm_completes_and_loop_exits() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 64;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { max_sessions: 8, ..ServeConfig::default() };
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let r = serve_listener(listener, SpecDecConfig::default(), cfg, THREADS * PER_THREAD);
        let _ = done_tx.send(r);
    });

    let drivers: Vec<_> = (0..THREADS)
        .map(|t| {
            std::thread::spawn(move || {
                let mut completed = 0usize;
                for i in 0..PER_THREAD {
                    match i % 3 {
                        // Vanish before sending anything.
                        0 => drop(TcpStream::connect(addr).unwrap()),
                        // Complete a short generation end to end.
                        1 => {
                            let mut s = TcpStream::connect(addr).unwrap();
                            let mut r = BufReader::new(s.try_clone().unwrap());
                            writeln!(s, "GENERATE 2 {} {} 3 1", t + 1, i + 1).unwrap();
                            let mut line = String::new();
                            r.read_line(&mut line).unwrap();
                            assert!(line.starts_with("OK "), "storm request failed: {line}");
                            completed += 1;
                            writeln!(s, "QUIT").unwrap();
                        }
                        // Abandon a long generation mid-flight.
                        _ => {
                            let mut s = TcpStream::connect(addr).unwrap();
                            writeln!(s, "GENERATE 300 {} 7 5 3 2", t + 1).unwrap();
                        }
                    }
                }
                completed
            })
        })
        .collect();
    let completed: usize = drivers.into_iter().map(|d| d.join().unwrap()).sum();
    let live_per_thread = (0..PER_THREAD).filter(|i| i % 3 == 1).count();
    assert_eq!(completed, THREADS * live_per_thread, "every live storm request must complete");
    done_rx
        .recv_timeout(Duration::from_secs(120))
        .expect("serve loop did not exit after the storm consumed its accept budget")
        .unwrap();
}
