//! Cross-module integration tests: fleet-simulator invariants across all
//! frameworks and operating points, config-file round trips, SD-profile
//! plumbing, and failure injection.

use hat::config::{parser, Dataset, ExperimentConfig, Framework};
use hat::frameworks::run_experiment;
use hat::metrics::Recorder;
use hat::specdec::profile::SdProfile;
use hat::util::proptest::{cases, forall};

fn run(cfg: &ExperimentConfig) -> Recorder {
    run_experiment(cfg, &SdProfile::default_table())
}

#[test]
fn prop_fleet_invariants_hold_across_random_configs() {
    // For random (framework, dataset, rate, P, strategy flags): every
    // request finishes with exactly max_new_tokens, token times are
    // monotone, TTFT > 0, and per-GPU delays are positive.
    forall(cases(25), |rng| {
        let fw = *rng.choice(&Framework::all());
        let ds = *rng.choice(&[Dataset::SpecBench, Dataset::CnnDm]);
        let mut cfg = ExperimentConfig::preset(fw, ds);
        cfg.seed = rng.next_u64();
        cfg.workload.rate = rng.range_f64(1.0, 10.0);
        cfg.workload.n_requests = rng.range_usize(10, 60);
        cfg.workload.max_new_tokens = rng.range_usize(12, 64);
        cfg.cloud.pipeline_len = rng.range_usize(1, 8);
        if rng.bool(0.3) {
            cfg.strategies.pd = false;
        }
        if rng.bool(0.2) {
            cfg.strategies.sd = false;
        }
        let rec = run(&cfg);
        if rec.finished_requests().count() != cfg.workload.n_requests {
            return Err(format!(
                "{}: {} of {} finished",
                fw.name(),
                rec.finished_requests().count(),
                cfg.workload.n_requests
            ));
        }
        for r in rec.finished_requests() {
            if r.tokens_generated() < cfg.workload.max_new_tokens {
                return Err(format!("request {} short: {}", r.id, r.tokens_generated()));
            }
            let ts = &r.token_times;
            if ts.windows(2).any(|w| w[1] < w[0]) {
                return Err("token times not monotone".into());
            }
            if r.ttft_ms().unwrap() <= 0.0 {
                return Err("non-positive TTFT".into());
            }
            if r.first_token.unwrap() < r.arrived {
                return Err("first token before arrival".into());
            }
        }
        if rec.gpu_step_delays.iter().any(|&d| d <= 0.0) {
            return Err("non-positive gpu step delay".into());
        }
        Ok(())
    });
}

#[test]
fn virtual_time_is_causally_consistent_with_load() {
    // Tripling the arrival rate must not *reduce* mean TTFT (queueing).
    let mut lo = ExperimentConfig::preset(Framework::UShape, Dataset::SpecBench);
    lo.workload.n_requests = 150;
    lo.workload.rate = 3.0;
    let mut hi = lo.clone();
    hi.workload.rate = 9.0;
    let s_lo = run(&lo).summary();
    let s_hi = run(&hi).summary();
    assert!(
        s_hi.ttft_mean_ms >= s_lo.ttft_mean_ms * 0.95,
        "rate 9 TTFT {} < rate 3 TTFT {}",
        s_hi.ttft_mean_ms,
        s_lo.ttft_mean_ms
    );
}

#[test]
fn sd_profile_accept_length_feeds_through_metrics() {
    let profile = SdProfile::default_table();
    let expected = SdProfile::accept_length(&profile.hat);
    let mut cfg = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
    cfg.workload.n_requests = 120;
    let rec = run_experiment(&cfg, &profile);
    let measured = rec.accept_length();
    assert!(
        (measured - expected).abs() < 0.35,
        "sim accept {measured:.2} vs profile {expected:.2}"
    );
}

#[test]
fn config_file_round_trip_drives_experiment() {
    let toml = r#"
framework = "usarathi"
dataset = "cnndm"
seed = 7
[workload]
rate = 2.5
n_requests = 25
max_new_tokens = 16
[cloud]
pipeline_len = 2
"#;
    let map = parser::parse(toml).unwrap();
    let cfg = parser::build(&map).unwrap();
    assert_eq!(cfg.framework, Framework::USarathi);
    assert_eq!(cfg.strategies.server_chunk, Some(256));
    let rec = run(&cfg);
    assert_eq!(rec.finished_requests().count(), 25);
}

#[test]
fn ablation_flags_change_behaviour() {
    // PC on vs off must change the chunk-size trace; SD off must force
    // accept length to exactly 1.
    let mut base = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
    base.workload.n_requests = 60;
    let with_pc = run(&base);
    assert!(!with_pc.chunk_sizes.is_empty());

    let mut no_pc = base.clone();
    no_pc.strategies.pc = false;
    let r = run(&no_pc);
    assert!(r.chunk_sizes.is_empty(), "chunk optimizer ran with PC off");

    let mut no_sd = base.clone();
    no_sd.strategies.sd = false;
    let r = run(&no_sd);
    assert!((r.accept_length() - 1.0).abs() < 1e-9, "accept {}", r.accept_length());
}

#[test]
fn failure_injection_bad_configs_are_rejected() {
    for bad in [
        "workload.rate = 0\n",
        "[cloud]\npipeline_len = 0\n",
        "[specdec]\neta = 1.5\n",
        "[specdec]\ntemperature = -1\n",
        "[specdec]\ntop_p = 0\n",
        "[specdec]\nrep_penalty = 0\n",
        "[workload]\nmin_prompt = 100\nmax_prompt = 10\n",
        "unknown_key = 1\n",
    ] {
        let map = parser::parse(bad).unwrap();
        assert!(parser::build(&map).is_err(), "accepted bad config: {bad}");
    }
}

#[test]
fn medusa_framework_uses_tree_verification_cost() {
    // U-Medusa verify jobs carry the tree size (8 tokens), visible as a
    // higher mean per-GPU delay than U-shape's single-token decodes under
    // identical workload.
    let mut um = ExperimentConfig::preset(Framework::UMedusa, Dataset::SpecBench);
    um.workload.n_requests = 100;
    let mut us = um.clone();
    us.framework = Framework::UShape;
    us.strategies = hat::config::Strategies::for_framework(Framework::UShape, Dataset::SpecBench);
    let (m_mean, _) = run(&um).gpu_delay_stats();
    let (s_mean, _) = run(&us).gpu_delay_stats();
    assert!(m_mean > s_mean, "medusa {m_mean} !> ushape {s_mean}");
}

#[test]
fn seeds_isolate_experiments() {
    let mut a = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
    a.workload.n_requests = 50;
    let mut b = a.clone();
    b.seed = 43;
    let sa = run(&a).summary();
    let sb = run(&b).summary();
    assert_ne!(sa.ttft_mean_ms, sb.ttft_mean_ms, "different seeds, same trace?");
}
