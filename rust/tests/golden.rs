//! Cross-language golden tests: the rust engine (PJRT artifacts, cached KV,
//! bucket padding, chunked prefill, speculative decoding with rollback)
//! must reproduce the token streams computed by the JAX model in
//! training-form full-sequence forward (python/compile/aot.py →
//! artifacts/golden.json).
//!
//! These tests prove, end to end:
//! - the AOT HLO round-trip is numerically faithful;
//! - the cached/chunked inference path equals the full forward;
//! - speculative decoding (HAT rounds), U-shape decode and U-Medusa rounds
//!   are all *lossless* under greedy decoding;
//! - KV rollback of rejected draft tokens never corrupts the stream.
//!
//! Every session here uses `SpecDecConfig::default()` — temperature 0 —
//! so the stochastic-sampling machinery is provably inert on this path
//! (`Sampler::greedy()` short-circuits to the original argmax code).
//! The seeded-sampling losslessness oracles live in
//! tests/sampling_stats.rs against the reference backend.

use std::path::PathBuf;

use hat::config::SpecDecConfig;
use hat::engine::Engine;
use hat::specdec::{chunk_sizes, Session};
use hat::util::json;

struct Golden {
    prompt: Vec<u32>,
    full_greedy: Vec<u32>,
    draft_greedy: Vec<u32>,
}

fn artifacts() -> Option<PathBuf> {
    // The golden streams were recorded from the *trained* model: they are
    // only reproducible on the real PJRT backend.  The default reference
    // backend executes seeded pseudo-weights and would trivially diverge.
    if !cfg!(feature = "pjrt") || std::env::var("HAT_BACKEND").as_deref() != Ok("pjrt") {
        eprintln!("skipping: golden tests need --features pjrt and HAT_BACKEND=pjrt");
        return None;
    }
    let d = hat::runtime::ArtifactRegistry::default_dir();
    d.join("golden.json").exists().then_some(d)
}

fn load_golden(dir: &PathBuf) -> Golden {
    let text = std::fs::read_to_string(dir.join("golden.json")).unwrap();
    let v = json::parse(&text).unwrap();
    let toks = |key: &str| -> Vec<u32> {
        v.get(key)
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect()
    };
    Golden { prompt: toks("prompt"), full_greedy: toks("full_greedy"), draft_greedy: toks("draft_greedy") }
}

fn engine(dir: &PathBuf) -> Engine {
    Engine::load(dir).unwrap()
}

/// Run a HAT session until >= n tokens generated; returns generated tokens.
fn run_hat(e: &Engine, prompt: &[u32], chunks: &[usize], pd: bool, n: usize) -> Vec<u32> {
    let mut s = Session::new(e, SpecDecConfig::default()).unwrap();
    let t1 = s.prefill(prompt, chunks).unwrap();
    let mut out = vec![t1];
    while out.len() < n {
        let r = s.hat_round(pd, 4).unwrap();
        out.extend_from_slice(&r.emitted);
    }
    out.truncate(n);
    out
}

#[test]
fn hat_rounds_reproduce_full_greedy() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = load_golden(&dir);
    let e = engine(&dir);
    let n = g.full_greedy.len();
    let out = run_hat(&e, &g.prompt, &[g.prompt.len()], false, n);
    assert_eq!(out, g.full_greedy, "HAT (single-chunk prefill) diverged from full greedy");
}

#[test]
fn hat_is_lossless_under_chunked_prefill() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = load_golden(&dir);
    let e = engine(&dir);
    let n = g.full_greedy.len();
    for chunk in [8usize, 16, 13] {
        let chunks = chunk_sizes(g.prompt.len(), chunk);
        let out = run_hat(&e, &g.prompt, &chunks, false, n);
        assert_eq!(out, g.full_greedy, "chunk size {chunk} changed the output");
    }
}

#[test]
fn hat_parallel_drafting_is_lossless() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = load_golden(&dir);
    let e = engine(&dir);
    let n = g.full_greedy.len();
    let out = run_hat(&e, &g.prompt, &[g.prompt.len()], true, n);
    assert_eq!(out, g.full_greedy, "parallel drafting changed the output");
}

#[test]
fn ushape_reproduces_full_greedy() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = load_golden(&dir);
    let e = engine(&dir);
    let mut s = Session::new(&e, SpecDecConfig::default()).unwrap();
    let t1 = s.prefill(&g.prompt, &[g.prompt.len()]).unwrap();
    let mut out = vec![t1];
    while out.len() < g.full_greedy.len() {
        out.push(s.ushape_step().unwrap());
    }
    assert_eq!(out, g.full_greedy, "U-shape decode diverged");
}

#[test]
fn medusa_rounds_reproduce_full_greedy() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = load_golden(&dir);
    let e = engine(&dir);
    let mut s = Session::new(&e, SpecDecConfig::default()).unwrap();
    let t1 = s.prefill(&g.prompt, &[g.prompt.len()]).unwrap();
    let mut out = vec![t1];
    while out.len() < g.full_greedy.len() {
        let r = s.medusa_round().unwrap();
        out.extend_from_slice(&r.emitted);
    }
    out.truncate(g.full_greedy.len());
    assert_eq!(out, g.full_greedy, "U-Medusa decode diverged");
}

#[test]
fn draft_model_matches_python_draft_greedy() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = load_golden(&dir);
    let e = engine(&dir);
    let mut s = Session::new(&e, SpecDecConfig::default()).unwrap();
    // Prefill fills shallow+adapter KV; then drive the draft model alone.
    s.prefill(&g.prompt, &[g.prompt.len()]).unwrap();
    // The draft model's own greedy continuation starts from the prompt's
    // last token? No: python drafted from the full prompt context, token
    // by token, appending its own outputs.  Mirror that: first draft input
    // is the prompt's last token... python's draft_train_forward(ctx)[-1]
    // predicts the token after ctx — its first output corresponds to
    // processing the last prompt token.  Here the prompt is already in the
    // KV, so we must NOT reprocess it; instead each draft step processes
    // the previously drafted token.  python ctx starts as prompt, so its
    // first draft output is the draft model's t1 given the prompt — which
    // for the cached path is the logits of draft_step on the last prompt
    // token.  But that token is already in the KV.  To align, python's
    // golden drafted with the *full* prompt; the cached equivalent is:
    // rebuild a fresh session and prefill with prompt[..len-1], then step
    // from prompt[len-1].
    let mut s2 = Session::new(&e, SpecDecConfig::default()).unwrap();
    let p = &g.prompt[..g.prompt.len() - 1];
    s2.prefill(p, &[p.len()]).unwrap();
    drop(s);
    // Drive draft steps directly through the engine on s2's device state.
    let mut cur = *g.prompt.last().unwrap();
    let mut out = Vec::new();
    for _ in 0..g.draft_greedy.len() {
        let o = e.draft_step(&mut s2.dev, cur).unwrap();
        cur = hat::engine::Engine::argmax(&o.logits);
        out.push(cur);
    }
    assert_eq!(out, g.draft_greedy, "draft model diverged from python");
}
