//! Backend-seam integration tests: the full protocol stack — chunked
//! prefill, HAT speculative-decoding rounds with parallel drafting,
//! U-shape decode, U-Medusa rounds, profile measurement and the
//! four-framework fleet simulation — running end-to-end against the
//! deterministic reference backend, with **zero** artifacts on disk and
//! no accelerator libraries.
//!
//! The headline assertions are bit-identity: two same-seed runs of any
//! layer must produce identical token streams and identical metrics.

use hat::backend::reference::ReferenceBackend;
use hat::backend::{ExecBackend, RuntimeStats, Tensor};
use hat::config::{Dataset, ExperimentConfig, Framework, SpecDecConfig};
use hat::engine::Engine;
use hat::frameworks::run_experiment;
use hat::runtime::Manifest;
use hat::specdec::profile::SdProfile;
use hat::specdec::{chunk_sizes, Session};
use hat::workload::PromptPool;

fn prompt(len: usize, seed: u64) -> Vec<u32> {
    let pool = PromptPool::synthetic(256, 4, 160, seed);
    let mut rng = hat::util::rng::Rng::new(seed);
    pool.sample(len, &mut rng)
}

/// Generate `n` tokens through HAT rounds; returns the full context.
fn run_hat_session(e: &Engine, p: &[u32], chunk: usize, pd: bool, n: usize) -> Vec<u32> {
    let mut s = Session::new(e, SpecDecConfig::default()).unwrap();
    let chunks = chunk_sizes(p.len(), chunk);
    s.prefill(p, &chunks).unwrap();
    while s.generated() < n {
        let r = s.hat_round(pd, 4).unwrap();
        assert!(!r.emitted.is_empty());
        assert!(r.accepted <= r.proposed.len());
        assert_eq!(r.emitted.len(), r.accepted + 1);
        assert_eq!(r.verify_tokens, r.proposed.len() + 1);
    }
    s.ctx.clone()
}

/// A reference backend stripped of its `run_batch` override: delegates
/// everything, so batch calls fall back to the trait's loop-over-`run`
/// default — the path the PJRT backend takes.
struct LoopBackend(ReferenceBackend);

impl ExecBackend for LoopBackend {
    fn name(&self) -> &'static str {
        "loop-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.0.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.0.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.run(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.0.weight(name)
    }
    fn stats(&self) -> RuntimeStats {
        self.0.stats()
    }
    // No run_batch override: the default loop impl applies.
}

/// Reference backend with switchable fault injection by artifact-kind
/// prefix — exercises the error-recovery contracts (staged-round
/// abandonment, KV write-head rollback) that only failing cloud calls can
/// reach.
struct FlakyBackend {
    inner: ReferenceBackend,
    fail_cloud: std::rc::Rc<std::cell::Cell<bool>>,
    fail_head: std::rc::Rc<std::cell::Cell<bool>>,
}

impl FlakyBackend {
    fn check(&self, name: &str) -> anyhow::Result<()> {
        if self.fail_cloud.get() && name.starts_with("cloud_middle") {
            anyhow::bail!("injected cloud_middle failure");
        }
        if self.fail_head.get() && name.starts_with("device_head") {
            anyhow::bail!("injected device_head failure");
        }
        Ok(())
    }
}

impl ExecBackend for FlakyBackend {
    fn name(&self) -> &'static str {
        "flaky-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.inner.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.inner.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.check(name)?;
        self.inner.run(name, inputs)
    }
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> anyhow::Result<Vec<Vec<Tensor>>> {
        self.check(name)?;
        self.inner.run_batch(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.inner.weight(name)
    }
    fn stats(&self) -> hat::backend::RuntimeStats {
        self.inner.stats()
    }
}

#[test]
fn failed_rounds_roll_back_and_the_session_recovers() {
    // A round that dies at the middle stage (nothing mutated) or at the
    // head stage (middle already advanced the cloud stream — verify_batch
    // must roll it back) leaves the session re-drivable, and the recovered
    // stream is bit-identical to an uninterrupted run.
    use std::cell::Cell;
    use std::rc::Rc;

    let fail_cloud = Rc::new(Cell::new(false));
    let fail_head = Rc::new(Cell::new(false));
    let flaky = FlakyBackend {
        inner: ReferenceBackend::synthetic(42),
        fail_cloud: fail_cloud.clone(),
        fail_head: fail_head.clone(),
    };
    let engine =
        Engine::with_registry(hat::runtime::ArtifactRegistry::with_backend(Box::new(flaky)).unwrap())
            .unwrap();

    let cfg = SpecDecConfig::default();
    let prompt = [5u32, 9, 2, 14];

    // Uninterrupted reference run (same seed → same model).
    let clean_engine = Engine::synthetic();
    let mut clean = Session::new(&clean_engine, cfg.clone()).unwrap();
    clean.prefill(&prompt, &[prompt.len()]).unwrap();
    let mut expect = Vec::new();
    for _ in 0..4 {
        expect.extend(clean.hat_round(true, cfg.max_draft).unwrap().emitted);
    }

    let mut s = Session::new(&engine, cfg.clone()).unwrap();
    s.prefill(&prompt, &[prompt.len()]).unwrap();
    // Round dies at the middle stage.
    fail_cloud.set(true);
    assert!(s.hat_round(true, cfg.max_draft).is_err());
    fail_cloud.set(false);
    // Round dies at the head stage, after the middle advanced the stream.
    fail_head.set(true);
    assert!(s.hat_round(true, cfg.max_draft).is_err());
    fail_head.set(false);
    // Fully recovered: the stream continues exactly as if nothing failed.
    let mut got = Vec::new();
    for _ in 0..4 {
        got.extend(s.hat_round(true, cfg.max_draft).unwrap().emitted);
    }
    assert_eq!(got, expect, "recovered session diverged after failed rounds");

    // The prefill wrapper's recovery paths: a chunk that dies at the
    // middle stage, and a *final* chunk whose head call dies (the chunk
    // commits nothing and re-drives from scratch), both recover to a
    // stream identical to a clean prefill.
    let mut p = Session::new(&engine, cfg.clone()).unwrap();
    p.prefill_begin(&prompt).unwrap();
    fail_cloud.set(true);
    assert!(p.prefill_step(2).is_err());
    fail_cloud.set(false);
    assert_eq!(p.prefill_remaining(), prompt.len(), "failed chunk must not consume tokens");
    assert!(p.prefill_step(2).unwrap().is_none());
    fail_head.set(true);
    assert!(p.prefill_step(2).is_err(), "final chunk's head must fail");
    fail_head.set(false);
    assert_eq!(p.prefill_remaining(), 2, "failed final chunk must not consume tokens");
    let first = p.prefill_step(2).unwrap();
    let mut q = Session::new(&clean_engine, cfg).unwrap();
    let t1 = q.prefill(&prompt, &[2, 2]).unwrap();
    assert_eq!(first, Some(t1), "recovered prefill diverged");
}

#[test]
fn failed_prefill_chunks_leak_no_pool_blocks() {
    // A chunk that dies mid-flight must leave the committed prefix where
    // it was and must not leak staged KV rows: retrying the same failed
    // chunk never grows the pool census (abandoned rows sit past the
    // committed prefix in table-owned blocks and are overwritten on the
    // re-drive), the recovered stream is bit-identical to a clean prefill,
    // and every block returns to the free list when the session drops.
    use std::cell::Cell;
    use std::rc::Rc;

    let fail_cloud = Rc::new(Cell::new(false));
    let fail_head = Rc::new(Cell::new(false));
    let flaky = FlakyBackend {
        inner: ReferenceBackend::synthetic(42),
        fail_cloud: fail_cloud.clone(),
        fail_head: fail_head.clone(),
    };
    let engine =
        Engine::with_registry(hat::runtime::ArtifactRegistry::with_backend(Box::new(flaky)).unwrap())
            .unwrap();

    let p = prompt(40, 21);
    let first = {
        let mut s = Session::new(&engine, SpecDecConfig::default()).unwrap();
        s.prefill_begin(&p).unwrap();
        assert!(s.prefill_step(16).unwrap().is_none());

        // Two consecutive failures of the same chunk: the census after each
        // must agree — a retry reuses the staged rows' blocks, it does not
        // allocate fresh ones on top.
        fail_cloud.set(true);
        assert!(s.prefill_step(16).is_err());
        let census = engine.kv_pool().stats().blocks_in_use;
        assert!(s.prefill_step(16).is_err());
        fail_cloud.set(false);
        assert_eq!(
            engine.kv_pool().stats().blocks_in_use,
            census,
            "retrying a failed chunk leaked staged KV blocks"
        );
        assert_eq!(s.prefill_remaining(), p.len() - 16, "failed chunks consumed tokens");

        // Same invariant when the *final* chunk dies at the head stage,
        // after the middle already advanced the cloud stream.
        assert!(s.prefill_step(16).unwrap().is_none());
        fail_head.set(true);
        assert!(s.prefill_step(16).is_err());
        let census = engine.kv_pool().stats().blocks_in_use;
        assert!(s.prefill_step(16).is_err());
        fail_head.set(false);
        assert_eq!(
            engine.kv_pool().stats().blocks_in_use,
            census,
            "retrying a failed final chunk leaked staged KV blocks"
        );

        s.prefill_step(16).unwrap()
    };
    assert!(engine.kv_pool().quiesced(), "session drop left blocks in use");

    // The recovered stream is bit-identical to an uninterrupted prefill.
    let clean_engine = Engine::synthetic();
    let mut q = Session::new(&clean_engine, SpecDecConfig::default()).unwrap();
    let t = q.prefill(&p, &chunk_sizes(p.len(), 16)).unwrap();
    assert_eq!(first, Some(t), "recovered prefill diverged from clean run");
}

#[test]
fn run_batch_default_loop_matches_vectorized_reference() {
    // The run_batch contract: the default loop implementation and the
    // reference backend's vectorized pass must produce bit-identical
    // outputs for every item — only their stats accounting differs.
    let vectorized = ReferenceBackend::synthetic(42);
    let looped = LoopBackend(ReferenceBackend::synthetic(42));
    let m = vectorized.manifest().model.clone();
    let h = m.hidden;

    // Three lanes of cloud_middle work with distinct KV states/positions.
    let kvs: Vec<Tensor> = (0..3)
        .map(|lane| {
            let mut kv = hat::backend::zeros_tensor(&m.middle_kv_dims());
            for d in 0..h {
                kv.data[d] = 0.1 * lane as f32;
            }
            kv
        })
        .collect();
    let hiddens: Vec<Tensor> = (0..3)
        .map(|lane| {
            let data: Vec<f32> =
                (0..4 * h).map(|i| ((i + lane) as f32 * 0.03).sin()).collect();
            Tensor::new(vec![4, h], data).unwrap()
        })
        .collect();
    let poss: Vec<Tensor> =
        (0..3).map(|lane| hat::backend::pos_tensor(lane + 1)).collect();
    let items: Vec<Vec<&Tensor>> =
        (0..3).map(|i| vec![&hiddens[i], &kvs[i], &poss[i]]).collect();

    let a = vectorized.run_batch("cloud_middle_4", &items).unwrap();
    let b = looped.run_batch("cloud_middle_4", &items).unwrap();
    assert_eq!(a, b, "vectorized and loop run_batch disagree");

    // Accounting: one execution with occupancy 3 vs three with 1 each.
    let sv = vectorized.stats();
    let sl = looped.stats();
    assert_eq!((sv.executions, sv.batch_occupancy), (1, 3));
    assert_eq!((sl.executions, sl.batch_occupancy), (3, 3));
}

#[test]
fn shared_prefix_sessions_dedup_kv_blocks() {
    // The pool seals full blocks content-addressed, so two sessions
    // prefilled with the same 512-token system prompt (plus distinct
    // short tails) store the prefix once: they must consume measurably
    // fewer blocks than two sessions with fully distinct prompts of the
    // same length, and the sharing must be visible in `shared_blocks`.
    let mut rng = hat::util::rng::Rng::new(3);
    let mut toks = |n: usize| -> Vec<u32> { (0..n).map(|_| rng.below(256) as u32).collect() };
    let system = toks(512);
    let tail_a = toks(8);
    let tail_b = toks(8);
    let distinct_a = toks(520);
    let distinct_b = toks(520);

    // Prefill two concurrent sessions, return (blocks_in_use, shared).
    let census = |p1: &[u32], p2: &[u32]| -> (usize, usize) {
        let e = Engine::synthetic();
        let mut a = Session::new(&e, SpecDecConfig::default()).unwrap();
        a.prefill(p1, &chunk_sizes(p1.len(), 64)).unwrap();
        let mut b = Session::new(&e, SpecDecConfig::default()).unwrap();
        b.prefill(p2, &chunk_sizes(p2.len(), 64)).unwrap();
        let s = e.kv_pool().stats();
        drop(b);
        drop(a);
        assert!(e.kv_pool().quiesced(), "dropped sessions left blocks behind");
        (s.blocks_in_use, s.shared_blocks)
    };

    let shared_p1: Vec<u32> = system.iter().chain(&tail_a).copied().collect();
    let shared_p2: Vec<u32> = system.iter().chain(&tail_b).copied().collect();
    let (shared_use, shared_shared) = census(&shared_p1, &shared_p2);
    let (distinct_use, distinct_shared) = census(&distinct_a, &distinct_b);

    assert_eq!(distinct_shared, 0, "distinct prompts must not alias blocks");
    // 512 shared tokens = 8 sealed 64-token blocks per cache; with three
    // caches per session the savings must be at least one full prefix.
    assert!(
        shared_use + 8 <= distinct_use,
        "shared prefix saved too little: {shared_use} vs {distinct_use} blocks"
    );
    assert!(
        shared_shared >= 8,
        "a 512-token shared prefix must alias ≥ 8 blocks, saw {shared_shared}"
    );
}

#[test]
fn hat_session_runs_end_to_end_and_is_deterministic() {
    let p = prompt(48, 7);
    let a = run_hat_session(&Engine::synthetic(), &p, 16, true, 32);
    let b = run_hat_session(&Engine::synthetic(), &p, 16, true, 32);
    assert_eq!(a, b, "same-seed HAT sessions must be bit-identical");
    assert!(a.len() >= p.len() + 32);
    assert_eq!(&a[..p.len()], &p[..], "context starts with the prompt");
    let spec = Engine::synthetic().spec().clone();
    assert!(a.iter().all(|&t| (t as usize) < spec.vocab));
}

#[test]
fn hat_output_is_invariant_to_prefill_chunking() {
    // The reference backend masks by absolute position, so the chunked
    // prefill data path must not change the generated stream — the same
    // losslessness property the golden tests check on real artifacts.
    let e = Engine::synthetic();
    let p = prompt(40, 11);
    let whole = run_hat_session(&e, &p, p.len(), false, 24);
    let e2 = Engine::synthetic();
    let chunked = run_hat_session(&e2, &p, 8, false, 24);
    let n = p.len() + 24;
    assert_eq!(&whole[..n], &chunked[..n], "chunk size changed the output");
}

#[test]
fn temperature_zero_ignores_all_other_sampling_knobs() {
    // temperature = 0 short-circuits the sampler to the original argmax
    // path before any knob is consulted, so a config with aggressive
    // top-k / top-p / repetition-penalty / seed settings must still be
    // bit-identical to the all-defaults greedy stream.
    let p = prompt(36, 13);
    let run = |cfg: SpecDecConfig| -> Vec<u32> {
        let e = Engine::synthetic();
        let mut s = Session::new(&e, cfg).unwrap();
        s.prefill(&p, &chunk_sizes(p.len(), 12)).unwrap();
        while s.generated() < 20 {
            s.hat_round(true, 4).unwrap();
        }
        s.ctx.clone()
    };
    let greedy = run(SpecDecConfig::default());
    let knobbed = run(SpecDecConfig {
        temperature: 0.0,
        top_k_sample: 5,
        top_p: 0.5,
        rep_penalty: 1.4,
        seed: 999,
        ..SpecDecConfig::default()
    });
    let n = p.len() + 20;
    assert_eq!(&greedy[..n], &knobbed[..n], "sampling knobs leaked into the greedy path");
}

#[test]
fn ushape_and_medusa_rounds_run_on_reference_backend() {
    let e = Engine::synthetic();
    let p = prompt(32, 3);
    let mut s = Session::new(&e, SpecDecConfig::default()).unwrap();
    s.prefill(&p, &[p.len()]).unwrap();
    for _ in 0..8 {
        s.ushape_step().unwrap();
    }
    assert!(s.generated() >= 9);

    let mut m = Session::new(&e, SpecDecConfig::default()).unwrap();
    m.prefill(&p, &[p.len()]).unwrap();
    while m.generated() < 12 {
        let r = m.medusa_round().unwrap();
        assert_eq!(r.proposed.len(), e.spec().n_medusa);
        assert!(!r.emitted.is_empty());
    }
}

#[test]
fn profile_measures_on_reference_backend_without_artifacts() {
    let e = Engine::synthetic();
    let pool = PromptPool::synthetic(e.spec().vocab, 8, 128, 5);
    let cfg = SpecDecConfig::default();
    let p1 = SdProfile::measure(&e, &pool, &cfg, 2, 24, 42).unwrap();
    let e2 = Engine::synthetic();
    let p2 = SdProfile::measure(&e2, &pool, &cfg, 2, 24, 42).unwrap();
    assert!(!p1.hat.is_empty() && !p1.medusa.is_empty());
    assert_eq!(p1.hat, p2.hat, "same-seed profiles must be identical");
    assert_eq!(p1.medusa, p2.medusa);
    for r in p1.hat.iter().chain(&p1.medusa) {
        assert!(r.emitted >= 1);
        assert!(r.emitted <= r.verify_tokens + 1);
    }
}

#[test]
fn all_four_frameworks_run_on_reference_profile_bit_identically() {
    // Tiny fleet, profile measured on the reference backend: every
    // framework finishes every request, and two same-seed runs agree on
    // every metric to the bit.
    let e = Engine::synthetic();
    let pool = PromptPool::synthetic(e.spec().vocab, 8, 128, 9);
    let profile = SdProfile::measure(&e, &pool, &SpecDecConfig::default(), 2, 24, 42).unwrap();

    for fw in Framework::all() {
        let mut cfg = ExperimentConfig::preset(fw, Dataset::SpecBench);
        cfg.workload.n_requests = 25;
        cfg.workload.max_new_tokens = 32;

        let a = run_experiment(&cfg, &profile);
        let b = run_experiment(&cfg, &profile);

        assert_eq!(a.finished_requests().count(), 25, "{}", fw.name());
        for r in a.finished_requests() {
            assert!(r.tokens_generated() >= 32, "{} generated {}", fw.name(), r.tokens_generated());
            assert!(r.ttft_ms().unwrap() > 0.0);
        }

        let (sa, sb) = (a.summary(), b.summary());
        assert_eq!(sa.ttft_mean_ms, sb.ttft_mean_ms, "{} TTFT drifted", fw.name());
        assert_eq!(sa.tbt_mean_ms, sb.tbt_mean_ms, "{} TBT drifted", fw.name());
        assert_eq!(a.gpu_step_delays, b.gpu_step_delays, "{} GPU delays drifted", fw.name());
        assert_eq!(a.chunk_sizes, b.chunk_sizes, "{} chunk trace drifted", fw.name());
    }
}
