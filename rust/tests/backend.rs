//! Backend-seam integration tests: the full protocol stack — chunked
//! prefill, HAT speculative-decoding rounds with parallel drafting,
//! U-shape decode, U-Medusa rounds, profile measurement and the
//! four-framework fleet simulation — running end-to-end against the
//! deterministic reference backend, with **zero** artifacts on disk and
//! no accelerator libraries.
//!
//! The headline assertions are bit-identity: two same-seed runs of any
//! layer must produce identical token streams and identical metrics.

use hat::config::{Dataset, ExperimentConfig, Framework, SpecDecConfig};
use hat::engine::Engine;
use hat::frameworks::run_experiment;
use hat::specdec::profile::SdProfile;
use hat::specdec::{chunk_sizes, Session};
use hat::workload::PromptPool;

fn prompt(len: usize, seed: u64) -> Vec<u32> {
    let pool = PromptPool::synthetic(256, 4, 160, seed);
    let mut rng = hat::util::rng::Rng::new(seed);
    pool.sample(len, &mut rng)
}

/// Generate `n` tokens through HAT rounds; returns the full context.
fn run_hat_session(e: &Engine, p: &[u32], chunk: usize, pd: bool, n: usize) -> Vec<u32> {
    let mut s = Session::new(e, SpecDecConfig::default()).unwrap();
    let chunks = chunk_sizes(p.len(), chunk);
    s.prefill(p, &chunks).unwrap();
    while s.generated() < n {
        let r = s.hat_round(pd, 4).unwrap();
        assert!(!r.emitted.is_empty());
        assert!(r.accepted <= r.proposed.len());
        assert_eq!(r.emitted.len(), r.accepted + 1);
        assert_eq!(r.verify_tokens, r.proposed.len() + 1);
    }
    s.ctx.clone()
}

#[test]
fn hat_session_runs_end_to_end_and_is_deterministic() {
    let p = prompt(48, 7);
    let a = run_hat_session(&Engine::synthetic(), &p, 16, true, 32);
    let b = run_hat_session(&Engine::synthetic(), &p, 16, true, 32);
    assert_eq!(a, b, "same-seed HAT sessions must be bit-identical");
    assert!(a.len() >= p.len() + 32);
    assert_eq!(&a[..p.len()], &p[..], "context starts with the prompt");
    let spec = Engine::synthetic().spec().clone();
    assert!(a.iter().all(|&t| (t as usize) < spec.vocab));
}

#[test]
fn hat_output_is_invariant_to_prefill_chunking() {
    // The reference backend masks by absolute position, so the chunked
    // prefill data path must not change the generated stream — the same
    // losslessness property the golden tests check on real artifacts.
    let e = Engine::synthetic();
    let p = prompt(40, 11);
    let whole = run_hat_session(&e, &p, p.len(), false, 24);
    let e2 = Engine::synthetic();
    let chunked = run_hat_session(&e2, &p, 8, false, 24);
    let n = p.len() + 24;
    assert_eq!(&whole[..n], &chunked[..n], "chunk size changed the output");
}

#[test]
fn ushape_and_medusa_rounds_run_on_reference_backend() {
    let e = Engine::synthetic();
    let p = prompt(32, 3);
    let mut s = Session::new(&e, SpecDecConfig::default()).unwrap();
    s.prefill(&p, &[p.len()]).unwrap();
    for _ in 0..8 {
        s.ushape_step().unwrap();
    }
    assert!(s.generated() >= 9);

    let mut m = Session::new(&e, SpecDecConfig::default()).unwrap();
    m.prefill(&p, &[p.len()]).unwrap();
    while m.generated() < 12 {
        let r = m.medusa_round().unwrap();
        assert_eq!(r.proposed.len(), e.spec().n_medusa);
        assert!(!r.emitted.is_empty());
    }
}

#[test]
fn profile_measures_on_reference_backend_without_artifacts() {
    let e = Engine::synthetic();
    let pool = PromptPool::synthetic(e.spec().vocab, 8, 128, 5);
    let cfg = SpecDecConfig::default();
    let p1 = SdProfile::measure(&e, &pool, &cfg, 2, 24, 42).unwrap();
    let e2 = Engine::synthetic();
    let p2 = SdProfile::measure(&e2, &pool, &cfg, 2, 24, 42).unwrap();
    assert!(!p1.hat.is_empty() && !p1.medusa.is_empty());
    assert_eq!(p1.hat, p2.hat, "same-seed profiles must be identical");
    assert_eq!(p1.medusa, p2.medusa);
    for r in p1.hat.iter().chain(&p1.medusa) {
        assert!(r.emitted >= 1);
        assert!(r.emitted <= r.verify_tokens + 1);
    }
}

#[test]
fn all_four_frameworks_run_on_reference_profile_bit_identically() {
    // Tiny fleet, profile measured on the reference backend: every
    // framework finishes every request, and two same-seed runs agree on
    // every metric to the bit.
    let e = Engine::synthetic();
    let pool = PromptPool::synthetic(e.spec().vocab, 8, 128, 9);
    let profile = SdProfile::measure(&e, &pool, &SpecDecConfig::default(), 2, 24, 42).unwrap();

    for fw in Framework::all() {
        let mut cfg = ExperimentConfig::preset(fw, Dataset::SpecBench);
        cfg.workload.n_requests = 25;
        cfg.workload.max_new_tokens = 32;

        let a = run_experiment(&cfg, &profile);
        let b = run_experiment(&cfg, &profile);

        assert_eq!(a.finished_requests().count(), 25, "{}", fw.name());
        for r in a.finished_requests() {
            assert!(r.tokens_generated() >= 32, "{} generated {}", fw.name(), r.tokens_generated());
            assert!(r.ttft_ms().unwrap() > 0.0);
        }

        let (sa, sb) = (a.summary(), b.summary());
        assert_eq!(sa.ttft_mean_ms, sb.ttft_mean_ms, "{} TTFT drifted", fw.name());
        assert_eq!(sa.tbt_mean_ms, sb.tbt_mean_ms, "{} TBT drifted", fw.name());
        assert_eq!(a.gpu_step_delays, b.gpu_step_delays, "{} GPU delays drifted", fw.name());
        assert_eq!(a.chunk_sizes, b.chunk_sizes, "{} chunk trace drifted", fw.name());
    }
}
