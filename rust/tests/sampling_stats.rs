//! Statistical and seeded-identity oracles for stochastic speculative
//! sampling on the reference backend.
//!
//! Two kinds of losslessness are certified:
//!
//! 1. **Token identity** (`SampleVerify::Coupled`, the default): for a
//!    grid of ≥ 100 `(seed, prompt, temperature, top_p)` cases, the
//!    speculative HAT stream is token-identical to direct (u-shape)
//!    seeded sampling from the target model.
//! 2. **Distribution identity** (`SampleVerify::Rejection`): the
//!    marginal next-token distribution of speculative sampling matches
//!    direct sampling — two-sample chi-squared and Kolmogorov–Smirnov
//!    tests at α = 0.01 over seeded draws.  Smoke-sized versions run in
//!    tier-1; the ≥ 10k-draw versions are `#[ignore]` and run in the
//!    dedicated CI statistical-equivalence job with `--release`.
//!
//! All seeds are fixed, so every verdict here is deterministic.

use hat::config::{SampleVerify, SpecDecConfig};
use hat::engine::Engine;
use hat::specdec::Session;
use hat::util::proptest::{cases, forall};
use hat::util::stats::{
    chi2_critical, chi2_two_sample, ks_critical, ks_two_sample, KS_C_ALPHA_01, Z_ALPHA_01,
};

/// Direct seeded sampling: prefill + `n` u-shape steps (one target-model
/// token per step).  The reference stream speculative decoding must match.
fn direct_stream(engine: &Engine, cfg: &SpecDecConfig, prompt: &[u32], n: usize) -> Vec<u32> {
    let mut s = Session::new(engine, cfg.clone()).unwrap();
    let t1 = s.prefill(prompt, &[prompt.len()]).unwrap();
    let mut out = vec![t1];
    for _ in 1..n {
        out.push(s.ushape_step().unwrap());
    }
    out
}

/// Speculative seeded sampling: prefill + HAT rounds (parallel drafting
/// on) until `n` tokens, truncated to `n`.
fn speculative_stream(engine: &Engine, cfg: &SpecDecConfig, prompt: &[u32], n: usize) -> Vec<u32> {
    let mut s = Session::new(engine, cfg.clone()).unwrap();
    let t1 = s.prefill(prompt, &[prompt.len()]).unwrap();
    let mut out = vec![t1];
    while out.len() < n {
        let budget = (n - out.len()).saturating_sub(1).max(1);
        out.extend(s.hat_round_capped(true, 4, budget).unwrap().emitted);
    }
    out.truncate(n);
    out
}

#[test]
fn coupled_speculative_is_token_identical_over_a_100_case_grid() {
    let engine = Engine::synthetic();
    let prompts: [&[u32]; 2] = [&[7, 3, 200, 41, 5], &[1, 99, 250, 12, 63, 17, 88]];
    let mut n_cases = 0;
    for seed in [11u64, 29, 47, 83, 131] {
        for (pi, prompt) in prompts.iter().enumerate() {
            for &temperature in &[0.3, 0.7, 1.0, 1.4] {
                for &top_p in &[1.0, 0.9, 0.7] {
                    let cfg = SpecDecConfig {
                        temperature,
                        top_p,
                        rep_penalty: 1.1,
                        seed,
                        ..SpecDecConfig::default()
                    };
                    let want = direct_stream(&engine, &cfg, prompt, 12);
                    let got = speculative_stream(&engine, &cfg, prompt, 12);
                    assert_eq!(
                        got, want,
                        "coupled sampling diverged: seed={seed} prompt#{pi} T={temperature} top_p={top_p}"
                    );
                    n_cases += 1;
                }
            }
        }
    }
    assert!(n_cases >= 100, "oracle grid too small: {n_cases}");
}

#[test]
fn temperature_zero_degenerates_to_greedy_argmax() {
    // temperature = 0 with any other sampling knobs set must reproduce
    // the default (greedy) stream bit-for-bit — no draws are consumed.
    let engine = Engine::synthetic();
    let prompt = [5u32, 9, 2, 14, 77];
    let greedy = SpecDecConfig::default();
    let zero = SpecDecConfig {
        temperature: 0.0,
        top_k_sample: 5,
        top_p: 0.5,
        rep_penalty: 1.4,
        seed: 999,
        ..SpecDecConfig::default()
    };
    assert_eq!(
        speculative_stream(&engine, &zero, &prompt, 16),
        speculative_stream(&engine, &greedy, &prompt, 16),
    );
    assert_eq!(
        direct_stream(&engine, &zero, &prompt, 16),
        direct_stream(&engine, &greedy, &prompt, 16),
    );
}

/// One (speculative, direct) pair of next-token draws for `seed`: the
/// first *stochastically emitted* token after an identical seeded prefix.
/// Both sessions share the seed, so their contexts match exactly and the
/// two draws target the same per-seed distribution p — making the
/// mixtures over seeds identical under H0.
fn marginal_pair(engine: &Engine, base: &SpecDecConfig, seed: u64) -> (u32, u32) {
    let cfg = SpecDecConfig { seed, ..base.clone() };
    let prompt = [3u32, 17, 121];
    let mut spec = Session::new(engine, cfg.clone()).unwrap();
    spec.prefill(&prompt, &[prompt.len()]).unwrap();
    let spec_tok = spec.hat_round(true, 4).unwrap().emitted[0];
    let mut direct = Session::new(engine, cfg).unwrap();
    direct.prefill(&prompt, &[prompt.len()]).unwrap();
    let direct_tok = direct.ushape_step().unwrap();
    (spec_tok, direct_tok)
}

/// Chi-squared + KS equivalence of the speculative vs direct marginals
/// over `n` seeded draws, with token ids folded into `bins` histogram
/// bins (marginal identity implies identity of any fixed binning; coarse
/// bins keep expected counts high enough for the chi-squared
/// approximation at smoke sample sizes).
fn assert_marginals_match(mode: SampleVerify, n: u64, bins: usize, seed0: u64) {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    let base = SpecDecConfig {
        temperature: 0.8,
        top_p: 0.95,
        verify_mode: mode,
        ..SpecDecConfig::default()
    };
    let mut spec_hist = vec![0u64; bins];
    let mut direct_hist = vec![0u64; bins];
    let mut spec_ids = Vec::new();
    let mut direct_ids = Vec::new();
    for i in 0..n {
        let (s, d) = marginal_pair(&engine, &base, seed0 + i);
        assert!((s as usize) < vocab && (d as usize) < vocab);
        spec_hist[s as usize * bins / vocab] += 1;
        direct_hist[d as usize * bins / vocab] += 1;
        spec_ids.push(s as f64);
        direct_ids.push(d as f64);
    }
    let (stat, dof) = chi2_two_sample(&spec_hist, &direct_hist);
    let crit = chi2_critical(dof.max(1), Z_ALPHA_01);
    assert!(
        stat < crit,
        "chi2 rejects speculative==direct at alpha=0.01: stat={stat:.2} crit={crit:.2} dof={dof}"
    );
    let d = ks_two_sample(&spec_ids, &direct_ids);
    let kcrit = ks_critical(spec_ids.len(), direct_ids.len(), KS_C_ALPHA_01);
    assert!(d < kcrit, "KS rejects speculative==direct at alpha=0.01: D={d:.4} crit={kcrit:.4}");
}

#[test]
fn rejection_marginal_matches_direct_sampling_smoke() {
    assert_marginals_match(SampleVerify::Rejection, 500, 16, 10_000);
}

#[test]
fn coupled_marginal_matches_direct_sampling_smoke() {
    // Coupled mode is token-identical per seed, so its marginal test is
    // a tautology — kept as a harness sanity check (stat ~ 0).
    assert_marginals_match(SampleVerify::Coupled, 300, 16, 20_000);
}

#[test]
#[ignore = "10k-draw statistical job: run with --release (CI stat-equiv job)"]
fn rejection_marginal_matches_direct_sampling_10k() {
    assert_marginals_match(SampleVerify::Rejection, 10_000, 256, 1);
}

#[test]
#[ignore = "10k-draw statistical job: run with --release (CI stat-equiv job)"]
fn rejection_marginal_matches_direct_sampling_10k_sharper_nucleus() {
    let engine = Engine::synthetic();
    let vocab = engine.spec().vocab;
    let base = SpecDecConfig {
        temperature: 1.2,
        top_p: 0.8,
        top_k_sample: 32,
        rep_penalty: 1.2,
        verify_mode: SampleVerify::Rejection,
        ..SpecDecConfig::default()
    };
    let mut spec_hist = vec![0u64; vocab];
    let mut direct_hist = vec![0u64; vocab];
    for i in 0..10_000u64 {
        let (s, d) = marginal_pair(&engine, &base, 500_000 + i);
        spec_hist[s as usize] += 1;
        direct_hist[d as usize] += 1;
    }
    let (stat, dof) = chi2_two_sample(&spec_hist, &direct_hist);
    let crit = chi2_critical(dof.max(1), Z_ALPHA_01);
    assert!(stat < crit, "chi2 rejects: stat={stat:.2} crit={crit:.2} dof={dof}");
}

#[test]
#[ignore = "large coupled grid: run with --release (CI stat-equiv job)"]
fn coupled_token_identity_holds_across_many_seeds() {
    // Deeper streams and many more seeds than the tier-1 grid.
    let engine = Engine::synthetic();
    let prompt = [9u32, 1, 77, 130];
    for seed in 0..200u64 {
        let cfg = SpecDecConfig {
            temperature: 1.0,
            top_p: 0.9,
            rep_penalty: 1.15,
            seed,
            ..SpecDecConfig::default()
        };
        let want = direct_stream(&engine, &cfg, &prompt, 40);
        let got = speculative_stream(&engine, &cfg, &prompt, 40);
        assert_eq!(got, want, "seed {seed} diverged");
    }
}

#[test]
fn prop_pick_frequencies_match_the_distribution() {
    // The inverse-CDF sampler itself: empirical frequencies of 4000
    // uniform-driven picks from a random 8-bin distribution agree with
    // expected counts (chi-squared against the exact expectation).
    use hat::util::rng::Rng;
    forall(cases(20), |rng| {
        let k = rng.range_usize(3, 8);
        let w: Vec<f64> = (0..k).map(|_| rng.range_f64(0.2, 2.0)).collect();
        let total: f64 = w.iter().sum();
        let dist: Vec<f64> = w.iter().map(|x| x / total).collect();
        let n = 4000u64;
        let mut got = vec![0u64; k];
        let mut draws = Rng::new(rng.next_u64());
        for _ in 0..n {
            got[hat::sampler::Sampler::pick(&dist, draws.f64()) as usize] += 1;
        }
        // One-sample chi-squared against the exact expected counts.
        let mut stat = 0.0;
        for i in 0..k {
            let e = dist[i] * n as f64;
            stat += (got[i] as f64 - e).powi(2) / e;
        }
        let crit = chi2_critical(k - 1, Z_ALPHA_01);
        if stat >= crit {
            return Err(format!("pick frequencies off: stat={stat:.2} crit={crit:.2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rejection_round_output_is_in_processed_support() {
    // Every emitted token of a rejection-mode round lies in the vocab and
    // rounds always emit accepted+1 tokens (residual fallback included).
    let engine = Engine::synthetic();
    forall(cases(30), |rng| {
        let cfg = SpecDecConfig {
            temperature: rng.range_f64(0.3, 1.5),
            top_p: rng.range_f64(0.5, 1.0),
            top_k_sample: rng.range_usize(0, 64),
            rep_penalty: rng.range_f64(1.0, 1.5),
            seed: rng.next_u64(),
            verify_mode: SampleVerify::Rejection,
            ..SpecDecConfig::default()
        };
        let prompt: Vec<u32> = (0..rng.range_usize(2, 8)).map(|_| rng.below(256) as u32).collect();
        let mut s = Session::new(&engine, cfg).unwrap();
        s.prefill(&prompt, &[prompt.len()]).unwrap();
        for _ in 0..3 {
            let r = s.hat_round(true, 4).unwrap();
            if r.emitted.len() != r.accepted + 1 {
                return Err(format!("round emitted {} != accepted+1", r.emitted.len()));
            }
            if r.emitted.iter().any(|&t| (t as usize) >= engine.spec().vocab) {
                return Err("token outside vocab".into());
            }
        }
        Ok(())
    });
}
