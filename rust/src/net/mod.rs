//! Network model: the WiFi links between devices and the cloud.
//!
//! The paper characterizes its links purely by iperf3-measured bandwidth
//! ranges (§4.1: devices grouped at 2 m / 8 m / 14 m from the routers;
//! uplink 5–10 MB/s, downlink 10–15 MB/s, time-varying under channel noise
//! and contention).  We reproduce exactly that characterization: each
//! device gets a bounded-random-walk bandwidth process per direction, with
//! the walk range set by its distance group.

use crate::util::rng::Rng;

/// Direction of a transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Up,
    Down,
}

/// Distance group (paper: 10 devices at each of 2 m, 8 m, 14 m).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DistanceGroup {
    Near,   // 2 m
    Mid,    // 8 m
    Far,    // 14 m
}

impl DistanceGroup {
    pub fn for_device(device_id: usize, n_devices: usize) -> DistanceGroup {
        // Paper: three equal groups.
        let third = n_devices.div_ceil(3).max(1);
        match device_id / third {
            0 => DistanceGroup::Near,
            1 => DistanceGroup::Mid,
            _ => DistanceGroup::Far,
        }
    }

    /// (up_min, up_max, down_min, down_max) in MB/s.  The paper gives the
    /// fleet-wide ranges (5–10 up / 10–15 down); distance shifts where in
    /// the range a device's walk lives.
    fn ranges(self) -> (f64, f64, f64, f64) {
        match self {
            DistanceGroup::Near => (8.0, 10.0, 13.0, 15.0),
            DistanceGroup::Mid => (6.5, 8.5, 11.5, 13.5),
            DistanceGroup::Far => (5.0, 7.0, 10.0, 12.0),
        }
    }
}

/// Bounded random walk over bandwidth, one per (device, direction).
#[derive(Debug, Clone)]
pub struct BandwidthProcess {
    cur_mbps: f64, // MB/s
    pub lo: f64,
    pub hi: f64,
    rng: Rng,
}

impl BandwidthProcess {
    pub fn new(lo: f64, hi: f64, rng: Rng) -> Self {
        let mut s = BandwidthProcess { cur_mbps: 0.0, lo, hi, rng };
        s.cur_mbps = s.rng.range_f64(lo, hi);
        s
    }

    /// Sample the bandwidth for the next transfer, advancing the walk.
    pub fn sample(&mut self) -> f64 {
        // ±7% multiplicative step, clamped to [lo, hi].
        let step = 1.0 + self.rng.range_f64(-0.07, 0.07);
        self.cur_mbps = (self.cur_mbps * step).clamp(self.lo, self.hi);
        self.cur_mbps
    }

    pub fn current(&self) -> f64 {
        self.cur_mbps
    }
}

/// The link of one device: up + down bandwidth processes and transfer-delay
/// computation with per-message overhead.
#[derive(Debug, Clone)]
pub struct DeviceLink {
    pub up: BandwidthProcess,
    pub down: BandwidthProcess,
    /// Fixed per-message latency (WiFi MAC + TCP), ms.
    pub base_latency_ms: f64,
}

impl DeviceLink {
    pub fn new(device_id: usize, n_devices: usize, root: &Rng) -> Self {
        let group = DistanceGroup::for_device(device_id, n_devices);
        let (ul, uh, dl, dh) = group.ranges();
        let base_latency_ms = match group {
            DistanceGroup::Near => 1.5,
            DistanceGroup::Mid => 2.5,
            DistanceGroup::Far => 4.0,
        };
        DeviceLink {
            up: BandwidthProcess::new(ul, uh, root.substream(device_id as u64 * 2 + 1)),
            down: BandwidthProcess::new(dl, dh, root.substream(device_id as u64 * 2 + 2)),
            base_latency_ms,
        }
    }

    /// Transfer delay in ms for `bytes` in direction `dir`, sampling the
    /// bandwidth walk once per transfer.
    pub fn transfer_ms(&mut self, bytes: usize, dir: Dir) -> f64 {
        self.base_latency_ms + self.streamed_ms(bytes, dir)
    }

    /// Transfer delay without the per-message latency — for payloads that
    /// ride an already-open stream back-to-back (e.g. consecutive prompt
    /// chunks of one prefill: only the first pays MAC/TCP setup).
    pub fn streamed_ms(&mut self, bytes: usize, dir: Dir) -> f64 {
        let mbps = match dir {
            Dir::Up => self.up.sample(),
            Dir::Down => self.down.sample(),
        };
        bytes as f64 / (mbps * 1e6) * 1e3
    }

    /// Latest sampled uplink bandwidth in bytes/ms (the β_{i,up}^t the
    /// state monitor reports to the chunk-size optimizer, Eq. 3).
    pub fn up_bytes_per_ms(&self) -> f64 {
        self.up.current() * 1e3
    }

    pub fn down_bytes_per_ms(&self) -> f64 {
        self.down.current() * 1e3
    }
}

/// Wire sizes (paper §2.2: hidden states are much larger than tokens).
/// Hidden states travel as fp16 (A = hidden × 2 bytes per token); tokens as
/// 4-byte ids.  `hidden` here is the *delay-model* hidden size — paper
/// scale (4096/5120), not the tiny executable model (DESIGN.md §3).
pub fn hidden_state_bytes(tokens: usize, hidden: usize) -> usize {
    tokens * hidden * 2
}

pub fn token_bytes(tokens: usize) -> usize {
    tokens * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{cases, forall};

    #[test]
    fn groups_split_in_thirds() {
        assert_eq!(DistanceGroup::for_device(0, 30), DistanceGroup::Near);
        assert_eq!(DistanceGroup::for_device(10, 30), DistanceGroup::Mid);
        assert_eq!(DistanceGroup::for_device(29, 30), DistanceGroup::Far);
    }

    #[test]
    fn walk_stays_in_bounds() {
        let mut p = BandwidthProcess::new(5.0, 10.0, Rng::new(3));
        for _ in 0..10_000 {
            let b = p.sample();
            assert!((5.0..=10.0).contains(&b), "bw {b}");
        }
    }

    #[test]
    fn transfer_scales_with_bytes() {
        let root = Rng::new(1);
        let mut l = DeviceLink::new(0, 30, &root);
        let t1 = l.transfer_ms(1_000_000, Dir::Up);
        let root = Rng::new(1);
        let mut l2 = DeviceLink::new(0, 30, &root);
        let t2 = l2.transfer_ms(2_000_000, Dir::Up);
        assert!(t2 > t1, "{t2} !> {t1}");
    }

    #[test]
    fn hidden_states_dwarf_tokens() {
        // The core premise of §2.2: per-token hidden state (4096·2B) vs 4B id.
        assert_eq!(hidden_state_bytes(1, 4096) / token_bytes(1), 2048);
    }

    #[test]
    fn downlink_faster_than_uplink() {
        // Paper: 5–10 MB/s up, 10–15 down; holds per group.
        for g in [DistanceGroup::Near, DistanceGroup::Mid, DistanceGroup::Far] {
            let (ul, uh, dl, dh) = g.ranges();
            assert!(dl >= uh || dl > ul, "{g:?}");
            assert!(dh > uh);
        }
    }

    #[test]
    fn prop_transfer_positive_and_monotone_in_bytes() {
        forall(cases(50), |rng| {
            let root = Rng::new(rng.next_u64());
            let dev = rng.below(30);
            let mut l = DeviceLink::new(dev, 30, &root);
            let b1 = rng.range_usize(1, 1 << 20);
            let b2 = b1 * 2;
            // Same link state for both: use bandwidth bounds to compare
            let t1_min = l.base_latency_ms + b1 as f64 / (l.up.hi * 1e6) * 1e3;
            let t2 = l.transfer_ms(b2, Dir::Up);
            if t2 <= 0.0 {
                return Err("non-positive delay".into());
            }
            if t2 < t1_min {
                return Err(format!("2x bytes faster than 1x at max bw: {t2} < {t1_min}"));
            }
            Ok(())
        });
    }
}
