//! Workload generation: Poisson request arrivals over the device fleet and
//! prompt-length sampling matched to the paper's Table 3, plus the loader
//! for `artifacts/prompts.bin` (pre-tokenized in-distribution prompts for
//! the real-execution path).

use std::io::Read;
use std::path::Path;

use crate::config::{Dataset, WorkloadConfig};
use crate::sim::SimTime;
use crate::util::rng::Rng;

/// A generated request (before entering the system).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: usize,
    pub device: usize,
    pub arrival: SimTime,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

/// Lognormal prompt-length sampler fit to Table 3 per dataset, clamped.
#[derive(Debug, Clone)]
pub struct PromptSampler {
    mu: f64,
    sigma: f64,
    min: usize,
    max: usize,
}

impl PromptSampler {
    pub fn new(dataset: Dataset, min: usize, max: usize) -> Self {
        let (mu, sigma) = dataset.lognormal();
        PromptSampler { mu, sigma, min, max }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        (rng.lognormal(self.mu, self.sigma).round() as usize).clamp(self.min, self.max)
    }
}

/// Generate the full arrival trace: aggregate Poisson process at
/// `cfg.rate` req/s, each request assigned to a uniformly random device
/// (paper §4.2: "devices generate requests following a Poisson process").
pub fn generate_trace(cfg: &WorkloadConfig, seed: u64) -> Vec<Request> {
    let root = Rng::new(seed);
    let mut arr_rng = root.substream(0xA11);
    let mut len_rng = root.substream(0x1E4);
    let mut dev_rng = root.substream(0xDE7);
    let sampler = PromptSampler::new(cfg.dataset, cfg.min_prompt, cfg.max_prompt);

    let mut t = 0.0_f64; // seconds
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        t += arr_rng.exponential(cfg.rate);
        out.push(Request {
            id,
            device: dev_rng.below(cfg.n_devices),
            arrival: SimTime::from_secs(t),
            prompt_len: sampler.sample(&mut len_rng),
            max_new_tokens: cfg.max_new_tokens,
        });
    }
    out
}

/// Pool of real token prompts written by `python -m compile.aot`
/// (format: magic "HATP", u32 count, then per prompt u32 len + u32 toks).
#[derive(Debug, Clone)]
pub struct PromptPool {
    prompts: Vec<Vec<u32>>,
}

impl PromptPool {
    pub fn load(path: &Path) -> anyhow::Result<PromptPool> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        anyhow::ensure!(buf.len() >= 8 && &buf[..4] == b"HATP", "bad prompts.bin magic");
        let rd_u32 = |b: &[u8], off: usize| -> u32 {
            u32::from_le_bytes(b[off..off + 4].try_into().unwrap())
        };
        let count = rd_u32(&buf, 4) as usize;
        let mut prompts = Vec::with_capacity(count);
        let mut off = 8;
        for _ in 0..count {
            anyhow::ensure!(off + 4 <= buf.len(), "truncated prompts.bin");
            let len = rd_u32(&buf, off) as usize;
            off += 4;
            anyhow::ensure!(off + 4 * len <= buf.len(), "truncated prompt body");
            let toks = (0..len).map(|i| rd_u32(&buf, off + 4 * i)).collect();
            off += 4 * len;
            prompts.push(toks);
        }
        anyhow::ensure!(!prompts.is_empty(), "empty prompt pool");
        Ok(PromptPool { prompts })
    }

    /// Deterministic synthetic pool for artifact-free runs (reference
    /// backend): `count` prompts of `max_len` tokens with ids < `vocab`.
    pub fn synthetic(vocab: usize, count: usize, max_len: usize, seed: u64) -> PromptPool {
        assert!(vocab > 0 && count > 0 && max_len > 0);
        let mut rng = Rng::new(seed);
        let prompts = (0..count)
            .map(|_| (0..max_len).map(|_| rng.below(vocab) as u32).collect())
            .collect();
        PromptPool { prompts }
    }

    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }

    /// Pick a prompt of exactly `len` tokens: find the shortest pooled
    /// prompt with length >= len and truncate (all pool prompts are
    /// in-distribution prefixes).  Falls back to the longest available.
    pub fn sample(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let candidates: Vec<&Vec<u32>> =
            self.prompts.iter().filter(|p| p.len() >= len).collect();
        if candidates.is_empty() {
            let longest = self.prompts.iter().max_by_key(|p| p.len()).unwrap();
            return longest.clone();
        }
        let p = candidates[rng.below(candidates.len())];
        p[..len].to_vec()
    }

    pub fn max_len(&self) -> usize {
        self.prompts.iter().map(|p| p.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataset;
    use crate::util::proptest::{cases, forall};

    fn wl(rate: f64, n: usize) -> WorkloadConfig {
        let mut c = WorkloadConfig::preset(Dataset::SpecBench);
        c.rate = rate;
        c.n_requests = n;
        c
    }

    #[test]
    fn trace_is_sorted_and_sized() {
        let tr = generate_trace(&wl(6.0, 200), 1);
        assert_eq!(tr.len(), 200);
        for w in tr.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn poisson_rate_approximately_honoured() {
        let tr = generate_trace(&wl(8.0, 4000), 2);
        let span = tr.last().unwrap().arrival.as_secs();
        let rate = tr.len() as f64 / span;
        assert!((rate - 8.0).abs() < 0.5, "rate {rate}");
    }

    #[test]
    fn prompt_lengths_match_table3_mean() {
        let tr = generate_trace(&wl(6.0, 8000), 3);
        let mean: f64 =
            tr.iter().map(|r| r.prompt_len as f64).sum::<f64>() / tr.len() as f64;
        // Table 3 SpecBench mean 351.2; clamping shifts it slightly.
        assert!((mean - 351.0).abs() < 40.0, "mean {mean}");
    }

    #[test]
    fn devices_covered() {
        let tr = generate_trace(&wl(6.0, 2000), 4);
        let mut seen = vec![false; 30];
        for r in &tr {
            seen[r.device] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let a = generate_trace(&wl(5.0, 100), 9);
        let b = generate_trace(&wl(5.0, 100), 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_len, y.prompt_len);
            assert_eq!(x.device, y.device);
        }
    }

    #[test]
    fn prop_prompt_sampler_respects_clamp() {
        forall(cases(100), |rng| {
            let lo = rng.range_usize(1, 50);
            let hi = lo + rng.range_usize(1, 1000);
            let s = PromptSampler::new(Dataset::CnnDm, lo, hi);
            let mut r = Rng::new(rng.next_u64());
            for _ in 0..50 {
                let l = s.sample(&mut r);
                if l < lo || l > hi {
                    return Err(format!("length {l} outside [{lo},{hi}]"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prompt_pool_roundtrip() {
        // Synthesize a tiny pool file in-memory format and parse it.
        let dir = std::env::temp_dir().join("hat_test_prompts.bin");
        let mut bytes = b"HATP".to_vec();
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for p in [[1u32, 2, 3].as_slice(), [7u32, 8, 9, 10, 11].as_slice()] {
            bytes.extend_from_slice(&(p.len() as u32).to_le_bytes());
            for &t in p {
                bytes.extend_from_slice(&t.to_le_bytes());
            }
        }
        std::fs::write(&dir, &bytes).unwrap();
        let pool = PromptPool::load(&dir).unwrap();
        assert_eq!(pool.len(), 2);
        assert_eq!(pool.max_len(), 5);
        let mut rng = Rng::new(0);
        let s = pool.sample(4, &mut rng);
        assert_eq!(s, vec![7, 8, 9, 10]);
        // longer than everything -> longest available
        let s = pool.sample(100, &mut rng);
        assert_eq!(s.len(), 5);
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn prompt_pool_rejects_garbage() {
        let dir = std::env::temp_dir().join("hat_test_bad.bin");
        std::fs::write(&dir, b"NOPE").unwrap();
        assert!(PromptPool::load(&dir).is_err());
        std::fs::write(&dir, b"HATP\x02\x00\x00\x00\x05\x00\x00\x00").unwrap();
        assert!(PromptPool::load(&dir).is_err());
        std::fs::remove_file(&dir).ok();
    }
}
