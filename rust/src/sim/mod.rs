//! Discrete-event simulation core.
//!
//! The paper's evaluation runs on a physical testbed (30 Jetsons + 8×A6000
//! over WiFi).  Without that hardware, all *latency* metrics come from a
//! deterministic DES in virtual time, while all *token decisions* come from
//! real PJRT execution of the AOT artifacts (DESIGN.md §3, "dual-scale
//! principle").  This module is the substrate the offline crate set forced
//! us to build in place of tokio: a seeded, totally-ordered event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in microseconds.  Integer, so event ordering is exact and
/// runs are bit-reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_ms(ms: f64) -> SimTime {
        SimTime((ms * 1000.0).round().max(0.0) as u64)
    }

    pub fn from_secs(s: f64) -> SimTime {
        SimTime::from_ms(s * 1e3)
    }

    pub fn as_ms(self) -> f64 {
        self.0 as f64 / 1000.0
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 / 1e6
    }

    pub fn add_ms(self, ms: f64) -> SimTime {
        SimTime(self.0 + SimTime::from_ms(ms).0)
    }
}

impl std::ops::Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl std::ops::Sub for SimTime {
    type Output = SimTime;

    /// Difference of two virtual times.
    ///
    /// Subtracting a later time from an earlier one is a causality bug in
    /// the caller (metrics only ever subtract an event's start from its
    /// end), so debug builds assert `self >= rhs`.  Release builds keep
    /// the historical saturating behaviour — clamping to `ZERO` — so a
    /// long simulation degrades a metric instead of aborting.
    fn sub(self, rhs: SimTime) -> SimTime {
        debug_assert!(
            self.0 >= rhs.0,
            "SimTime underflow: {:?} - {:?} (subtracting a later time)",
            self,
            rhs
        );
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

struct Scheduled<E> {
    time: SimTime,
    seq: u64, // FIFO tie-break: equal-time events pop in push order
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first.
        other.time.cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// Deterministic future-event list.
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), now: SimTime::ZERO, seq: 0, processed: 0 }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `event` at absolute time `at`.  Panics if `at` is in the
    /// past — a DES that time-travels is a bug, not a policy.
    pub fn schedule_at(&mut self, at: SimTime, event: E) {
        assert!(at >= self.now, "scheduling into the past: {:?} < {:?}", at, self.now);
        self.seq += 1;
        self.heap.push(Scheduled { time: at, seq: self.seq, event });
    }

    /// Schedule `event` `delay_ms` virtual milliseconds from now.
    pub fn schedule_in_ms(&mut self, delay_ms: f64, event: E) {
        let at = self.now.add_ms(delay_ms.max(0.0));
        self.schedule_at(at, event);
    }

    /// Pop the earliest event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.now);
        self.now = s.time;
        self.processed += 1;
        Some((s.time, s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{cases, forall};

    #[test]
    fn time_conversions() {
        assert_eq!(SimTime::from_ms(1.5).0, 1500);
        assert!((SimTime::from_secs(2.0).as_ms() - 2000.0).abs() < 1e-9);
        assert_eq!(SimTime::from_ms(-5.0), SimTime::ZERO);
    }

    #[test]
    fn sub_is_ordered_difference() {
        assert_eq!(SimTime(30) - SimTime(10), SimTime(20));
        assert_eq!(SimTime(5) - SimTime(5), SimTime::ZERO);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SimTime underflow")]
    fn sub_underflow_asserts_in_debug() {
        let _ = SimTime(1) - SimTime(2);
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(30), "c");
        q.schedule_at(SimTime(10), "a");
        q.schedule_at(SimTime(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
        assert_eq!(q.now(), SimTime(30));
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.schedule_at(SimTime(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(10), ());
        q.pop();
        q.schedule_at(SimTime(5), ());
    }

    #[test]
    fn relative_scheduling_advances_from_now() {
        let mut q = EventQueue::new();
        q.schedule_in_ms(1.0, 1);
        let _ = q.pop();
        q.schedule_in_ms(2.0, 2);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(3000));
    }

    #[test]
    fn prop_monotone_nondecreasing_time() {
        forall(cases(50), |rng| {
            let mut q = EventQueue::new();
            for i in 0..rng.range_usize(1, 200) {
                q.schedule_at(SimTime(rng.next_u64() % 10_000), i);
            }
            let mut last = SimTime::ZERO;
            while let Some((t, _)) = q.pop() {
                if t < last {
                    return Err(format!("time went backwards: {t:?} < {last:?}"));
                }
                last = t;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_interleaved_schedule_pop_stays_consistent() {
        forall(cases(30), |rng| {
            let mut q = EventQueue::new();
            let mut popped = 0u64;
            for _ in 0..200 {
                if rng.bool(0.6) || q.is_empty() {
                    let delay = rng.range_f64(0.0, 50.0);
                    q.schedule_in_ms(delay, ());
                } else {
                    q.pop();
                    popped += 1;
                }
            }
            while q.pop().is_some() {
                popped += 1;
            }
            if popped != q.processed() {
                return Err("processed counter mismatch".into());
            }
            Ok(())
        });
    }
}
