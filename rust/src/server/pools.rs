//! Prefill/decode disaggregated serve pools (the P/D split).
//!
//! HAT's prompt chunking parallelizes long-prompt prefill, but in the
//! single-pool scheduler every prefill chunk still executes *inside the
//! same iteration* as the live decode rounds: a long-prompt aggressor's
//! 256-token middle call sits between two of an interactive stream's
//! tokens and inflates its TBT — the co-scheduling failure mode
//! P/D-disaggregation work (P/D-Device, EdgeShard) splits phases to
//! avoid.  This module is that split for the serve path:
//!
//! * a **prefill pool** — `[serve] prefill_workers` slots, throughput-
//!   oriented, batching wide over `cloud::Batcher` prefill chunks sized
//!   by the Eq. 3 optimizer;
//! * a **decode pool** — `[serve] decode_workers` slots, latency-
//!   oriented, iterating hat verify rounds;
//!
//! each a full [`Scheduler`] owning its own engine (own backend client,
//! own compile/exec counters), its own [`cloud::Batcher`] queue and its
//! own per-phase g^t state monitor.  Both engines share **one** paged KV
//! pool, which is what makes the boundary cheap: a session finishing
//! prefill is handed to the decode pool as a whole [`Session`] — hidden
//! state (pending token + last deep row) plus paged-KV *block tables* —
//! so the handoff transfers block ownership and copies no dense KV.
//!
//! ## Scheduling discipline
//!
//! [`PdScheduler::step`] is decode-first: the decode pool steps every
//! iteration, while the prefill pool steps only when the decode side has
//! slack (a free slot and no handoff waiting).  When the decode pool is
//! saturated, prefill work is *deferred* — this is exactly the knob that
//! keeps aggressor chunks from interleaving with live streams' rounds —
//! but never starved: after [`PREFILL_STARVE_BOUND`] consecutive
//! deferrals the prefill pool is stepped regardless, bounding aggressor
//! TTFT.  The whole coordinator is single-threaded and deterministic
//! (one engine-owning worker thread, like the single-pool path), so the
//! lifecycle property tests drive it step-by-step.
//!
//! ## Lifecycle at the seam
//!
//! Cancels, deadlines and client-death sweeps work in both pools *and*
//! in the in-between states (the prefill pool's handoff buffer, this
//! coordinator's pending queue).  A handoff can never race a cancel: the
//! session's prefill-pool epoch dies with the move and adoption stamps a
//! fresh decode-pool epoch, so a stale job from before the boundary can
//! never drive the adopted session.  Under `[serve] priority = preempt`
//! the decode pool parks a victim to make room for a waiting handoff —
//! preemption's anti-thrash bound (one park/resume per request) carries
//! over unchanged.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::{PriorityMode, ServeConfig, SpecDecConfig};
use crate::engine::Engine;
use crate::metrics::ServeStats;

use super::scheduler::{Active, Request, Scheduler};

/// Consecutive prefill deferrals the decode-first discipline may take
/// before the prefill pool is stepped regardless — the aggressor-TTFT
/// bound.
pub const PREFILL_STARVE_BOUND: u32 = 8;

/// The executor seam the engine worker drives: one iteration-stepped
/// continuous-batching scheduler, single-pool ([`Scheduler`]) or
/// disaggregated ([`PdScheduler`]).  All execution flows through an
/// implementation of this trait — admission code never calls the exec
/// backend directly (enforced by hatlint's `seam-pool`).
pub trait ServeExec {
    fn submit(&mut self, req: Request);
    fn cancel(&mut self, id: u64) -> bool;
    fn reap_all(&mut self);
    fn has_work(&self) -> bool;
    fn step(&mut self) -> usize;
    /// Sessions currently holding slots (across all pools).
    fn live_sessions(&self) -> usize;
    /// Requests queued but not yet holding a slot (across all pools) —
    /// the depth the front end's `serve.admit_queue` bound sheds
    /// against.
    fn queued(&self) -> usize;
    /// The stats block the connection layer's flow-control counters
    /// (`rate_limited`, `shed_busy`, `slow_reader_dropped`, the
    /// `open_conns` gauge) are recorded into.
    fn serve_stats(&mut self) -> &mut ServeStats;
    /// The full `OK …` STATS reply line (runtime counters + scheduler
    /// aggregates).
    fn stats_line(&mut self) -> String;
}

fn fmt_stats_line(
    rt: crate::backend::RuntimeStats,
    fields: String,
    g_learned: bool,
    queued: usize,
    live: usize,
    dq: usize,
    pq: usize,
) -> String {
    format!(
        "OK executions={} exec_ms={:.1} compiles={} compile_ms={:.1} {} \
         g_learned={} queued={} live={} decode_q={dq} prefill_q={pq}",
        rt.executions,
        rt.execute_ms,
        rt.compiles,
        rt.compile_ms,
        fields,
        g_learned as u8,
        queued,
        live,
    )
}

impl<'e> ServeExec for Scheduler<'e> {
    fn submit(&mut self, req: Request) {
        Scheduler::submit(self, req);
    }
    fn cancel(&mut self, id: u64) -> bool {
        Scheduler::cancel(self, id)
    }
    fn reap_all(&mut self) {
        Scheduler::reap_all(self);
    }
    fn has_work(&self) -> bool {
        Scheduler::has_work(self)
    }
    fn step(&mut self) -> usize {
        Scheduler::step(self)
    }
    fn live_sessions(&self) -> usize {
        Scheduler::live_sessions(self)
    }
    fn queued(&self) -> usize {
        Scheduler::queued(self)
    }
    fn serve_stats(&mut self) -> &mut ServeStats {
        &mut self.stats
    }
    fn stats_line(&mut self) -> String {
        self.refresh_kv_stats();
        let (dq, pq) = self.job_depths();
        fmt_stats_line(
            self.engine().reg.stats(),
            self.stats.stats_fields(),
            self.predictor_learned(),
            self.queued(),
            self.live_sessions(),
            dq,
            pq,
        )
    }
}

/// Deterministic coordinator over a prefill pool and a decode pool.
///
/// Single-threaded by design: both pools' engines live on the one
/// engine-owning worker thread (the backend is not `Send`), and
/// [`PdScheduler::step`] decides each iteration which pool runs.  The
/// disaggregation win is *iteration composition*, not thread
/// parallelism — decode iterations stop sharing their batch (and their
/// wall-clock) with 256-token aggressor chunks.
pub struct PdScheduler<'e> {
    prefill: Scheduler<'e>,
    decode: Scheduler<'e>,
    /// Handed-off sessions awaiting a decode slot (with their
    /// handoff-ready timestamps), when adoption found the pool full.
    pending: VecDeque<(Active<'e>, Instant)>,
    /// Consecutive iterations the prefill pool was deferred while it had
    /// work (the starvation counter behind [`PREFILL_STARVE_BOUND`]).
    starved: u32,
    priority: PriorityMode,
    deadline_ms: u64,
}

impl<'e> PdScheduler<'e> {
    /// Build the pool pair over two *sibling* engines (same artifacts,
    /// same shared KV pool — see [`Engine::sibling`]).  `cfg` must carry
    /// `prefill_workers > 0` and `decode_workers > 0`; each pool gets a
    /// [`Scheduler`] sized to its worker count, and the prefill side is
    /// switched into handoff mode.
    pub fn new(
        prefill_engine: &'e Engine,
        decode_engine: &'e Engine,
        spec_cfg: SpecDecConfig,
        cfg: ServeConfig,
    ) -> Result<PdScheduler<'e>> {
        ensure!(
            cfg.prefill_workers > 0 && cfg.decode_workers > 0,
            "disaggregated pools need prefill_workers > 0 and decode_workers > 0"
        );
        ensure!(
            prefill_engine.kv_pool().same_pool(decode_engine.kv_pool()),
            "pool engines must share one kv pool (block tables cross the handoff)"
        );
        let pf_cfg = ServeConfig { max_sessions: cfg.prefill_workers, ..cfg.clone() };
        let dc_cfg = ServeConfig { max_sessions: cfg.decode_workers, ..cfg.clone() };
        let mut prefill = Scheduler::new(prefill_engine, spec_cfg.clone(), pf_cfg);
        prefill.enable_handoff();
        let decode = Scheduler::new(decode_engine, spec_cfg, dc_cfg);
        Ok(PdScheduler {
            prefill,
            decode,
            pending: VecDeque::new(),
            starved: 0,
            priority: cfg.priority,
            deadline_ms: cfg.deadline_ms,
        })
    }

    /// Move handoff-ready sessions out of the prefill pool and adopt as
    /// many as the decode pool has slots for; the rest wait in `pending`
    /// (retried every iteration).  Under `priority = preempt`, a full
    /// decode pool parks one victim per waiting handoff.
    fn adopt_ready(&mut self) {
        for entry in self.prefill.take_handoffs() {
            self.pending.push_back(entry);
        }
        while let Some((a, ready)) = self.pending.pop_front() {
            match self.decode.adopt(a) {
                Ok(()) => {
                    self.decode
                        .stats
                        .decode_wait_ms
                        .push(ready.elapsed().as_secs_f64() * 1e3);
                }
                Err(a) => {
                    let retry = self.priority == PriorityMode::Preempt
                        && self.decode.preempt_one();
                    if retry {
                        match self.decode.adopt(a) {
                            Ok(()) => {
                                self.decode
                                    .stats
                                    .decode_wait_ms
                                    .push(ready.elapsed().as_secs_f64() * 1e3);
                                continue;
                            }
                            Err(a) => {
                                self.pending.push_front((a, ready));
                                break;
                            }
                        }
                    }
                    self.pending.push_front((a, ready));
                    break;
                }
            }
        }
    }

    /// Sweep the pending-handoff queue for dead clients and expired
    /// deadlines — the in-between state gets the same lifecycle
    /// guarantees as pool residence.
    fn sweep_pending(&mut self) {
        let deadline = self.deadline_ms;
        let stats = &mut self.decode.stats;
        self.pending.retain(|(a, _)| {
            if a.reply.is_dead() {
                stats.reaped += 1;
                return false;
            }
            if deadline > 0 && a.enqueued.elapsed().as_millis() as u64 >= deadline {
                a.reply.send("ERR deadline".into());
                stats.deadline_expired += 1;
                return false;
            }
            true
        });
    }

    /// Is the request resident in the prefill pool (incl. its handoff
    /// buffer)?  Paired with [`PdScheduler::in_decode`] for the
    /// no-dual-residence invariant the seam tests assert.
    pub fn in_prefill(&self, id: u64) -> bool {
        self.prefill.holds(id)
    }

    /// Is the request resident in the decode pool (incl. the pending
    /// adoption queue, which already left the prefill pool)?
    pub fn in_decode(&self, id: u64) -> bool {
        self.decode.holds(id) || self.pending.iter().any(|(a, _)| a.id == id)
    }

    /// Completed prefill→decode handoffs so far.
    pub fn handoffs(&self) -> u64 {
        self.decode.stats.handoffs
    }

    /// Merged aggregate stats of both pools (counters sum, Welford
    /// streams merge, shared-KV snapshots take the max).
    pub fn merged_stats(&mut self) -> ServeStats {
        self.prefill.refresh_kv_stats();
        self.decode.refresh_kv_stats();
        let mut m = ServeStats::new();
        m.merge(&self.prefill.stats);
        m.merge(&self.decode.stats);
        m.sampler_seed = self.prefill.stats.sampler_seed;
        m
    }

    pub fn queued(&self) -> usize {
        self.prefill.queued() + self.decode.queued() + self.pending.len()
    }

    pub fn live_sessions(&self) -> usize {
        self.prefill.live_sessions() + self.decode.live_sessions()
    }

    pub fn job_depths(&self) -> (usize, usize) {
        let (d1, p1) = self.prefill.job_depths();
        let (d2, p2) = self.decode.job_depths();
        (d1 + d2, p1 + p2)
    }
}

impl<'e> ServeExec for PdScheduler<'e> {
    /// Admission goes to the prefill pool; the session reaches the
    /// decode pool only through the handoff.
    fn submit(&mut self, req: Request) {
        self.prefill.submit(req);
    }

    /// Cancel wherever the request is resident: prefill pool (waiting /
    /// slot / parked / handoff buffer), the pending adoption queue, or
    /// the decode pool.  Ownership lives in exactly one place, so the
    /// first hit wins.
    fn cancel(&mut self, id: u64) -> bool {
        if self.prefill.cancel(id) {
            return true;
        }
        if let Some(i) = self.pending.iter().position(|(a, _)| a.id == id) {
            if let Some((a, _)) = self.pending.remove(i) {
                a.reply.send("ERR cancelled".into());
                self.decode.stats.cancelled += 1;
            }
            return true;
        }
        self.decode.cancel(id)
    }

    fn reap_all(&mut self) {
        self.prefill.reap_all();
        self.decode.stats.reaped += self.pending.len() as u64;
        self.pending.clear();
        self.decode.reap_all();
    }

    fn has_work(&self) -> bool {
        self.prefill.has_work() || !self.pending.is_empty() || self.decode.has_work()
    }

    /// One coordinator iteration: adopt ready handoffs, always step the
    /// decode pool, and step the prefill pool only under decode slack
    /// (or the starvation bound / idle-decode fallback).  Returns jobs
    /// executed across both pools.
    fn step(&mut self) -> usize {
        self.sweep_pending();
        self.adopt_ready();
        let mut n = self.decode.step();
        // Finished decode sessions just freed slots — adopt into them
        // before deciding whether the decode side has slack.
        self.adopt_ready();
        let slack = self.pending.is_empty()
            && self.decode.live_sessions() < self.decode.capacity();
        if slack || self.starved >= PREFILL_STARVE_BOUND || n == 0 {
            self.starved = 0;
            n += self.prefill.step();
            self.adopt_ready();
        } else if self.prefill.has_work() {
            self.starved += 1;
        }
        // Per-pool occupancy, sampled once per coordinator iteration.
        let pf = &mut self.prefill;
        pf.stats
            .prefill_occ
            .push(pf.live_sessions() as f64 / pf.capacity().max(1) as f64);
        let dc = &mut self.decode;
        dc.stats
            .decode_occ
            .push(dc.live_sessions() as f64 / dc.capacity().max(1) as f64);
        n
    }

    fn live_sessions(&self) -> usize {
        PdScheduler::live_sessions(self)
    }

    fn queued(&self) -> usize {
        PdScheduler::queued(self)
    }

    /// Front-end counters live on the decode side (they are summed, not
    /// doubled, by [`PdScheduler::merged_stats`] — the prefill pool's
    /// stay zero).
    fn serve_stats(&mut self) -> &mut ServeStats {
        &mut self.decode.stats
    }

    fn stats_line(&mut self) -> String {
        let mut rt = self.prefill.engine().reg.stats();
        let rt2 = self.decode.engine().reg.stats();
        rt.executions += rt2.executions;
        rt.execute_ms += rt2.execute_ms;
        rt.compiles += rt2.compiles;
        rt.compile_ms += rt2.compile_ms;
        rt.batch_occupancy += rt2.batch_occupancy;
        let learned = self.prefill.predictor_learned() || self.decode.predictor_learned();
        let fields = self.merged_stats().stats_fields();
        let (dq, pq) = self.job_depths();
        fmt_stats_line(rt, fields, learned, self.queued(), self.live_sessions(), dq, pq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TokenId;
    use crate::server::conn::ReplySink;
    use crate::server::generate;
    use crate::util::clock;
    use std::sync::atomic::{AtomicU64, Ordering};

    static NEXT_ID: AtomicU64 = AtomicU64::new(1_000_000);

    fn req(prompt: Vec<TokenId>, max_new: usize) -> (Request, ReplySink) {
        let rx = ReplySink::new();
        (
            Request {
                id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
                prompt,
                max_new,
                reply: rx.clone(),
                enqueued: clock::now(),
            },
            rx,
        )
    }

    fn sibling_pair() -> (Engine, Engine) {
        let a = Engine::synthetic();
        let b = Engine::with_registry_shared(
            crate::runtime::ArtifactRegistry::synthetic(),
            a.kv_pool(),
        )
        .unwrap();
        (a, b)
    }

    fn pd<'e>(
        pf: &'e Engine,
        dc: &'e Engine,
        prefill_workers: usize,
        decode_workers: usize,
    ) -> PdScheduler<'e> {
        let cfg = ServeConfig { prefill_workers, decode_workers, ..ServeConfig::default() };
        PdScheduler::new(pf, dc, SpecDecConfig::default(), cfg).unwrap()
    }

    fn drain(x: &mut PdScheduler<'_>) {
        let mut iters = 0;
        while x.has_work() {
            assert!(x.step() > 0, "pd scheduler idle with pending work");
            iters += 1;
            assert!(iters < 40_000, "pd scheduler failed to drain");
        }
    }

    #[test]
    fn handoff_streams_match_serial_generate() {
        let (pf, dc) = sibling_pair();
        let spec = SpecDecConfig::default();
        let reqs: Vec<(Vec<TokenId>, usize)> = vec![
            ((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 12),
            ((0u32..75).map(|i| (i * 5 + 2) % 256).collect(), 17),
            (vec![5, 9, 2, 14], 9),
            ((0u32..23).map(|i| (i * 11 + 7) % 256).collect(), 24),
            (vec![8, 1, 3], 1), // max_new = 1 finishes in the prefill pool
        ];
        let serial: Vec<String> = reqs
            .iter()
            .map(|(p, m)| generate(&pf, p, *m, &spec).unwrap().reply_line())
            .collect();
        let mut x = pd(&pf, &dc, 2, 3);
        let mut rxs = Vec::new();
        for (p, m) in &reqs {
            let (r, rx) = req(p.clone(), *m);
            x.submit(r);
            rxs.push(rx);
        }
        drain(&mut x);
        for (rx, want) in rxs.iter().zip(&serial) {
            assert_eq!(&rx.recv().unwrap(), want, "handoff changed a greedy-lossless stream");
        }
        let m = x.merged_stats();
        assert_eq!(m.finished, reqs.len());
        // Every multi-token request crossed the boundary exactly once;
        // the max_new = 1 request never handed off.
        assert_eq!(x.handoffs(), (reqs.len() - 1) as u64);
        assert!(m.decode_wait_ms.count() >= 4, "handoff waits recorded");
        assert!(m.prefill_wait_ms.count() as usize >= reqs.len());
        assert!(pf.kv_pool().quiesced(), "blocks leaked across the handoff seam");
    }

    #[test]
    fn pending_handoffs_never_dual_resident_and_drain_under_pressure() {
        // 1 decode slot, several concurrent prefills: handoffs outnumber
        // decode capacity, so sessions queue at the seam.  At every
        // step, no id may be resident in both pools.
        let (pf, dc) = sibling_pair();
        let mut x = pd(&pf, &dc, 3, 1);
        let mut rxs = Vec::new();
        let mut ids = Vec::new();
        for i in 0..5u32 {
            let (r, rx) = req(vec![i + 1, 40, 7, 9], 6);
            ids.push(r.id);
            x.submit(r);
            rxs.push(rx);
        }
        let mut iters = 0;
        while x.has_work() {
            assert!(x.step() > 0);
            for &id in &ids {
                assert!(
                    !(x.in_prefill(id) && x.in_decode(id)),
                    "request {id} resident in both pools"
                );
            }
            iters += 1;
            assert!(iters < 40_000);
        }
        for rx in &rxs {
            assert!(rx.recv().unwrap().starts_with("OK "));
        }
        assert!(pf.kv_pool().quiesced());
    }

    #[test]
    fn cancel_hits_every_residence_state() {
        let (pf, dc) = sibling_pair();
        let mut x = pd(&pf, &dc, 2, 1);
        // Fill the decode slot with a stream long enough to outlive the
        // next handoff's starvation-bounded prefill, so that handoff
        // parks at the seam.
        let (busy, rx_busy) = req((0u32..30).map(|i| i % 256).collect(), 64);
        x.submit(busy);
        while x.handoffs() < 1 {
            assert!(x.step() > 0);
        }
        // This one will be handoff-pending behind the busy decode slot.
        let (parked, rx_parked) = req(vec![3, 1, 4, 1, 5], 8);
        let parked_id = parked.id;
        x.submit(parked);
        // Step until it leaves the prefill pool for the seam's pending
        // queue (in the decode pool's custody but holding no slot), then
        // cancel it there.
        let mut iters = 0;
        while !(x.in_decode(parked_id) && !x.decode.holds(parked_id)) {
            assert!(x.step() > 0);
            iters += 1;
            assert!(iters < 10_000, "never reached the seam's pending state");
        }
        assert!(!x.in_prefill(parked_id), "seam residence must be exclusive");
        assert!(x.cancel(parked_id), "cancel must find the seam-resident session");
        assert_eq!(rx_parked.recv().unwrap(), "ERR cancelled");
        // Unknown id: nothing to cancel.
        assert!(!x.cancel(0xdead_beef));
        drain(&mut x);
        assert!(rx_busy.recv().unwrap().starts_with("OK "));
        assert!(pf.kv_pool().quiesced(), "cancelled seam session leaked blocks");
    }

    #[test]
    fn decode_first_discipline_defers_but_never_starves_prefill() {
        // Saturate the 1-slot decode pool with a long interactive
        // stream, then submit an aggressor: its prefill must be deferred
        // (starvation counter engages) yet still complete within the
        // bound.
        let (pf, dc) = sibling_pair();
        let mut x = pd(&pf, &dc, 1, 1);
        let (live, rx_live) = req(vec![2, 7, 1], 40);
        x.submit(live);
        while x.decode.live_sessions() == 0 {
            assert!(x.step() > 0);
        }
        let (agg, rx_agg) = req((0u32..120).map(|i| (i * 7 + 3) % 256).collect(), 2);
        x.submit(agg);
        // With the decode slot held, prefill only runs on forced steps:
        // within ~2 starvation windows the aggressor must still be
        // making progress (its prefill eventually completes).
        drain(&mut x);
        assert!(rx_live.recv().unwrap().starts_with("OK "));
        assert!(rx_agg.recv().unwrap().starts_with("OK "));
        let m = x.merged_stats();
        assert_eq!(m.finished, 2);
        assert!(pf.kv_pool().quiesced());
    }

    #[test]
    fn preempt_priority_parks_decode_victim_for_waiting_handoff() {
        let (pf, dc) = sibling_pair();
        let cfg = ServeConfig {
            prefill_workers: 1,
            decode_workers: 1,
            priority: PriorityMode::Preempt,
            ..ServeConfig::default()
        };
        let mut x = PdScheduler::new(&pf, &dc, SpecDecConfig::default(), cfg).unwrap();
        // Long enough to still hold the decode slot when the starvation
        // bound forces b's prefill through (>= 13 verify rounds even at
        // full greedy acceptance), so the adoption must park it.
        let (a, rx_a) = req(vec![2, 7, 1], 64);
        x.submit(a);
        while x.decode.live_sessions() == 0 {
            assert!(x.step() > 0);
        }
        let (b, rx_b) = req(vec![9, 9, 8], 4);
        x.submit(b);
        drain(&mut x);
        assert!(rx_a.recv().unwrap().starts_with("OK "));
        assert!(rx_b.recv().unwrap().starts_with("OK "));
        let m = x.merged_stats();
        assert!(m.preemptions >= 1, "full decode pool must park a victim for the handoff");
        assert!(pf.kv_pool().quiesced());
    }

    #[test]
    fn rejects_mismatched_pools_and_half_configured_workers() {
        let (pf, _) = sibling_pair();
        let other = Engine::synthetic(); // its own kv pool
        let cfg = ServeConfig { prefill_workers: 1, decode_workers: 1, ..ServeConfig::default() };
        assert!(PdScheduler::new(&pf, &other, SpecDecConfig::default(), cfg.clone()).is_err());
        let zero = ServeConfig { prefill_workers: 0, decode_workers: 1, ..ServeConfig::default() };
        assert!(PdScheduler::new(&pf, &pf, SpecDecConfig::default(), zero).is_err());
    }
}
