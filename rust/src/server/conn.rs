//! Event-driven serve front end: the connection layer.
//!
//! One non-blocking readiness loop ([`event_loop`]) owns the listener,
//! every client connection *and* the engine-stepping [`ServeExec`]
//! executor.  Each connection is a [`Conn`] state machine — incremental
//! read buffer → line framing → parse/validate → submit; replies drain
//! through a bounded per-client outbox on writability — polled between
//! scheduler iterations on the one engine-owning thread.  No
//! per-connection OS threads, no reply channels, no timeout-bounded
//! socket probes: the loop that steps the engine is the loop that sees a
//! client disconnect, so cancel-on-disconnect is an *event* (the `Ok(0)`
//! read), not a poll.
//!
//! Flow control the old thread-per-connection design could not express:
//!
//! - **Admission shedding** — a GENERATE arriving while the executor
//!   already holds `serve.admit_queue` queued requests is refused with
//!   `ERR busy` (counted in `shed_busy`) instead of growing the queue
//!   without bound.
//! - **Bounded outbox** — a client that stops reading past
//!   `serve.outbox_lines` queued reply lines is dropped
//!   (`slow_reader_dropped`); the loop never blocks on, and never
//!   buffers unboundedly for, a slow reader.
//! - **Per-client rate limits** — a token bucket per connection
//!   (`serve.rate_limit_rps` refill, `serve.burst` cap; 0 rps = off)
//!   refuses excess GENERATEs with `ERR rate limited` (`rate_limited`).
//! - **Incremental line cap** — the [`MAX_LINE_BYTES`] frame cap is
//!   enforced byte-by-byte as data arrives, so a never-terminating
//!   sender is rejected (`ERR line too long`, connection closed) while
//!   its line is still arriving, not after an unbounded buffered read.
//!
//! This module is the one sanctioned home of socket I/O in
//! `rust/src/server/` — hatlint's `seam-conn` lint keeps thread spawns
//! and blocking socket calls out of the rest of the server tree.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::util::clock;

use super::pools::ServeExec;
use super::scheduler::Request;
use super::{parse_line, Command};

/// Hard per-line byte cap, enforced *incrementally* during framing: the
/// connection is refused (`ERR line too long`, then closed) as soon as
/// an unterminated line crosses the cap, while the bytes are still
/// arriving.  Generous for the protocol's longest legitimate line (a
/// GENERATE carrying a full-context prompt).
pub const MAX_LINE_BYTES: usize = 16 * 1024;

/// Complete-but-unprocessed lines buffered per connection before the
/// loop stops draining its socket (TCP backpressure does the rest).
const PENDING_MAX: usize = 64;

/// Per-`read(2)` scratch size.
const READ_CHUNK: usize = 4096;

/// Consecutive fully-idle loop iterations (no accepts, no bytes, no
/// scheduler progress) before the loop naps instead of spinning.  The
/// spin window keeps accept/read latency in the microseconds while a
/// storm is in progress; the nap caps idle CPU burn.
const IDLE_SPINS: u32 = 256;
const IDLE_SLEEP: Duration = Duration::from_millis(1);

/// Error returned by [`ReplySink::recv`] / [`ReplySink::try_recv`] when
/// no reply line is queued.  Everything runs on one thread, so "empty"
/// is not "not yet": a reply either is queued or will never be.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("no reply queued in sink")
    }
}

impl std::error::Error for RecvError {}

#[derive(Default)]
struct SinkInner {
    queue: VecDeque<String>,
    closed: bool,
}

/// Reply mailbox for one request, with an observable liveness flag.
///
/// The single-threaded successor to the old mpsc `ReplyHandle`: the
/// scheduler `send`s the protocol reply line into the sink, the event
/// loop drains it into the owning connection's outbox, and the loop
/// marks the sink dead the moment it observes the client disconnect —
/// which is what lets `Scheduler::admit` prune queued work for dead
/// clients before it ever takes a slot.  Sends into a dead sink are
/// dropped (the old failed-channel-send semantics).
#[derive(Clone, Default)]
pub struct ReplySink {
    inner: Rc<RefCell<SinkInner>>,
}

impl ReplySink {
    pub fn new() -> ReplySink {
        ReplySink::default()
    }

    /// Queue a reply line; dropped if the client is already gone.
    pub fn send(&self, line: String) {
        let mut inner = self.inner.borrow_mut();
        if !inner.closed {
            inner.queue.push_back(line);
        }
    }

    /// Has the client been observed gone?
    pub fn is_dead(&self) -> bool {
        self.inner.borrow().closed
    }

    /// Mark the client gone (the event loop saw EOF/error, or a test
    /// simulating a disconnect).
    pub fn mark_dead(&self) {
        self.inner.borrow_mut().closed = true;
    }

    /// Pop the next queued reply line, if any.
    pub fn try_recv(&self) -> Result<String, RecvError> {
        self.inner.borrow_mut().queue.pop_front().ok_or(RecvError)
    }

    /// Alias of [`ReplySink::try_recv`]; named for the mpsc receiver
    /// call shape the direct-driving tests and benches use.
    pub fn recv(&self) -> Result<String, RecvError> {
        self.try_recv()
    }
}

/// Per-client token bucket: `rate_limit_rps` tokens/s refill up to a
/// `burst` cap; each admitted GENERATE spends one token.  `rps <= 0`
/// disables limiting (the default).
struct TokenBucket {
    rps: f64,
    burst: f64,
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn new(rps: f64, burst: usize) -> TokenBucket {
        TokenBucket { rps, burst: burst as f64, tokens: burst as f64, last: clock::now() }
    }

    fn allow(&mut self) -> bool {
        if self.rps <= 0.0 {
            return true;
        }
        let now = clock::now();
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.rps).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// One client connection as a state machine: non-blocking socket, the
/// current partial line, complete-but-unprocessed lines (strict pipeline
/// order — only CANCEL overtakes), the in-flight GENERATE's reply sink,
/// and the bounded write outbox.
struct Conn {
    stream: TcpStream,
    /// Bytes of the current, not-yet-newline-terminated line.
    rbuf: Vec<u8>,
    /// Complete lines awaiting processing.
    pending: VecDeque<String>,
    /// Framed reply lines awaiting socket writability.
    outbox: VecDeque<Vec<u8>>,
    /// Bytes of `outbox.front()` already written.
    wpos: usize,
    /// Reply mailbox of the in-flight GENERATE (fresh per request).
    sink: ReplySink,
    /// Id of the in-flight GENERATE, if any.
    inflight: Option<u64>,
    bucket: TokenBucket,
    max_new_cap: usize,
    admit_queue: usize,
    outbox_lines: usize,
    /// Stop reading; close once the outbox drains (QUIT, oversized line).
    close_after_flush: bool,
    /// EOF observed: disconnect after already-received lines are
    /// processed (a client's final pipelined command and its FIN can
    /// arrive in the same read burst).
    eof: bool,
    /// Remove this connection from the loop's set.
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, cfg: &ServeConfig, max_new_cap: usize) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            pending: VecDeque::new(),
            outbox: VecDeque::new(),
            wpos: 0,
            sink: ReplySink::new(),
            inflight: None,
            bucket: TokenBucket::new(cfg.rate_limit_rps, cfg.burst),
            max_new_cap,
            admit_queue: cfg.admit_queue,
            outbox_lines: cfg.outbox_lines,
            close_after_flush: false,
            eof: false,
            dead: false,
        }
    }

    /// One readiness pass: read/frame, process pending lines, drain the
    /// reply sink into the outbox, flush writes.  Returns whether any
    /// byte or state moved (the loop's idle detector).
    fn pump(&mut self, exec: &mut dyn ServeExec, next_id: &mut u64) -> bool {
        let mut activity = self.fill(exec);
        self.process(exec, next_id);
        if self.eof {
            // Disconnect only after `process` has seen the lines that
            // arrived with the FIN: a GENERATE pipelined right before
            // the close is still submitted — and then cancelled here,
            // which is what makes the disconnect observable as a
            // `cancelled` count rather than silently swallowed work.
            self.disconnect(exec);
        }
        self.drain_sink(exec);
        activity |= self.flush(exec);
        if self.close_after_flush && self.outbox.is_empty() && !self.dead {
            // Clean close: everything queued has been written.
            self.dead = true;
            activity = true;
        }
        activity
    }

    /// Non-blocking read: frame complete lines into `pending`, enforcing
    /// [`MAX_LINE_BYTES`] on every byte as it arrives.  EOF or a read
    /// error is the disconnect event.
    fn fill(&mut self, exec: &mut dyn ServeExec) -> bool {
        if self.dead || self.close_after_flush || self.eof {
            return false;
        }
        let mut buf = [0u8; READ_CHUNK];
        let mut activity = false;
        while self.pending.len() < PENDING_MAX {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.eof = true;
                    activity = true;
                    break;
                }
                Ok(n) => {
                    activity = true;
                    for &b in &buf[..n] {
                        if b == b'\n' {
                            let line = String::from_utf8_lossy(&self.rbuf).into_owned();
                            self.rbuf.clear();
                            self.pending.push_back(line);
                        } else {
                            self.rbuf.push(b);
                            if self.rbuf.len() > MAX_LINE_BYTES {
                                // Reject while the oversized line is
                                // still arriving — never buffer it out.
                                self.rbuf.clear();
                                self.pending.clear();
                                if let Some(id) = self.inflight.take() {
                                    self.sink.mark_dead();
                                    exec.cancel(id);
                                }
                                self.queue_reply(exec, "ERR line too long");
                                self.close_after_flush = true;
                                return true;
                            }
                        }
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.disconnect(exec);
                    break;
                }
            }
        }
        activity
    }

    /// Process pending lines in pipeline order.  While a GENERATE is in
    /// flight only a pipelined `CANCEL` may overtake it (the pending
    /// GENERATE then replies `ERR cancelled`); other lines wait.
    fn process(&mut self, exec: &mut dyn ServeExec, next_id: &mut u64) {
        loop {
            if self.dead || self.close_after_flush {
                return;
            }
            if let Some(id) = self.inflight {
                match self.pending.front() {
                    Some(l) if l.trim() == "CANCEL" => {
                        self.pending.pop_front();
                        exec.cancel(id);
                    }
                    _ => return,
                }
            } else {
                let Some(line) = self.pending.pop_front() else { return };
                self.handle_line(exec, &line, next_id);
            }
        }
    }

    fn handle_line(&mut self, exec: &mut dyn ServeExec, line: &str, next_id: &mut u64) {
        let cmd = match parse_line(line.trim(), self.max_new_cap) {
            Ok(c) => c,
            Err(e) => {
                self.queue_reply(exec, &format!("ERR {e}"));
                return;
            }
        };
        match cmd {
            Command::Quit => {
                self.queue_reply(exec, "OK bye");
                self.close_after_flush = true;
            }
            // Reached only with no generation in flight (in-flight
            // CANCELs are consumed by `process`).
            Command::Cancel => self.queue_reply(exec, "ERR nothing in flight"),
            Command::Stats => {
                let stats = exec.stats_line();
                self.queue_reply(exec, &stats);
            }
            Command::Generate { max_new, prompt } => {
                if !self.bucket.allow() {
                    exec.serve_stats().rate_limited += 1;
                    self.queue_reply(exec, "ERR rate limited");
                    return;
                }
                if exec.queued() >= self.admit_queue {
                    exec.serve_stats().shed_busy += 1;
                    self.queue_reply(exec, "ERR busy");
                    return;
                }
                let id = *next_id;
                *next_id += 1;
                self.sink = ReplySink::new();
                self.inflight = Some(id);
                exec.submit(Request {
                    id,
                    prompt,
                    max_new,
                    reply: self.sink.clone(),
                    enqueued: clock::now(),
                });
            }
        }
    }

    /// Move finished-generation replies from the sink to the outbox.
    fn drain_sink(&mut self, exec: &mut dyn ServeExec) {
        while let Ok(line) = self.sink.try_recv() {
            self.inflight = None;
            self.queue_reply(exec, &line);
            if self.dead {
                return;
            }
        }
    }

    /// Queue one reply line, enforcing the bounded outbox: a client that
    /// stops reading past `serve.outbox_lines` queued replies is dropped
    /// — the loop never stalls on a slow reader.
    fn queue_reply(&mut self, exec: &mut dyn ServeExec, line: &str) {
        if self.dead {
            return;
        }
        if self.outbox.len() >= self.outbox_lines {
            exec.serve_stats().slow_reader_dropped += 1;
            self.disconnect(exec);
            return;
        }
        let mut framed = Vec::with_capacity(line.len() + 1);
        framed.extend_from_slice(line.as_bytes());
        framed.push(b'\n');
        self.outbox.push_back(framed);
    }

    /// Non-blocking write of as much outbox as the socket accepts.
    fn flush(&mut self, exec: &mut dyn ServeExec) -> bool {
        let mut activity = false;
        while let Some(front) = self.outbox.front() {
            match self.stream.write(&front[self.wpos..]) {
                Ok(0) => break,
                Ok(n) => {
                    activity = true;
                    self.wpos += n;
                    if self.wpos >= front.len() {
                        self.outbox.pop_front();
                        self.wpos = 0;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    self.disconnect(exec);
                    break;
                }
            }
        }
        activity
    }

    /// The client is gone (EOF, read/write error, slow-reader drop):
    /// mark the sink dead so queued work is pruned, cancel any in-flight
    /// generation — the disconnect *is* the cancel event — and drop the
    /// connection from the loop's set.
    fn disconnect(&mut self, exec: &mut dyn ServeExec) {
        if self.dead {
            return;
        }
        self.dead = true;
        self.sink.mark_dead();
        self.pending.clear();
        if let Some(id) = self.inflight.take() {
            exec.cancel(id);
        }
    }
}

/// The serve event loop: accept (until `max_conns` accepts retire the
/// listener), pump every connection, step the executor, repeat — all on
/// the calling thread, which owns the engine.
///
/// Exit is an explicit loop condition, not an inference from dead reply
/// channels: once the listener is retired *and* no connection remains,
/// nothing can ever submit again, so the loop reaps abandoned work and
/// returns.  (`max_conns = usize::MAX` serves forever.)
pub fn event_loop(
    listener: &TcpListener,
    exec: &mut dyn ServeExec,
    max_new_cap: usize,
    cfg: &ServeConfig,
    max_conns: usize,
) -> Result<(), String> {
    listener.set_nonblocking(true).map_err(|e| format!("listener nonblocking: {e}"))?;
    let mut conns: Vec<Conn> = Vec::new();
    let mut accepted = 0usize;
    let mut next_id: u64 = 1;
    let mut idle_spins: u32 = 0;
    loop {
        let mut activity = false;
        while accepted < max_conns {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    // Only successful accepts count toward the bound:
                    // callers size max_conns exactly (tests, benches).
                    accepted += 1;
                    activity = true;
                    match stream.set_nonblocking(true) {
                        Ok(()) => conns.push(Conn::new(stream, cfg, max_new_cap)),
                        Err(e) => eprintln!("conn setup error: {e}"),
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) => {
                    eprintln!("accept error: {e}");
                    break;
                }
            }
        }
        exec.serve_stats().open_conns = conns.len();
        for c in conns.iter_mut() {
            activity |= c.pump(exec, &mut next_id);
        }
        if conns.iter().any(|c| c.dead) {
            conns.retain(|c| !c.dead);
            exec.serve_stats().open_conns = conns.len();
            activity = true;
        }
        if exec.has_work() {
            activity |= exec.step() > 0;
        }
        if accepted >= max_conns && conns.is_empty() {
            exec.reap_all();
            exec.serve_stats().open_conns = 0;
            return Ok(());
        }
        if activity {
            idle_spins = 0;
        } else {
            idle_spins = idle_spins.saturating_add(1);
            if idle_spins >= IDLE_SPINS {
                clock::sleep(IDLE_SLEEP);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_queues_in_order_and_reports_empty() {
        let sink = ReplySink::new();
        assert!(sink.try_recv().is_err());
        sink.send("a".into());
        sink.send("b".into());
        assert_eq!(sink.recv().unwrap(), "a");
        assert_eq!(sink.try_recv().unwrap(), "b");
        assert!(sink.recv().is_err());
    }

    #[test]
    fn dead_sink_drops_sends_and_clones_share_state() {
        let sink = ReplySink::new();
        let clone = sink.clone();
        assert!(!clone.is_dead());
        sink.mark_dead();
        assert!(clone.is_dead());
        clone.send("late".into());
        assert!(sink.try_recv().is_err());
    }

    #[test]
    fn token_bucket_disabled_at_zero_rps() {
        let mut b = TokenBucket::new(0.0, 1);
        for _ in 0..1000 {
            assert!(b.allow());
        }
    }

    #[test]
    fn token_bucket_spends_burst_then_refuses() {
        // Refill so slow (1 token per 10k seconds) the test window adds
        // nothing: exactly `burst` spends succeed.
        let mut b = TokenBucket::new(0.0001, 3);
        assert!(b.allow());
        assert!(b.allow());
        assert!(b.allow());
        assert!(!b.allow());
        assert!(!b.allow());
    }
}
