//! Continuous-batching serve scheduler: the engine worker's iteration loop.
//!
//! The old serve path ran one whole request at a time through `generate()`,
//! so concurrent connections head-of-line blocked behind entire
//! generations and `cloud::Batcher` + `cloud::chunker::optimal_chunk`
//! stayed simulator-only.  This module is the real-execution counterpart
//! of the paper's §3.3 cloud scheduler: the engine-owning thread holds up
//! to `max_sessions` live [`Session`]s, and every iteration pops work from
//! a [`Batcher`] — HAT verify rounds admitted first (tiny,
//! latency-critical), prefill chunks filling the remaining token budget
//! FIFO — so requests interleave at *chunk/round* granularity.
//!
//! Batches *execute as batches*: each iteration groups the formed batch's
//! jobs by kind and token bucket and issues **one batched engine call per
//! group** — `Engine::verify_batch` for the decode/verify rounds,
//! `Engine::cloud_middle_batch` for the prefill chunks — instead of
//! looping jobs through single-sequence calls.  Per-session KV caches and
//! positions thread independently through the batch lanes, so greedy
//! losslessness is untouched: every session's stream stays byte-identical
//! to a serial `generate()` run (tested in `tests/serve.rs`).
//!
//! Prefill chunk sizes come from the Eq. 3 optimizer (`optimal_chunk`)
//! driven by the *learned* state-monitor delay curve g^t(·) (Eq. 2 EWMAs
//! of observed per-iteration delays, falling back to the configured
//! static [`GModel`](crate::config::GModel) until observations arrive)
//! and the Eq. 1 moving average μ^t of observed batch sizes — not a
//! hard-coded constant.
//!
//! Session lifecycle: a request can leave the scheduler five ways —
//! finished (`OK …`), failed mid-flight (`ERR <cause>`), cancelled
//! (client disconnect noticed by its connection thread, or an explicit
//! `CANCEL` verb → `ERR cancelled`), deadline-expired
//! (`serve.deadline_ms` → `ERR deadline`), or reaped without a reply
//! (the client was already gone).  Teardown is always at an iteration
//! boundary: the slot is freed, the session (KV) dropped, and any still-
//! queued [`Batcher`] job for the slot is left to die on the slot-epoch
//! identity check — every admission gets a fresh epoch, every job is
//! stamped with its session's epoch, and the job runners drop jobs whose
//! epoch disagrees with the slot's current occupant, so a stale job can
//! never drive a session admitted after it was queued.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::cloud::state_monitor::StateMonitor;
use crate::cloud::{optimal_chunk, Batcher, Job, JobKind};
use crate::config::{AdmitPolicy, PriorityMode, ServeConfig, SpecDecConfig};
use crate::engine::Engine;
use crate::metrics::ServeStats;
use crate::model::{CloudStream, TokenId};
use crate::specdec::Session;
use crate::util::clock;

use super::conn::ReplySink;
use super::Generation;

/// Panic firewall for the serve hot path: run a session/engine call and
/// convert a panic (backend bug, slipped assert) into an `Err`, so the
/// existing per-lane failure machinery — ERR reply, serial fallback,
/// rollback — contains it.  The worker thread owns *every* live session;
/// an uncaught panic here would not fail one lane, it would take down all
/// of them and the listener's command channel with it.  State safety
/// matches the `Err` contract of each wrapped call: the batched engine
/// calls mutate no lane before success, and `verify_batch`-style rollback
/// runs in the caller's error arm either way.
fn catch<T>(what: &str, f: impl FnOnce() -> anyhow::Result<T>) -> anyhow::Result<T> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(anyhow::anyhow!("panic in {what}: {msg}"))
        }
    }
}

/// One GENERATE request submitted to the scheduler.
pub struct Request {
    /// Caller-assigned identity for targeted cancellation
    /// ([`Scheduler::cancel`]).  The TCP front-end draws these from one
    /// server-wide counter; ids must be unique among in-flight requests.
    pub id: u64,
    pub prompt: Vec<TokenId>,
    pub max_new: usize,
    /// Where the protocol reply line is sent when the request finishes
    /// (or fails / is cancelled).
    pub reply: ReplySink,
    /// Arrival time (queue-wait, TTFT and the deadline are measured from
    /// here).
    pub enqueued: Instant,
}

/// A request occupying a scheduler slot, with its live session.
///
/// `pub(super)` because it is also the currency of the prefill→decode
/// handoff: [`super::pools::PdScheduler`] moves whole `Active`s between
/// its two schedulers — the session *is* the hidden state plus the paged
/// KV block tables, so moving the struct moves the request with zero
/// dense-KV copies.
pub(super) struct Active<'e> {
    pub(super) id: u64,
    /// Admission epoch stamped into this session's batcher jobs: slot
    /// indices are reused, so a popped job is only valid for the slot's
    /// occupant if the epochs agree.
    epoch: u64,
    sess: Session<'e>,
    max_new: usize,
    out: Vec<TokenId>,
    rounds: usize,
    proposed: usize,
    accepted: usize,
    pub(super) reply: ReplySink,
    pub(super) enqueued: Instant,
    admitted: Instant,
    first_token: Option<Instant>,
    /// Has this session already been preempted and resumed once?  A
    /// resumed session is never picked as a preemption victim again —
    /// the anti-thrash rule that bounds each request to at most one
    /// park/resume cycle, so total preemption work is bounded by the
    /// request count and every session provably finishes.
    resumed: bool,
}

/// A job past its device half, awaiting its group's batched cloud call.
/// The payload is what the kind carries to the upload: a decode round's
/// row count (`P = usize`, the k+1 it buckets under) or a prefill chunk's
/// shallow hidden rows (`P = Vec<f32>`, [c, H]).
struct Staged<'e, P> {
    slot: usize,
    a: Active<'e>,
    payload: P,
}

impl<'e, P> Staged<'e, P> {
    fn stream(&mut self) -> &mut CloudStream {
        &mut self.a.sess.cloud
    }
    fn reply(&self) -> &ReplySink {
        &self.a.reply
    }
}

/// A decode round staged past drafting (payload: the k+1 upload rows).
type StagedVerify<'e> = Staged<'e, usize>;
/// A prefill chunk staged past the device submodels (payload: [c, H]).
type StagedPrefill<'e> = Staged<'e, Vec<f32>>;

/// Iteration-level scheduler over one engine: N live sessions multiplexed
/// through a [`Batcher`].
pub struct Scheduler<'e> {
    engine: &'e Engine,
    spec_cfg: SpecDecConfig,
    cfg: ServeConfig,
    batcher: Batcher,
    /// Slot i's session; `Job::req` indexes into this.
    slots: Vec<Option<Active<'e>>>,
    /// Admission queue beyond `max_sessions`.
    waiting: VecDeque<Request>,
    /// Sessions parked by preemption (`[serve] priority = preempt`): KV
    /// paged out to the pool's host store, no slot, no resident blocks.
    /// Resumed oldest-first into free slots *before* fresh admissions,
    /// so a parked request cannot starve behind the arrivals that
    /// displaced it.
    preempted: VecDeque<Active<'e>>,
    /// Monotonic admission counter: every session admitted into a slot
    /// gets the next epoch, stamped into its jobs (slot-reuse identity).
    next_epoch: u64,
    /// Handoff mode (the prefill pool of a disaggregated pair): a
    /// completed prefill parks its session here — first token emitted,
    /// nothing staged — instead of queueing a decode round, and the
    /// [`super::pools::PdScheduler`] moves it to the decode pool.  The
    /// timestamp is when the handoff became ready (`dc_wait_ms` measures
    /// from here to decode-slot adoption).
    handoff: bool,
    handoff_ready: VecDeque<(Active<'e>, Instant)>,
    /// State monitor (§3.2): μ^t (Eq. 1) over executed batch token sizes
    /// and the learned delay curve g^t(·) (Eq. 2) over observed iteration
    /// wall times, feeding the Eq. 3 chunk optimizer.
    monitor: StateMonitor,
    pub stats: ServeStats,
}

/// Clamp the Eq. 3 chunk bounds to the engine's largest compiled bucket
/// (a prefill chunk executes as one engine call).  Shared by the
/// scheduler and the serial [`generate`](super::generate) reference path.
pub fn clamp_chunk_bounds(cfg: &mut ServeConfig, engine: &Engine) {
    let max_bucket =
        engine.reg.manifest().buckets.iter().copied().max().unwrap_or(cfg.max_chunk);
    cfg.max_chunk = cfg.max_chunk.min(max_bucket).max(1);
    cfg.min_chunk = cfg.min_chunk.clamp(1, cfg.max_chunk);
}

/// Eq. 3 chunk size under `cfg`'s wire model and an explicit delay
/// predictor at cloud load μ (call [`clamp_chunk_bounds`] first).  The
/// scheduler passes the learned state-monitor curve here;
/// [`eq3_chunk`] is the static-`GModel` wrapper.
pub fn eq3_chunk_with(cfg: &ServeConfig, mu: f64, g: impl Fn(f64) -> f64) -> usize {
    optimal_chunk(
        cfg.a_bytes,
        cfg.up_bytes_per_ms,
        g,
        mu,
        cfg.pipeline_len,
        (cfg.min_chunk, cfg.max_chunk),
    )
}

/// Eq. 3 chunk size under `cfg`'s wire model and its *static* `GModel`
/// delay predictor (the serial `generate` path and cold-start behaviour).
pub fn eq3_chunk(cfg: &ServeConfig, mu: f64) -> usize {
    let g = cfg.g;
    eq3_chunk_with(cfg, mu, move |b| g.eval(b))
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e Engine, spec_cfg: SpecDecConfig, mut cfg: ServeConfig) -> Scheduler<'e> {
        clamp_chunk_bounds(&mut cfg, engine);
        // The learned g^t(·) must cover every batch size an iteration can
        // reach: the prefill budget plus every session's worst-case verify
        // upload.
        let g_max_tokens =
            cfg.prefill_budget + cfg.max_sessions.max(1) * (spec_cfg.max_draft + 1);
        let monitor = StateMonitor::new(cfg.alpha, 0, g_max_tokens);
        let slots = (0..cfg.max_sessions.max(1)).map(|_| None).collect();
        let mut stats = ServeStats::new();
        stats.sampler_seed = spec_cfg.seed;
        Scheduler {
            engine,
            spec_cfg,
            cfg,
            batcher: Batcher::new(),
            slots,
            waiting: VecDeque::new(),
            preempted: VecDeque::new(),
            next_epoch: 1,
            handoff: false,
            handoff_ready: VecDeque::new(),
            monitor,
            stats,
        }
    }

    /// Turn this scheduler into the *prefill pool* of a disaggregated
    /// pair: completed prefills park in the handoff buffer (first token
    /// emitted) instead of entering the decode loop here.
    pub(super) fn enable_handoff(&mut self) {
        self.handoff = true;
    }

    /// Total slots (pool size) — the denominator of the per-pool
    /// occupancy metric.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// The engine this scheduler's sessions execute on.
    pub(super) fn engine(&self) -> &'e Engine {
        self.engine
    }

    /// Is a request resident here, in any state — waiting, parked,
    /// holding a slot, or sitting in the handoff buffer?  The
    /// disaggregation invariant ("no session in both pools") is asserted
    /// over this.
    pub fn holds(&self, id: u64) -> bool {
        self.waiting.iter().any(|r| r.id == id)
            || self.preempted.iter().any(|a| a.id == id)
            || self.handoff_ready.iter().any(|(a, _)| a.id == id)
            || self.slots.iter().any(|s| s.as_ref().is_some_and(|a| a.id == id))
    }

    /// Drain the handoff buffer (prefill pool → [`super::pools`]).  Each
    /// entry's session has its first token committed and nothing staged;
    /// its old epoch dies with the move — adoption stamps a fresh one, so
    /// a job queued here can never drive the session in the decode pool
    /// (the handoff-racing-a-cancel hazard).
    pub(super) fn take_handoffs(&mut self) -> Vec<(Active<'e>, Instant)> {
        self.handoff_ready.drain(..).collect()
    }

    /// Adopt a handed-off session into a free slot (decode pool side):
    /// re-home the session on this scheduler's engine (zero-copy — same
    /// KV pool, block tables move by ownership), stamp a fresh admission
    /// epoch, and queue its first decode round.  `Err(a)` hands the
    /// session back when no slot is free (the caller retries next
    /// iteration); a rebind failure fails the lane and consumes it.
    pub(super) fn adopt(&mut self, mut a: Active<'e>) -> Result<(), Active<'e>> {
        let Some(i) = self.slots.iter().position(|s| s.is_none()) else {
            return Err(a);
        };
        let engine = self.engine;
        if let Err(e) = catch("rebind", || a.sess.rebind(engine)) {
            self.fail(&a.reply, &e);
            return Ok(());
        }
        a.epoch = self.next_epoch;
        self.next_epoch += 1;
        let j = self.decode_job(i, a.epoch);
        self.batcher.push(j);
        self.slots[i] = Some(a);
        self.stats.handoffs += 1;
        Ok(())
    }

    /// Enqueue a request (admitted to a slot on a later [`Scheduler::step`]).
    /// Validation failures — including the shared request checks of
    /// [`validate_request`](super::validate_request), which the protocol
    /// parser applies too — are rejected immediately.
    pub fn submit(&mut self, req: Request) {
        if let Err(e) =
            super::validate_request(&req.prompt, req.max_new, self.spec_cfg.max_new_tokens)
        {
            self.fail(&req.reply, e);
            return;
        }
        let max_ctx = self.engine.spec().max_seq;
        if req.prompt.len() + req.max_new + self.spec_cfg.max_draft + 2 > max_ctx {
            self.fail(&req.reply, format!("prompt+generation exceeds model max_seq {max_ctx}"));
            return;
        }
        self.waiting.push_back(req);
    }

    /// Cancel a request by id.  A waiting request is removed from the
    /// queue; a live one is torn down — slot freed, session (KV cache)
    /// dropped, any staged mid-round state aborted.  Its queued batcher
    /// job is deliberately *not* swept here: cancellation is the churn
    /// hot path, so teardown stays O(sessions), and the job — now
    /// carrying a dead admission's epoch — is dropped the moment a job
    /// runner pops it ([`Scheduler::take_for_job`]).  Either way the
    /// reply channel gets `ERR cancelled` — a no-op when the client is
    /// already gone.  Returns false when the id is unknown, i.e. the
    /// request already finished (the race is benign: cancelling a
    /// finished request does nothing).
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(i) = self.waiting.iter().position(|r| r.id == id) {
            if let Some(r) = self.waiting.remove(i) {
                r.reply.send("ERR cancelled".into());
                self.stats.cancelled += 1;
            }
            return true;
        }
        if let Some(i) = self.preempted.iter().position(|a| a.id == id) {
            if let Some(a) = self.preempted.remove(i) {
                // Parked sessions hold no staged state and no resident
                // blocks; dropping the Active frees the host-store copy.
                a.reply.send("ERR cancelled".into());
                self.stats.cancelled += 1;
            }
            return true;
        }
        if let Some(i) = self.handoff_ready.iter().position(|(a, _)| a.id == id) {
            if let Some((a, _)) = self.handoff_ready.remove(i) {
                // A cancel arriving while the session sits between pools:
                // it never reaches the decode pool (nothing staged, so
                // dropping the Active releases its blocks cleanly).
                a.reply.send("ERR cancelled".into());
                self.stats.cancelled += 1;
            }
            return true;
        }
        for slot in self.slots.iter_mut() {
            if slot.as_ref().is_some_and(|a| a.id == id) {
                if let Some(mut a) = slot.take() {
                    a.sess.abort_staged();
                    a.reply.send("ERR cancelled".into());
                    self.stats.cancelled += 1;
                }
                return true;
            }
        }
        false
    }

    /// Tear down every waiting and live request without sending replies.
    /// The event loop calls this on exit, once the listener is retired
    /// and the last connection is gone: every reply sink is provably
    /// dead, so finishing the remaining work would only burn compute
    /// into dead sinks.  Counted as `reaped`.
    pub fn reap_all(&mut self) {
        self.stats.reaped += self.waiting.len() as u64;
        self.waiting.clear();
        self.stats.reaped += self.preempted.len() as u64;
        self.preempted.clear();
        self.stats.reaped += self.handoff_ready.len() as u64;
        self.handoff_ready.clear();
        for i in 0..self.slots.len() {
            if let Some(mut a) = self.slots[i].take() {
                a.sess.abort_staged();
                self.batcher.remove_session(i);
                self.stats.reaped += 1;
            }
        }
    }

    /// Anything queued, parked, handoff-pending, or live?
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty()
            || !self.preempted.is_empty()
            || !self.handoff_ready.is_empty()
            || self.slots.iter().any(|s| s.is_some())
    }

    /// Requests waiting for a slot: fresh admissions, preempted sessions
    /// parked for resume, and handoff-ready sessions awaiting decode
    /// adoption (so in-flight submissions always reconcile as queued +
    /// live + terminal outcomes).
    pub fn queued(&self) -> usize {
        self.waiting.len() + self.preempted.len() + self.handoff_ready.len()
    }

    /// Sessions currently occupying slots.
    pub fn live_sessions(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Pending (decode, prefill) jobs in the batcher.
    pub fn job_depths(&self) -> (usize, usize) {
        (self.batcher.decode_pending(), self.batcher.prefill_pending())
    }

    /// One scheduler iteration: admit waiting requests into free slots,
    /// form a batch under the prefill token budget, group its jobs by kind
    /// and token bucket, and issue **one batched engine call per group**.
    /// Returns the number of jobs executed (0 = idle).  While any session
    /// is live, every iteration makes progress on every decoding session
    /// and on at least the head prefill chunk, so no admitted request can
    /// starve.
    pub fn step(&mut self) -> usize {
        self.expire_deadlines();
        self.admit();
        let batch = self.batcher.form_batch(self.cfg.prefill_budget);
        if batch.is_empty() {
            self.refresh_kv_stats();
            return 0;
        }
        self.stats.iterations += 1;
        let n = batch.len();
        let (decode_jobs, prefill_jobs): (Vec<Job>, Vec<Job>) =
            batch.into_iter().partition(|j| j.kind == JobKind::Decode);
        let (decode_tokens, decode_cloud_ms) = self.run_decode_jobs(decode_jobs);
        let (prefill_tokens, prefill_cloud_ms) = self.run_prefill_jobs(prefill_jobs);
        // Feed the state monitor (§3.2): μ^t averages *executed* batch
        // tokens, and g^t learns (batch tokens → η̂^t), the *in-cloud*
        // computation delay of the iteration's batched cloud calls — not
        // whole-iteration wall time, which would fold device drafting into
        // the curve Eq. 3 treats as cloud-side — so the optimizer tracks
        // the real engine instead of the static GModel.  The phases feed
        // *separate* delay curves: Eq. 3 chunk sizing reads only the
        // prefill curve, so a burst of small fast decode rounds must not
        // drag its small-batch buckets toward decode latencies.
        // Stale-job-only iterations execute nothing and must not drag the
        // curves to zero.
        if decode_tokens > 0 {
            self.monitor.observe_decode(decode_tokens, decode_cloud_ms);
        }
        if prefill_tokens > 0 {
            self.monitor.observe_prefill(prefill_tokens, prefill_cloud_ms);
        }
        self.refresh_kv_stats();
        n
    }

    /// Snapshot the shared KV pool's occupancy counters into `stats`
    /// (`kv_blocks` / `kv_shared` on the STATS wire line).  Runs at every
    /// iteration boundary and on each STATS request, so the numbers track
    /// current block usage rather than usage at some past event.
    pub fn refresh_kv_stats(&mut self) {
        let p = self.engine.kv_pool().stats();
        self.stats.kv_blocks_in_use = p.blocks_in_use;
        self.stats.kv_blocks_shared = p.shared_blocks;
    }

    /// Cancel live sessions whose wall-clock deadline (measured from
    /// arrival) has passed: `ERR deadline` reply, slot freed, queued
    /// jobs removed.  Waiting requests are expired in [`Scheduler::admit`]
    /// before they can take a slot.
    fn expire_deadlines(&mut self) {
        if self.cfg.deadline_ms == 0 {
            return;
        }
        let deadline = self.cfg.deadline_ms;
        let mut kept = VecDeque::with_capacity(self.handoff_ready.len());
        for (a, ready) in self.handoff_ready.drain(..) {
            if a.enqueued.elapsed().as_millis() as u64 >= deadline {
                a.reply.send("ERR deadline".into());
                self.stats.deadline_expired += 1;
            } else {
                kept.push_back((a, ready));
            }
        }
        self.handoff_ready = kept;
        for i in 0..self.slots.len() {
            let expired = self.slots[i]
                .as_ref()
                .is_some_and(|a| a.enqueued.elapsed().as_millis() as u64 >= self.cfg.deadline_ms);
            if expired {
                if let Some(mut a) = self.slots[i].take() {
                    a.sess.abort_staged();
                    self.batcher.remove_session(i);
                    a.reply.send("ERR deadline".into());
                    self.stats.deadline_expired += 1;
                }
            }
        }
    }

    /// Pick the next waiting request under the configured admission
    /// policy.  FIFO pops the oldest; SJF picks the shortest prompt,
    /// bounded by aging — once the *oldest* waiter has waited
    /// `sjf_aging_ms`, it goes first regardless of length.
    fn next_admission(&mut self) -> Option<Request> {
        match self.cfg.policy {
            AdmitPolicy::Fifo => self.waiting.pop_front(),
            AdmitPolicy::Sjf => {
                let aged = self.waiting.front().is_some_and(|r| {
                    r.enqueued.elapsed().as_millis() as u64 >= self.cfg.sjf_aging_ms
                });
                if aged {
                    return self.waiting.pop_front();
                }
                let i = (0..self.waiting.len()).min_by_key(|&i| self.waiting[i].prompt.len())?;
                self.waiting.remove(i)
            }
        }
    }

    /// Admission pass: sweep dead/expired entries from both queues,
    /// resume parked sessions into free slots (oldest first, ahead of
    /// fresh admissions), fill the remaining slots from the waiting
    /// queue, and — under `[serve] priority = preempt` — park live
    /// sessions to make room for admissions that would otherwise wait.
    /// A dead or doomed request must never cost a slot or a token of
    /// cloud compute, so the sweeps run before anything takes a slot.
    fn admit(&mut self) {
        let before = self.waiting.len();
        self.waiting.retain(|r| !r.reply.is_dead());
        self.stats.reaped += (before - self.waiting.len()) as u64;
        let before = self.preempted.len();
        self.preempted.retain(|a| !a.reply.is_dead());
        self.stats.reaped += (before - self.preempted.len()) as u64;
        let before = self.handoff_ready.len();
        self.handoff_ready.retain(|(a, _)| !a.reply.is_dead());
        self.stats.reaped += (before - self.handoff_ready.len()) as u64;
        if self.cfg.deadline_ms > 0 {
            let deadline = self.cfg.deadline_ms;
            let mut kept = VecDeque::with_capacity(self.waiting.len());
            for r in self.waiting.drain(..) {
                if r.enqueued.elapsed().as_millis() as u64 >= deadline {
                    r.reply.send("ERR deadline".into());
                    self.stats.deadline_expired += 1;
                } else {
                    kept.push_back(r);
                }
            }
            self.waiting = kept;
            let mut kept = VecDeque::with_capacity(self.preempted.len());
            for a in self.preempted.drain(..) {
                if a.enqueued.elapsed().as_millis() as u64 >= deadline {
                    a.reply.send("ERR deadline".into());
                    self.stats.deadline_expired += 1;
                } else {
                    kept.push_back(a);
                }
            }
            self.preempted = kept;
        }
        self.resume_preempted();
        self.fill_free_slots();
        if self.cfg.priority == PriorityMode::Preempt && !self.waiting.is_empty() {
            self.preempt_for_waiting();
            self.fill_free_slots();
        }
    }

    /// Resume parked sessions into free slots, oldest first.  Swap-in can
    /// fail under pool pressure; the session then goes back to the front
    /// of the parked queue and is retried next iteration, once live
    /// sessions have released blocks (a parked session holds none, so
    /// with no live session left a failure is unrecoverable and fails
    /// the lane instead of spinning).
    fn resume_preempted(&mut self) {
        while !self.preempted.is_empty() {
            let Some(i) = self.slots.iter().position(|s| s.is_none()) else { break };
            let Some(mut a) = self.preempted.pop_front() else { break };
            match catch("swap_in", || a.sess.swap_in()) {
                Ok(bytes) => {
                    self.stats.kv_swap_bytes += bytes;
                    a.resumed = true;
                    // Fresh epoch: any job still queued from before the
                    // preemption must not drive the resumed session.
                    a.epoch = self.next_epoch;
                    self.next_epoch += 1;
                    // A preemption victim is always past prefill (it has
                    // a pending token), so it resumes straight into the
                    // decode loop.
                    let j = self.decode_job(i, a.epoch);
                    self.batcher.push(j);
                    self.slots[i] = Some(a);
                }
                Err(e) => {
                    if self.slots.iter().any(|s| s.is_some()) {
                        self.preempted.push_front(a);
                    } else {
                        self.fail(&a.reply, &e);
                    }
                    break;
                }
            }
        }
    }

    /// Park live sessions to free slots for waiting admissions
    /// (`priority = preempt`).  Eligible victims are past prefill (they
    /// hold a committed stream a resume can continue exactly) and have
    /// never been resumed (the anti-thrash bound); among them, the one
    /// with the most remaining tokens goes first — it holds the slot
    /// longest.  The victim's staged state is aborted, its queued jobs
    /// die on the epoch check, its KV pages out to the host store, and
    /// it parks at the back of the resume queue.
    fn preempt_for_waiting(&mut self) {
        let mut want = self.waiting.len();
        while want > 0 {
            if self.slots.iter().any(|s| s.is_none()) {
                break; // a slot is already free for the next admission
            }
            if !self.preempt_one() {
                break;
            }
            want -= 1;
        }
    }

    /// Park one preemption victim (the shared step of
    /// [`Scheduler::preempt_for_waiting`], also driven directly by the
    /// disaggregated pool coordinator to make room for a handoff
    /// adoption).  Victim rules unchanged: past prefill, never resumed,
    /// most remaining tokens.  Returns whether a victim was parked.
    pub(super) fn preempt_one(&mut self) -> bool {
        let victim = (0..self.slots.len())
            .filter(|&i| {
                self.slots[i]
                    .as_ref()
                    .is_some_and(|a| !a.resumed && a.first_token.is_some())
            })
            .max_by_key(|&i| {
                self.slots[i].as_ref().map_or(0, |a| a.max_new.saturating_sub(a.out.len()))
            });
        let Some(i) = victim else { return false };
        if let Some(mut a) = self.slots[i].take() {
            a.sess.abort_staged();
            self.batcher.remove_session(i);
            self.stats.kv_swap_bytes += a.sess.swap_out();
            self.stats.preemptions += 1;
            self.preempted.push_back(a);
        }
        true
    }

    /// Move waiting requests into free slots and queue their first
    /// prefill chunk.
    fn fill_free_slots(&mut self) {
        while !self.waiting.is_empty() {
            let Some(i) = self.slots.iter().position(|s| s.is_none()) else { break };
            let Some(req) = self.next_admission() else { break };
            match Session::new(self.engine, self.spec_cfg.clone()) {
                Ok(mut sess) => {
                    if let Err(e) = catch("prefill_begin", || sess.prefill_begin(&req.prompt)) {
                        self.fail(&req.reply, &e);
                        continue;
                    }
                    let epoch = self.next_epoch;
                    self.next_epoch += 1;
                    let chunk = self.plan_chunk(sess.prefill_remaining());
                    self.batcher.push(Job {
                        req: i,
                        kind: JobKind::PrefillChunk,
                        tokens: chunk,
                        epoch,
                    });
                    // Queue-wait split, prefill side: arrival →
                    // prefill-slot admission (the handoff→decode wait is
                    // recorded separately as dc_wait_ms).
                    self.stats
                        .prefill_wait_ms
                        .push(req.enqueued.elapsed().as_secs_f64() * 1e3);
                    self.slots[i] = Some(Active {
                        id: req.id,
                        epoch,
                        sess,
                        max_new: req.max_new,
                        out: Vec::new(),
                        rounds: 0,
                        proposed: 0,
                        accepted: 0,
                        reply: req.reply,
                        enqueued: req.enqueued,
                        admitted: clock::now(),
                        first_token: None,
                        resumed: false,
                    });
                }
                Err(e) => {
                    self.fail(&req.reply, &e);
                }
            }
        }
    }

    /// Send a failure reply and count it (`failed` in STATS) — every
    /// `ERR` path that isn't a cancel/deadline/reap routes through here,
    /// submit-time rejections included, so submissions reconcile against
    /// `finished + failed + cancelled + deadline_expired + reaped +
    /// queued + live`.
    fn fail(&mut self, reply: &ReplySink, e: impl std::fmt::Display) {
        reply.send(format!("ERR {e}"));
        self.stats.failed += 1;
    }

    /// Eq. 3 chunk size for a session's next prefill chunk, clamped to the
    /// tokens it still needs.  Uses the learned g^t(·) delay curve when
    /// `learned_g` is on (static `GModel` as the cold-start fallback),
    /// the static curve alone otherwise.
    fn plan_chunk(&mut self, remaining: usize) -> usize {
        let g_static = self.cfg.g;
        let mu = self.monitor.mu_t();
        let x = if self.cfg.learned_g {
            let mon = &self.monitor;
            eq3_chunk_with(&self.cfg, mu, |b| mon.g_t(b, |x| g_static.eval(x)))
        } else {
            eq3_chunk(&self.cfg, mu)
        };
        // Record the *executed* chunk size, after the clamp to the prompt
        // tokens actually remaining: recording the raw Eq. 3 plan made
        // STATS `chunk_mean` overstate chunk sizes whenever the prompt
        // tail was shorter than the plan.
        let x = x.min(remaining).max(1);
        self.stats.chunk_sizes.push(x as f64);
        x
    }

    /// Whether the Eq. 3 optimizer is currently driven by *learned* delay
    /// observations (vs the static `GModel` fallback) — `g_learned` in
    /// the STATS reply.
    pub fn predictor_learned(&self) -> bool {
        self.cfg.learned_g && self.monitor.g.predict(1.0).is_some()
    }

    /// The next verify-round job for a slot, stamped with the session's
    /// admission epoch.  Decode `tokens` is informational only (the
    /// batcher admits every decode job regardless and μ^t averages
    /// *executed* sizes): one convention, the worst-case upload of
    /// max_draft proposals plus the bonus row.
    fn decode_job(&self, req: usize, epoch: u64) -> Job {
        Job { req, kind: JobKind::Decode, tokens: self.spec_cfg.max_draft + 1, epoch }
    }

    /// Take the slot's occupant for a popped job, dropping the job if it
    /// is stale: the slot is empty (its session finished or failed
    /// earlier in this batch) or holds a *different* admission (the slot
    /// was freed by a cancel/expiry and reused) — driving the new
    /// session with an old job is exactly the slot-reuse hazard the
    /// epoch stamp closes.
    fn take_for_job(&mut self, job: &Job) -> Option<Active<'e>> {
        let live = self.slots[job.req].as_ref().is_some_and(|a| a.epoch == job.epoch);
        if !live {
            self.stats.stale_dropped += 1;
            return None;
        }
        self.slots[job.req].take()
    }

    /// Execute this iteration's decode/verify jobs.  The device halves
    /// (drafting, parallel-draft branches) run per session — each lives on
    /// its own device in the real deployment — then the cloud halves of
    /// same-bucket rounds execute as **one** batched middle call plus
    /// **one** batched head call ([`Engine::cloud_middle_batch`] /
    /// [`Engine::head_batch`]; [`Engine::verify_batch`] is their one-shot
    /// composition — the scheduler keeps the stages separate so each has
    /// a state-safe per-lane fallback).  Returns the uploaded verify rows
    /// — what μ^t must average, as opposed to the jobs' *planned* sizes —
    /// and the in-cloud ms spent in the cloud calls (the η̂^t feeding
    /// g^t).
    fn run_decode_jobs(&mut self, jobs: Vec<Job>) -> (usize, f64) {
        // Device half: draft every session's round; its k+1 upload rows
        // decide the bucket it batches under.
        let mut staged: Vec<StagedVerify<'e>> = Vec::new();
        for job in jobs {
            let Some(mut a) = self.take_for_job(&job) else {
                continue; // stale job (session finished/failed/cancelled)
            };
            let remaining = a.max_new - a.out.len();
            let budget = remaining.saturating_sub(1).max(1);
            let max_draft = self.spec_cfg.max_draft;
            match catch("verify_begin", || a.sess.verify_begin(true, max_draft, budget)) {
                Ok(rows) => staged.push(StagedVerify { slot: job.req, a, payload: rows }),
                Err(e) => {
                    self.fail(&a.reply, &e);
                }
            }
        }
        // Group by token bucket (BTreeMap: deterministic group order).
        let mut groups: BTreeMap<usize, Vec<StagedVerify<'e>>> = BTreeMap::new();
        for sv in staged {
            match self.engine.reg.bucket_for(sv.payload) {
                Ok(b) => groups.entry(b).or_default().push(sv),
                Err(e) => {
                    self.fail(&sv.a.reply, &e);
                }
            }
        }
        // Cloud half: one batched middle call + one batched head call per
        // group, each with a per-lane serial fallback so one poisoned lane
        // cannot take out its co-batched sessions (the serial path's
        // failure domain).
        let mut executed = 0usize;
        let mut cloud_ms = 0.0f64;
        for (_bucket, mut group) in groups {
            let shallows: Vec<Vec<f32>> =
                group.iter_mut().map(|sv| sv.a.sess.take_verify_shallow()).collect();
            // Middle stage (KV-mutating).
            let lanes =
                self.middle_with_fallback(group, shallows, &mut executed, &mut cloud_ms);
            // Head stage (stateless).
            let (heads, head_ms) = {
                let refs: Vec<&[f32]> = lanes.iter().map(|(_, d)| d.as_slice()).collect();
                let t0 = clock::now();
                let r = catch("batched head call", || self.engine.head_batch(&refs));
                (r, t0.elapsed().as_secs_f64() * 1e3)
            };
            match heads {
                Ok(logits) => {
                    cloud_ms += head_ms;
                    for ((sv, deep), l) in lanes.into_iter().zip(logits) {
                        self.complete_verify(sv.slot, sv.a, &deep, &l);
                    }
                }
                Err(e) => {
                    if lanes.len() <= 1 {
                        // Retrying a 1-lane batch re-issues the identical
                        // call: fail the lane instead.
                        for (sv, _) in lanes {
                            self.fail(&sv.a.reply, &e);
                        }
                    } else {
                        eprintln!(
                            "batched head call failed ({e}); degrading {}-lane group to serial",
                            lanes.len()
                        );
                        self.stats.fallbacks += 1;
                        for (sv, deep) in lanes {
                            let t0 = clock::now();
                            match catch("serial head call", || self.engine.head(&deep)) {
                                Ok(l) => {
                                    cloud_ms += t0.elapsed().as_secs_f64() * 1e3;
                                    self.complete_verify(sv.slot, sv.a, &deep, &l);
                                }
                                Err(e) => {
                                    self.fail(&sv.a.reply, &e);
                                }
                            }
                        }
                    }
                }
            }
        }
        (executed, cloud_ms)
    }

    /// Finish one session's verify round given its verified (deep, logits)
    /// lane: acceptance bookkeeping, requeue or completion.
    fn complete_verify(&mut self, slot: usize, mut a: Active<'e>, deep: &[f32], logits: &[f32]) {
        match catch("verify_finish", || a.sess.verify_finish(deep, logits)) {
            Ok(r) => {
                a.rounds += 1;
                a.proposed += r.proposed.len();
                a.accepted += r.accepted;
                self.stats.record_round(r.accepted);
                a.out.extend_from_slice(&r.emitted);
                if a.out.len() >= a.max_new {
                    a.out.truncate(a.max_new);
                    self.finish(a);
                } else {
                    let j = self.decode_job(slot, a.epoch);
                    self.batcher.push(j);
                    self.slots[slot] = Some(a);
                }
            }
            Err(e) => {
                self.fail(&a.reply, &e);
            }
        }
    }

    /// Execute this iteration's prefill-chunk jobs.  The device halves
    /// (input + adapter submodels) run per session, then same-bucket
    /// chunks upload through **one** batched middle call
    /// ([`Engine::cloud_middle_batch`]).  Returns the prefill rows
    /// processed and the in-cloud ms spent in the batched calls.
    fn run_prefill_jobs(&mut self, jobs: Vec<Job>) -> (usize, f64) {
        let h = self.engine.spec().hidden;
        let mut executed = 0usize;
        // Device half: run each chunk up to the upload boundary.
        let mut staged: Vec<StagedPrefill<'e>> = Vec::new();
        for job in jobs {
            let Some(mut a) = self.take_for_job(&job) else {
                continue; // stale job (session finished/failed/cancelled)
            };
            match catch("prefill_chunk_begin", || a.sess.prefill_chunk_begin(job.tokens)) {
                Ok(hidden) => staged.push(StagedPrefill { slot: job.req, a, payload: hidden }),
                Err(e) => {
                    self.fail(&a.reply, &e);
                }
            }
        }
        // Group by the chunk's token bucket.
        let mut groups: BTreeMap<usize, Vec<StagedPrefill<'e>>> = BTreeMap::new();
        for sp in staged {
            match self.engine.reg.bucket_for(sp.payload.len() / h) {
                Ok(b) => groups.entry(b).or_default().push(sp),
                Err(e) => {
                    self.fail(&sp.a.reply, &e);
                }
            }
        }
        // Cloud half: one batched middle call per group, with the shared
        // per-lane fallback and accounting.
        let mut cloud_ms = 0.0f64;
        for (_bucket, mut group) in groups {
            let hiddens: Vec<Vec<f32>> =
                group.iter_mut().map(|sp| std::mem::take(&mut sp.payload)).collect();
            let survived =
                self.middle_with_fallback(group, hiddens, &mut executed, &mut cloud_ms);
            for (sp, deep) in survived {
                self.complete_prefill(sp.slot, sp.a, &deep);
            }
        }
        (executed, cloud_ms)
    }

    /// The middle stage both job kinds share: one batched
    /// [`Engine::cloud_middle_batch`] call for a same-bucket job group,
    /// degrading to per-lane serial calls on group failure so one
    /// poisoned lane cannot take out its co-batched sessions (state-safe:
    /// a failed batched call mutated no lane's stream).  Central home of
    /// the monitor accounting: delay and rows are counted only for calls
    /// that actually ran — a matched (μ̂, η̂) observation pair for g^t —
    /// and one occupancy sample is pushed per executed group (or per lane
    /// in the fallback).  Returns the surviving (item, deep-rows) lanes;
    /// failed lanes get their ERR reply here.
    fn middle_with_fallback<P>(
        &mut self,
        mut group: Vec<Staged<'e, P>>,
        uploads: Vec<Vec<f32>>,
        executed: &mut usize,
        cloud_ms: &mut f64,
    ) -> Vec<(Staged<'e, P>, Vec<f32>)> {
        let h = self.engine.spec().hidden;
        let (result, call_ms) = {
            let mut streams: Vec<&mut CloudStream> =
                group.iter_mut().map(|t| t.stream()).collect();
            let refs: Vec<&[f32]> = uploads.iter().map(|u| u.as_slice()).collect();
            let t0 = clock::now();
            let r =
                catch("batched cloud call", || self.engine.cloud_middle_batch(&mut streams, &refs));
            (r, t0.elapsed().as_secs_f64() * 1e3)
        };
        match result {
            Ok(deeps) => {
                *cloud_ms += call_ms;
                *executed += deeps.iter().map(|d| d.len() / h).sum::<usize>();
                self.stats.batch_occupancy.push(deeps.len() as f64);
                group.into_iter().zip(deeps).collect()
            }
            Err(e) => {
                // A 1-lane "fallback" would re-issue the byte-identical
                // batch-of-1 call: fail the lane instead of retrying and
                // counting a spurious degradation.
                if group.len() <= 1 {
                    for item in group {
                        item.reply().send(format!("ERR {e}"));
                        self.stats.failed += 1;
                    }
                    return Vec::new();
                }
                // Degradation must be observable: a backend that rejects
                // every batched call leaves the server answering correctly
                // at serial throughput, and this log + the STATS
                // `fallbacks` counter are the only signals.
                eprintln!(
                    "batched cloud call failed ({e}); degrading {}-lane group to serial",
                    group.len()
                );
                self.stats.fallbacks += 1;
                let mut lanes = Vec::new();
                for (mut item, upload) in group.into_iter().zip(uploads) {
                    let t0 = clock::now();
                    match catch("serial cloud call", || {
                        self.engine.cloud_middle(item.stream(), &upload)
                    }) {
                        Ok(deep) => {
                            *cloud_ms += t0.elapsed().as_secs_f64() * 1e3;
                            *executed += deep.len() / h;
                            self.stats.batch_occupancy.push(1.0);
                            lanes.push((item, deep));
                        }
                        Err(e) => {
                            item.reply().send(format!("ERR {e}"));
                            self.stats.failed += 1;
                        }
                    }
                }
                lanes
            }
        }
    }

    /// Finish one session's prefill chunk given its verified deep rows:
    /// first-token bookkeeping, next-chunk planning, requeue or
    /// completion.
    fn complete_prefill(&mut self, slot: usize, mut a: Active<'e>, deep: &[f32]) {
        match catch("prefill_chunk_finish", || a.sess.prefill_chunk_finish(deep)) {
            Ok(Some(t1)) => {
                a.first_token = Some(clock::now());
                a.out.push(t1);
                if a.out.len() >= a.max_new {
                    // max_new == 1: the prefill's own first token is the
                    // whole generation — finish here, never hand off.
                    self.finish(a);
                } else if self.handoff {
                    // Prefill pool: the prefill→decode boundary.  The
                    // session carries its hidden state (pending token +
                    // last deep row) and its paged block tables; the slot
                    // is already free (taken by the job runner), so the
                    // next prompt can start prefilling immediately.
                    self.handoff_ready.push_back((a, clock::now()));
                } else {
                    let j = self.decode_job(slot, a.epoch);
                    self.batcher.push(j);
                    self.slots[slot] = Some(a);
                }
            }
            Ok(None) => {
                let chunk = self.plan_chunk(a.sess.prefill_remaining());
                self.batcher.push(Job {
                    req: slot,
                    kind: JobKind::PrefillChunk,
                    tokens: chunk,
                    epoch: a.epoch,
                });
                self.slots[slot] = Some(a);
            }
            Err(e) => {
                self.fail(&a.reply, &e);
            }
        }
    }

    /// Record metrics and send the protocol reply (slot already vacated by
    /// the `take()` in the job runners).
    fn finish(&mut self, a: Active<'e>) {
        let now = clock::now();
        let first = a.first_token.unwrap_or(now);
        let queue_wait = (a.admitted - a.enqueued).as_secs_f64() * 1e3;
        let ttft = (first - a.enqueued).as_secs_f64() * 1e3;
        let tbt = if a.out.len() > 1 {
            Some((now - first).as_secs_f64() * 1e3 / (a.out.len() - 1) as f64)
        } else {
            None
        };
        self.stats.record_finish(queue_wait, ttft, tbt, a.rounds, a.proposed, a.accepted);
        if let Some(t) = tbt {
            // Off-wire per-request TBT: the pd bench attributes tail
            // latency to specific streams (interactive vs aggressor).
            self.stats.tbt_by_request.push((a.id, t));
        }
        let gen = Generation {
            tokens: a.out,
            rounds: a.rounds,
            proposed: a.proposed,
            accepted: a.accepted,
        };
        a.reply.send(gen.reply_line());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::generate;
    use std::sync::atomic::{AtomicU64, Ordering};

    static NEXT_ID: AtomicU64 = AtomicU64::new(1);

    fn req(prompt: Vec<TokenId>, max_new: usize) -> (Request, ReplySink) {
        let rx = ReplySink::new();
        (
            Request {
                id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
                prompt,
                max_new,
                reply: rx.clone(),
                enqueued: clock::now(),
            },
            rx,
        )
    }

    /// Like [`req`] but every request replies into one shared sink, so
    /// the receive order *is* the completion order.
    fn req_shared(tx: &ReplySink, prompt: Vec<TokenId>, max_new: usize) -> Request {
        Request {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new,
            reply: tx.clone(),
            enqueued: clock::now(),
        }
    }

    fn drain(sched: &mut Scheduler<'_>) -> usize {
        let mut iters = 0;
        while sched.has_work() {
            assert!(sched.step() > 0, "scheduler idle with pending work");
            iters += 1;
            assert!(iters < 20_000, "scheduler failed to drain");
        }
        iters
    }

    #[test]
    fn interleaved_sessions_match_serial_generate() {
        let engine = Engine::synthetic();
        let spec = SpecDecConfig::default();
        let reqs: Vec<(Vec<TokenId>, usize)> = vec![
            ((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 12),
            ((0u32..75).map(|i| (i * 5 + 2) % 256).collect(), 17),
            (vec![5, 9, 2, 14], 9),
            ((0u32..23).map(|i| (i * 11 + 7) % 256).collect(), 24),
        ];
        let serial: Vec<String> = reqs
            .iter()
            .map(|(p, m)| generate(&engine, p, *m, &spec).unwrap().reply_line())
            .collect();

        let cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, spec, cfg);
        let mut rxs = Vec::new();
        for (p, m) in &reqs {
            let (r, rx) = req(p.clone(), *m);
            sched.submit(r);
            rxs.push(rx);
        }
        drain(&mut sched);
        assert!(sched.stats.chunk_sizes.count() > 0, "optimal_chunk never consulted");
        for (rx, want) in rxs.iter().zip(&serial) {
            let got = rx.recv().unwrap();
            assert_eq!(&got, want, "interleaving changed a greedy-lossless stream");
        }
        assert_eq!(sched.stats.finished, reqs.len());
    }

    #[test]
    fn oversubscribed_queue_drains_fifo() {
        // More requests than slots: later requests wait, all finish, and
        // queue-wait metrics are recorded for each.
        let engine = Engine::synthetic();
        let cfg = ServeConfig { max_sessions: 2, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let mut rxs = Vec::new();
        for i in 0..5u32 {
            let (r, rx) = req(vec![i + 1, 40, 7], 6);
            sched.submit(r);
            rxs.push(rx);
        }
        assert_eq!(sched.queued(), 5);
        drain(&mut sched);
        for rx in &rxs {
            let line = rx.recv().unwrap();
            assert!(line.starts_with("OK "), "bad reply: {line}");
        }
        assert_eq!(sched.stats.finished, 5);
        assert_eq!(sched.stats.queue_wait_ms.count(), 5);
        assert_eq!(sched.stats.ttft_ms.count(), 5);
    }

    #[test]
    fn concurrent_decode_rounds_execute_as_one_batched_call() {
        // η = 1.0 stops drafting after one proposal, so every session's
        // verify round uploads exactly 2 rows (bucket 4): with 3 sessions
        // decoding, an iteration's cloud side is exactly one batched
        // middle call and one batched head call.  Occupancy accounting
        // separates the paths: a single `run` adds (1 execution, 1 item),
        // a 3-wide `run_batch` adds (1 execution, 3 items) — so the
        // iteration's item delta exceeds its execution delta by 2·(3−1)=4,
        // where the old sequential loop gave exactly 0.
        let engine = Engine::synthetic();
        let spec = SpecDecConfig { eta: 1.0, ..SpecDecConfig::default() };
        let cfg = ServeConfig { max_sessions: 3, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, spec, cfg);
        let mut rxs = Vec::new();
        for i in 0..3u32 {
            let (r, rx) = req(vec![i + 1, 40, 7], 12);
            sched.submit(r);
            rxs.push(rx);
        }
        // Iteration 1: all three prefills complete (3-token prompts).
        assert!(sched.step() > 0);
        let (dq, _) = sched.job_depths();
        assert_eq!(dq, 3, "all sessions should be decoding after prefill");

        let before = engine.reg.stats();
        assert!(sched.step() > 0);
        let after = engine.reg.stats();
        let d_exec = after.executions - before.executions;
        let d_occ = after.batch_occupancy - before.batch_occupancy;
        assert_eq!(
            d_occ - d_exec,
            4,
            "expected one 3-wide middle call and one 3-wide head call"
        );

        drain(&mut sched);
        for rx in &rxs {
            assert!(rx.recv().unwrap().starts_with("OK "));
        }
        assert!(sched.stats.batch_occupancy.mean() > 1.0, "nothing batched");
    }

    #[test]
    fn draft_length_follows_config_not_hardcode() {
        // Regression: the decode path hard-coded λ = 4 where
        // SpecDecConfig::max_draft governs every other draft-length use
        // (decode_job's token estimate, draft_live's cap).  With
        // max_draft = 2 the scheduler and the serial path must agree and
        // no round may propose more than 2 tokens.
        let engine = Engine::synthetic();
        let spec = SpecDecConfig { max_draft: 2, ..SpecDecConfig::default() };

        let mut s = crate::specdec::Session::new(&engine, spec.clone()).unwrap();
        s.prefill(&[5, 9, 2, 14], &[4]).unwrap();
        for _ in 0..6 {
            let r = s.hat_round_capped(true, spec.max_draft, usize::MAX).unwrap();
            assert!(r.proposed.len() <= 2, "proposed {} > max_draft 2", r.proposed.len());
        }

        let serial = generate(&engine, &[7, 3, 200, 41], 10, &spec).unwrap().reply_line();
        let mut sched = Scheduler::new(&engine, spec, ServeConfig::default());
        let (r, rx) = req(vec![7, 3, 200, 41], 10);
        sched.submit(r);
        drain(&mut sched);
        assert_eq!(rx.recv().unwrap(), serial, "max_draft=2 streams diverged");
    }

    #[test]
    fn lambda_is_observable_in_draft_work() {
        // Greedy losslessness makes token streams λ-invariant, so the
        // byte-identity assertions above cannot catch a reintroduced
        // hard-coded λ.  This one can: with η = 0 the Eq. 5 stop rule
        // never fires, so every parallel-draft branch drafts exactly λ
        // proposals — generate()'s backend execution count must equal an
        // explicit λ = max_draft replica of its loop (the old hard-coded
        // λ = 4 drafts deeper branches and fails the comparison).
        let spec = SpecDecConfig { eta: 0.0, max_draft: 2, ..SpecDecConfig::default() };
        let prompt = [5u32, 9, 2, 14];
        let e1 = Engine::synthetic();
        let g = generate(&e1, &prompt, 8, &spec).unwrap();

        let e2 = Engine::synthetic();
        let mut s = crate::specdec::Session::new(&e2, spec.clone()).unwrap();
        let mut serve = ServeConfig::default();
        clamp_chunk_bounds(&mut serve, &e2);
        let x = eq3_chunk(&serve, 0.0);
        let chunks = crate::specdec::chunk_sizes(prompt.len(), x);
        let t1 = s.prefill(&prompt, &chunks).unwrap();
        let mut out = vec![t1];
        while out.len() < 8 {
            let budget = (8 - out.len()).saturating_sub(1).max(1);
            let r = s.hat_round_capped(true, spec.max_draft, budget).unwrap();
            out.extend_from_slice(&r.emitted);
        }
        out.truncate(8);
        assert_eq!(g.tokens, out, "replica loop diverged from generate()");
        assert_eq!(
            e1.reg.stats().executions,
            e2.reg.stats().executions,
            "generate() drafted with a different λ than max_draft"
        );
    }

    #[test]
    fn learned_predictor_feeds_chunk_planning() {
        // After iterations execute, the state monitor has (tokens → delay)
        // observations and the Eq. 3 optimizer runs on the learned curve.
        let engine = Engine::synthetic();
        let mut sched =
            Scheduler::new(&engine, SpecDecConfig::default(), ServeConfig::default());
        assert!(!sched.predictor_learned(), "no observations before any iteration");
        let (r, rx) = req((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 6);
        sched.submit(r);
        drain(&mut sched);
        assert!(rx.recv().unwrap().starts_with("OK "));
        assert!(sched.predictor_learned(), "iterations observed, g^t must be learned");

        // learned_g = false keeps the optimizer on the static curve.
        let cfg = ServeConfig { learned_g: false, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let (r, rx) = req(vec![1, 2, 3, 4], 4);
        sched.submit(r);
        drain(&mut sched);
        assert!(rx.recv().unwrap().starts_with("OK "));
        assert!(!sched.predictor_learned(), "static mode must report g_learned=0");
    }

    #[test]
    fn rejects_out_of_context_requests_immediately() {
        let engine = Engine::synthetic();
        let max_seq = engine.spec().max_seq;
        let mut sched =
            Scheduler::new(&engine, SpecDecConfig::default(), ServeConfig::default());
        let (r, rx) = req(vec![1; max_seq], 64);
        sched.submit(r);
        assert!(rx.recv().unwrap().starts_with("ERR "));
        assert!(!sched.has_work());
        let (r, rx) = req(vec![], 4);
        sched.submit(r);
        assert!(rx.recv().unwrap().starts_with("ERR "));
    }

    #[test]
    fn chunk_stats_record_executed_not_planned_sizes() {
        // Regression: plan_chunk recorded the Eq. 3 plan *before* the
        // clamp to the remaining prompt tokens, so `chunk_mean`
        // overstated executed chunks whenever the prompt tail was shorter
        // than the plan.  A 3-token prompt under min_chunk = 16 executes
        // exactly one 3-token chunk; the recorded mean must say 3.
        let engine = Engine::synthetic();
        let cfg = ServeConfig { min_chunk: 16, ..ServeConfig::default() };
        assert!(cfg.min_chunk > 3, "premise: plan cannot go below min_chunk");
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let (r, rx) = req(vec![5, 9, 2], 4);
        sched.submit(r);
        drain(&mut sched);
        assert!(rx.recv().unwrap().starts_with("OK "));
        assert_eq!(sched.stats.chunk_sizes.count(), 1, "one prompt, one chunk");
        assert!(
            (sched.stats.chunk_sizes.mean() - 3.0).abs() < 1e-9,
            "chunk_mean must report the executed (clamped) size, got {}",
            sched.stats.chunk_sizes.mean()
        );
    }

    #[test]
    fn cancel_frees_slots_and_epoch_drops_the_stale_job() {
        let engine = Engine::synthetic();
        let spec = SpecDecConfig::default();
        let cfg = ServeConfig { max_sessions: 1, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, spec.clone(), cfg);

        let (a, rx_a) = req((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 32);
        let a_id = a.id;
        let (b, rx_b) = req(vec![1, 2, 3], 4);
        let b_id = b.id;
        sched.submit(a);
        sched.submit(b);
        assert!(sched.step() > 0, "first iteration admits and prefills");
        assert_eq!(sched.live_sessions(), 1);
        assert_eq!(sched.queued(), 1);

        // Cancel the waiting request: removed before it ever takes a slot.
        assert!(sched.cancel(b_id));
        assert_eq!(rx_b.try_recv().unwrap(), "ERR cancelled");
        assert_eq!(sched.queued(), 0);

        // Cancel the live session: the slot frees immediately (KV cache
        // dropped with the session); its queued batcher job stays behind
        // carrying the dead epoch.
        assert!(sched.cancel(a_id));
        assert_eq!(rx_a.try_recv().unwrap(), "ERR cancelled");
        assert_eq!(sched.live_sessions(), 0);
        assert_eq!(sched.stats.cancelled, 2);
        assert!(!sched.cancel(a_id), "cancelling a gone id is a no-op");

        // A fresh request reuses slot 0; the stale job must be dropped by
        // the epoch check — not drive the new session — and the stream
        // must match a serial run exactly.
        let want = generate(&engine, &[9, 7, 5], 6, &spec).unwrap().reply_line();
        let (c, rx_c) = req(vec![9, 7, 5], 6);
        sched.submit(c);
        drain(&mut sched);
        assert_eq!(rx_c.recv().unwrap(), want, "stale job corrupted the reused slot");
        assert!(
            sched.stats.stale_dropped >= 1,
            "the cancelled session's queued job was never epoch-dropped"
        );
        assert_eq!(sched.stats.finished, 1);
    }

    #[test]
    fn deadline_expires_live_and_waiting_requests() {
        let engine = Engine::synthetic();
        let cfg = ServeConfig { max_sessions: 1, deadline_ms: 5, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);

        // Live expiry: admit, then let the deadline pass between
        // iterations — the next step boundary tears the session down.
        let (a, rx_a) = req((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 64);
        sched.submit(a);
        assert!(sched.step() > 0);
        assert_eq!(sched.live_sessions(), 1);
        clock::sleep(std::time::Duration::from_millis(10));
        sched.step();
        assert_eq!(rx_a.try_recv().unwrap(), "ERR deadline");
        assert_eq!(sched.live_sessions(), 0);

        // Waiting expiry: a request whose deadline passes in the queue is
        // expired before it can take the (free) slot.
        let (b, rx_b) = req(vec![1, 2, 3], 4);
        sched.submit(b);
        clock::sleep(std::time::Duration::from_millis(10));
        sched.step();
        assert_eq!(rx_b.try_recv().unwrap(), "ERR deadline");
        assert_eq!(sched.stats.deadline_expired, 2);
        assert!(!sched.has_work());
    }

    #[test]
    fn preemption_parks_resumes_and_preserves_streams() {
        // One slot, priority = preempt: a long-running session is parked
        // (KV paged out) so a later arrival can run, then resumed — and
        // both streams stay byte-identical to serial generate().
        let engine = Engine::synthetic();
        let spec = SpecDecConfig::default();
        let long: Vec<TokenId> = (0u32..40).map(|i| (i * 3 + 1) % 256).collect();
        let short = vec![9u32, 7, 5];
        let want_long = generate(&engine, &long, 24, &spec).unwrap().reply_line();
        let want_short = generate(&engine, &short, 5, &spec).unwrap().reply_line();

        let cfg = ServeConfig {
            max_sessions: 1,
            priority: PriorityMode::Preempt,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, spec, cfg);
        let (a, rx_a) = req(long, 24);
        sched.submit(a);
        // Drive A past prefill (a decode job pending marks it eligible).
        let mut guard = 0;
        while sched.job_depths().0 == 0 {
            assert!(sched.step() > 0);
            guard += 1;
            assert!(guard < 100, "A never reached decode");
        }
        let (b, rx_b) = req(short, 5);
        sched.submit(b);
        assert!(sched.step() > 0);
        assert_eq!(sched.stats.preemptions, 1, "A must be parked for B");
        assert!(sched.stats.kv_swap_bytes > 0, "parking pages KV to the host store");
        assert_eq!(sched.live_sessions(), 1, "B holds the slot");
        assert_eq!(sched.queued(), 1, "parked A counts as queued");

        drain(&mut sched);
        assert_eq!(rx_b.recv().unwrap(), want_short, "preempting arrival diverged");
        assert_eq!(rx_a.recv().unwrap(), want_long, "park/resume changed the stream");
        assert_eq!(sched.stats.finished, 2);
        assert!(
            engine.kv_pool().quiesced(),
            "leaked or refcount-stuck KV blocks after all sessions quiesced"
        );
    }

    #[test]
    fn resumed_sessions_are_never_preempted_twice() {
        // Anti-thrash: once a session has been parked and resumed, a
        // later arrival waits instead of re-parking it.
        let engine = Engine::synthetic();
        let spec = SpecDecConfig::default();
        let long: Vec<TokenId> = (0u32..30).map(|i| (i * 5 + 2) % 256).collect();
        let want_long = generate(&engine, &long, 16, &spec).unwrap().reply_line();
        let cfg = ServeConfig {
            max_sessions: 1,
            priority: PriorityMode::Preempt,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, spec, cfg);
        let (a, rx_a) = req(long, 16);
        sched.submit(a);
        let mut guard = 0;
        while sched.job_depths().0 == 0 {
            assert!(sched.step() > 0);
            guard += 1;
            assert!(guard < 100, "A never reached decode");
        }
        let (b, rx_b) = req(vec![1, 2, 3], 4);
        sched.submit(b);
        // Park A, run B to completion, then one more step resumes A.
        let mut guard = 0;
        while rx_b.try_recv().is_err() {
            assert!(sched.step() > 0);
            guard += 1;
            assert!(guard < 1000, "B never finished");
        }
        assert!(sched.step() > 0, "resuming A makes progress");
        assert_eq!(sched.live_sessions(), 1, "A resumed into the freed slot");
        let (c, rx_c) = req(vec![4, 5, 6], 4);
        sched.submit(c);
        drain(&mut sched);
        assert_eq!(sched.stats.preemptions, 1, "resumed A was re-preempted for C");
        assert_eq!(rx_a.recv().unwrap(), want_long);
        assert!(rx_c.recv().unwrap().starts_with("OK "));
    }

    #[test]
    fn cancel_reaches_parked_sessions() {
        let engine = Engine::synthetic();
        let cfg = ServeConfig {
            max_sessions: 1,
            priority: PriorityMode::Preempt,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let (a, rx_a) = req((0u32..30).map(|i| (i * 7 + 3) % 256).collect(), 20);
        let a_id = a.id;
        sched.submit(a);
        let mut guard = 0;
        while sched.job_depths().0 == 0 {
            assert!(sched.step() > 0);
            guard += 1;
            assert!(guard < 100, "A never reached decode");
        }
        let (b, rx_b) = req(vec![1, 2, 3], 4);
        sched.submit(b);
        assert!(sched.step() > 0);
        assert_eq!(sched.stats.preemptions, 1);
        // Cancel the parked session: reply sent, no resume ever happens.
        assert!(sched.cancel(a_id));
        assert_eq!(rx_a.try_recv().unwrap(), "ERR cancelled");
        assert_eq!(sched.queued(), 0);
        drain(&mut sched);
        assert!(rx_b.recv().unwrap().starts_with("OK "));
        assert_eq!(sched.stats.finished, 1);
        assert!(engine.kv_pool().quiesced(), "cancelled parked session leaked blocks");
    }

    fn completion_token_counts(
        sched: &mut Scheduler<'_>,
        rx: &ReplySink,
        n: usize,
    ) -> Vec<usize> {
        drain(sched);
        (0..n)
            .map(|_| {
                let line = rx.try_recv().expect("missing completion");
                let body = line.strip_prefix("OK ").expect("request failed");
                body.split(" | ").next().unwrap().split_whitespace().count()
            })
            .collect()
    }

    #[test]
    fn sjf_admits_shortest_prompt_first_with_aging_bound() {
        // One slot; three waiting requests with distinct prompt lengths
        // and distinct max_new (the reply's token count identifies the
        // request).  Shared reply channel: receive order = finish order.
        let engine = Engine::synthetic();
        fn submit_all(sched: &mut Scheduler<'_>, tx: &ReplySink) {
            sched.submit(req_shared(tx, (0u32..60).map(|i| (i * 3 + 1) % 256).collect(), 3));
            sched.submit(req_shared(tx, (0u32..30).map(|i| (i * 5 + 2) % 256).collect(), 4));
            sched.submit(req_shared(tx, vec![7, 3, 200, 41, 5, 9, 2, 14], 5));
        }

        // Pure SJF (aging bound far away): shortest prompt first.
        let cfg = ServeConfig {
            max_sessions: 1,
            policy: AdmitPolicy::Sjf,
            sjf_aging_ms: 600_000,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let rx = ReplySink::new();
        submit_all(&mut sched, &rx);
        assert_eq!(completion_token_counts(&mut sched, &rx, 3), vec![5, 4, 3]);

        // Aging bound 0: every oldest waiter is instantly "aged", so SJF
        // degenerates to FIFO — the starvation bound in its purest form.
        let cfg = ServeConfig {
            max_sessions: 1,
            policy: AdmitPolicy::Sjf,
            sjf_aging_ms: 0,
            ..ServeConfig::default()
        };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let rx = ReplySink::new();
        submit_all(&mut sched, &rx);
        assert_eq!(completion_token_counts(&mut sched, &rx, 3), vec![3, 4, 5]);

        // FIFO control: arrival order.
        let cfg = ServeConfig { max_sessions: 1, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let rx = ReplySink::new();
        submit_all(&mut sched, &rx);
        assert_eq!(completion_token_counts(&mut sched, &rx, 3), vec![3, 4, 5]);
    }
}
