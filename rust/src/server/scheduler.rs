//! Continuous-batching serve scheduler: the engine worker's iteration loop.
//!
//! The old serve path ran one whole request at a time through `generate()`,
//! so concurrent connections head-of-line blocked behind entire
//! generations and `cloud::Batcher` + `cloud::chunker::optimal_chunk`
//! stayed simulator-only.  This module is the real-execution counterpart
//! of the paper's §3.3 cloud scheduler: the engine-owning thread holds up
//! to `max_sessions` live [`Session`]s, and every iteration pops work from
//! a [`Batcher`] — HAT verify rounds admitted first (tiny,
//! latency-critical), prefill chunks filling the remaining token budget
//! FIFO — so requests interleave at *chunk/round* granularity.
//!
//! Prefill chunk sizes come from the Eq. 3 optimizer (`optimal_chunk`)
//! driven by a configured [`GModel`](crate::config::GModel) delay
//! predictor and the Eq. 1 moving average μ^t of observed batch sizes —
//! not a hard-coded constant.  Greedy-decoding losslessness makes the
//! interleaving invisible in the output: each session's token stream is
//! byte-identical to a serial run (tested in `tests/serve.rs`).

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use crate::cloud::state_monitor::Ewma;
use crate::cloud::{optimal_chunk, Batcher, Job, JobKind};
use crate::config::{ServeConfig, SpecDecConfig};
use crate::engine::Engine;
use crate::metrics::ServeStats;
use crate::model::TokenId;
use crate::specdec::Session;

use super::Generation;

/// One GENERATE request submitted to the scheduler.
pub struct Request {
    pub prompt: Vec<TokenId>,
    pub max_new: usize,
    /// Where the protocol reply line is sent when the request finishes
    /// (or fails).
    pub reply: mpsc::Sender<String>,
    /// Arrival time (queue-wait and TTFT are measured from here).
    pub enqueued: Instant,
}

/// A request occupying a scheduler slot, with its live session.
struct Active<'e> {
    sess: Session<'e>,
    max_new: usize,
    out: Vec<TokenId>,
    rounds: usize,
    proposed: usize,
    accepted: usize,
    reply: mpsc::Sender<String>,
    enqueued: Instant,
    admitted: Instant,
    first_token: Option<Instant>,
}

/// Iteration-level scheduler over one engine: N live sessions multiplexed
/// through a [`Batcher`].
pub struct Scheduler<'e> {
    engine: &'e Engine,
    spec_cfg: SpecDecConfig,
    cfg: ServeConfig,
    batcher: Batcher,
    /// Slot i's session; `Job::req` indexes into this.
    slots: Vec<Option<Active<'e>>>,
    /// Admission queue beyond `max_sessions`.
    waiting: VecDeque<Request>,
    /// μ^t (Eq. 1): moving average of executed batch token sizes, feeding
    /// the Eq. 3 chunk optimizer.
    mu: Ewma,
    pub stats: ServeStats,
}

/// Clamp the Eq. 3 chunk bounds to the engine's largest compiled bucket
/// (a prefill chunk executes as one engine call).  Shared by the
/// scheduler and the serial [`generate`](super::generate) reference path.
pub fn clamp_chunk_bounds(cfg: &mut ServeConfig, engine: &Engine) {
    let max_bucket =
        engine.reg.manifest().buckets.iter().copied().max().unwrap_or(cfg.max_chunk);
    cfg.max_chunk = cfg.max_chunk.min(max_bucket).max(1);
    cfg.min_chunk = cfg.min_chunk.clamp(1, cfg.max_chunk);
}

/// Eq. 3 chunk size under `cfg`'s wire model and delay predictor at cloud
/// load μ (call [`clamp_chunk_bounds`] first).
pub fn eq3_chunk(cfg: &ServeConfig, mu: f64) -> usize {
    let g = cfg.g;
    optimal_chunk(
        cfg.a_bytes,
        cfg.up_bytes_per_ms,
        move |b| g.eval(b),
        mu,
        cfg.pipeline_len,
        (cfg.min_chunk, cfg.max_chunk),
    )
}

impl<'e> Scheduler<'e> {
    pub fn new(engine: &'e Engine, spec_cfg: SpecDecConfig, mut cfg: ServeConfig) -> Scheduler<'e> {
        clamp_chunk_bounds(&mut cfg, engine);
        let alpha = cfg.alpha;
        let slots = (0..cfg.max_sessions.max(1)).map(|_| None).collect();
        Scheduler {
            engine,
            spec_cfg,
            cfg,
            batcher: Batcher::new(),
            slots,
            waiting: VecDeque::new(),
            mu: Ewma::new(alpha),
            stats: ServeStats::new(),
        }
    }

    /// Enqueue a request (admitted to a slot on a later [`Scheduler::step`]).
    /// Context-bound violations are rejected immediately.
    pub fn submit(&mut self, req: Request) {
        let max_ctx = self.engine.spec().max_seq;
        if req.prompt.is_empty() {
            let _ = req.reply.send("ERR empty prompt".into());
            return;
        }
        if req.max_new == 0 {
            let _ = req.reply.send("ERR max_new_tokens must be > 0".into());
            return;
        }
        if req.prompt.len() + req.max_new + self.spec_cfg.max_draft + 2 > max_ctx {
            let _ = req
                .reply
                .send(format!("ERR prompt+generation exceeds model max_seq {max_ctx}"));
            return;
        }
        self.waiting.push_back(req);
    }

    /// Anything queued or live?
    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.waiting.len()
    }

    /// Sessions currently occupying slots.
    pub fn live_sessions(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Pending (decode, prefill) jobs in the batcher.
    pub fn job_depths(&self) -> (usize, usize) {
        (self.batcher.decode_pending(), self.batcher.prefill_pending())
    }

    /// One scheduler iteration: admit waiting requests into free slots,
    /// form a batch under the prefill token budget, and run every job in
    /// it.  Returns the number of jobs executed (0 = idle).  While any
    /// session is live, every iteration makes progress on every decoding
    /// session and on at least the head prefill chunk, so no admitted
    /// request can starve.
    pub fn step(&mut self) -> usize {
        self.admit();
        let batch = self.batcher.form_batch(self.cfg.prefill_budget);
        if batch.is_empty() {
            return 0;
        }
        self.stats.iterations += 1;
        let n = batch.len();
        let mut executed_tokens = 0usize;
        for job in batch {
            executed_tokens += self.run_job(job);
        }
        self.mu.observe(executed_tokens as f64);
        n
    }

    /// Move waiting requests into free slots and queue their first
    /// prefill chunk.
    fn admit(&mut self) {
        while !self.waiting.is_empty() {
            let Some(i) = self.slots.iter().position(|s| s.is_none()) else { break };
            let req = self.waiting.pop_front().expect("checked non-empty");
            match Session::new(self.engine, self.spec_cfg.clone()) {
                Ok(mut sess) => {
                    sess.prefill_begin(&req.prompt);
                    let chunk = self.plan_chunk(sess.prefill_remaining());
                    self.batcher.push(Job {
                        req: i,
                        kind: JobKind::PrefillChunk,
                        tokens: chunk,
                        tag: 0,
                    });
                    self.slots[i] = Some(Active {
                        sess,
                        max_new: req.max_new,
                        out: Vec::new(),
                        rounds: 0,
                        proposed: 0,
                        accepted: 0,
                        reply: req.reply,
                        enqueued: req.enqueued,
                        admitted: Instant::now(),
                        first_token: None,
                    });
                }
                Err(e) => {
                    let _ = req.reply.send(format!("ERR {e}"));
                }
            }
        }
    }

    /// Eq. 3 chunk size for a session's next prefill chunk, clamped to the
    /// tokens it still needs.
    fn plan_chunk(&mut self, remaining: usize) -> usize {
        let x = eq3_chunk(&self.cfg, self.mu.get().unwrap_or(0.0));
        self.stats.chunk_sizes.push(x as f64);
        x.min(remaining).max(1)
    }

    /// The next verify-round job for a slot.  Decode `tokens` is
    /// informational only (the batcher admits every decode job regardless
    /// and μ^t averages *executed* sizes): one convention, the worst-case
    /// upload of max_draft proposals plus the bonus row.
    fn decode_job(&self, req: usize) -> Job {
        Job { req, kind: JobKind::Decode, tokens: self.spec_cfg.max_draft + 1, tag: 0 }
    }

    /// Execute one batcher job against its slot's session.  Returns the
    /// tokens actually processed (prefill rows or uploaded verify rows) —
    /// what μ^t must average, as opposed to the job's *planned* size.
    fn run_job(&mut self, job: Job) -> usize {
        let Some(mut a) = self.slots[job.req].take() else {
            return 0; // session already finished/failed (stale job)
        };
        match job.kind {
            JobKind::PrefillChunk => {
                let executed = job.tokens.min(a.sess.prefill_remaining());
                match a.sess.prefill_step(job.tokens) {
                    Ok(Some(t1)) => {
                        a.first_token = Some(Instant::now());
                        a.out.push(t1);
                        if a.out.len() >= a.max_new {
                            self.finish(a);
                        } else {
                            let j = self.decode_job(job.req);
                            self.batcher.push(j);
                            self.slots[job.req] = Some(a);
                        }
                    }
                    Ok(None) => {
                        let chunk = self.plan_chunk(a.sess.prefill_remaining());
                        self.batcher.push(Job {
                            req: job.req,
                            kind: JobKind::PrefillChunk,
                            tokens: chunk,
                            tag: 0,
                        });
                        self.slots[job.req] = Some(a);
                    }
                    Err(e) => {
                        let _ = a.reply.send(format!("ERR {e}"));
                    }
                }
                executed
            }
            JobKind::Decode => {
                let remaining = a.max_new - a.out.len();
                let budget = remaining.saturating_sub(1).max(1);
                match a.sess.hat_round_capped(true, 4, budget) {
                    Ok(r) => {
                        a.rounds += 1;
                        a.proposed += r.proposed.len();
                        a.accepted += r.accepted;
                        a.out.extend_from_slice(&r.emitted);
                        let executed = r.verify_tokens;
                        if a.out.len() >= a.max_new {
                            a.out.truncate(a.max_new);
                            self.finish(a);
                        } else {
                            let j = self.decode_job(job.req);
                            self.batcher.push(j);
                            self.slots[job.req] = Some(a);
                        }
                        executed
                    }
                    Err(e) => {
                        let _ = a.reply.send(format!("ERR {e}"));
                        0
                    }
                }
            }
        }
    }

    /// Record metrics and send the protocol reply (slot already vacated by
    /// the `take()` in [`Scheduler::run_job`]).
    fn finish(&mut self, a: Active<'e>) {
        let now = Instant::now();
        let first = a.first_token.unwrap_or(now);
        let queue_wait = (a.admitted - a.enqueued).as_secs_f64() * 1e3;
        let ttft = (first - a.enqueued).as_secs_f64() * 1e3;
        let tbt = if a.out.len() > 1 {
            Some((now - first).as_secs_f64() * 1e3 / (a.out.len() - 1) as f64)
        } else {
            None
        };
        self.stats.record_finish(queue_wait, ttft, tbt, a.rounds, a.proposed, a.accepted);
        let gen = Generation {
            tokens: a.out,
            rounds: a.rounds,
            proposed: a.proposed,
            accepted: a.accepted,
        };
        let _ = a.reply.send(gen.reply_line());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::generate;

    fn req(prompt: Vec<TokenId>, max_new: usize) -> (Request, mpsc::Receiver<String>) {
        let (tx, rx) = mpsc::channel();
        (Request { prompt, max_new, reply: tx, enqueued: Instant::now() }, rx)
    }

    fn drain(sched: &mut Scheduler<'_>) -> usize {
        let mut iters = 0;
        while sched.has_work() {
            assert!(sched.step() > 0, "scheduler idle with pending work");
            iters += 1;
            assert!(iters < 20_000, "scheduler failed to drain");
        }
        iters
    }

    #[test]
    fn interleaved_sessions_match_serial_generate() {
        let engine = Engine::synthetic();
        let spec = SpecDecConfig::default();
        let reqs: Vec<(Vec<TokenId>, usize)> = vec![
            ((0u32..40).map(|i| (i * 3 + 1) % 256).collect(), 12),
            ((0u32..75).map(|i| (i * 5 + 2) % 256).collect(), 17),
            (vec![5, 9, 2, 14], 9),
            ((0u32..23).map(|i| (i * 11 + 7) % 256).collect(), 24),
        ];
        let serial: Vec<String> = reqs
            .iter()
            .map(|(p, m)| generate(&engine, p, *m, &spec).unwrap().reply_line())
            .collect();

        let cfg = ServeConfig { max_sessions: 4, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, spec, cfg);
        let mut rxs = Vec::new();
        for (p, m) in &reqs {
            let (r, rx) = req(p.clone(), *m);
            sched.submit(r);
            rxs.push(rx);
        }
        drain(&mut sched);
        assert!(sched.stats.chunk_sizes.count() > 0, "optimal_chunk never consulted");
        for (rx, want) in rxs.iter().zip(&serial) {
            let got = rx.recv().unwrap();
            assert_eq!(&got, want, "interleaving changed a greedy-lossless stream");
        }
        assert_eq!(sched.stats.finished, reqs.len());
    }

    #[test]
    fn oversubscribed_queue_drains_fifo() {
        // More requests than slots: later requests wait, all finish, and
        // queue-wait metrics are recorded for each.
        let engine = Engine::synthetic();
        let cfg = ServeConfig { max_sessions: 2, ..ServeConfig::default() };
        let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);
        let mut rxs = Vec::new();
        for i in 0..5u32 {
            let (r, rx) = req(vec![i + 1, 40, 7], 6);
            sched.submit(r);
            rxs.push(rx);
        }
        assert_eq!(sched.queued(), 5);
        drain(&mut sched);
        for rx in &rxs {
            let line = rx.recv().unwrap();
            assert!(line.starts_with("OK "), "bad reply: {line}");
        }
        assert_eq!(sched.stats.finished, 5);
        assert_eq!(sched.stats.queue_wait_ms.count(), 5);
        assert_eq!(sched.stats.ttft_ms.count(), 5);
    }

    #[test]
    fn rejects_out_of_context_requests_immediately() {
        let engine = Engine::synthetic();
        let max_seq = engine.spec().max_seq;
        let mut sched =
            Scheduler::new(&engine, SpecDecConfig::default(), ServeConfig::default());
        let (r, rx) = req(vec![1; max_seq], 64);
        sched.submit(r);
        assert!(rx.recv().unwrap().starts_with("ERR "));
        assert!(!sched.has_work());
        let (r, rx) = req(vec![], 4);
        sched.submit(r);
        assert!(rx.recv().unwrap().starts_with("ERR "));
    }
}
