//! Real serving mode: a TCP line-protocol server over the real engine
//! (the offline crate set has no tokio/hyper; std::net + threads is the
//! substrate we build instead).
//!
//! Protocol (UTF-8 lines):
//!
//! ```text
//! C: GENERATE <max_new_tokens> <tok> <tok> ...\n
//! S: OK <tok> <tok> ... | rounds=<n> accept=<mean>\n
//! C: STATS\n
//! S: OK executions=<n> exec_ms=<t> compiles=<n>\n
//! C: QUIT\n
//! ```
//!
//! The engine is not thread-safe (one backend client), so a single worker
//! thread owns it and connections are multiplexed through a channel — the
//! same leader/worker shape a production router uses.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;

use crate::cli::Flags;
use crate::config::SpecDecConfig;
use crate::engine::Engine;
use crate::specdec::{chunk_sizes, Session};

/// A parsed request.
#[derive(Debug, PartialEq)]
pub enum Command {
    Generate { max_new: usize, prompt: Vec<u32> },
    Stats,
    Quit,
}

/// Parse one protocol line.  `max_new_cap` bounds GENERATE's
/// max_new_tokens (from `SpecDecConfig::max_new_tokens` — no hard-coded
/// limit).
pub fn parse_line(line: &str, max_new_cap: usize) -> Result<Command, String> {
    let mut it = line.split_whitespace();
    match it.next() {
        Some("GENERATE") => {
            let max_new: usize = it
                .next()
                .ok_or("GENERATE needs max_new_tokens")?
                .parse()
                .map_err(|_| "bad max_new_tokens".to_string())?;
            let prompt: Result<Vec<u32>, _> = it.map(|t| t.parse::<u32>()).collect();
            let prompt = prompt.map_err(|_| "bad token id".to_string())?;
            if prompt.is_empty() {
                return Err("empty prompt".into());
            }
            if max_new == 0 || max_new > max_new_cap {
                return Err(format!("max_new_tokens out of range (1..={max_new_cap})"));
            }
            Ok(Command::Generate { max_new, prompt })
        }
        Some("STATS") => Ok(Command::Stats),
        Some("QUIT") => Ok(Command::Quit),
        Some(other) => Err(format!("unknown command {other}")),
        None => Err("empty line".into()),
    }
}

/// Serve one request on the engine: HAT protocol (chunked prefill + SD).
pub fn generate(
    engine: &Engine,
    prompt: &[u32],
    max_new: usize,
    spec_cfg: &SpecDecConfig,
) -> anyhow::Result<(Vec<u32>, usize, f64)> {
    let max_ctx = engine.spec().max_seq;
    anyhow::ensure!(
        prompt.len() + max_new + spec_cfg.max_draft + 2 <= max_ctx,
        "prompt+generation exceeds model max_seq {max_ctx}"
    );
    let mut s = Session::new(engine, spec_cfg.clone())?;
    let chunks = chunk_sizes(prompt.len(), 64);
    let t1 = s.prefill(prompt, &chunks)?;
    let mut out = vec![t1];
    let mut rounds = 0usize;
    while out.len() < max_new {
        let r = s.hat_round(true, 4)?;
        out.extend_from_slice(&r.emitted);
        rounds += 1;
    }
    out.truncate(max_new);
    let accept = if rounds == 0 { 0.0 } else { (out.len() - 1) as f64 / rounds as f64 };
    Ok((out, rounds, accept))
}

enum WorkerMsg {
    Gen { max_new: usize, prompt: Vec<u32>, reply: mpsc::Sender<String> },
    Stats { reply: mpsc::Sender<String> },
}

fn worker_loop(engine: Engine, spec_cfg: SpecDecConfig, rx: mpsc::Receiver<WorkerMsg>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Gen { max_new, prompt, reply } => {
                let resp = match generate(&engine, &prompt, max_new, &spec_cfg) {
                    Ok((toks, rounds, accept)) => {
                        let toks: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
                        format!("OK {} | rounds={rounds} accept={accept:.2}", toks.join(" "))
                    }
                    Err(e) => format!("ERR {e}"),
                };
                let _ = reply.send(resp);
            }
            WorkerMsg::Stats { reply } => {
                let s = engine.reg.stats();
                let _ = reply.send(format!(
                    "OK executions={} exec_ms={:.1} compiles={} compile_ms={:.1}",
                    s.executions, s.execute_ms, s.compiles, s.compile_ms
                ));
            }
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: &mpsc::Sender<WorkerMsg>,
    max_new_cap: usize,
) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let cmd = match parse_line(line.trim(), max_new_cap) {
            Ok(c) => c,
            Err(e) => {
                writeln!(stream, "ERR {e}")?;
                continue;
            }
        };
        match cmd {
            Command::Quit => {
                writeln!(stream, "OK bye")?;
                return Ok(());
            }
            Command::Stats => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.send(WorkerMsg::Stats { reply: rtx });
                writeln!(stream, "{}", rrx.recv().unwrap_or_else(|_| "ERR worker gone".into()))?;
            }
            Command::Generate { max_new, prompt } => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.send(WorkerMsg::Gen { max_new, prompt, reply: rtx });
                writeln!(stream, "{}", rrx.recv().unwrap_or_else(|_| "ERR worker gone".into()))?;
            }
        }
        let _ = peer; // keep for logging hooks
    }
}

/// `hat serve --addr 127.0.0.1:7071 [--config FILE]`
///
/// `--config` reuses the experiment-config format; its `[specdec]` section
/// (eta, max_draft, top_k, max_new_tokens) governs serving.
pub fn cmd_serve(f: &Flags) -> Result<(), String> {
    let addr = f.get("addr").unwrap_or("127.0.0.1:7071").to_string();
    let spec_cfg = match f.get("config") {
        Some(path) => crate::config::parser::load_file(path)?.specdec,
        None => SpecDecConfig::default(),
    };
    let max_new_cap = spec_cfg.max_new_tokens;
    // The engine (backend client) is !Send: construct it inside its owning
    // worker thread and hand back only the ready/failed signal.
    let (tx, rx) = mpsc::channel::<WorkerMsg>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
    std::thread::spawn(move || match Engine::load_default() {
        Ok(engine) => {
            let _ = ready_tx.send(Ok(()));
            worker_loop(engine, spec_cfg, rx);
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e.to_string()));
        }
    });
    ready_rx
        .recv()
        .map_err(|_| "engine worker died".to_string())?
        .map_err(|e| format!("engine load: {e}"))?;

    let listener = TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!("hat serving on {addr} (line protocol; see rust/src/server/mod.rs)");
    let max_conns = f.get_usize("max-conns").map_err(|e| e)?.unwrap_or(usize::MAX);
    let mut served = 0usize;
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(s, &tx, max_new_cap) {
                        eprintln!("conn error: {e}");
                    }
                });
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
        served += 1;
        if served >= max_conns {
            break; // test hook: bounded accept loop
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const CAP: usize = 512;

    #[test]
    fn parses_generate() {
        let c = parse_line("GENERATE 16 1 2 3", CAP).unwrap();
        assert_eq!(c, Command::Generate { max_new: 16, prompt: vec![1, 2, 3] });
    }

    #[test]
    fn parses_stats_and_quit() {
        assert_eq!(parse_line("STATS", CAP).unwrap(), Command::Stats);
        assert_eq!(parse_line("QUIT", CAP).unwrap(), Command::Quit);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("GENERATE", CAP).is_err());
        assert!(parse_line("GENERATE 10", CAP).is_err()); // empty prompt
        assert!(parse_line("GENERATE 0 1 2", CAP).is_err());
        assert!(parse_line("GENERATE 9999 1", CAP).is_err());
        assert!(parse_line("GENERATE 4 1 x", CAP).is_err());
        assert!(parse_line("NOPE 1", CAP).is_err());
        assert!(parse_line("", CAP).is_err());
    }

    #[test]
    fn cap_comes_from_config_not_hardcode() {
        // A configured cap of 64 rejects 65 and accepts 64; the old
        // hard-coded 512 no longer applies.
        assert!(parse_line("GENERATE 65 1", 64).is_err());
        let c = parse_line("GENERATE 64 1", 64).unwrap();
        assert_eq!(c, Command::Generate { max_new: 64, prompt: vec![1] });
        assert!(parse_line("GENERATE 600 1", 1024).is_ok());
        assert_eq!(
            SpecDecConfig::default().max_new_tokens,
            512,
            "default cap preserves the old protocol limit"
        );
    }

    #[test]
    fn generate_end_to_end_on_synthetic_engine() {
        // The headline of the backend seam: real serving path, no
        // artifacts, no accelerator libraries.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let (toks, rounds, _accept) = generate(&engine, &[5, 9, 2, 14], 12, &cfg).unwrap();
        assert_eq!(toks.len(), 12);
        assert!(rounds >= 1);
        assert!(toks.iter().all(|&t| (t as usize) < engine.spec().vocab));
        // Deterministic: same prompt, same stream.
        let (toks2, _, _) = generate(&engine, &[5, 9, 2, 14], 12, &cfg).unwrap();
        assert_eq!(toks, toks2);
    }
}
