//! Real serving mode: a TCP line-protocol server over the real engine
//! (the offline crate set has no tokio/hyper; std::net + threads is the
//! substrate we build instead).
//!
//! Protocol (UTF-8 lines):
//!
//! ```text
//! C: GENERATE <max_new_tokens> <tok> <tok> ...\n
//! S: OK <tok> <tok> ... | rounds=<n> accept=<rate>\n
//! C: CANCEL\n            (only meaningful while a GENERATE is in flight)
//! S: -                   (no reply of its own: the pending GENERATE
//!                         replies `ERR cancelled`; a CANCEL with nothing
//!                         in flight replies `ERR nothing in flight`)
//! C: STATS\n
//! S: OK executions=<n> exec_ms=<t> compiles=<n> compile_ms=<t>
//!       requests=<n> iterations=<n> queue_wait_ms=<t> ttft_ms=<t>
//!       tbt_ms=<t> rounds=<n> accept=<rate> accept_hist=<c0,c1,...|->
//!       seed=<n> chunk_mean=<x> batch_mean=<x> fallbacks=<n>
//!       cancelled=<n> failed=<n> reaped=<n> deadline_expired=<n>
//!       preempted=<n> kv_swap_bytes=<n> kv_blocks=<n> kv_shared=<n>
//!       handoffs=<n> pf_wait_ms=<t> dc_wait_ms=<t> pf_occ=<x> dc_occ=<x>
//!       g_learned=<0|1> queued=<n> live=<n> decode_q=<n> prefill_q=<n>\n
//!                                                 (one line on the wire)
//! C: QUIT\n
//! S: OK bye\n
//! ```
//!
//! GENERATE's `accept` is the speculative-decoding acceptance rate
//! Σ accepted / Σ proposed over the request's rounds (independent of the
//! final truncation to max_new_tokens).  STATS carries the backend runtime
//! counters followed by the scheduler aggregates: finished request count,
//! scheduler iterations, mean queue wait / TTFT / TBT (wall-clock ms),
//! total SD rounds, the aggregate acceptance rate, `accept_hist` — the
//! per-round acceptance histogram (`accept_hist[a]` counts verify rounds
//! that accepted exactly `a` proposals; comma-joined, `-` while no round
//! has finished) — `seed` — the `[specdec] seed` the scheduler's sessions
//! sample with — the mean Eq. 3 chunk
//! size (of *executed* chunks, post-clamp), `batch_mean` — the mean
//! session count per batched engine-call group the scheduler issued (1.0
//! means nothing batched, higher means verify rounds / prefill chunks of
//! concurrent sessions actually executed as one `run_batch` call) —
//! `fallbacks` — batched cloud calls that failed and degraded to
//! per-lane serial execution — the session-lifecycle counters —
//! `cancelled` (client disconnects noticed mid-generation plus explicit
//! CANCELs), `failed` (`ERR` replies from the job runners and
//! submit-time rejections), `reaped` (requests dropped without a reply
//! because their client was
//! already gone), `deadline_expired` (`serve.deadline_ms` cancellations)
//! — the paged-KV counters — `preempted` (sessions parked under
//! `[serve] priority = preempt`: KV paged out to the host store and the
//! slot handed to a waiting admission; the session resumes later, it is
//! never cancelled), `kv_swap_bytes` (bytes moved by preemption swap-out
//! plus resume swap-in; blocks the pool re-shares by content dedup move
//! zero), `kv_blocks` (pool blocks currently mapped by live caches,
//! refreshed each scheduler iteration), `kv_shared` (blocks mapped by
//! more than one cache table via copy-on-write prefix sharing)
//! — the disaggregation counters — `handoffs` (sessions transferred
//! prefill→decode across the pool seam; 0 in single-pool mode),
//! `pf_wait_ms` (mean arrival→prefill-slot admission wait),
//! `dc_wait_ms` (mean handoff-ready→decode-slot adoption wait; the two
//! splits of the old single queue-wait), `pf_occ` / `dc_occ` (mean
//! per-pool slot occupancy in [0,1], sampled each coordinator
//! iteration; in single-pool mode both read 0)
//! — `g_learned` — 1 when the Eq. 3 optimizer is driven by the learned
//! state-monitor delay curve, 0 while it still falls back to the static
//! `GModel` calibration — and the current queue depth / live session
//! count.
//!
//! Concurrency model: the engine is not thread-safe (one backend client),
//! so a single worker thread owns it and connections are multiplexed
//! through a channel.  Unlike the original serial worker (one whole
//! request at a time), the worker drives a continuous-batching
//! [`scheduler::Scheduler`]: up to `--max-sessions` live sessions
//! interleave at prefill-chunk / verify-round granularity, with prefill
//! admitted under a `--prefill-budget` token budget per iteration and
//! chunk sizes from the Eq. 3 optimizer.  Losslessness makes the
//! interleaving invisible in each connection's output: bit-for-bit under
//! greedy decoding (`temperature = 0`, the default), and token-identical
//! to a serial seeded run under stochastic sampling, because every
//! session's draws are keyed by `(seed, context position)` rather than by
//! call order.
//!
//! Session lifecycle: while a GENERATE is in flight its connection thread
//! keeps watching the socket ([`handle_conn`]'s reply wait).  A client
//! that disconnects mid-generation — or pipelines a `CANCEL` line — has
//! its request cancelled at the scheduler's next iteration boundary: the
//! slot is freed and the session's KV dropped instead of the old
//! behaviour of running the abandoned generation to completion into a
//! dead channel while live clients queued for the slot.
//!
//! Preemption: with `[serve] priority = preempt` (or `--priority
//! preempt`), a full scheduler with waiting admissions parks a live
//! session instead of making arrivals queue behind it: the victim's KV
//! is paged out to the pool's host-side store, the slot is handed to the
//! waiting request, and the victim resumes — swap-in re-shares
//! bit-identical sealed blocks at zero copy cost — as soon as a slot
//! frees.  Losslessness holds across the park/resume: the emitted stream
//! is byte-identical to an uninterrupted run.  The default (`priority =
//! none`) never preempts.
//!
//! Disaggregation: with `[serve] prefill_workers = N` and
//! `decode_workers = M` both set (or `--prefill-workers` /
//! `--decode-workers`), the worker drives a [`pools::PdScheduler`]
//! instead of one [`scheduler::Scheduler`]: a throughput-oriented
//! prefill pool (N slots) and a latency-oriented decode pool (M slots),
//! each with its own engine, batcher queue and per-phase g^t monitor,
//! sharing one paged KV pool.  Sessions finish prefill in the first
//! pool and are handed off — hidden state plus KV block tables, no
//! dense copy — to the second for their hat rounds; the coordinator
//! steps decode-first so aggressor prefill chunks stop inflating
//! interactive TBT.  Both workers unset (the default) keeps the
//! single-pool scheduler.  See [`pools`] for the discipline and seam
//! lifecycle.

pub mod pools;
pub mod scheduler;

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::Duration;

use crate::util::clock;

use crate::cli::Flags;
use crate::config::{AdmitPolicy, PriorityMode, ServeConfig, SpecDecConfig};
use crate::engine::Engine;
use crate::specdec::{chunk_sizes, Session};

use pools::{PdScheduler, ServeExec};
use scheduler::{ReplyHandle, Request, Scheduler};

/// A parsed request.
#[derive(Debug, PartialEq)]
pub enum Command {
    Generate { max_new: usize, prompt: Vec<u32> },
    Cancel,
    Stats,
    Quit,
}

/// Shared GENERATE request validation — the single definition both the
/// protocol parser ([`parse_line`]) and the directly-driven scheduler
/// ([`scheduler::Scheduler::submit`]) route through, so their error
/// strings cannot drift.  `max_new_cap` comes from
/// `SpecDecConfig::max_new_tokens`.
pub fn validate_request(prompt: &[u32], max_new: usize, max_new_cap: usize) -> Result<(), String> {
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    if max_new == 0 || max_new > max_new_cap {
        return Err(format!("max_new_tokens out of range (1..={max_new_cap})"));
    }
    Ok(())
}

/// Parse one protocol line.  `max_new_cap` bounds GENERATE's
/// max_new_tokens (from `SpecDecConfig::max_new_tokens` — no hard-coded
/// limit).
pub fn parse_line(line: &str, max_new_cap: usize) -> Result<Command, String> {
    let mut it = line.split_whitespace();
    match it.next() {
        Some("GENERATE") => {
            let max_new: usize = it
                .next()
                .ok_or("GENERATE needs max_new_tokens")?
                .parse()
                .map_err(|_| "bad max_new_tokens".to_string())?;
            let prompt: Result<Vec<u32>, _> = it.map(|t| t.parse::<u32>()).collect();
            let prompt = prompt.map_err(|_| "bad token id".to_string())?;
            validate_request(&prompt, max_new, max_new_cap)?;
            Ok(Command::Generate { max_new, prompt })
        }
        Some("CANCEL") => Ok(Command::Cancel),
        Some("STATS") => Ok(Command::Stats),
        Some("QUIT") => Ok(Command::Quit),
        Some(other) => Err(format!("unknown command {other}")),
        None => Err("empty line".into()),
    }
}

/// Result of one generation, with speculative-decoding accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub tokens: Vec<u32>,
    /// Decode rounds executed.
    pub rounds: usize,
    /// Σ draft tokens proposed across rounds.
    pub proposed: usize,
    /// Σ draft tokens accepted across rounds.
    pub accepted: usize,
}

impl Generation {
    /// Acceptance rate Σ accepted / Σ proposed.  The old serve path
    /// reported `(out.len()-1)/rounds` *after* truncation — that measures
    /// emitted-per-round (it routinely exceeds 1.0) and truncation
    /// deflated it; this is per-proposal acceptance, truncation-invariant
    /// (one shared definition: [`crate::metrics::accept_rate`]).
    pub fn accept_rate(&self) -> f64 {
        crate::metrics::accept_rate(self.accepted, self.proposed)
    }

    /// The GENERATE protocol reply line (shared by the serial path and the
    /// scheduler so the two are byte-identical by construction).
    pub fn reply_line(&self) -> String {
        let toks: Vec<String> = self.tokens.iter().map(|t| t.to_string()).collect();
        format!("OK {} | rounds={} accept={:.3}", toks.join(" "), self.rounds, self.accept_rate())
    }
}

/// Serve one request on the engine serially: HAT protocol (chunked prefill
/// + SD).  This is the reference path the scheduler's interleaved
/// execution must match byte-for-byte; prefill chunks come from the Eq. 3
/// optimizer (same helpers as the scheduler) under a default `ServeConfig`
/// and an idle-cloud assumption (μ = 0) — greedy losslessness means the
/// chunk plan cannot change the emitted stream either way.
pub fn generate(
    engine: &Engine,
    prompt: &[u32],
    max_new: usize,
    spec_cfg: &SpecDecConfig,
) -> anyhow::Result<Generation> {
    let max_ctx = engine.spec().max_seq;
    anyhow::ensure!(
        prompt.len() + max_new + spec_cfg.max_draft + 2 <= max_ctx,
        "prompt+generation exceeds model max_seq {max_ctx}"
    );
    let mut serve = ServeConfig::default();
    scheduler::clamp_chunk_bounds(&mut serve, engine);
    let x = scheduler::eq3_chunk(&serve, 0.0);

    let mut s = Session::new(engine, spec_cfg.clone())?;
    let chunks = chunk_sizes(prompt.len(), x);
    let t1 = s.prefill(prompt, &chunks)?;
    let mut out = vec![t1];
    let (mut rounds, mut proposed, mut accepted) = (0usize, 0usize, 0usize);
    while out.len() < max_new {
        // Cap the round's draft length by the tokens still needed, so the
        // final round does not draft tokens that would only be truncated.
        let budget = (max_new - out.len()).saturating_sub(1).max(1);
        // λ follows the configured draft cap (the old hard-coded 4
        // silently disagreed with SpecDecConfig::max_draft).
        let r = s.hat_round_capped(true, spec_cfg.max_draft, budget)?;
        out.extend_from_slice(&r.emitted);
        rounds += 1;
        proposed += r.proposed.len();
        accepted += r.accepted;
    }
    out.truncate(max_new);
    Ok(Generation { tokens: out, rounds, proposed, accepted })
}

enum WorkerMsg {
    Gen(Request),
    /// Cancel the GENERATE with this [`Request::id`]: the connection
    /// thread observed its client disconnect mid-generation, or the
    /// client sent an explicit `CANCEL`.
    Cancel { id: u64 },
    Stats { reply: mpsc::Sender<String> },
}

/// The engine-owning worker: a continuous-batching scheduler loop.  New
/// commands are drained between iterations (blocking only when fully
/// idle), so cancels land at iteration boundaries; GENERATE replies are
/// sent by the scheduler when each request finishes, so concurrent
/// connections interleave at chunk/round granularity instead of
/// head-of-line blocking.
///
/// Exit: when the command channel disconnects, the listener and every
/// connection thread (each held a `Sender` clone) are gone, so every
/// in-flight reply channel is provably dead — the worker reaps the
/// remaining work and returns promptly instead of the old drain that ran
/// abandoned generations to completion and only then noticed via a
/// `recv()` error (spinning a `try_recv` per iteration on the way).
fn worker_loop(
    engine: Engine,
    spec_cfg: SpecDecConfig,
    serve_cfg: ServeConfig,
    rx: mpsc::Receiver<WorkerMsg>,
) {
    if serve_cfg.prefill_workers > 0 && serve_cfg.decode_workers > 0 {
        // Disaggregated path: the prefill pool runs on this engine, the
        // decode pool on a sibling sharing its KV pool (block tables
        // must be valid across the handoff).  Both live on this one
        // thread — the backend is not Send; the split is in iteration
        // composition, not threads.
        match engine.sibling() {
            Ok(decode_engine) => {
                match PdScheduler::new(&engine, &decode_engine, spec_cfg, serve_cfg) {
                    Ok(mut sched) => return drive(&mut sched, &rx),
                    Err(e) => {
                        eprintln!("serve: disaggregated pools unavailable ({e}); exiting");
                        return;
                    }
                }
            }
            Err(e) => {
                eprintln!("serve: sibling engine for decode pool failed ({e}); exiting");
                return;
            }
        }
    }
    let mut sched = Scheduler::new(&engine, spec_cfg, serve_cfg);
    drive(&mut sched, &rx);
}

/// The executor-generic worker body: drains commands between iterations
/// (blocking only when fully idle) and steps the scheduler — single-pool
/// or disaggregated, anything behind [`ServeExec`].
fn drive(sched: &mut dyn ServeExec, rx: &mpsc::Receiver<WorkerMsg>) {
    let mut connected = true;
    loop {
        loop {
            // `connected` is always true here: both setters below yield
            // None, breaking this loop into the reap-and-return exit.
            let msg = if sched.has_work() {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        connected = false;
                        None
                    }
                }
            } else {
                match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => {
                        connected = false;
                        None
                    }
                }
            };
            match msg {
                Some(WorkerMsg::Gen(req)) => sched.submit(req),
                Some(WorkerMsg::Cancel { id }) => {
                    sched.cancel(id);
                }
                Some(WorkerMsg::Stats { reply }) => {
                    let _ = reply.send(sched.stats_line());
                }
                None => break,
            }
        }
        if !connected {
            sched.reap_all();
            return;
        }
        sched.step();
    }
}

/// Monotonic GENERATE identity for targeted cancellation — the
/// connection thread needs the id before the worker ever sees the
/// request, so it cannot be scheduler-assigned.
static NEXT_REQ_ID: AtomicU64 = AtomicU64::new(1);

/// How often a connection's reply wait polls its socket for
/// disconnect / pipelined CANCEL.
const REPLY_POLL: Duration = Duration::from_millis(10);

/// Wait for an in-flight generation's reply while watching the
/// connection.  A client that disconnects mid-generation (reader EOF or
/// error) is the whole point of this loop: its reply handle is marked
/// dead and a cancel forwarded to the worker, so the scheduler frees the
/// slot instead of running the abandoned generation to completion.  A
/// pipelined `CANCEL` line is the explicit form of the same thing (the
/// pending GENERATE then replies `ERR cancelled`); other pipelined lines
/// are queued for the main loop.  Returns false when the client is gone.
#[allow(clippy::too_many_arguments)]
fn await_reply(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    pending: &mut VecDeque<String>,
    partial: &mut String,
    rrx: &mpsc::Receiver<String>,
    reply: &ReplyHandle,
    tx: &mpsc::Sender<WorkerMsg>,
    id: u64,
) -> std::io::Result<bool> {
    // The *socket* read is the blocking poll (bounded by REPLY_POLL) and
    // the reply check is non-blocking: an already-closed connection or an
    // already-pipelined CANCEL is then acted on immediately on entry,
    // before the generation can make progress — not after a reply-wait
    // timeout it might win.  `partial` is the caller's buffer: a command
    // prefix read here but not yet newline-terminated when the reply
    // arrives must survive into the main loop's next read, not be
    // dropped.
    stream.set_read_timeout(Some(REPLY_POLL))?;
    let alive = loop {
        match rrx.try_recv() {
            Ok(result) => {
                writeln!(stream, "{result}")?;
                break true;
            }
            Err(mpsc::TryRecvError::Disconnected) => {
                writeln!(stream, "ERR worker gone")?;
                break true;
            }
            Err(mpsc::TryRecvError::Empty) => {}
        }
        // Poll the socket.  On timeout, bytes read so far stay appended
        // to `partial` (the protocol is ASCII, so no partial-UTF-8 loss)
        // and the next poll continues the line.
        match reader.read_line(partial) {
            Ok(0) => {
                reply.mark_dead();
                let _ = tx.send(WorkerMsg::Cancel { id });
                break false;
            }
            Ok(_) => {
                if partial.ends_with('\n') {
                    let line = std::mem::take(partial);
                    if line.trim() == "CANCEL" {
                        let _ = tx.send(WorkerMsg::Cancel { id });
                    } else {
                        pending.push_back(line);
                    }
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(_) => {
                reply.mark_dead();
                let _ = tx.send(WorkerMsg::Cancel { id });
                break false;
            }
        }
    };
    stream.set_read_timeout(None)?;
    Ok(alive)
}

fn handle_conn(
    mut stream: TcpStream,
    tx: &mpsc::Sender<WorkerMsg>,
    max_new_cap: usize,
) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // Lines the client pipelined while a generation was in flight, and
    // the prefix of a line whose tail had not arrived when the last
    // reply wait ended.
    let mut pending: VecDeque<String> = VecDeque::new();
    let mut partial = String::new();
    loop {
        let next = match pending.pop_front() {
            Some(l) => l,
            None => {
                // Blocking read; continues any partial line left over
                // from a reply wait instead of dropping those bytes.
                if reader.read_line(&mut partial)? == 0 {
                    return Ok(());
                }
                std::mem::take(&mut partial)
            }
        };
        let cmd = match parse_line(next.trim(), max_new_cap) {
            Ok(c) => c,
            Err(e) => {
                writeln!(stream, "ERR {e}")?;
                continue;
            }
        };
        match cmd {
            Command::Quit => {
                writeln!(stream, "OK bye")?;
                return Ok(());
            }
            Command::Cancel => {
                // Reached only with no generation in flight (in-flight
                // CANCELs are consumed by await_reply).
                writeln!(stream, "ERR nothing in flight")?;
            }
            Command::Stats => {
                let (rtx, rrx) = mpsc::channel();
                let _ = tx.send(WorkerMsg::Stats { reply: rtx });
                writeln!(stream, "{}", rrx.recv().unwrap_or_else(|_| "ERR worker gone".into()))?;
            }
            Command::Generate { max_new, prompt } => {
                let id = NEXT_REQ_ID.fetch_add(1, Ordering::Relaxed);
                let (rtx, rrx) = mpsc::channel();
                let reply = ReplyHandle::new(rtx);
                let _ = tx.send(WorkerMsg::Gen(Request {
                    id,
                    prompt,
                    max_new,
                    reply: reply.clone(),
                    enqueued: clock::now(),
                }));
                let alive = await_reply(
                    &mut stream,
                    &mut reader,
                    &mut pending,
                    &mut partial,
                    &rrx,
                    &reply,
                    tx,
                    id,
                )?;
                if !alive {
                    return Ok(()); // client disconnected mid-generation
                }
            }
        }
        let _ = peer; // keep for logging hooks
    }
}

/// Run the serve loop on an already-bound listener (the testable core of
/// [`cmd_serve`]; binding is the caller's job so tests can use port 0).
/// Accepts at most `max_conns` connections, then returns.
pub fn serve_listener(
    listener: TcpListener,
    spec_cfg: SpecDecConfig,
    serve_cfg: ServeConfig,
    max_conns: usize,
) -> Result<(), String> {
    let max_new_cap = spec_cfg.max_new_tokens;
    // The engine (backend client) is !Send: construct it inside its owning
    // worker thread and hand back only the ready/failed signal.
    let (tx, rx) = mpsc::channel::<WorkerMsg>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
    std::thread::spawn(move || match Engine::load_default() {
        Ok(engine) => {
            let _ = ready_tx.send(Ok(()));
            worker_loop(engine, spec_cfg, serve_cfg, rx);
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e.to_string()));
        }
    });
    ready_rx
        .recv()
        .map_err(|_| "engine worker died".to_string())?
        .map_err(|e| format!("engine load: {e}"))?;

    let mut served = 0usize;
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    if let Err(e) = handle_conn(s, &tx, max_new_cap) {
                        eprintln!("conn error: {e}");
                    }
                });
                // Only successful accepts count toward the bound: callers
                // size max_conns exactly (tests, examples), and a transient
                // accept error must not strand the last expected client.
                served += 1;
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
        if served >= max_conns {
            break; // test hook: bounded accept loop
        }
    }
    Ok(())
}

/// `hat serve --addr 127.0.0.1:7071 [--config FILE] [--max-sessions N]
/// [--prefill-budget T] [--policy fifo|sjf] [--deadline-ms T]
/// [--prefill-workers N] [--decode-workers M]
/// [--max-conns N] [--temperature X] [--top-k-sample N] [--top-p X]
/// [--rep-penalty X] [--seed N] [--verify-mode coupled|rejection]`
///
/// `--config` reuses the experiment-config format: its `[specdec]` section
/// (eta, max_draft, top_k, max_new_tokens, plus the sampling keys
/// temperature, top_k_sample, top_p, rep_penalty, seed, verify_mode) and
/// `[serve]` section (max_sessions, prefill_budget, min_chunk, max_chunk,
/// alpha, pipeline_len, policy, sjf_aging_ms, deadline_ms, priority,
/// prefill_workers, decode_workers)
/// govern serving;
/// the flags override the file.  `--temperature 0` (the default) is greedy
/// decoding; with a positive temperature every session samples with the
/// shared `--seed`, position-keyed per session.  `--prefill-workers` and
/// `--decode-workers` (set together) switch the worker to the
/// disaggregated P/D pools; `--max-sessions` then only applies to the
/// single-pool fallback.
pub fn cmd_serve(f: &Flags) -> Result<(), String> {
    let addr = f.get("addr").unwrap_or("127.0.0.1:7071").to_string();
    let (mut spec_cfg, mut serve_cfg) = match f.get("config") {
        Some(path) => {
            let cfg = crate::config::parser::load_file(path)?;
            (cfg.specdec, cfg.serve)
        }
        None => (SpecDecConfig::default(), ServeConfig::default()),
    };
    if let Some(n) = f.get_usize("max-sessions")? {
        if n == 0 {
            return Err("--max-sessions must be > 0".into());
        }
        serve_cfg.max_sessions = n;
    }
    if let Some(t) = f.get_usize("prefill-budget")? {
        if t == 0 {
            return Err("--prefill-budget must be > 0".into());
        }
        serve_cfg.prefill_budget = t;
    }
    if let Some(p) = f.get("policy") {
        serve_cfg.policy =
            AdmitPolicy::parse(p).ok_or(format!("--policy: unknown policy {p:?} (fifo|sjf)"))?;
    }
    if let Some(p) = f.get("priority") {
        serve_cfg.priority = PriorityMode::parse(p)
            .ok_or(format!("--priority: unknown mode {p:?} (none|preempt)"))?;
    }
    if let Some(t) = f.get_usize("deadline-ms")? {
        serve_cfg.deadline_ms = t as u64;
    }
    if let Some(n) = f.get_usize("prefill-workers")? {
        serve_cfg.prefill_workers = n;
    }
    if let Some(n) = f.get_usize("decode-workers")? {
        serve_cfg.decode_workers = n;
    }
    if (serve_cfg.prefill_workers == 0) != (serve_cfg.decode_workers == 0) {
        return Err(
            "--prefill-workers and --decode-workers must be set together (both > 0)".into()
        );
    }
    if let Some(t) = f.get_f64("temperature")? {
        if t < 0.0 {
            return Err("--temperature must be >= 0".into());
        }
        spec_cfg.temperature = t;
    }
    if let Some(k) = f.get_usize("top-k-sample")? {
        spec_cfg.top_k_sample = k;
    }
    if let Some(p) = f.get_f64("top-p")? {
        if !(p > 0.0 && p <= 1.0) {
            return Err("--top-p must be in (0,1]".into());
        }
        spec_cfg.top_p = p;
    }
    if let Some(r) = f.get_f64("rep-penalty")? {
        if r <= 0.0 {
            return Err("--rep-penalty must be > 0".into());
        }
        spec_cfg.rep_penalty = r;
    }
    if let Some(s) = f.get_usize("seed")? {
        spec_cfg.seed = s as u64;
    }
    if let Some(m) = f.get("verify-mode") {
        spec_cfg.verify_mode = crate::config::SampleVerify::parse(m)
            .ok_or(format!("--verify-mode: unknown mode {m:?} (coupled|rejection)"))?;
    }
    let max_conns = f.get_usize("max-conns")?.unwrap_or(usize::MAX);

    let listener = TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!(
        "hat serving on {addr} ({} sessions, prefill budget {}; line protocol — see rust/src/server/mod.rs)",
        serve_cfg.max_sessions, serve_cfg.prefill_budget
    );
    serve_listener(listener, spec_cfg, serve_cfg, max_conns)
}

#[cfg(test)]
mod tests {
    use super::*;

    const CAP: usize = 512;

    #[test]
    fn parses_generate() {
        let c = parse_line("GENERATE 16 1 2 3", CAP).unwrap();
        assert_eq!(c, Command::Generate { max_new: 16, prompt: vec![1, 2, 3] });
    }

    #[test]
    fn parses_stats_quit_and_cancel() {
        assert_eq!(parse_line("STATS", CAP).unwrap(), Command::Stats);
        assert_eq!(parse_line("QUIT", CAP).unwrap(), Command::Quit);
        assert_eq!(parse_line("CANCEL", CAP).unwrap(), Command::Cancel);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("GENERATE", CAP).is_err());
        assert!(parse_line("GENERATE 10", CAP).is_err()); // empty prompt
        assert!(parse_line("GENERATE 0 1 2", CAP).is_err());
        assert!(parse_line("GENERATE 9999 1", CAP).is_err());
        assert!(parse_line("GENERATE 4 1 x", CAP).is_err());
        assert!(parse_line("NOPE 1", CAP).is_err());
        assert!(parse_line("", CAP).is_err());
    }

    #[test]
    fn cap_comes_from_config_not_hardcode() {
        // A configured cap of 64 rejects 65 and accepts 64; the old
        // hard-coded 512 no longer applies.
        assert!(parse_line("GENERATE 65 1", 64).is_err());
        let c = parse_line("GENERATE 64 1", 64).unwrap();
        assert_eq!(c, Command::Generate { max_new: 64, prompt: vec![1] });
        assert!(parse_line("GENERATE 600 1", 1024).is_ok());
        assert_eq!(
            SpecDecConfig::default().max_new_tokens,
            512,
            "default cap preserves the old protocol limit"
        );
    }

    #[test]
    fn parser_and_scheduler_share_validation_strings() {
        // Both entry points route through validate_request, so the error
        // strings are identical by construction — a drift regression test.
        let cap = SpecDecConfig::default().max_new_tokens;
        let engine = Engine::synthetic();

        let parse_err = parse_line("GENERATE 600 1", cap).unwrap_err();
        let mut sched =
            Scheduler::new(&engine, SpecDecConfig::default(), ServeConfig::default());
        let (tx, rx) = mpsc::channel();
        sched.submit(Request {
            id: 1,
            prompt: vec![1],
            max_new: 600,
            reply: ReplyHandle::new(tx),
            enqueued: clock::now(),
        });
        assert_eq!(rx.recv().unwrap(), format!("ERR {parse_err}"));

        let parse_err = parse_line("GENERATE 4", cap).unwrap_err();
        assert_eq!(parse_err, "empty prompt");
        let (tx, rx) = mpsc::channel();
        sched.submit(Request {
            id: 2,
            prompt: vec![],
            max_new: 4,
            reply: ReplyHandle::new(tx),
            enqueued: clock::now(),
        });
        assert_eq!(rx.recv().unwrap(), format!("ERR {parse_err}"));
        assert!(!sched.has_work(), "rejected requests must not occupy the queue");
    }

    #[test]
    fn worker_exits_promptly_after_last_connection_closes() {
        // Regression for the worker's shutdown path: with the command
        // channel disconnected, the old loop finished all admitted work
        // first (spinning a try_recv per iteration) and only exited via a
        // recv() error once idle — an abandoned long generation kept the
        // thread alive arbitrarily.  Every reply channel is provably dead
        // at that point, so the worker must reap and return promptly.
        let (tx, rx) = mpsc::channel();
        let (done_tx, done_rx) = mpsc::channel();
        std::thread::spawn(move || {
            // The engine's backend client is !Send: build it in the
            // owning thread, exactly like serve_listener does.
            let engine = Engine::synthetic();
            worker_loop(engine, SpecDecConfig::default(), ServeConfig::default(), rx);
            let _ = done_tx.send(());
        });
        // A long generation whose client vanishes immediately.
        let (rtx, rrx) = mpsc::channel();
        tx.send(WorkerMsg::Gen(Request {
            id: 1,
            prompt: (0u32..64).map(|i| (i * 7 + 3) % 256).collect(),
            max_new: 200,
            reply: ReplyHandle::new(rtx),
            enqueued: clock::now(),
        }))
        .unwrap();
        drop(rrx);
        drop(tx);
        done_rx
            .recv_timeout(Duration::from_secs(20))
            .expect("worker did not exit after the last connection closed");
    }

    #[test]
    fn generate_end_to_end_on_synthetic_engine() {
        // The headline of the backend seam: real serving path, no
        // artifacts, no accelerator libraries.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let g = generate(&engine, &[5, 9, 2, 14], 12, &cfg).unwrap();
        assert_eq!(g.tokens.len(), 12);
        assert!(g.rounds >= 1);
        assert!(g.tokens.iter().all(|&t| (t as usize) < engine.spec().vocab));
        // Deterministic: same prompt, same stream.
        let g2 = generate(&engine, &[5, 9, 2, 14], 12, &cfg).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn accept_rate_is_truncation_invariant() {
        // Regression for the old `(out.len()-1)/rounds` metric: replay the
        // pre-fix serial loop (uncapped rounds, truncate at the end) and
        // find a case where the final round overshoots max_new — there the
        // old metric changed under truncation, while Σaccepted/Σproposed
        // is computed from the rounds themselves and cannot.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let mut found = false;
        for seed in 0..20u32 {
            let prompt = vec![3 + seed, 9, 2, 14];
            let mut s = Session::new(&engine, cfg.clone()).unwrap();
            let t1 = s.prefill(&prompt, &[prompt.len()]).unwrap();
            let mut out = vec![t1];
            let (mut rounds, mut proposed, mut accepted) = (0usize, 0usize, 0usize);
            let max_new = 2;
            while out.len() < max_new {
                let r = s.hat_round(true, 4).unwrap(); // uncapped, as before
                out.extend_from_slice(&r.emitted);
                rounds += 1;
                proposed += r.proposed.len();
                accepted += r.accepted;
            }
            let before = out.len();
            out.truncate(max_new);
            if before > max_new {
                found = true;
                let old_untruncated = (before - 1) as f64 / rounds as f64;
                let old_truncated = (out.len() - 1) as f64 / rounds as f64;
                assert_ne!(
                    old_untruncated, old_truncated,
                    "old metric was truncation-sensitive"
                );
                let rate = accepted as f64 / proposed as f64;
                assert!(rate <= 1.0, "a rate cannot exceed 1: {rate}");
            }
        }
        assert!(found, "no overshooting round in 20 prompts — widen the sweep");

        // The serving path reports the corrected metric.
        let g = generate(&engine, &[5, 9, 2, 14], 7, &cfg).unwrap();
        assert_eq!(g.tokens.len(), 7);
        assert!(g.accept_rate() <= 1.0);
        assert!(g.proposed >= g.accepted);
        assert!(
            g.reply_line().contains(&format!("accept={:.3}", g.accept_rate())),
            "reply must carry the corrected rate"
        );
    }

    #[test]
    fn generate_is_chunk_plan_invariant() {
        // The Eq. 3-planned chunks must not change the stream vs the old
        // fixed-64 chunking (greedy losslessness covers prefill too).
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt: Vec<u32> = (0u32..130).map(|i| (i * 13 + 5) % 256).collect();
        let g = generate(&engine, &prompt, 10, &cfg).unwrap();

        let mut s = Session::new(&engine, cfg.clone()).unwrap();
        let t1 = s.prefill(&prompt, &chunk_sizes(prompt.len(), 64)).unwrap();
        let mut out = vec![t1];
        while out.len() < 10 {
            let budget = (10 - out.len()).saturating_sub(1).max(1);
            let r = s.hat_round_capped(true, 4, budget).unwrap();
            out.extend_from_slice(&r.emitted);
        }
        out.truncate(10);
        assert_eq!(g.tokens, out);
    }
}
