//! Real serving mode: a TCP line-protocol server over the real engine
//! (the offline crate set has no tokio/hyper; std::net with
//! `set_nonblocking` and a polled connection set is the substrate we
//! build instead).
//!
//! Protocol (UTF-8 lines):
//!
//! ```text
//! C: GENERATE <max_new_tokens> <tok> <tok> ...\n
//! S: OK <tok> <tok> ... | rounds=<n> accept=<rate>\n
//!    (or `ERR busy` — the admission queue is full, `serve.admit_queue`;
//!     or `ERR rate limited` — the connection's token bucket is empty,
//!     `serve.rate_limit_rps` / `serve.burst`)
//! C: CANCEL\n            (only meaningful while a GENERATE is in flight)
//! S: -                   (no reply of its own: the pending GENERATE
//!                         replies `ERR cancelled`; a CANCEL with nothing
//!                         in flight replies `ERR nothing in flight`)
//! C: STATS\n
//! S: OK executions=<n> exec_ms=<t> compiles=<n> compile_ms=<t>
//!       requests=<n> iterations=<n> queue_wait_ms=<t> ttft_ms=<t>
//!       tbt_ms=<t> rounds=<n> accept=<rate> accept_hist=<c0,c1,...|->
//!       seed=<n> chunk_mean=<x> batch_mean=<x> fallbacks=<n>
//!       cancelled=<n> failed=<n> reaped=<n> deadline_expired=<n>
//!       preempted=<n> kv_swap_bytes=<n> kv_blocks=<n> kv_shared=<n>
//!       handoffs=<n> pf_wait_ms=<t> dc_wait_ms=<t> pf_occ=<x> dc_occ=<x>
//!       rate_limited=<n> shed_busy=<n> slow_reader_dropped=<n>
//!       open_conns=<n>
//!       g_learned=<0|1> queued=<n> live=<n> decode_q=<n> prefill_q=<n>\n
//!                                                 (one line on the wire)
//! C: QUIT\n
//! S: OK bye\n
//! ```
//!
//! A single request line is capped at [`conn::MAX_LINE_BYTES`]; the cap
//! is enforced incrementally during framing, so a line that crosses it
//! is refused (`ERR line too long`, connection closed) while its bytes
//! are still arriving.
//!
//! GENERATE's `accept` is the speculative-decoding acceptance rate
//! Σ accepted / Σ proposed over the request's rounds (independent of the
//! final truncation to max_new_tokens).  STATS carries the backend runtime
//! counters followed by the scheduler aggregates: finished request count,
//! scheduler iterations, mean queue wait / TTFT / TBT (wall-clock ms),
//! total SD rounds, the aggregate acceptance rate, `accept_hist` — the
//! per-round acceptance histogram (`accept_hist[a]` counts verify rounds
//! that accepted exactly `a` proposals; comma-joined, `-` while no round
//! has finished) — `seed` — the `[specdec] seed` the scheduler's sessions
//! sample with — the mean Eq. 3 chunk
//! size (of *executed* chunks, post-clamp), `batch_mean` — the mean
//! session count per batched engine-call group the scheduler issued (1.0
//! means nothing batched, higher means verify rounds / prefill chunks of
//! concurrent sessions actually executed as one `run_batch` call) —
//! `fallbacks` — batched cloud calls that failed and degraded to
//! per-lane serial execution — the session-lifecycle counters —
//! `cancelled` (client disconnects noticed mid-generation plus explicit
//! CANCELs), `failed` (`ERR` replies from the job runners and
//! submit-time rejections), `reaped` (requests dropped without a reply
//! because their client was
//! already gone), `deadline_expired` (`serve.deadline_ms` cancellations)
//! — the paged-KV counters — `preempted` (sessions parked under
//! `[serve] priority = preempt`: KV paged out to the host store and the
//! slot handed to a waiting admission; the session resumes later, it is
//! never cancelled), `kv_swap_bytes` (bytes moved by preemption swap-out
//! plus resume swap-in; blocks the pool re-shares by content dedup move
//! zero), `kv_blocks` (pool blocks currently mapped by live caches,
//! refreshed each scheduler iteration), `kv_shared` (blocks mapped by
//! more than one cache table via copy-on-write prefix sharing)
//! — the disaggregation counters — `handoffs` (sessions transferred
//! prefill→decode across the pool seam; 0 in single-pool mode),
//! `pf_wait_ms` (mean arrival→prefill-slot admission wait),
//! `dc_wait_ms` (mean handoff-ready→decode-slot adoption wait; the two
//! splits of the old single queue-wait), `pf_occ` / `dc_occ` (mean
//! per-pool slot occupancy in [0,1], sampled each coordinator
//! iteration; in single-pool mode both read 0)
//! — the front-end flow-control counters — `rate_limited` (GENERATEs
//! refused `ERR rate limited` by a connection's token bucket),
//! `shed_busy` (GENERATEs refused `ERR busy` by the bounded admission
//! queue), `slow_reader_dropped` (connections dropped because their
//! bounded reply outbox overflowed — a client that stopped reading),
//! `open_conns` (connections currently held by the event loop — a
//! gauge, not a counter)
//! — `g_learned` — 1 when the Eq. 3 optimizer is driven by the learned
//! state-monitor delay curve, 0 while it still falls back to the static
//! `GModel` calibration — and the current queue depth / live session
//! count.
//!
//! Concurrency model: the engine is not thread-safe (one backend
//! client), so ONE thread owns it — and, since this refactor, that same
//! thread owns the listener and every client connection.  There are no
//! per-connection threads and no reply channels: [`conn::event_loop`] is
//! a non-blocking readiness loop that accepts, reads, parses, submits,
//! writes and *steps the scheduler* in one cycle, with each connection a
//! [`conn`] state machine and each in-flight request's reply routed
//! through a single-threaded [`conn::ReplySink`].  The worker drives a
//! continuous-batching [`scheduler::Scheduler`]: up to `--max-sessions`
//! live sessions interleave at prefill-chunk / verify-round granularity,
//! with prefill admitted under a `--prefill-budget` token budget per
//! iteration and chunk sizes from the Eq. 3 optimizer.  Losslessness
//! makes the interleaving invisible in each connection's output:
//! bit-for-bit under greedy decoding (`temperature = 0`, the default),
//! and token-identical to a serial seeded run under stochastic sampling,
//! because every session's draws are keyed by `(seed, context position)`
//! rather than by call order.
//!
//! Session lifecycle: because connection liveness is observed by the
//! engine-owning loop itself, a client that disconnects mid-generation —
//! or pipelines a `CANCEL` line — has its request cancelled at the next
//! iteration boundary as a direct *event* (the EOF read), not via the
//! old timeout-bounded socket probe: the slot is freed and the session's
//! KV dropped instead of running the abandoned generation to completion
//! into a dead channel while live clients queued for the slot.  Slow
//! readers cannot stall the loop either: replies drain through a bounded
//! per-connection outbox on writability, and a connection whose outbox
//! overflows is dropped (`slow_reader_dropped`), its generation
//! cancelled through the same path.
//!
//! Preemption: with `[serve] priority = preempt` (or `--priority
//! preempt`), a full scheduler with waiting admissions parks a live
//! session instead of making arrivals queue behind it: the victim's KV
//! is paged out to the pool's host-side store, the slot is handed to the
//! waiting request, and the victim resumes — swap-in re-shares
//! bit-identical sealed blocks at zero copy cost — as soon as a slot
//! frees.  Losslessness holds across the park/resume: the emitted stream
//! is byte-identical to an uninterrupted run.  The default (`priority =
//! none`) never preempts.
//!
//! Disaggregation: with `[serve] prefill_workers = N` and
//! `decode_workers = M` both set (or `--prefill-workers` /
//! `--decode-workers`), the loop drives a [`pools::PdScheduler`]
//! instead of one [`scheduler::Scheduler`]: a throughput-oriented
//! prefill pool (N slots) and a latency-oriented decode pool (M slots),
//! each with its own engine, batcher queue and per-phase g^t monitor,
//! sharing one paged KV pool.  Sessions finish prefill in the first
//! pool and are handed off — hidden state plus KV block tables, no
//! dense copy — to the second for their hat rounds; the coordinator
//! steps decode-first so aggressor prefill chunks stop inflating
//! interactive TBT.  Both workers unset (the default) keeps the
//! single-pool scheduler.  See [`pools`] for the discipline and seam
//! lifecycle.

pub mod conn;
pub mod pools;
pub mod scheduler;

use std::net::TcpListener;

use crate::cli::Flags;
use crate::config::{AdmitPolicy, PriorityMode, ServeConfig, SpecDecConfig};
use crate::engine::Engine;
use crate::specdec::{chunk_sizes, Session};

use pools::PdScheduler;
use scheduler::Scheduler;

/// A parsed request.
#[derive(Debug, PartialEq)]
pub enum Command {
    Generate { max_new: usize, prompt: Vec<u32> },
    Cancel,
    Stats,
    Quit,
}

/// Shared GENERATE request validation — the single definition both the
/// protocol parser ([`parse_line`]) and the directly-driven scheduler
/// ([`scheduler::Scheduler::submit`]) route through, so their error
/// strings cannot drift.  `max_new_cap` comes from
/// `SpecDecConfig::max_new_tokens`.
pub fn validate_request(prompt: &[u32], max_new: usize, max_new_cap: usize) -> Result<(), String> {
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    if max_new == 0 || max_new > max_new_cap {
        return Err(format!("max_new_tokens out of range (1..={max_new_cap})"));
    }
    Ok(())
}

/// Parse one protocol line.  `max_new_cap` bounds GENERATE's
/// max_new_tokens (from `SpecDecConfig::max_new_tokens` — no hard-coded
/// limit).
pub fn parse_line(line: &str, max_new_cap: usize) -> Result<Command, String> {
    let mut it = line.split_whitespace();
    match it.next() {
        Some("GENERATE") => {
            let max_new: usize = it
                .next()
                .ok_or("GENERATE needs max_new_tokens")?
                .parse()
                .map_err(|_| "bad max_new_tokens".to_string())?;
            let prompt: Result<Vec<u32>, _> = it.map(|t| t.parse::<u32>()).collect();
            let prompt = prompt.map_err(|_| "bad token id".to_string())?;
            validate_request(&prompt, max_new, max_new_cap)?;
            Ok(Command::Generate { max_new, prompt })
        }
        Some("CANCEL") => Ok(Command::Cancel),
        Some("STATS") => Ok(Command::Stats),
        Some("QUIT") => Ok(Command::Quit),
        Some(other) => Err(format!("unknown command {other}")),
        None => Err("empty line".into()),
    }
}

/// Result of one generation, with speculative-decoding accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    pub tokens: Vec<u32>,
    /// Decode rounds executed.
    pub rounds: usize,
    /// Σ draft tokens proposed across rounds.
    pub proposed: usize,
    /// Σ draft tokens accepted across rounds.
    pub accepted: usize,
}

impl Generation {
    /// Acceptance rate Σ accepted / Σ proposed.  The old serve path
    /// reported `(out.len()-1)/rounds` *after* truncation — that measures
    /// emitted-per-round (it routinely exceeds 1.0) and truncation
    /// deflated it; this is per-proposal acceptance, truncation-invariant
    /// (one shared definition: [`crate::metrics::accept_rate`]).
    pub fn accept_rate(&self) -> f64 {
        crate::metrics::accept_rate(self.accepted, self.proposed)
    }

    /// The GENERATE protocol reply line (shared by the serial path and the
    /// scheduler so the two are byte-identical by construction).
    pub fn reply_line(&self) -> String {
        let toks: Vec<String> = self.tokens.iter().map(|t| t.to_string()).collect();
        format!("OK {} | rounds={} accept={:.3}", toks.join(" "), self.rounds, self.accept_rate())
    }
}

/// Serve one request on the engine serially: HAT protocol (chunked prefill
/// + SD).  This is the reference path the scheduler's interleaved
/// execution must match byte-for-byte; prefill chunks come from the Eq. 3
/// optimizer (same helpers as the scheduler) under a default `ServeConfig`
/// and an idle-cloud assumption (μ = 0) — greedy losslessness means the
/// chunk plan cannot change the emitted stream either way.
pub fn generate(
    engine: &Engine,
    prompt: &[u32],
    max_new: usize,
    spec_cfg: &SpecDecConfig,
) -> anyhow::Result<Generation> {
    let max_ctx = engine.spec().max_seq;
    anyhow::ensure!(
        prompt.len() + max_new + spec_cfg.max_draft + 2 <= max_ctx,
        "prompt+generation exceeds model max_seq {max_ctx}"
    );
    let mut serve = ServeConfig::default();
    scheduler::clamp_chunk_bounds(&mut serve, engine);
    let x = scheduler::eq3_chunk(&serve, 0.0);

    let mut s = Session::new(engine, spec_cfg.clone())?;
    let chunks = chunk_sizes(prompt.len(), x);
    let t1 = s.prefill(prompt, &chunks)?;
    let mut out = vec![t1];
    let (mut rounds, mut proposed, mut accepted) = (0usize, 0usize, 0usize);
    while out.len() < max_new {
        // Cap the round's draft length by the tokens still needed, so the
        // final round does not draft tokens that would only be truncated.
        let budget = (max_new - out.len()).saturating_sub(1).max(1);
        // λ follows the configured draft cap (the old hard-coded 4
        // silently disagreed with SpecDecConfig::max_draft).
        let r = s.hat_round_capped(true, spec_cfg.max_draft, budget)?;
        out.extend_from_slice(&r.emitted);
        rounds += 1;
        proposed += r.proposed.len();
        accepted += r.accepted;
    }
    out.truncate(max_new);
    Ok(Generation { tokens: out, rounds, proposed, accepted })
}

/// Run the serve loop on an already-bound listener (the testable core of
/// [`cmd_serve`]; binding is the caller's job so tests can use port 0).
/// Accepts at most `max_conns` connections, then — once the last of them
/// closes — returns.
///
/// Everything runs on the calling thread: the engine (whose backend
/// client is `!Send`), the scheduler, the listener and every connection,
/// multiplexed by [`conn::event_loop`].
pub fn serve_listener(
    listener: TcpListener,
    spec_cfg: SpecDecConfig,
    serve_cfg: ServeConfig,
    max_conns: usize,
) -> Result<(), String> {
    let max_new_cap = spec_cfg.max_new_tokens;
    let engine = Engine::load_default().map_err(|e| format!("engine load: {e}"))?;
    if serve_cfg.prefill_workers > 0 && serve_cfg.decode_workers > 0 {
        // Disaggregated path: the prefill pool runs on this engine, the
        // decode pool on a sibling sharing its KV pool (block tables
        // must be valid across the handoff).  Both live on this one
        // thread — the backend is not Send; the split is in iteration
        // composition, not threads.
        let decode_engine = engine
            .sibling()
            .map_err(|e| format!("serve: sibling engine for decode pool failed ({e})"))?;
        let mut sched = PdScheduler::new(&engine, &decode_engine, spec_cfg, serve_cfg.clone())
            .map_err(|e| format!("serve: disaggregated pools unavailable ({e})"))?;
        return conn::event_loop(&listener, &mut sched, max_new_cap, &serve_cfg, max_conns);
    }
    let mut sched = Scheduler::new(&engine, spec_cfg, serve_cfg.clone());
    conn::event_loop(&listener, &mut sched, max_new_cap, &serve_cfg, max_conns)
}

/// `hat serve --addr 127.0.0.1:7071 [--config FILE] [--max-sessions N]
/// [--prefill-budget T] [--policy fifo|sjf] [--deadline-ms T]
/// [--prefill-workers N] [--decode-workers M]
/// [--max-conns N] [--rate-limit X] [--temperature X] [--top-k-sample N]
/// [--top-p X] [--rep-penalty X] [--seed N]
/// [--verify-mode coupled|rejection]`
///
/// `--config` reuses the experiment-config format: its `[specdec]` section
/// (eta, max_draft, top_k, max_new_tokens, plus the sampling keys
/// temperature, top_k_sample, top_p, rep_penalty, seed, verify_mode) and
/// `[serve]` section (max_sessions, prefill_budget, min_chunk, max_chunk,
/// alpha, pipeline_len, policy, sjf_aging_ms, deadline_ms, priority,
/// prefill_workers, decode_workers, rate_limit_rps, burst, admit_queue,
/// outbox_lines)
/// govern serving;
/// the flags override the file.  `--temperature 0` (the default) is greedy
/// decoding; with a positive temperature every session samples with the
/// shared `--seed`, position-keyed per session.  `--prefill-workers` and
/// `--decode-workers` (set together) switch the worker to the
/// disaggregated P/D pools; `--max-sessions` then only applies to the
/// single-pool fallback.  `--rate-limit X` sets the per-connection token
/// bucket to X GENERATEs per second (refill rate; `serve.burst` caps the
/// bucket) — 0, the default, disables limiting.
pub fn cmd_serve(f: &Flags) -> Result<(), String> {
    let addr = f.get("addr").unwrap_or("127.0.0.1:7071").to_string();
    let (mut spec_cfg, mut serve_cfg) = match f.get("config") {
        Some(path) => {
            let cfg = crate::config::parser::load_file(path)?;
            (cfg.specdec, cfg.serve)
        }
        None => (SpecDecConfig::default(), ServeConfig::default()),
    };
    if let Some(n) = f.get_usize("max-sessions")? {
        if n == 0 {
            return Err("--max-sessions must be > 0".into());
        }
        serve_cfg.max_sessions = n;
    }
    if let Some(t) = f.get_usize("prefill-budget")? {
        if t == 0 {
            return Err("--prefill-budget must be > 0".into());
        }
        serve_cfg.prefill_budget = t;
    }
    if let Some(p) = f.get("policy") {
        serve_cfg.policy =
            AdmitPolicy::parse(p).ok_or(format!("--policy: unknown policy {p:?} (fifo|sjf)"))?;
    }
    if let Some(p) = f.get("priority") {
        serve_cfg.priority = PriorityMode::parse(p)
            .ok_or(format!("--priority: unknown mode {p:?} (none|preempt)"))?;
    }
    if let Some(t) = f.get_usize("deadline-ms")? {
        serve_cfg.deadline_ms = t as u64;
    }
    if let Some(n) = f.get_usize("prefill-workers")? {
        serve_cfg.prefill_workers = n;
    }
    if let Some(n) = f.get_usize("decode-workers")? {
        serve_cfg.decode_workers = n;
    }
    if (serve_cfg.prefill_workers == 0) != (serve_cfg.decode_workers == 0) {
        return Err(
            "--prefill-workers and --decode-workers must be set together (both > 0)".into()
        );
    }
    if let Some(r) = f.get_f64("rate-limit")? {
        if r < 0.0 {
            return Err("--rate-limit must be >= 0".into());
        }
        serve_cfg.rate_limit_rps = r;
    }
    if let Some(t) = f.get_f64("temperature")? {
        if t < 0.0 {
            return Err("--temperature must be >= 0".into());
        }
        spec_cfg.temperature = t;
    }
    if let Some(k) = f.get_usize("top-k-sample")? {
        spec_cfg.top_k_sample = k;
    }
    if let Some(p) = f.get_f64("top-p")? {
        if !(p > 0.0 && p <= 1.0) {
            return Err("--top-p must be in (0,1]".into());
        }
        spec_cfg.top_p = p;
    }
    if let Some(r) = f.get_f64("rep-penalty")? {
        if r <= 0.0 {
            return Err("--rep-penalty must be > 0".into());
        }
        spec_cfg.rep_penalty = r;
    }
    if let Some(s) = f.get_usize("seed")? {
        spec_cfg.seed = s as u64;
    }
    if let Some(m) = f.get("verify-mode") {
        spec_cfg.verify_mode = crate::config::SampleVerify::parse(m)
            .ok_or(format!("--verify-mode: unknown mode {m:?} (coupled|rejection)"))?;
    }
    let max_conns = f.get_usize("max-conns")?.unwrap_or(usize::MAX);

    let listener = TcpListener::bind(&addr).map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!(
        "hat serving on {addr} ({} sessions, prefill budget {}; line protocol — see rust/src/server/mod.rs)",
        serve_cfg.max_sessions, serve_cfg.prefill_budget
    );
    serve_listener(listener, spec_cfg, serve_cfg, max_conns)
}

#[cfg(test)]
mod tests {
    use super::conn::ReplySink;
    use super::scheduler::Request;
    use super::*;
    use crate::util::clock;
    use std::time::Duration;

    const CAP: usize = 512;

    #[test]
    fn parses_generate() {
        let c = parse_line("GENERATE 16 1 2 3", CAP).unwrap();
        assert_eq!(c, Command::Generate { max_new: 16, prompt: vec![1, 2, 3] });
    }

    #[test]
    fn parses_stats_quit_and_cancel() {
        assert_eq!(parse_line("STATS", CAP).unwrap(), Command::Stats);
        assert_eq!(parse_line("QUIT", CAP).unwrap(), Command::Quit);
        assert_eq!(parse_line("CANCEL", CAP).unwrap(), Command::Cancel);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_line("GENERATE", CAP).is_err());
        assert!(parse_line("GENERATE 10", CAP).is_err()); // empty prompt
        assert!(parse_line("GENERATE 0 1 2", CAP).is_err());
        assert!(parse_line("GENERATE 9999 1", CAP).is_err());
        assert!(parse_line("GENERATE 4 1 x", CAP).is_err());
        assert!(parse_line("NOPE 1", CAP).is_err());
        assert!(parse_line("", CAP).is_err());
    }

    #[test]
    fn cap_comes_from_config_not_hardcode() {
        // A configured cap of 64 rejects 65 and accepts 64; the old
        // hard-coded 512 no longer applies.
        assert!(parse_line("GENERATE 65 1", 64).is_err());
        let c = parse_line("GENERATE 64 1", 64).unwrap();
        assert_eq!(c, Command::Generate { max_new: 64, prompt: vec![1] });
        assert!(parse_line("GENERATE 600 1", 1024).is_ok());
        assert_eq!(
            SpecDecConfig::default().max_new_tokens,
            512,
            "default cap preserves the old protocol limit"
        );
    }

    #[test]
    fn parser_and_scheduler_share_validation_strings() {
        // Both entry points route through validate_request, so the error
        // strings are identical by construction — a drift regression test.
        let cap = SpecDecConfig::default().max_new_tokens;
        let engine = Engine::synthetic();

        let parse_err = parse_line("GENERATE 600 1", cap).unwrap_err();
        let mut sched =
            Scheduler::new(&engine, SpecDecConfig::default(), ServeConfig::default());
        let rx = ReplySink::new();
        sched.submit(Request {
            id: 1,
            prompt: vec![1],
            max_new: 600,
            reply: rx.clone(),
            enqueued: clock::now(),
        });
        assert_eq!(rx.recv().unwrap(), format!("ERR {parse_err}"));

        let parse_err = parse_line("GENERATE 4", cap).unwrap_err();
        assert_eq!(parse_err, "empty prompt");
        let rx = ReplySink::new();
        sched.submit(Request {
            id: 2,
            prompt: vec![],
            max_new: 4,
            reply: rx.clone(),
            enqueued: clock::now(),
        });
        assert_eq!(rx.recv().unwrap(), format!("ERR {parse_err}"));
        assert!(!sched.has_work(), "rejected requests must not occupy the queue");
    }

    #[test]
    fn worker_exits_promptly_after_last_connection_closes() {
        // Regression for the serve loop's shutdown path: exit is an
        // explicit loop condition — listener retired (accept budget
        // spent) and no open connections — not an inference from dead
        // reply channels.  The old loop finished all admitted work first
        // and only noticed via a recv() error once idle; an abandoned
        // long generation kept the thread alive arbitrarily.  The loop
        // must reap the abandoned generation and return promptly.
        use std::io::Write as _;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let r =
                serve_listener(listener, SpecDecConfig::default(), ServeConfig::default(), 1);
            let _ = done_tx.send(r);
        });
        {
            // A long generation whose client vanishes immediately.
            let mut c = std::net::TcpStream::connect(addr).unwrap();
            let prompt: Vec<String> =
                (0u32..64).map(|i| ((i * 7 + 3) % 256).to_string()).collect();
            writeln!(c, "GENERATE 200 {}", prompt.join(" ")).unwrap();
        }
        done_rx
            .recv_timeout(Duration::from_secs(20))
            .expect("serve loop did not exit after the last connection closed")
            .unwrap();
    }

    #[test]
    fn generate_end_to_end_on_synthetic_engine() {
        // The headline of the backend seam: real serving path, no
        // artifacts, no accelerator libraries.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let g = generate(&engine, &[5, 9, 2, 14], 12, &cfg).unwrap();
        assert_eq!(g.tokens.len(), 12);
        assert!(g.rounds >= 1);
        assert!(g.tokens.iter().all(|&t| (t as usize) < engine.spec().vocab));
        // Deterministic: same prompt, same stream.
        let g2 = generate(&engine, &[5, 9, 2, 14], 12, &cfg).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn accept_rate_is_truncation_invariant() {
        // Regression for the old `(out.len()-1)/rounds` metric: replay the
        // pre-fix serial loop (uncapped rounds, truncate at the end) and
        // find a case where the final round overshoots max_new — there the
        // old metric changed under truncation, while Σaccepted/Σproposed
        // is computed from the rounds themselves and cannot.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let mut found = false;
        for seed in 0..20u32 {
            let prompt = vec![3 + seed, 9, 2, 14];
            let mut s = Session::new(&engine, cfg.clone()).unwrap();
            let t1 = s.prefill(&prompt, &[prompt.len()]).unwrap();
            let mut out = vec![t1];
            let (mut rounds, mut proposed, mut accepted) = (0usize, 0usize, 0usize);
            let max_new = 2;
            while out.len() < max_new {
                let r = s.hat_round(true, 4).unwrap(); // uncapped, as before
                out.extend_from_slice(&r.emitted);
                rounds += 1;
                proposed += r.proposed.len();
                accepted += r.accepted;
            }
            let before = out.len();
            out.truncate(max_new);
            if before > max_new {
                found = true;
                let old_untruncated = (before - 1) as f64 / rounds as f64;
                let old_truncated = (out.len() - 1) as f64 / rounds as f64;
                assert_ne!(
                    old_untruncated, old_truncated,
                    "old metric was truncation-sensitive"
                );
                let rate = accepted as f64 / proposed as f64;
                assert!(rate <= 1.0, "a rate cannot exceed 1: {rate}");
            }
        }
        assert!(found, "no overshooting round in 20 prompts — widen the sweep");

        // The serving path reports the corrected metric.
        let g = generate(&engine, &[5, 9, 2, 14], 7, &cfg).unwrap();
        assert_eq!(g.tokens.len(), 7);
        assert!(g.accept_rate() <= 1.0);
        assert!(g.proposed >= g.accepted);
        assert!(
            g.reply_line().contains(&format!("accept={:.3}", g.accept_rate())),
            "reply must carry the corrected rate"
        );
    }

    #[test]
    fn generate_is_chunk_plan_invariant() {
        // The Eq. 3-planned chunks must not change the stream vs the old
        // fixed-64 chunking (greedy losslessness covers prefill too).
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt: Vec<u32> = (0u32..130).map(|i| (i * 13 + 5) % 256).collect();
        let g = generate(&engine, &prompt, 10, &cfg).unwrap();

        let mut s = Session::new(&engine, cfg.clone()).unwrap();
        let t1 = s.prefill(&prompt, &chunk_sizes(prompt.len(), 64)).unwrap();
        let mut out = vec![t1];
        while out.len() < 10 {
            let budget = (10 - out.len()).saturating_sub(1).max(1);
            let r = s.hat_round_capped(true, 4, budget).unwrap();
            out.extend_from_slice(&r.emitted);
        }
        out.truncate(10);
        assert_eq!(g.tokens, out);
    }
}
