//! Time choke point: the only sanctioned callers of `Instant::now` and
//! `thread::sleep` in the tree.
//!
//! `clippy.toml` bans the raw `std` calls (`disallowed-methods`) so every
//! time read and every blocking sleep routes through here — one place to
//! audit for wall-clock coupling, and one seam to hook if timing ever
//! needs to be virtualized (benches opt out file-by-file: they exist to
//! measure real wall time).

use std::time::{Duration, Instant};

/// Read the monotonic clock.
#[allow(clippy::disallowed_methods)] // the one sanctioned Instant::now call
pub fn now() -> Instant {
    Instant::now()
}

/// Block the current thread for `d`.
#[allow(clippy::disallowed_methods)] // the one sanctioned thread::sleep call
pub fn sleep(d: Duration) {
    std::thread::sleep(d);
}
