//! Property-testing mini-framework (proptest is not in the offline crate
//! set).  Seeded case generation + first-failure shrinking for integer
//! vectors, used on the coordinator invariants (routing, batching,
//! chunking, KV-position state machines).
//!
//! ```ignore
//! forall(cases(200), |rng| {
//!     let n = rng.range_usize(1, 64);
//!     /* ... build input, return Err(msg) on violation ... */
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Configuration: number of cases and base seed.
#[derive(Clone, Copy)]
pub struct Cases {
    pub n: usize,
    pub seed: u64,
}

pub fn cases(n: usize) -> Cases {
    // Honour HAT_PROPTEST_SEED for reproduction of CI failures.
    let seed = std::env::var("HAT_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    Cases { n, seed }
}

/// Run `prop` for `cases.n` seeded cases; panic with the failing seed on the
/// first violation so the case can be replayed exactly.
pub fn forall<F>(cases: Cases, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for i in 0..cases.n {
        let case_seed = cases.seed.wrapping_add(i as u64);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property violated on case {i} (replay with HAT_PROPTEST_SEED={case_seed} and n=1): {msg}"
            );
        }
    }
}

/// Generate a random vector of usize in [lo, hi], length in [1, max_len].
pub fn vec_usize(rng: &mut Rng, max_len: usize, lo: usize, hi: usize) -> Vec<usize> {
    let len = rng.range_usize(1, max_len);
    (0..len).map(|_| rng.range_usize(lo, hi)).collect()
}

/// Generate a random vector of f64 in [lo, hi), length in [1, max_len].
pub fn vec_f64(rng: &mut Rng, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
    let len = rng.range_usize(1, max_len);
    (0..len).map(|_| rng.range_f64(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(cases(50), |rng| {
            let v = vec_usize(rng, 10, 0, 100);
            if v.iter().sum::<usize>() <= 100 * v.len() {
                Ok(())
            } else {
                Err("sum exceeded bound".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property violated")]
    fn reports_failure_with_seed() {
        forall(cases(10), |rng| {
            let x = rng.below(10);
            if x < 9 { Ok(()) } else { Err(format!("x={x}")) }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        forall(cases(100), |rng| {
            let v = vec_f64(rng, 5, -1.0, 1.0);
            if v.iter().all(|x| (-1.0..1.0).contains(x)) {
                Ok(())
            } else {
                Err("out of bounds".into())
            }
        });
    }
}
