//! Substrate utilities built in-tree (the offline crate set has no rand /
//! serde / proptest): deterministic PRNG, statistics, JSON, and a
//! property-testing mini-framework.

pub mod clock;
pub mod json;
pub mod proptest;
pub mod report;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
