//! Minimal JSON parser + writer (serde is not in the offline crate set).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serializes bench/metric reports.  Supports the full JSON grammar
//! except unicode escapes beyond BMP \uXXXX pairs (not needed here).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Path access: `v.path(&["model", "hidden"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

pub fn parse(s: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: s.as_bytes(), pos: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("short \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.pos]).map_err(|_| self.err("bad utf8"))?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Value::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

pub fn write(v: &Value) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => {
            out.push('"');
            out.push_str(&escape(s));
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('"');
                out.push_str(&escape(k));
                out.push_str("\":");
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builder for report objects.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn parse_unicode_and_escape_roundtrip() {
        let v = parse("\"caf\\u00e9 ∆\"").unwrap();
        assert_eq!(v.as_str(), Some("café ∆"));
        let original = Value::Str("tab\t\"q\" ∆".into());
        assert_eq!(parse(&write(&original)).unwrap(), original);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn write_roundtrip_manifest_like() {
        let v = obj(vec![
            ("model", obj(vec![("hidden", Value::Num(128.0))])),
            ("buckets", arr_f64(&[1.0, 2.0, 4.0])),
            ("name", Value::Str("device_input_1".into())),
        ]);
        let s = write(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(write(&Value::Num(128.0)), "128");
        assert_eq!(write(&Value::Num(0.5)), "0.5");
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n \"a\" :\t1 , \"b\" : [ ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 0);
    }
}
