//! Statistics used by the metrics layer: streaming mean/std (Welford),
//! percentiles, CDFs, and simple moving averages (the paper's Eq. 1–2 use
//! exponential moving averages — those live in `cloud::state_monitor`).

/// Streaming mean / variance (Welford). O(1) memory.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (matches how the paper reports per-GPU delay std).
    pub fn var(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Fold another accumulator into this one (parallel Welford / Chan et
    /// al.): the result is identical to having pushed both sample streams
    /// into a single accumulator.  Used to merge per-pool serve stats.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { count: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let var = s.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / s.len() as f64;
        Summary {
            count: s.len(),
            mean,
            std: var.sqrt(),
            min: s[0],
            max: *s.last().unwrap(),
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

/// Percentile of a pre-sorted sample (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Empirical CDF evaluated at `points`: fraction of the sample <= point.
/// This is how the SLA-compliance curves of Figs. 9–10 are produced
/// (compliance rate at SLA `s` = CDF of per-unit delay at `s`).
pub fn cdf_at(sample: &[f64], points: &[f64]) -> Vec<f64> {
    let mut s = sample.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points
        .iter()
        .map(|&p| {
            let idx = s.partition_point(|&x| x <= p);
            idx as f64 / s.len().max(1) as f64
        })
        .collect()
}

/// Inverse of `cdf_at`: the delay at which a fraction `q` of requests comply
/// ("50% of requests meet a decode SLA of X ms").
pub fn quantile(sample: &[f64], q: f64) -> f64 {
    let mut s = sample.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, q * 100.0)
}

/// Upper-tail standard-normal z for α = 0.01 (used with
/// [`chi2_critical`] for the distribution-identity tests).
pub const Z_ALPHA_01: f64 = 2.326_347_9;

/// Two-sample KS scale constant c(α) for α = 0.01 (used with
/// [`ks_critical`]).
pub const KS_C_ALPHA_01: f64 = 1.628;

/// Two-sample Pearson chi-squared statistic over aligned count
/// histograms (bin i of `a` and `b` counts the same outcome).  Returns
/// `(statistic, degrees of freedom)`; empty bins (zero in both samples)
/// are skipped and don't contribute a degree of freedom.  Under H0
/// ("both histograms draw from one distribution") the statistic is
/// asymptotically chi-squared with `bins - 1` dof.
pub fn chi2_two_sample(a: &[u64], b: &[u64]) -> (f64, usize) {
    assert_eq!(a.len(), b.len(), "histograms must share bins");
    let n1: u64 = a.iter().sum();
    let n2: u64 = b.iter().sum();
    assert!(n1 > 0 && n2 > 0, "empty sample");
    let k1 = (n2 as f64 / n1 as f64).sqrt();
    let k2 = (n1 as f64 / n2 as f64).sqrt();
    let mut stat = 0.0;
    let mut bins = 0usize;
    for (&x, &y) in a.iter().zip(b) {
        let t = x + y;
        if t == 0 {
            continue;
        }
        bins += 1;
        let d = k1 * x as f64 - k2 * y as f64;
        stat += d * d / t as f64;
    }
    (stat, bins.saturating_sub(1))
}

/// Upper-tail chi-squared critical value via the Wilson–Hilferty cube
/// approximation: `chi2_{1-α}(k) ≈ k·(1 - 2/9k + z_{1-α}·sqrt(2/9k))³`
/// — accurate to a few percent for k ≥ 3, which is all the equivalence
/// harness needs (a slightly loose critical value only makes the test
/// marginally more permissive).
pub fn chi2_critical(dof: usize, z: f64) -> f64 {
    assert!(dof > 0, "chi2 needs >= 1 dof");
    let k = dof as f64;
    let c = 1.0 - 2.0 / (9.0 * k) + z * (2.0 / (9.0 * k)).sqrt();
    k * c.powi(3)
}

/// Two-sample Kolmogorov–Smirnov statistic: sup |F_a - F_b| over the
/// empirical CDFs.  For discrete data (token ids) the usual critical
/// values are conservative, which is the safe direction for an
/// equivalence check.
pub fn ks_two_sample(a: &[f64], b: &[f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty(), "empty sample");
    let mut x = a.to_vec();
    let mut y = b.to_vec();
    x.sort_by(|p, q| p.partial_cmp(q).unwrap());
    y.sort_by(|p, q| p.partial_cmp(q).unwrap());
    let (n, m) = (x.len(), y.len());
    let (mut i, mut j, mut d) = (0usize, 0usize, 0.0f64);
    while i < n && j < m {
        let v = x[i].min(y[j]);
        while i < n && x[i] <= v {
            i += 1;
        }
        while j < m && y[j] <= v {
            j += 1;
        }
        let gap = (i as f64 / n as f64 - j as f64 / m as f64).abs();
        if gap > d {
            d = gap;
        }
    }
    d
}

/// KS rejection threshold `c(α)·sqrt((n+m)/(n·m))`; reject H0 when the
/// statistic exceeds it.
pub fn ks_critical(n: usize, m: usize, c_alpha: f64) -> f64 {
    assert!(n > 0 && m > 0);
    c_alpha * ((n + m) as f64 / (n as f64 * m as f64)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn welford_merge_equals_single_stream() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0, -2.5, 7.0];
        for split in 0..=xs.len() {
            let mut a = Welford::new();
            let mut b = Welford::new();
            for &x in &xs[..split] {
                a.push(x);
            }
            for &x in &xs[split..] {
                b.push(x);
            }
            let mut whole = Welford::new();
            for &x in &xs {
                whole.push(x);
            }
            a.merge(&b);
            assert_eq!(a.count(), whole.count());
            assert!((a.mean() - whole.mean()).abs() < 1e-12);
            assert!((a.var() - whole.var()).abs() < 1e-12);
        }
    }

    #[test]
    fn percentile_basics() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 100.0), 5.0);
        assert_eq!(percentile_sorted(&s, 50.0), 3.0);
        assert!((percentile_sorted(&s, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_of_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn cdf_monotone_and_bounds() {
        let sample = [5.0, 1.0, 3.0, 2.0, 4.0];
        let pts = [0.0, 1.0, 2.5, 5.0, 10.0];
        let c = cdf_at(&sample, &pts);
        assert_eq!(c[0], 0.0);
        assert_eq!(c[1], 0.2);
        assert_eq!(c[2], 0.4);
        assert_eq!(c[3], 1.0);
        assert_eq!(c[4], 1.0);
        for w in c.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn quantile_median() {
        let sample = [10.0, 20.0, 30.0];
        assert!((quantile(&sample, 0.5) - 20.0).abs() < 1e-12);
    }

    #[test]
    fn chi2_accepts_same_distribution_and_rejects_different() {
        use crate::util::rng::Rng;
        let draw = |seed: u64, w: &[f64], n: usize| -> Vec<u64> {
            let mut rng = Rng::new(seed);
            let mut h = vec![0u64; w.len()];
            let total: f64 = w.iter().sum();
            for _ in 0..n {
                let mut u = rng.f64() * total;
                for (i, &wi) in w.iter().enumerate() {
                    u -= wi;
                    if u < 0.0 {
                        h[i] += 1;
                        break;
                    }
                }
            }
            h
        };
        let w = [1.0, 2.0, 4.0, 2.0, 1.0];
        let a = draw(1, &w, 5000);
        let b = draw(2, &w, 5000);
        let (stat, dof) = chi2_two_sample(&a, &b);
        assert!(stat < chi2_critical(dof, Z_ALPHA_01), "same dist rejected: {stat} (dof {dof})");
        let c = draw(3, &[4.0, 2.0, 1.0, 2.0, 4.0], 5000);
        let (stat, dof) = chi2_two_sample(&a, &c);
        assert!(stat > chi2_critical(dof, Z_ALPHA_01), "different dists accepted: {stat}");
    }

    #[test]
    fn chi2_critical_matches_tables() {
        // chi2_{0.99}: k=5 → 15.086, k=10 → 23.209, k=50 → 76.154.
        assert!((chi2_critical(5, Z_ALPHA_01) - 15.086).abs() < 0.15);
        assert!((chi2_critical(10, Z_ALPHA_01) - 23.209).abs() < 0.15);
        assert!((chi2_critical(50, Z_ALPHA_01) - 76.154).abs() < 0.3);
    }

    #[test]
    fn ks_accepts_same_distribution_and_rejects_shifted() {
        use crate::util::rng::Rng;
        let sample = |seed: u64, shift: f64| -> Vec<f64> {
            let mut rng = Rng::new(seed);
            (0..2000).map(|_| rng.f64() + shift).collect()
        };
        let a = sample(1, 0.0);
        let b = sample(2, 0.0);
        let d = ks_two_sample(&a, &b);
        assert!(d < ks_critical(a.len(), b.len(), KS_C_ALPHA_01), "same dist rejected: {d}");
        let c = sample(3, 0.2);
        let d = ks_two_sample(&a, &c);
        assert!(d > ks_critical(a.len(), c.len(), KS_C_ALPHA_01), "shifted accepted: {d}");
        // Exactly identical samples → D = 0.
        assert_eq!(ks_two_sample(&a, &a), 0.0);
    }
}
