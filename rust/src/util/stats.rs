//! Statistics used by the metrics layer: streaming mean/std (Welford),
//! percentiles, CDFs, and simple moving averages (the paper's Eq. 1–2 use
//! exponential moving averages — those live in `cloud::state_monitor`).

/// Streaming mean / variance (Welford). O(1) memory.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (matches how the paper reports per-GPU delay std).
    pub fn var(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Summary of a sample: mean/std/min/max/percentiles.
#[derive(Debug, Clone)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { count: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0, p50: 0.0, p90: 0.0, p99: 0.0 };
        }
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        let var = s.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / s.len() as f64;
        Summary {
            count: s.len(),
            mean,
            std: var.sqrt(),
            min: s[0],
            max: *s.last().unwrap(),
            p50: percentile_sorted(&s, 50.0),
            p90: percentile_sorted(&s, 90.0),
            p99: percentile_sorted(&s, 99.0),
        }
    }
}

/// Percentile of a pre-sorted sample (linear interpolation).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Empirical CDF evaluated at `points`: fraction of the sample <= point.
/// This is how the SLA-compliance curves of Figs. 9–10 are produced
/// (compliance rate at SLA `s` = CDF of per-unit delay at `s`).
pub fn cdf_at(sample: &[f64], points: &[f64]) -> Vec<f64> {
    let mut s = sample.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points
        .iter()
        .map(|&p| {
            let idx = s.partition_point(|&x| x <= p);
            idx as f64 / s.len().max(1) as f64
        })
        .collect()
}

/// Inverse of `cdf_at`: the delay at which a fraction `q` of requests comply
/// ("50% of requests meet a decode SLA of X ms").
pub fn quantile(sample: &[f64], q: f64) -> f64 {
    let mut s = sample.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&s, q * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_basics() {
        let s = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 100.0), 5.0);
        assert_eq!(percentile_sorted(&s, 50.0), 3.0);
        assert!((percentile_sorted(&s, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_of_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn cdf_monotone_and_bounds() {
        let sample = [5.0, 1.0, 3.0, 2.0, 4.0];
        let pts = [0.0, 1.0, 2.5, 5.0, 10.0];
        let c = cdf_at(&sample, &pts);
        assert_eq!(c[0], 0.0);
        assert_eq!(c[1], 0.2);
        assert_eq!(c[2], 0.4);
        assert_eq!(c[3], 1.0);
        assert_eq!(c[4], 1.0);
        for w in c.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn quantile_median() {
        let sample = [10.0, 20.0, 30.0];
        assert!((quantile(&sample, 0.5) - 20.0).abs() < 1e-12);
    }
}
