//! Deterministic PRNG (SplitMix64 core) with the distributions the
//! simulator needs: uniform, exponential (Poisson arrivals), normal,
//! lognormal (prompt lengths, Table 3), and categorical choice.
//!
//! Determinism matters: every experiment is seeded, so paper figures
//! regenerate bit-identically run-to-run.

/// SplitMix64 — tiny, fast, passes BigCrush for our purposes; also used to
/// seed independent substreams (one per device, one per link, …) so
/// component behaviour is independent of event interleaving.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent substream (e.g. per device id).
    pub fn substream(&self, tag: u64) -> Rng {
        let mut r = Rng::new(self.state ^ tag.wrapping_mul(0xD1342543DE82EF95));
        r.next_u64(); // decorrelate
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Panics on n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "rng.below(0)");
        // multiply-shift; bias is negligible for our n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Exponential with rate `lambda` (mean 1/lambda) — Poisson interarrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // avoid ln(0)
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal parameterized by the mean/std of the *underlying* normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pick an element uniformly.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// Fit lognormal (mu, sigma) from a target mean and std of the distribution
/// itself (not of the log).  Used to match Table 3's prompt-length stats.
pub fn lognormal_params_from_mean_std(mean: f64, std: f64) -> (f64, f64) {
    let cv2 = (std / mean).powi(2);
    let sigma2 = (1.0 + cv2).ln();
    let mu = mean.ln() - sigma2 / 2.0;
    (mu, sigma2.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn substreams_differ() {
        let root = Rng::new(7);
        let mut a = root.substream(1);
        let mut b = root.substream(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let lambda = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_fit_matches_target() {
        // Table 3, SpecBench: mean 351.2, std 397.3
        let (mu, sigma) = lognormal_params_from_mean_std(351.2, 397.3);
        let mut r = Rng::new(5);
        let n = 60_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal(mu, sigma)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 351.2).abs() / 351.2 < 0.05, "mean {mean}");
        assert!((var.sqrt() - 397.3).abs() / 397.3 < 0.12, "std {}", var.sqrt());
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(13);
        let hits = (0..10_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 10_000.0 - 0.3).abs() < 0.02);
    }
}
