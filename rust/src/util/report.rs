//! Bench reporting helpers: results directory, JSON dumps, and fixed-width
//! tables shaped like the paper's.

use std::path::PathBuf;

use super::json::Value;

/// Where bench harnesses write their JSON results.
pub fn results_dir() -> PathBuf {
    let d = std::env::var("HAT_BENCH_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("bench_results"));
    std::fs::create_dir_all(&d).ok();
    d
}

/// Write a JSON result file, returning its path.
pub fn write_json(name: &str, v: &Value) -> PathBuf {
    let p = results_dir().join(format!("{name}.json"));
    std::fs::write(&p, super::json::write(v)).expect("write bench result");
    p
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Fixed-width row formatting.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>width$}", width = w))
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    #[test]
    fn writes_results() {
        std::env::set_var("HAT_BENCH_DIR", std::env::temp_dir().join("hat_br").to_str().unwrap());
        let p = write_json("t", &Value::Num(1.0));
        assert!(p.exists());
        std::fs::remove_file(p).ok();
        std::env::remove_var("HAT_BENCH_DIR");
    }

    #[test]
    fn row_formats_fixed_width() {
        let r = row(&["a".into(), "bb".into()], &[3, 4]);
        assert_eq!(r, "  a   bb");
    }
}
