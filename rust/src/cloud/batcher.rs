//! Continuous batcher: prefill/decode mixing with a token budget.
//!
//! Decode/verify jobs are tiny (1..k tokens) and latency-critical; prefill
//! chunks are big and throughput-bound.  The batcher admits *all* pending
//! decode jobs first (they barely move the batch size, §2.1), then fills
//! the remaining token budget with prefill chunks in FIFO order —
//! the Sarathi-style mixing HAT builds on.

use std::collections::VecDeque;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// Verification / single-token decode for a stream in decode phase.
    Decode,
    /// One prompt chunk for a stream in prefill phase.
    PrefillChunk,
}

/// One unit of cloud work (per request stream).
#[derive(Debug, Clone)]
pub struct Job {
    pub req: usize,
    pub kind: JobKind,
    /// Tokens this job contributes to the batch.
    pub tokens: usize,
    /// Admission epoch of the session this job drives.  `req` is a *slot*
    /// index, and slots are reused: once cancellation can free a slot
    /// while jobs for it are still queued, a stale job would otherwise
    /// drive whatever session is admitted into the slot next.  Consumers
    /// that reuse request slots must stamp each admission with a fresh
    /// epoch and drop popped jobs whose epoch disagrees with the slot's
    /// current occupant (the serve scheduler does; the fleet simulator
    /// never reuses ids and passes 0).
    pub epoch: u64,
}

#[derive(Debug, Default)]
pub struct Batcher {
    decode_q: VecDeque<Job>,
    prefill_q: VecDeque<Job>,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    pub fn push(&mut self, job: Job) {
        match job.kind {
            JobKind::Decode => self.decode_q.push_back(job),
            JobKind::PrefillChunk => self.prefill_q.push_back(job),
        }
    }

    pub fn pending(&self) -> usize {
        self.decode_q.len() + self.prefill_q.len()
    }

    /// Pending verify/decode jobs (the serve scheduler reports queue
    /// depths through STATS).
    pub fn decode_pending(&self) -> usize {
        self.decode_q.len()
    }

    /// Pending prefill chunks.
    pub fn prefill_pending(&self) -> usize {
        self.prefill_q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending() == 0
    }

    /// Form the next batch under a *prefill* token budget (Sarathi-style
    /// iteration semantics: each step carries at most `max_prefill_tokens`
    /// of prompt work).  All decode jobs are admitted — they are
    /// individually tiny and starving them deadlocks decoding; prefill
    /// chunks then fill the budget FIFO.  A lone over-budget prefill chunk
    /// still runs when nothing else is pending (it must eventually).
    pub fn form_batch(&mut self, max_prefill_tokens: usize) -> Vec<Job> {
        let mut batch = Vec::new();
        let mut prefill_tokens = 0usize;
        while let Some(j) = self.decode_q.pop_front() {
            batch.push(j);
        }
        while let Some(head) = self.prefill_q.front() {
            if prefill_tokens > 0 && prefill_tokens + head.tokens > max_prefill_tokens {
                break;
            }
            let Some(j) = self.prefill_q.pop_front() else { break };
            prefill_tokens += j.tokens;
            batch.push(j);
            if prefill_tokens >= max_prefill_tokens {
                break;
            }
        }
        batch
    }

    /// Total tokens across a formed batch.
    pub fn batch_tokens(batch: &[Job]) -> usize {
        batch.iter().map(|j| j.tokens).sum()
    }

    /// Remove every queued job for one request slot, returning how many
    /// were dropped.  Used when a session is torn down (cancel, deadline
    /// expiry) so its queued work never pollutes a later batch; the epoch
    /// stamp on [`Job`] is the backstop for staleness this sweep cannot
    /// see (jobs already popped into a formed batch).
    pub fn remove_session(&mut self, req: usize) -> usize {
        let before = self.decode_q.len() + self.prefill_q.len();
        self.decode_q.retain(|j| j.req != req);
        self.prefill_q.retain(|j| j.req != req);
        before - (self.decode_q.len() + self.prefill_q.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{cases, forall, vec_usize};

    fn job(req: usize, kind: JobKind, tokens: usize) -> Job {
        Job { req, kind, tokens, epoch: 0 }
    }

    #[test]
    fn remove_session_drops_only_that_slot() {
        let mut b = Batcher::new();
        b.push(job(0, JobKind::PrefillChunk, 64));
        b.push(job(1, JobKind::Decode, 3));
        b.push(job(0, JobKind::Decode, 2));
        b.push(job(2, JobKind::PrefillChunk, 32));
        assert_eq!(b.remove_session(0), 2);
        assert_eq!(b.remove_session(0), 0, "removal is idempotent");
        assert_eq!(b.pending(), 2);
        let batch = b.form_batch(256);
        assert!(batch.iter().all(|j| j.req != 0), "slot 0 job survived removal");
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn decode_admitted_first_one_chunk_rides_along() {
        let mut b = Batcher::new();
        b.push(job(0, JobKind::PrefillChunk, 512));
        b.push(job(3, JobKind::PrefillChunk, 512));
        b.push(job(1, JobKind::Decode, 3));
        b.push(job(2, JobKind::Decode, 1));
        assert_eq!(b.decode_pending(), 2);
        assert_eq!(b.prefill_pending(), 2);
        let batch = b.form_batch(256);
        // All decodes + exactly one prefill chunk (the first chunk always
        // rides, further ones respect the budget).
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].kind, JobKind::Decode);
        assert_eq!(batch[1].kind, JobKind::Decode);
        assert_eq!(batch[2].req, 0);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn budget_bounds_prefill_tokens_per_step() {
        let mut b = Batcher::new();
        for i in 0..6 {
            b.push(job(i, JobKind::PrefillChunk, 128));
        }
        let batch = b.form_batch(256);
        assert_eq!(Batcher::batch_tokens(&batch), 256, "two 128-chunks fill the budget");
        assert_eq!(b.pending(), 4);
    }

    #[test]
    fn lone_oversized_prefill_still_runs() {
        let mut b = Batcher::new();
        b.push(job(0, JobKind::PrefillChunk, 999));
        let batch = b.form_batch(256);
        assert_eq!(batch.len(), 1);
        assert!(b.is_empty());
    }

    #[test]
    fn prefill_fifo_fills_budget() {
        let mut b = Batcher::new();
        for (i, t) in [100usize, 100, 100].iter().enumerate() {
            b.push(job(i, JobKind::PrefillChunk, *t));
        }
        let batch = b.form_batch(250);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].req, 0);
        assert_eq!(batch[1].req, 1);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn prop_decode_always_admits_and_prefill_budget_holds() {
        // The two form_batch invariants the scheduler's batched execution
        // relies on: every pending decode job is admitted every iteration
        // (starving one deadlocks its session), and the admitted prefill
        // tokens never exceed the budget — except the documented
        // lone-oversized-chunk case, which must then be the only prefill
        // chunk in the batch.
        forall(cases(200), |rng| {
            let mut b = Batcher::new();
            let n = rng.range_usize(1, 60);
            let budget = rng.range_usize(16, 512);
            for i in 0..n {
                let kind =
                    if rng.bool(0.4) { JobKind::Decode } else { JobKind::PrefillChunk };
                b.push(job(i, kind, rng.range_usize(1, 700)));
            }
            let mut guard = 0;
            while !b.is_empty() {
                let batch = b.form_batch(budget);
                if b.decode_pending() != 0 {
                    return Err("decode job left pending after form_batch".into());
                }
                let ptoks: usize = batch
                    .iter()
                    .filter(|j| j.kind == JobKind::PrefillChunk)
                    .map(|j| j.tokens)
                    .sum();
                let pcount =
                    batch.iter().filter(|j| j.kind == JobKind::PrefillChunk).count();
                if ptoks > budget && pcount > 1 {
                    return Err(format!(
                        "{ptoks} prefill tokens ({pcount} chunks) exceed budget {budget}"
                    ));
                }
                guard += 1;
                if guard > 1000 {
                    return Err("did not drain".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_batches_drain_everything_exactly_once() {
        forall(cases(100), |rng| {
            let mut b = Batcher::new();
            let sizes = vec_usize(rng, 40, 1, 600);
            for (i, &t) in sizes.iter().enumerate() {
                let kind = if rng.bool(0.5) { JobKind::Decode } else { JobKind::PrefillChunk };
                b.push(job(i, kind, t));
            }
            let mut seen = vec![0usize; sizes.len()];
            let budget = rng.range_usize(64, 1024);
            let mut guard = 0;
            while !b.is_empty() {
                let batch = b.form_batch(budget);
                if batch.is_empty() {
                    return Err("empty batch with pending jobs".into());
                }
                for j in &batch {
                    seen[j.req] += 1;
                }
                guard += 1;
                if guard > 1000 {
                    return Err("did not drain".into());
                }
            }
            if seen.iter().any(|&c| c != 1) {
                return Err("job lost or duplicated".into());
            }
            Ok(())
        });
    }
}
