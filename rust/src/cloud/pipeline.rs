//! Pipeline-parallel cloud model (length P).
//!
//! The paper's server runs the middle submodel pipeline-parallel over P
//! GPUs (§3.3, §4.5): a batch occupies each stage for g(B)/P, so a new
//! batch can enter every g(B)/P while a single batch still takes the full
//! g(B) to produce results ("computation delay per GPU is inversely
//! proportional to the number of GPUs ... eliminates the need to wait for
//! the previous inference to be finished across the entire model").
//!
//! We track stage-1 availability (admission) and per-GPU step delays
//! (the Fig. 8 metric).

use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct Pipeline {
    pub p: usize,
    /// When stage 1 next becomes free (admission time for the next batch).
    stage1_free: SimTime,
    /// Number of steps admitted.
    pub steps: usize,
}

impl Pipeline {
    pub fn new(p: usize) -> Pipeline {
        assert!(p >= 1);
        Pipeline { p, stage1_free: SimTime::ZERO, steps: 0 }
    }

    pub fn stage1_free_at(&self) -> SimTime {
        self.stage1_free
    }

    /// Whether a new batch can be admitted at `now`.
    pub fn can_admit(&self, now: SimTime) -> bool {
        now >= self.stage1_free
    }

    /// Admit a batch with full-model delay `g_ms` at `now` (must be
    /// admissible).  Returns (completion_time, per_gpu_delay_ms).
    pub fn admit(&mut self, now: SimTime, g_ms: f64) -> (SimTime, f64) {
        assert!(self.can_admit(now), "admitting into a busy pipeline");
        let per_stage = g_ms / self.p as f64;
        self.stage1_free = now.add_ms(per_stage);
        self.steps += 1;
        (now.add_ms(g_ms), per_stage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stage_serializes_fully() {
        let mut p = Pipeline::new(1);
        let (done, per) = p.admit(SimTime::ZERO, 10.0);
        assert_eq!(done, SimTime::from_ms(10.0));
        assert_eq!(per, 10.0);
        assert!(!p.can_admit(SimTime::from_ms(5.0)));
        assert!(p.can_admit(SimTime::from_ms(10.0)));
    }

    #[test]
    fn pipeline_overlaps_batches() {
        let mut p = Pipeline::new(4);
        let (done1, per) = p.admit(SimTime::ZERO, 12.0);
        assert_eq!(per, 3.0);
        assert_eq!(done1, SimTime::from_ms(12.0));
        // A second batch can enter after just one stage time.
        assert!(p.can_admit(SimTime::from_ms(3.0)));
        let (done2, _) = p.admit(SimTime::from_ms(3.0), 12.0);
        assert_eq!(done2, SimTime::from_ms(15.0));
        assert_eq!(p.steps, 2);
    }

    #[test]
    fn longer_pipeline_admits_sooner() {
        let mut a = Pipeline::new(1);
        let mut b = Pipeline::new(8);
        a.admit(SimTime::ZERO, 16.0);
        b.admit(SimTime::ZERO, 16.0);
        assert_eq!(a.stage1_free_at(), SimTime::from_ms(16.0));
        assert_eq!(b.stage1_free_at(), SimTime::from_ms(2.0));
    }

    #[test]
    #[should_panic(expected = "busy pipeline")]
    fn cannot_double_admit() {
        let mut p = Pipeline::new(2);
        p.admit(SimTime::ZERO, 10.0);
        p.admit(SimTime::from_ms(1.0), 10.0);
    }
}
