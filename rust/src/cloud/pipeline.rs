//! Pipeline-parallel cloud model (length P).
//!
//! The paper's server runs the middle submodel pipeline-parallel over P
//! GPUs (§3.3, §4.5): a batch occupies each stage for g(B)/P, so a new
//! batch can enter every g(B)/P while a single batch still takes the full
//! g(B) to produce results ("computation delay per GPU is inversely
//! proportional to the number of GPUs ... eliminates the need to wait for
//! the previous inference to be finished across the entire model").
//!
//! We track stage-1 availability (admission) and per-GPU step delays
//! (the Fig. 8 metric).

use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct Pipeline {
    pub p: usize,
    /// When stage 1 next becomes free (admission time for the next batch).
    stage1_free: SimTime,
    /// Number of steps admitted.
    pub steps: usize,
}

impl Pipeline {
    pub fn new(p: usize) -> Pipeline {
        assert!(p >= 1);
        Pipeline { p, stage1_free: SimTime::ZERO, steps: 0 }
    }

    pub fn stage1_free_at(&self) -> SimTime {
        self.stage1_free
    }

    /// Whether a new batch can be admitted at `now` without waiting.
    pub fn can_admit(&self, now: SimTime) -> bool {
        now >= self.stage1_free
    }

    /// Admit a batch with full-model delay `g_ms`.  If stage 1 is still
    /// busy at `now` (e.g. duplicate `CloudTryStep` events raced the
    /// admission check), the batch queues until stage 1 frees instead of
    /// aborting the whole simulation — the returned [`Admission`] carries
    /// the actual admission time.
    pub fn admit(&mut self, now: SimTime, g_ms: f64) -> Admission {
        let admitted_at = now.max(self.stage1_free);
        let per_gpu_ms = g_ms / self.p as f64;
        self.stage1_free = admitted_at.add_ms(per_gpu_ms);
        self.steps += 1;
        Admission { admitted_at, done: admitted_at.add_ms(g_ms), per_gpu_ms }
    }
}

/// Outcome of [`Pipeline::admit`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Admission {
    /// When the batch actually entered stage 1 (>= the requested time).
    pub admitted_at: SimTime,
    /// When all pipeline stages complete.
    pub done: SimTime,
    /// Per-GPU (per-stage) computation delay, ms — the Fig. 8 metric.
    pub per_gpu_ms: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_stage_serializes_fully() {
        let mut p = Pipeline::new(1);
        let adm = p.admit(SimTime::ZERO, 10.0);
        assert_eq!(adm.admitted_at, SimTime::ZERO);
        assert_eq!(adm.done, SimTime::from_ms(10.0));
        assert_eq!(adm.per_gpu_ms, 10.0);
        assert!(!p.can_admit(SimTime::from_ms(5.0)));
        assert!(p.can_admit(SimTime::from_ms(10.0)));
    }

    #[test]
    fn pipeline_overlaps_batches() {
        let mut p = Pipeline::new(4);
        let adm1 = p.admit(SimTime::ZERO, 12.0);
        assert_eq!(adm1.per_gpu_ms, 3.0);
        assert_eq!(adm1.done, SimTime::from_ms(12.0));
        // A second batch can enter after just one stage time.
        assert!(p.can_admit(SimTime::from_ms(3.0)));
        let adm2 = p.admit(SimTime::from_ms(3.0), 12.0);
        assert_eq!(adm2.done, SimTime::from_ms(15.0));
        assert_eq!(p.steps, 2);
    }

    #[test]
    fn longer_pipeline_admits_sooner() {
        let mut a = Pipeline::new(1);
        let mut b = Pipeline::new(8);
        a.admit(SimTime::ZERO, 16.0);
        b.admit(SimTime::ZERO, 16.0);
        assert_eq!(a.stage1_free_at(), SimTime::from_ms(16.0));
        assert_eq!(b.stage1_free_at(), SimTime::from_ms(2.0));
    }

    #[test]
    fn racing_admission_defers_instead_of_panicking() {
        // Regression: duplicate CloudTryStep events used to trip
        // `assert!(can_admit)` and abort the whole fleet simulation.  Now
        // the late batch queues behind stage 1.
        let mut p = Pipeline::new(2);
        let adm1 = p.admit(SimTime::ZERO, 10.0); // stage 1 busy until 5ms
        assert_eq!(adm1.admitted_at, SimTime::ZERO);
        let adm2 = p.admit(SimTime::from_ms(1.0), 10.0);
        assert_eq!(adm2.admitted_at, SimTime::from_ms(5.0), "deferred to stage-1 free");
        assert_eq!(adm2.done, SimTime::from_ms(15.0));
        assert_eq!(p.stage1_free_at(), SimTime::from_ms(10.0));
        assert_eq!(p.steps, 2);
        // Admission times never move backwards.
        assert!(adm2.admitted_at >= adm1.admitted_at);
    }
}
