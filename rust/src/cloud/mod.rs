//! The cloud coordinator — the paper's §3 system contribution.
//!
//! - `state_monitor` — Eqs. 1–2: moving-average workload μ^t and the
//!   learned in-cloud delay predictor g^t(·);
//! - `chunker` — Eq. 3: per-device optimal chunk size;
//! - `pipeline` — pipeline-parallel stage availability (length P) and
//!   per-GPU computation-delay accounting (Fig. 8);
//! - `batcher` — continuous batching with prefill/decode mixing and a
//!   token budget.

pub mod batcher;
pub mod chunker;
pub mod pipeline;
pub mod state_monitor;

pub use batcher::{Batcher, Job, JobKind};
pub use chunker::optimal_chunk;
pub use pipeline::{Admission, Pipeline};
pub use state_monitor::StateMonitor;
