//! State monitoring module (paper §3.2).
//!
//! The cloud tracks its own workload through two proxies it can observe
//! directly: the batched token size μ̂^t and the in-cloud computation delay
//! η̂^t of each step.  Robust estimates come from exponential moving
//! averages with α (Eq. 1–2):
//!
//!   μ^t      = α μ^{t-1}      + (1-α) μ̂^t
//!   g^t(μ^t) = α g^{t-1}(μ^t) + (1-α) η̂^t
//!
//! g^t(·) must predict the delay for *arbitrary* batch sizes (the chunk
//! optimizer evaluates g(μ+X) for candidate X), so we learn a bucketized
//! delay curve: observations update the bucket containing the observed
//! batch size with an EWMA, and queries interpolate linearly between the
//! nearest observed buckets (falling back to scaled neighbours before any
//! observation lands there).
//!
//! Device-side state (γ_i^t drafting delay, β_i^t bandwidths) is collected
//! the same way with per-device EWMAs.

/// EWMA scalar (Eq. 1).
#[derive(Debug, Clone, Copy)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Ewma {
        Ewma { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * v + (1.0 - self.alpha) * x,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Learned g^t(·): bucketized EWMA delay curve over batched token size.
#[derive(Debug, Clone)]
pub struct GPredictor {
    alpha: f64,
    /// Bucket upper edges (token sizes), log-spaced.
    edges: Vec<f64>,
    /// EWMA delay per bucket (None until observed).
    delays: Vec<Option<f64>>,
}

impl GPredictor {
    pub fn new(alpha: f64, max_tokens: usize) -> GPredictor {
        // Log-spaced edges: 1, 2, 4, ..., >= max_tokens.
        let mut edges = vec![1.0_f64];
        while *edges.last().unwrap() < max_tokens as f64 {
            edges.push(edges.last().unwrap() * 2.0);
        }
        let n = edges.len();
        GPredictor { alpha, edges, delays: vec![None; n] }
    }

    fn bucket(&self, tokens: f64) -> usize {
        self.edges
            .iter()
            .position(|&e| tokens <= e)
            .unwrap_or(self.edges.len() - 1)
    }

    /// Record an observed (batch tokens, step delay ms) pair (Eq. 2).
    pub fn observe(&mut self, tokens: f64, delay_ms: f64) {
        let b = self.bucket(tokens);
        self.delays[b] = Some(match self.delays[b] {
            None => delay_ms,
            Some(v) => self.alpha * v + (1.0 - self.alpha) * delay_ms,
        });
    }

    /// Predict the step delay for a batch of `tokens`.
    ///
    /// Interpolates linearly (in token space) between the nearest observed
    /// buckets below and above; extrapolates flat from the closest one at
    /// the ends.  Returns None until any observation arrived.
    pub fn predict(&self, tokens: f64) -> Option<f64> {
        let any = self.delays.iter().any(|d| d.is_some());
        if !any {
            return None;
        }
        let b = self.bucket(tokens);
        let below = (0..=b).rev().find(|&i| self.delays[i].is_some());
        let above = (b..self.edges.len()).find(|&i| self.delays[i].is_some());
        match (below, above) {
            (Some(i), Some(j)) if i == j => self.delays[i],
            (Some(i), Some(j)) => {
                let (xi, xj) = (self.edges[i], self.edges[j]);
                let (yi, yj) = (self.delays[i].unwrap(), self.delays[j].unwrap());
                let t = ((tokens - xi) / (xj - xi)).clamp(0.0, 1.0);
                Some(yi + t * (yj - yi))
            }
            (Some(i), None) => self.delays[i],
            (None, Some(j)) => self.delays[j],
            (None, None) => None,
        }
    }
}

/// Per-device collected state (γ, β_up, β_down — §3.2).
#[derive(Debug, Clone)]
pub struct DeviceState {
    pub gamma_ms: Ewma,
    pub up_bytes_per_ms: Ewma,
    pub down_bytes_per_ms: Ewma,
}

impl DeviceState {
    fn new(alpha: f64) -> DeviceState {
        DeviceState {
            gamma_ms: Ewma::new(alpha),
            up_bytes_per_ms: Ewma::new(alpha),
            down_bytes_per_ms: Ewma::new(alpha),
        }
    }
}

/// The full state-monitoring module.
///
/// The delay curve is kept *per phase*: prefill chunks (wide, compute-bound
/// batches) and decode verify rounds (narrow, latency-bound batches) have
/// different delay profiles, and Eq. 3 chunk sizing only ever queries the
/// prefill curve.  Folding both phases into one EWMA lets a burst of small
/// decode rounds drag the small-batch buckets of the curve toward decode
/// latencies and skew the chunk optimizer — so decode observations land in
/// their own `g_decode` curve and never touch `g`.
#[derive(Debug, Clone)]
pub struct StateMonitor {
    pub mu: Ewma,
    /// Prefill-phase delay curve — the one Eq. 3 chunk sizing reads.
    pub g: GPredictor,
    /// Decode-phase delay curve (verify rounds), tracked separately.
    pub g_decode: GPredictor,
    pub devices: Vec<DeviceState>,
}

impl StateMonitor {
    pub fn new(alpha: f64, n_devices: usize, max_tokens: usize) -> StateMonitor {
        StateMonitor {
            mu: Ewma::new(alpha),
            g: GPredictor::new(alpha, max_tokens),
            g_decode: GPredictor::new(alpha, max_tokens),
            devices: (0..n_devices).map(|_| DeviceState::new(alpha)).collect(),
        }
    }

    /// Record one completed cloud step (single-phase callers, e.g. the
    /// fleet simulator, whose steps are all chunk-shaped).  Feeds the
    /// prefill curve; per-phase callers use [`StateMonitor::observe_prefill`]
    /// / [`StateMonitor::observe_decode`].
    pub fn observe_step(&mut self, batch_tokens: usize, delay_ms: f64) {
        self.observe_prefill(batch_tokens, delay_ms);
    }

    /// Record one completed prefill-chunk cloud step (updates μ and the
    /// prefill g curve that Eq. 3 reads).
    pub fn observe_prefill(&mut self, batch_tokens: usize, delay_ms: f64) {
        self.mu.observe(batch_tokens as f64);
        self.g.observe(batch_tokens as f64, delay_ms);
    }

    /// Record one completed decode-round cloud step (updates μ and the
    /// decode curve only — the prefill g curve is untouched).
    pub fn observe_decode(&mut self, batch_tokens: usize, delay_ms: f64) {
        self.mu.observe(batch_tokens as f64);
        self.g_decode.observe(batch_tokens as f64, delay_ms);
    }

    /// Record a device report.
    pub fn observe_device(&mut self, dev: usize, gamma_ms: f64, up_bpms: f64, down_bpms: f64) {
        let d = &mut self.devices[dev];
        d.gamma_ms.observe(gamma_ms);
        d.up_bytes_per_ms.observe(up_bpms);
        d.down_bytes_per_ms.observe(down_bpms);
    }

    /// Current μ^t (0 before any step).
    pub fn mu_t(&self) -> f64 {
        self.mu.get().unwrap_or(0.0)
    }

    /// g^t(tokens) with a pessimistic cold-start fallback.
    pub fn g_t(&self, tokens: f64, fallback: impl Fn(f64) -> f64) -> f64 {
        self.g.predict(tokens).unwrap_or_else(|| fallback(tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_matches_eq1() {
        // Eq. 1 with α = 0.8: μ^t = 0.8 μ^{t-1} + 0.2 μ̂^t
        let mut e = Ewma::new(0.8);
        e.observe(100.0);
        assert_eq!(e.get(), Some(100.0));
        e.observe(200.0);
        assert!((e.get().unwrap() - (0.8 * 100.0 + 0.2 * 200.0)).abs() < 1e-12);
    }

    #[test]
    fn predictor_learns_linear_curve() {
        let mut g = GPredictor::new(0.8, 2048);
        // Feed a linear g(B) = 5 + 0.1 B at several sizes, repeatedly.
        for _ in 0..50 {
            for &b in &[1.0, 8.0, 64.0, 512.0, 2048.0] {
                g.observe(b, 5.0 + 0.1 * b);
            }
        }
        for &q in &[4.0, 32.0, 100.0, 1000.0] {
            let p = g.predict(q).unwrap();
            let truth = 5.0 + 0.1 * q;
            assert!((p - truth).abs() / truth < 0.6, "g({q}) = {p}, truth {truth}");
        }
        // Monotone between observed anchors.
        assert!(g.predict(512.0).unwrap() < g.predict(2048.0).unwrap());
    }

    #[test]
    fn predictor_cold_start_and_fallback() {
        let g = GPredictor::new(0.8, 1024);
        assert_eq!(g.predict(10.0), None);
        let m = StateMonitor::new(0.8, 2, 1024);
        let v = m.g_t(100.0, |b| 6.0 + 0.01 * b);
        assert!((v - 7.0).abs() < 1e-12);
    }

    #[test]
    fn predictor_single_observation_extrapolates_flat() {
        let mut g = GPredictor::new(0.8, 1024);
        g.observe(64.0, 8.0);
        assert_eq!(g.predict(1.0), Some(8.0));
        assert_eq!(g.predict(1000.0), Some(8.0));
    }

    #[test]
    fn monitor_tracks_devices() {
        let mut m = StateMonitor::new(0.8, 3, 1024);
        m.observe_device(1, 12.0, 7000.0, 12000.0);
        m.observe_device(1, 8.0, 7000.0, 12000.0);
        let g = m.devices[1].gamma_ms.get().unwrap();
        assert!((g - (0.8 * 12.0 + 0.2 * 8.0)).abs() < 1e-12);
        assert!(m.devices[0].gamma_ms.get().is_none());
    }

    #[test]
    fn observe_step_updates_mu_and_g() {
        let mut m = StateMonitor::new(0.8, 1, 2048);
        m.observe_step(128, 10.0);
        m.observe_step(256, 14.0);
        assert!(m.mu_t() > 128.0 && m.mu_t() < 256.0);
        assert!(m.g_t(128.0, |_| 0.0) > 0.0);
    }

    #[test]
    fn decode_rounds_do_not_move_prefill_g_curve() {
        // Regression for mixed-phase delay learning: establish a prefill
        // curve, then hammer the monitor with fast small decode rounds.
        // The prefill curve Eq. 3 reads must be bit-identical afterwards.
        let mut m = StateMonitor::new(0.8, 1, 2048);
        for _ in 0..20 {
            for &b in &[64usize, 256, 1024] {
                m.observe_prefill(b, 5.0 + 0.1 * b as f64);
            }
        }
        let before: Vec<Option<f64>> =
            (0..12).map(|i| m.g.predict((1u64 << i) as f64)).collect();
        for _ in 0..200 {
            m.observe_decode(4, 2.0);
            m.observe_decode(9, 2.5);
        }
        let after: Vec<Option<f64>> =
            (0..12).map(|i| m.g.predict((1u64 << i) as f64)).collect();
        assert_eq!(before, after, "decode observations moved the prefill g curve");
        // The decode curve did learn something, in its own estimator.
        assert!(m.g_decode.predict(4.0).is_some());
        // μ still tracks overall load (both phases feed it).
        assert!(m.mu_t() < 64.0);
    }
}
