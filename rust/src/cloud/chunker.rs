//! Prompt-chunking module (paper §3.3): dynamic chunk-size optimization.
//!
//! Eq. 3 balances the upload time of one chunk against the pipelined
//! in-cloud time of the *previous* chunk, so transmission and computation
//! overlap with neither side stalling:
//!
//! ```text
//! X_i · A / β_up  =  ( g^t(μ^t) + g^t(μ^t + X_i) ) / P
//! ```
//!
//! LHS (upload of a chunk of X_i tokens) grows linearly in X_i; RHS
//! (waiting ≈ g(μ) plus compute g(μ+X_i), spread over P pipeline stages)
//! grows sub-linearly below the saturation knee — so a unique crossing
//! exists; we find it by bisection and clamp into configured bounds.

/// Solve Eq. 3 for the optimal chunk size.
///
/// * `a_bytes`      — hidden-state wire size per token (A).
/// * `up_bytes_per_ms` — device uplink bandwidth β_up.
/// * `g`            — the current delay predictor g^t(·) in ms.
/// * `mu`           — current average batched token size μ^t.
/// * `p`            — pipeline length P.
/// * `bounds`       — (min_chunk, max_chunk).
pub fn optimal_chunk(
    a_bytes: f64,
    up_bytes_per_ms: f64,
    g: impl Fn(f64) -> f64,
    mu: f64,
    p: usize,
    bounds: (usize, usize),
) -> usize {
    let (lo_b, hi_b) = bounds;
    assert!(lo_b >= 1 && lo_b <= hi_b && p >= 1);
    let upload_ms = |x: f64| x * a_bytes / up_bytes_per_ms.max(1e-9);
    let cloud_ms = |x: f64| (g(mu) + g(mu + x)) / p as f64;
    // f(x) = upload(x) - cloud(x): negative while upload is cheaper.
    let f = |x: f64| upload_ms(x) - cloud_ms(x);

    let (mut lo, mut hi) = (lo_b as f64, hi_b as f64);
    if f(lo) >= 0.0 {
        // Even the smallest chunk uploads slower than the cloud computes:
        // take the smallest (upload-bound link).
        return lo_b;
    }
    if f(hi) <= 0.0 {
        // Upload always faster: take the largest chunk (compute-bound).
        return hi_b;
    }
    for _ in 0..50 {
        let mid = 0.5 * (lo + hi);
        if f(mid) < 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // Round to the nearest in-bracket multiple of 8 (token-bucket
    // friendliness).  The old `(x / 8).max(1) * 8` always rounded *down*,
    // which could land below `lo_b` (e.g. 19 → 16 with lo_b = 17) and then
    // get clamped to a non-multiple, biasing every chunk small.
    round_to_bucket(0.5 * (lo + hi), lo_b, hi_b)
}

/// Nearest multiple of 8 to `raw` within [lo, hi]; if the bracket contains
/// no multiple of 8, fall back to plain rounding clamped into the bracket.
fn round_to_bucket(raw: f64, lo: usize, hi: usize) -> usize {
    debug_assert!(lo <= hi);
    let down = (raw / 8.0).floor() as usize * 8;
    let up = down + 8;
    let in_bracket = |x: usize| (lo..=hi).contains(&x);
    match (in_bracket(down), in_bracket(up)) {
        (true, true) => {
            // Nearest wins; ties round down.
            if raw - down as f64 <= up as f64 - raw {
                down
            } else {
                up
            }
        }
        (true, false) => down,
        (false, true) => up,
        (false, false) => (raw.round() as usize).clamp(lo, hi),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GModel;
    use crate::util::proptest::{cases, forall};

    fn g7() -> impl Fn(f64) -> f64 {
        let g = GModel::vicuna7b();
        move |x| g.eval(x)
    }

    #[test]
    fn balances_upload_against_pipelined_cloud_time() {
        // Busy cloud (μ = 512), short pipeline: the crossing is interior.
        let x = optimal_chunk(8192.0, 7000.0, g7(), 512.0, 1, (16, 512));
        assert!((16..512).contains(&x), "X = {x} should be interior");
        // At the solution, the two sides are close.
        let up = x as f64 * 8192.0 / 7000.0;
        let cl = g7()(512.0) + g7()(512.0 + x as f64);
        assert!((up - cl).abs() / cl < 0.3, "upload {up} vs cloud {cl} at X={x}");
    }

    #[test]
    fn idle_cloud_fast_wire_regimes() {
        // Idle cloud + paper-scale wire: upload-bound → smallest chunk
        // (maximal overlap; Fig. 1d's "TTFT escalates" regime is avoided
        // because upload, not compute, is the bottleneck).
        assert_eq!(optimal_chunk(8192.0, 7000.0, g7(), 8.0, 4, (16, 512)), 16);
    }

    #[test]
    fn faster_uplink_means_bigger_chunks() {
        let slow = optimal_chunk(8192.0, 5000.0, g7(), 64.0, 4, (16, 512));
        let fast = optimal_chunk(8192.0, 10000.0, g7(), 64.0, 4, (16, 512));
        assert!(fast >= slow, "fast {fast} < slow {slow}");
    }

    #[test]
    fn longer_pipeline_means_smaller_chunks() {
        // More stages → cloud time per chunk shrinks → smaller chunks keep
        // the overlap balanced.
        let p1 = optimal_chunk(8192.0, 7000.0, g7(), 64.0, 1, (16, 512));
        let p8 = optimal_chunk(8192.0, 7000.0, g7(), 64.0, 8, (16, 512));
        assert!(p8 <= p1, "p8 {p8} > p1 {p1}");
    }

    #[test]
    fn busy_cloud_means_bigger_chunks() {
        // Higher μ → longer waits → upload can afford to be longer too.
        let idle = optimal_chunk(8192.0, 7000.0, g7(), 8.0, 4, (16, 512));
        let busy = optimal_chunk(8192.0, 7000.0, g7(), 1500.0, 4, (16, 512));
        assert!(busy >= idle, "busy {busy} < idle {idle}");
    }

    #[test]
    fn degenerate_links_clamp_to_bounds() {
        // Hopeless uplink → min chunk.
        assert_eq!(optimal_chunk(8192.0, 1.0, g7(), 64.0, 4, (16, 512)), 16);
        // Infinite-ish uplink → max chunk.
        assert_eq!(optimal_chunk(8192.0, 1e12, g7(), 64.0, 4, (16, 512)), 512);
    }

    #[test]
    fn rounding_respects_odd_lower_bound() {
        // Regression (lo_b = 17): craft a crossing at x ≈ 19.05 —
        // upload = 0.31·x, cloud = 4 + 0.1·x, equal at x = 4/0.21.
        // The old code rounded 19 down to 16 (< lo_b) and clamped to 17,
        // returning a non-multiple of 8; the fix picks 24, the nearest
        // in-bracket multiple of 8.
        let g = |x: f64| 2.0 + 0.1 * x;
        let x = optimal_chunk(0.31, 1.0, g, 0.0, 1, (17, 512));
        assert_eq!(x, 24, "nearest in-bracket multiple of 8 above lo_b");

        // Bracket with no multiple of 8 at all: fall back to plain
        // rounding inside the bracket.
        let x = optimal_chunk(0.31, 1.0, g, 0.0, 1, (17, 20));
        assert!((17..=20).contains(&x), "X = {x} outside [17,20]");
    }

    #[test]
    fn round_to_bucket_cases() {
        assert_eq!(round_to_bucket(19.05, 17, 512), 24);
        assert_eq!(round_to_bucket(19.9, 16, 512), 16); // nearest is 16
        assert_eq!(round_to_bucket(20.1, 16, 512), 24);
        assert_eq!(round_to_bucket(510.0, 16, 512), 512);
        assert_eq!(round_to_bucket(515.0, 16, 513), 512); // 520 > hi → down
        assert_eq!(round_to_bucket(19.0, 17, 20), 19); // no multiple in bracket
        assert_eq!(round_to_bucket(4.0, 1, 512), 8); // ties/near-zero stay in bracket
    }

    #[test]
    fn prop_result_in_bounds_and_multiple_of_8_or_clamped() {
        forall(cases(200), |rng| {
            let a = rng.range_f64(1000.0, 12000.0);
            let bw = rng.range_f64(500.0, 20000.0);
            let mu = rng.range_f64(0.0, 2048.0);
            let p = rng.range_usize(1, 8);
            let lo = rng.range_usize(8, 64);
            let hi = lo + rng.range_usize(8, 512);
            let x = optimal_chunk(a, bw, g7(), mu, p, (lo, hi));
            if x < lo || x > hi {
                return Err(format!("X={x} outside [{lo},{hi}]"));
            }
            Ok(())
        });
    }
}
