//! Command-line interface (hand-rolled; clap is not in the offline crate
//! set).  Subcommands:
//!
//! ```text
//! hat simulate [--framework F] [--dataset D] [--rate R] [--pipeline P]
//!              [--requests N] [--seed S] [--config FILE]
//! hat serve    [--addr HOST:PORT] [--config FILE] [--max-sessions N]
//!              [--prefill-budget T] [--policy fifo|sjf] [--deadline-ms T]
//!              [--prefill-workers N] [--decode-workers M]
//!              [--max-conns N] [--rate-limit X] [--temperature X]
//!              [--top-k-sample N] [--top-p X] [--rep-penalty X] [--seed N]
//!              [--verify-mode coupled|rejection]
//!              real TCP serving: one event loop multiplexing every
//!              connection with a continuous-batching scheduler over the
//!              engine (N concurrent sessions, T prefill tokens/iteration,
//!              slot admission policy + per-request deadline, X GENERATEs/s
//!              per-connection rate limit; temperature 0
//!              is greedy, > 0 samples seeded and position-keyed)
//! hat profile  [--rounds N]             measure SD round shapes
//! hat inspect                           print manifest / artifact summary
//! hat bench-diff <committed.json> <fresh.json>
//!              schema-compare a committed BENCH_*.json trajectory file
//!              against a fresh bench run (CI drift gate)
//! ```

use std::collections::BTreeMap;

use crate::config::{Dataset, ExperimentConfig, Framework};
use crate::frameworks::run_experiment;
use crate::metrics::RunSummary;
use crate::specdec::profile::SdProfile;

/// Parsed flags: `--key value` pairs plus positional args.
#[derive(Debug, Default)]
pub struct Flags {
    pub positional: Vec<String>,
    pub named: BTreeMap<String, String>,
}

pub fn parse_flags<I: Iterator<Item = String>>(args: I) -> Result<Flags, String> {
    let mut f = Flags::default();
    let mut it = args.peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap(),
                _ => "true".to_string(),
            };
            if f.named.insert(key.to_string(), val).is_some() {
                return Err(format!("duplicate flag --{key}"));
            }
        } else {
            f.positional.push(a);
        }
    }
    Ok(f)
}

impl Flags {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| format!("--{key}: bad number '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        Ok(self.get_f64(key)?.map(|v| v as usize))
    }
}

/// Build an ExperimentConfig from CLI flags (optionally seeded from a
/// config file).
pub fn config_from_flags(f: &Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = if let Some(path) = f.get("config") {
        crate::config::parser::load_file(path)?
    } else {
        let dataset = match f.get("dataset") {
            Some(d) => Dataset::parse(d).ok_or(format!("unknown dataset {d}"))?,
            None => Dataset::SpecBench,
        };
        let framework = match f.get("framework") {
            Some(x) => Framework::parse(x).ok_or(format!("unknown framework {x}"))?,
            None => Framework::Hat,
        };
        ExperimentConfig::preset(framework, dataset)
    };
    if let Some(r) = f.get_f64("rate")? {
        cfg.workload.rate = r;
    }
    if let Some(p) = f.get_usize("pipeline")? {
        cfg.cloud.pipeline_len = p;
    }
    if let Some(n) = f.get_usize("requests")? {
        cfg.workload.n_requests = n;
    }
    if let Some(s) = f.get_usize("seed")? {
        cfg.seed = s as u64;
    }
    cfg.validate().map_err(|e| e.join("; "))?;
    Ok(cfg)
}

fn cmd_simulate(f: &Flags) -> Result<(), String> {
    let cfg = config_from_flags(f)?;
    let profile = SdProfile::load_or_default(&cfg.specdec, 3);
    eprintln!(
        "simulating {} on {} | rate {}/s | P={} | {} requests",
        cfg.framework.name(),
        cfg.workload.dataset.name(),
        cfg.workload.rate,
        cfg.cloud.pipeline_len,
        cfg.workload.n_requests
    );
    let rec = run_experiment(&cfg, &profile);
    println!("{}", RunSummary::header());
    println!("{}", rec.summary().row(cfg.framework.name()));
    Ok(())
}

fn cmd_inspect() -> Result<(), String> {
    let dir = crate::runtime::ArtifactRegistry::default_dir();
    let reg =
        crate::runtime::ArtifactRegistry::load_or_synthetic(&dir).map_err(|e| e.to_string())?;
    let m = reg.manifest();
    println!("backend: {}", reg.backend_name());
    println!(
        "model: vocab={} hidden={} layers={} (device {} / cloud {}) heads={} max_seq={}",
        m.model.vocab,
        m.model.hidden,
        m.model.layers,
        m.model.shallow_layers,
        m.model.middle_layers(),
        m.model.heads,
        m.model.max_seq
    );
    println!("buckets: {:?}", m.buckets);
    println!("artifacts: {}", m.artifacts.len());
    println!(
        "params: LLM {} | adapter Λ {} | medusa heads {}",
        m.train_meta.lm_params, m.train_meta.adapter_params, m.train_meta.medusa_params
    );
    println!("accept-length probe (python): {:.2}", m.train_meta.accept_length_probe);
    Ok(())
}

/// Recursively compare the *schemas* of two bench-result JSON values:
/// object key sets (and value kinds) must match; numeric values may
/// differ — timings vary run to run, the committed trajectory files pin
/// what each bench reports, not how fast the runner was.  Arrays compare
/// element-wise when lengths match and are otherwise reported (bench row
/// counts are workload constants).  Returns the drift messages.
fn schema_drift(path: &str, a: &crate::util::json::Value, b: &crate::util::json::Value) -> Vec<String> {
    use crate::util::json::Value;
    let kind = |v: &Value| match v {
        Value::Null => "null",
        Value::Bool(_) => "bool",
        Value::Num(_) => "number",
        Value::Str(_) => "string",
        Value::Arr(_) => "array",
        Value::Obj(_) => "object",
    };
    match (a, b) {
        (Value::Obj(ma), Value::Obj(mb)) => {
            let mut out = Vec::new();
            for k in ma.keys() {
                if !mb.contains_key(k) {
                    out.push(format!("{path}.{k}: missing from fresh results"));
                }
            }
            for k in mb.keys() {
                if !ma.contains_key(k) {
                    out.push(format!("{path}.{k}: new key not in committed baseline"));
                }
            }
            for (k, va) in ma {
                if let Some(vb) = mb.get(k) {
                    out.extend(schema_drift(&format!("{path}.{k}"), va, vb));
                }
            }
            out
        }
        (Value::Arr(xa), Value::Arr(xb)) => {
            if xa.len() != xb.len() {
                return vec![format!(
                    "{path}: array length {} vs {} (bench row count changed)",
                    xa.len(),
                    xb.len()
                )];
            }
            xa.iter()
                .zip(xb)
                .enumerate()
                .flat_map(|(i, (va, vb))| schema_drift(&format!("{path}[{i}]"), va, vb))
                .collect()
        }
        _ if kind(a) == kind(b) => Vec::new(),
        _ => vec![format!("{path}: {} became {}", kind(a), kind(b))],
    }
}

/// `hat bench-diff <committed.json> <fresh.json>`: schema-compare a
/// committed bench trajectory file against a freshly generated run.  CI
/// runs this after each bench so a bench that silently drops or renames
/// a reported field fails the build; exit 1 lists every drifted path.
fn cmd_bench_diff(f: &Flags) -> Result<(), String> {
    let [committed, fresh] = match f.positional.as_slice() {
        [a, b] => [a, b],
        _ => return Err("usage: hat bench-diff <committed.json> <fresh.json>".into()),
    };
    let load = |p: &str| -> Result<crate::util::json::Value, String> {
        let text = std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?;
        crate::util::json::parse(&text).map_err(|e| format!("parse {p}: {e}"))
    };
    let a = load(committed)?;
    let b = load(fresh)?;
    let drift = schema_drift("$", &a, &b);
    if drift.is_empty() {
        println!("bench-diff: {committed} and {fresh} agree");
        Ok(())
    } else {
        Err(format!(
            "bench schema drift between {committed} and {fresh}:\n  {}",
            drift.join("\n  ")
        ))
    }
}

fn cmd_profile(f: &Flags) -> Result<(), String> {
    let n = f.get_usize("rounds")?.unwrap_or(6);
    let cfg = crate::config::SpecDecConfig::default();
    let p = SdProfile::load_or_default(&cfg, n);
    println!(
        "HAT rounds: {} | accept length {:.2} | pd hits {:.0}%",
        p.hat.len(),
        SdProfile::accept_length(&p.hat),
        100.0 * p.hat.iter().filter(|r| r.pd_hit).count() as f64 / p.hat.len() as f64
    );
    println!(
        "U-Medusa rounds: {} | accept length {:.2}",
        p.medusa.len(),
        SdProfile::accept_length(&p.medusa)
    );
    Ok(())
}

/// CLI entry; returns the process exit code.
pub fn main() -> i32 {
    let mut args = std::env::args().skip(1);
    let cmd = match args.next() {
        Some(c) => c,
        None => {
            eprintln!("usage: hat <simulate|serve|profile|inspect|bench-diff> [flags]");
            return 2;
        }
    };
    let flags = match parse_flags(args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let r = match cmd.as_str() {
        "simulate" => cmd_simulate(&flags),
        "serve" => crate::server::cmd_serve(&flags),
        "profile" => cmd_profile(&flags),
        "inspect" => cmd_inspect(),
        "bench-diff" => cmd_bench_diff(&flags),
        other => Err(format!("unknown command '{other}'")),
    };
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(s: &[&str]) -> Flags {
        parse_flags(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_mixed_flags() {
        // A flag consumes the next non-flag token as its value; trailing
        // flags with no value become "true".
        let f = flags(&["pos1", "--rate", "6.5", "--pipeline", "4", "--verbose"]);
        assert_eq!(f.get("rate"), Some("6.5"));
        assert_eq!(f.get("verbose"), Some("true"));
        assert_eq!(f.positional, vec!["pos1"]);
        assert_eq!(f.get_f64("rate").unwrap(), Some(6.5));
        assert_eq!(f.get_usize("pipeline").unwrap(), Some(4));
        assert_eq!(f.get("missing"), None);
    }

    #[test]
    fn rejects_duplicates_and_bad_numbers() {
        assert!(parse_flags(["--a", "1", "--a", "2"].iter().map(|x| x.to_string())).is_err());
        let f = flags(&["--rate", "abc"]);
        assert!(f.get_f64("rate").is_err());
    }

    #[test]
    fn config_from_flags_overrides_preset() {
        let f = flags(&["--framework", "ushape", "--rate", "9", "--pipeline", "8", "--requests", "5"]);
        let c = config_from_flags(&f).unwrap();
        assert_eq!(c.framework, Framework::UShape);
        assert_eq!(c.workload.rate, 9.0);
        assert_eq!(c.cloud.pipeline_len, 8);
        assert_eq!(c.workload.n_requests, 5);
    }

    #[test]
    fn config_from_flags_rejects_unknown_framework() {
        assert!(config_from_flags(&flags(&["--framework", "zzz"])).is_err());
    }

    #[test]
    fn schema_drift_ignores_values_but_catches_shape() {
        use crate::util::json::parse;
        let a = parse(r#"{"x": 1.0, "y": {"z": 2}, "rows": [1, 2]}"#).unwrap();
        // Different numbers, same shape: no drift.
        let b = parse(r#"{"x": 9.5, "y": {"z": -1}, "rows": [7, 8]}"#).unwrap();
        assert!(schema_drift("$", &a, &b).is_empty());
        // Missing key, new key, kind change, row-count change: all named.
        let c = parse(r#"{"x": "fast", "y": {}, "rows": [1], "extra": 0}"#).unwrap();
        let drift = schema_drift("$", &a, &c);
        assert!(drift.iter().any(|d| d.contains("$.x") && d.contains("number")), "{drift:?}");
        assert!(drift.iter().any(|d| d.contains("$.y.z")), "{drift:?}");
        assert!(drift.iter().any(|d| d.contains("$.rows") && d.contains("length")), "{drift:?}");
        assert!(drift.iter().any(|d| d.contains("$.extra")), "{drift:?}");
    }

    #[test]
    fn bench_diff_compares_files() {
        let dir = std::env::temp_dir().join("hat_bench_diff_test");
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("committed.json");
        let b = dir.join("fresh.json");
        std::fs::write(&a, r#"{"wall_ms": 10.0}"#).unwrap();
        std::fs::write(&b, r#"{"wall_ms": 99.9}"#).unwrap();
        let ok = flags(&[a.to_str().unwrap(), b.to_str().unwrap()]);
        assert!(cmd_bench_diff(&ok).is_ok());
        std::fs::write(&b, r#"{"renamed_ms": 99.9}"#).unwrap();
        let err = cmd_bench_diff(&ok).unwrap_err();
        assert!(err.contains("wall_ms") && err.contains("renamed_ms"), "{err}");
        assert!(cmd_bench_diff(&flags(&["only-one.json"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
