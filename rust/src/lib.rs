//! # HAT — Hat-shaped device-cloud collaborative LLM inference
//!
//! Reproduction of *"A Novel Hat-Shaped Device-Cloud Collaborative Inference
//! Framework for Large Language Models"* (Xie et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's coordination contribution: the cloud
//!   scheduler (continuous batching, prefill/decode mixing, pipeline-parallel
//!   model), state monitoring (Eqs. 1–2), dynamic prompt chunking (Eq. 3),
//!   speculative-decoding orchestration (Eq. 5) and parallel drafting
//!   (Eq. 6), plus the simulated testbed (30 heterogeneous Jetson devices,
//!   WiFi links, 8-GPU cloud) and the three baselines (U-shape, U-Medusa,
//!   U-Sarathi).
//! - **L2/L1 (python/, build-time only)** — the split transformer, adapter
//!   Λ, Medusa heads, and the Pallas flash-attention/SwiGLU kernels, AOT
//!   lowered to HLO text artifacts.
//! - **backend** — the execution seam ([`backend::ExecBackend`]): model
//!   execution behind a trait over plain `Tensor`s.  Default is the
//!   deterministic pure-Rust **reference** backend (runs everywhere, zero
//!   dependencies, can synthesize its own tiny model); the real **PJRT**
//!   path (`xla` crate, HLO artifacts, device-resident weights) compiles
//!   behind the `pjrt` cargo feature and is selected with
//!   `HAT_BACKEND=pjrt`.
//! - **runtime** — the backend-agnostic artifact registry (manifest,
//!   token buckets, lazy compile cache) the engine layer talks to.
//!
//! See DESIGN.md for the substitution table (physical testbed → simulators)
//! and the per-experiment index, and EXPERIMENTS.md for results.

pub mod backend;
pub mod cli;
pub mod cloud;
pub mod config;
pub mod devices;
pub mod engine;
pub mod frameworks;
pub mod kv;
pub mod metrics;
pub mod model;
pub mod net;
pub mod runtime;
pub mod sampler;
pub mod server;
pub mod sim;
pub mod specdec;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
