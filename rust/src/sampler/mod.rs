//! Stochastic token sampling for (speculative) decoding: temperature,
//! top-k, top-p and repetition penalty over max-subtracted softmax
//! probabilities, driven by a **position-keyed** seeded RNG.
//!
//! Determinism contract: every random draw is addressed by the absolute
//! context position of the token being decided, via
//! `Rng::new(seed).substream(position)`.  A draw therefore depends only
//! on `(seed, position, draw index)` — never on how many times or in
//! what order the sampler was consulted — so scheduler interleaving,
//! re-drafted rounds after an abort, and pre-drafted (PD) branches all
//! reproduce the exact stream of a serial run.  Three draws are budgeted
//! per position:
//!
//! | draw | used for |
//! |------|----------|
//! | 0 (`u_at`)  | inverse-CDF sample from a processed distribution |
//! | 1 (`r_at`)  | rejection-mode accept test `r <= p(d)/q(d)`      |
//! | 2 (`v_at`)  | rejection-mode residual resample                 |
//!
//! `temperature <= 0` means greedy: callers short-circuit to
//! `Engine::argmax` and no draws are consumed, keeping the greedy paths
//! bit-identical to the pre-sampling code.

use crate::config::SpecDecConfig;
use crate::model::TokenId;
use crate::util::rng::Rng;

/// Processed-probability sampler.  `Clone`-cheap and stateless between
/// calls: all randomness is re-derived from `(seed, position)`.
#[derive(Debug, Clone)]
pub struct Sampler {
    /// Softmax temperature; `<= 0` selects greedy argmax decoding.
    pub temperature: f64,
    /// Keep only the `top_k` most probable tokens (0 = disabled).
    pub top_k: usize,
    /// Nucleus mass: keep the minimal prefix of descending-probability
    /// tokens whose cumulative mass reaches `top_p` (1.0 = disabled).
    pub top_p: f64,
    /// CTRL-style repetition penalty on already-generated tokens
    /// (1.0 = disabled; must be > 0).
    pub rep_penalty: f64,
    /// Session seed keying every positional substream.
    pub seed: u64,
}

impl Sampler {
    pub fn from_cfg(cfg: &SpecDecConfig) -> Sampler {
        Sampler {
            temperature: cfg.temperature,
            top_k: cfg.top_k_sample,
            top_p: cfg.top_p,
            rep_penalty: cfg.rep_penalty,
            seed: cfg.seed,
        }
    }

    /// Greedy mode: the sampler is inert and callers use argmax.
    pub fn greedy(&self) -> bool {
        self.temperature <= 0.0
    }

    fn rng_at(&self, pos: usize) -> Rng {
        Rng::new(self.seed).substream(pos as u64)
    }

    /// Draw 0 at `pos`: the inverse-CDF uniform.
    pub fn u_at(&self, pos: usize) -> f64 {
        self.rng_at(pos).f64()
    }

    /// Draw 1 at `pos`: the rejection-test uniform.
    pub fn r_at(&self, pos: usize) -> f64 {
        let mut r = self.rng_at(pos);
        r.f64();
        r.f64()
    }

    /// Draw 2 at `pos`: the residual-resample uniform.
    pub fn v_at(&self, pos: usize) -> f64 {
        let mut r = self.rng_at(pos);
        r.f64();
        r.f64();
        r.f64()
    }

    /// NaN-tolerant argmax (ties -> lowest index), the greedy fallback
    /// when processing degenerates (e.g. every logit masked or NaN).
    fn argmax(logits: &[f32]) -> TokenId {
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for (i, &x) in logits.iter().enumerate() {
            if !x.is_nan() && x > best_v {
                best = i;
                best_v = x;
            }
        }
        best as TokenId
    }

    /// Processed probability distribution over the vocabulary:
    /// repetition penalty -> temperature -> max-subtracted softmax ->
    /// top-k mask -> top-p mask -> renormalize.  Always sums to 1; if
    /// the pipeline degenerates (all-NaN row, zero mass) it falls back
    /// to a point mass on the argmax so sampling stays total.
    pub fn dist(&self, logits: &[f32], rep_ctx: &[TokenId]) -> Vec<f64> {
        let v = logits.len();
        let mut z: Vec<f64> = logits.iter().map(|&x| x as f64).collect();

        // Repetition penalty (CTRL): shrink already-generated tokens
        // toward improbability on the *logit* scale, before softmax.
        if self.rep_penalty != 1.0 {
            let mut seen = vec![false; v];
            for &t in rep_ctx {
                if (t as usize) < v {
                    seen[t as usize] = true;
                }
            }
            for (zi, hit) in z.iter_mut().zip(&seen) {
                if *hit {
                    if *zi > 0.0 {
                        *zi /= self.rep_penalty;
                    } else {
                        *zi *= self.rep_penalty;
                    }
                }
            }
        }

        let t = self.temperature.max(1e-9);
        for zi in z.iter_mut() {
            *zi /= t;
        }

        // Max-subtracted softmax: without the shift, |logit/T| beyond
        // ~709 overflows exp() and the row collapses to NaN.
        let m = z.iter().cloned().filter(|x| !x.is_nan()).fold(f64::NEG_INFINITY, f64::max);
        let mut p: Vec<f64> = if m.is_finite() {
            z.iter().map(|&x| if x.is_nan() { 0.0 } else { (x - m).exp() }).collect()
        } else {
            vec![0.0; v]
        };

        // Top-k / top-p operate on the descending-probability order
        // (ties broken by lowest index, so masking is deterministic).
        let mut order: Vec<usize> = (0..v).collect();
        order.sort_by(|&a, &b| p[b].partial_cmp(&p[a]).unwrap().then(a.cmp(&b)));
        if self.top_k > 0 && self.top_k < v {
            for &i in &order[self.top_k..] {
                p[i] = 0.0;
            }
        }
        if self.top_p < 1.0 {
            let total: f64 = p.iter().sum();
            if total > 0.0 {
                let mut cum = 0.0;
                let mut cut = order.len();
                for (rank, &i) in order.iter().enumerate() {
                    cum += p[i] / total;
                    if cum >= self.top_p {
                        cut = rank + 1; // keep at least one token
                        break;
                    }
                }
                for &i in &order[cut..] {
                    p[i] = 0.0;
                }
            }
        }

        let total: f64 = p.iter().sum();
        if total > 0.0 && total.is_finite() {
            for pi in p.iter_mut() {
                *pi /= total;
            }
        } else {
            p.iter_mut().for_each(|pi| *pi = 0.0);
            p[Self::argmax(logits) as usize] = 1.0;
        }
        p
    }

    /// Inverse-CDF pick from a normalized distribution.
    pub fn pick(dist: &[f64], u: f64) -> TokenId {
        let mut cum = 0.0;
        let mut last_support = 0usize;
        for (i, &pi) in dist.iter().enumerate() {
            if pi <= 0.0 {
                continue;
            }
            last_support = i;
            cum += pi;
            if u < cum {
                return i as TokenId;
            }
        }
        // Rounding left u >= cum: highest-index support token.
        last_support as TokenId
    }

    /// Sample the token at absolute context position `pos` from a
    /// processed `logits` row (greedy mode falls through to argmax and
    /// consumes no draws).
    pub fn sample_at(&self, logits: &[f32], rep_ctx: &[TokenId], pos: usize) -> TokenId {
        if self.greedy() {
            return Self::argmax(logits);
        }
        Self::pick(&self.dist(logits, rep_ctx), self.u_at(pos))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{cases, forall};

    fn sampler(t: f64, k: usize, p: f64, rp: f64) -> Sampler {
        Sampler { temperature: t, top_k: k, top_p: p, rep_penalty: rp, seed: 7 }
    }

    #[test]
    fn draws_are_position_keyed_and_order_independent() {
        let s = sampler(1.0, 0, 1.0, 1.0);
        // Re-querying any draw in any order reproduces the same value.
        let (u5, r5, v5) = (s.u_at(5), s.r_at(5), s.v_at(5));
        assert_eq!(s.v_at(5), v5);
        assert_eq!(s.u_at(5), u5);
        assert_eq!(s.r_at(5), r5);
        assert_ne!(s.u_at(5), s.u_at(6), "positions must have independent streams");
        assert_ne!((u5, r5), (r5, v5), "draw indices must differ");
    }

    #[test]
    fn greedy_mode_is_argmax() {
        let s = sampler(0.0, 0, 1.0, 1.0);
        assert!(s.greedy());
        let logits = [0.1f32, 2.0, -1.0, 1.9];
        for pos in 0..32 {
            assert_eq!(s.sample_at(&logits, &[], pos), 1);
        }
    }

    #[test]
    fn dist_is_normalized_and_softmax_is_overflow_safe() {
        let s = sampler(0.7, 0, 1.0, 1.0);
        // |logits| ~ 1e4 would overflow exp() without the max shift.
        let logits = [30_000.0f32, 29_999.0, -30_000.0];
        let d = s.dist(&logits, &[]);
        assert!(d.iter().all(|p| p.is_finite()));
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(d[0] > d[1] && d[1] > d[2]);
    }

    #[test]
    fn top_k_and_top_p_restrict_support() {
        let logits = [2.0f32, 1.0, 0.5, 0.0, -1.0];
        let dk = sampler(1.0, 2, 1.0, 1.0).dist(&logits, &[]);
        assert_eq!(dk.iter().filter(|&&p| p > 0.0).count(), 2);
        assert!(dk[0] > 0.0 && dk[1] > 0.0);
        let dp = sampler(1.0, 0, 0.5, 1.0).dist(&logits, &[]);
        // Minimal prefix: the top token alone carries ~0.56 of the mass,
        // so nucleus 0.5 keeps exactly that one token.
        assert!((dp[0] - 1.0).abs() < 1e-9);
        assert_eq!(dp.iter().skip(1).filter(|&&p| p > 0.0).count(), 0);
    }

    #[test]
    fn rep_penalty_demotes_context_tokens() {
        let logits = [1.0f32, 1.0, 1.0];
        let base = sampler(1.0, 0, 1.0, 1.0).dist(&logits, &[0]);
        let pen = sampler(1.0, 0, 1.0, 1.3).dist(&logits, &[0]);
        assert!(pen[0] < base[0], "penalized token must lose mass");
        assert!((pen.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pick_is_inverse_cdf() {
        let d = [0.25f64, 0.0, 0.5, 0.25];
        assert_eq!(Sampler::pick(&d, 0.0), 0);
        assert_eq!(Sampler::pick(&d, 0.249), 0);
        assert_eq!(Sampler::pick(&d, 0.26), 2);
        assert_eq!(Sampler::pick(&d, 0.74), 2);
        assert_eq!(Sampler::pick(&d, 0.76), 3);
        assert_eq!(Sampler::pick(&d, 0.999_999), 3);
    }

    #[test]
    fn prop_dist_support_and_mass_invariants() {
        forall(cases(200), |rng| {
            let v = rng.range_usize(2, 24);
            let logits: Vec<f32> =
                (0..v).map(|_| rng.range_f64(-6.0, 6.0) as f32).collect();
            let k = rng.range_usize(0, v);
            let top_p = rng.range_f64(0.05, 1.0);
            let s = Sampler {
                temperature: rng.range_f64(0.05, 2.5),
                top_k: k,
                top_p,
                rep_penalty: rng.range_f64(0.5, 2.0),
                seed: rng.next_u64(),
            };
            let ctx: Vec<TokenId> =
                (0..rng.range_usize(0, 6)).map(|_| rng.below(v) as TokenId).collect();
            let d = s.dist(&logits, &ctx);
            let sum: f64 = d.iter().sum();
            if (sum - 1.0).abs() > 1e-9 {
                return Err(format!("mass {sum} != 1"));
            }
            let support = d.iter().filter(|&&p| p > 0.0).count();
            if support == 0 {
                return Err("empty support".into());
            }
            if k > 0 && support > k {
                return Err(format!("top-k={k} but support {support}"));
            }
            // The sampled token always lies in the support.
            let t = Sampler::pick(&d, rng.f64()) as usize;
            if d[t] <= 0.0 {
                return Err(format!("picked token {t} outside support"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_top_p_keeps_minimal_prefix_of_sorted_probs() {
        forall(cases(150), |rng| {
            let v = rng.range_usize(3, 16);
            let logits: Vec<f32> =
                (0..v).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
            let top_p = rng.range_f64(0.1, 0.95);
            let s = sampler(1.0, 0, top_p, 1.0);
            let full = sampler(1.0, 0, 1.0, 1.0).dist(&logits, &[]);
            let d = s.dist(&logits, &[]);
            // Support is exactly a prefix of the descending-prob order.
            let mut order: Vec<usize> = (0..v).collect();
            order.sort_by(|&a, &b| full[b].partial_cmp(&full[a]).unwrap().then(a.cmp(&b)));
            let support: Vec<bool> = d.iter().map(|&p| p > 0.0).collect();
            let n_kept = support.iter().filter(|&&b| b).count();
            for (rank, &i) in order.iter().enumerate() {
                if support[i] != (rank < n_kept) {
                    return Err(format!("support is not the top-{n_kept} prefix"));
                }
            }
            // Minimality: kept mass reaches p, kept-minus-last does not.
            let kept: f64 = order[..n_kept].iter().map(|&i| full[i]).sum();
            if kept + 1e-12 < top_p {
                return Err(format!("kept mass {kept} < top_p {top_p}"));
            }
            if n_kept > 1 {
                let prev: f64 = order[..n_kept - 1].iter().map(|&i| full[i]).sum();
                if prev >= top_p {
                    return Err(format!("prefix {n_kept} not minimal ({prev} >= {top_p})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_temperature_to_zero_converges_to_argmax() {
        forall(cases(100), |rng| {
            let v = rng.range_usize(2, 16);
            let mut logits: Vec<f32> =
                (0..v).map(|_| rng.range_f64(-4.0, 4.0) as f32).collect();
            let best = rng.below(v);
            logits[best] = 6.0; // unique max with a clear gap
            let s = sampler(1e-3, 0, 1.0, 1.0);
            let d = s.dist(&logits, &[]);
            if d[best] < 0.999_999 {
                return Err(format!("T->0 mass on argmax only {}", d[best]));
            }
            let got = s.sample_at(&logits, &[], rng.below(1000));
            if got as usize != best {
                return Err(format!("T->0 sampled {got}, argmax {best}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_rep_penalty_never_resurrects_a_masked_token() {
        // A token outside the top-k support stays at probability zero
        // for every repetition context: the penalty reshapes logits
        // *before* masking, it can never un-mask.
        forall(cases(150), |rng| {
            let v = rng.range_usize(4, 16);
            let logits: Vec<f32> =
                (0..v).map(|_| rng.range_f64(-3.0, 3.0) as f32).collect();
            let k = rng.range_usize(1, v - 1);
            let s = Sampler {
                temperature: rng.range_f64(0.2, 2.0),
                top_k: k,
                top_p: rng.range_f64(0.2, 1.0),
                rep_penalty: rng.range_f64(1.0, 2.0),
                seed: 1,
            };
            let ctx: Vec<TokenId> =
                (0..rng.range_usize(1, 8)).map(|_| rng.below(v) as TokenId).collect();
            let d = s.dist(&logits, &ctx);
            if d.iter().filter(|&&p| p > 0.0).count() > k {
                return Err("masked token resurrected past top-k".into());
            }
            if (d.iter().sum::<f64>() - 1.0).abs() > 1e-9 {
                return Err("mass != 1 under penalty+mask".into());
            }
            Ok(())
        });
    }
}
