//! TOML-subset parser for experiment config files (the offline crate set
//! has no toml/serde).  Supported grammar:
//!
//! ```toml
//! # comment
//! [section]
//! key = 1.5          # number
//! name = "hat"       # string
//! flag = true        # bool
//! ```
//!
//! Flat `section.key` lookup; `apply()` overlays a parsed file onto an
//! `ExperimentConfig` preset so config files only need to list overrides.

use std::collections::BTreeMap;

use super::{Dataset, ExperimentConfig, Framework, Strategies};

#[derive(Debug, Clone, PartialEq)]
pub enum Scalar {
    Num(f64),
    Str(String),
    Bool(bool),
}

impl Scalar {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Scalar::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Scalar::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Scalar::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug, thiserror::Error)]
#[error("config parse error on line {line}: {msg}")]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

/// Parse into `section.key -> Scalar` (keys before any section header have
/// no prefix).
pub fn parse(text: &str) -> Result<BTreeMap<String, Scalar>, ConfigError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let name = name.trim();
            if name.is_empty() {
                return Err(ConfigError { line: i + 1, msg: "empty section name".into() });
            }
            section = name.to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or(ConfigError {
            line: i + 1,
            msg: format!("expected key = value, got '{line}'"),
        })?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let v = v.trim();
        let scalar = if v == "true" {
            Scalar::Bool(true)
        } else if v == "false" {
            Scalar::Bool(false)
        } else if let Some(s) = v.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
            Scalar::Str(s.to_string())
        } else {
            Scalar::Num(v.parse::<f64>().map_err(|_| ConfigError {
                line: i + 1,
                msg: format!("bad value '{v}'"),
            })?)
        };
        if out.insert(key.clone(), scalar).is_some() {
            return Err(ConfigError { line: i + 1, msg: format!("duplicate key '{key}'") });
        }
    }
    Ok(out)
}

/// Build an ExperimentConfig: start from the preset named by
/// `framework`/`dataset` keys (defaults: hat/specbench), then overlay every
/// recognized key.  Unknown keys are an error — silent typos poison
/// experiments.
pub fn build(map: &BTreeMap<String, Scalar>) -> Result<ExperimentConfig, String> {
    let dataset = match map.get("dataset") {
        Some(s) => Dataset::parse(s.as_str().ok_or("dataset must be a string")?)
            .ok_or_else(|| format!("unknown dataset {:?}", s))?,
        None => Dataset::SpecBench,
    };
    let framework = match map.get("framework") {
        Some(s) => Framework::parse(s.as_str().ok_or("framework must be a string")?)
            .ok_or_else(|| format!("unknown framework {:?}", s))?,
        None => Framework::Hat,
    };
    let mut cfg = ExperimentConfig::preset(framework, dataset);

    for (k, v) in map {
        let num = || v.as_f64().ok_or_else(|| format!("{k} must be a number"));
        let us = || v.as_usize().ok_or_else(|| format!("{k} must be a number"));
        let b = || v.as_bool().ok_or_else(|| format!("{k} must be a bool"));
        match k.as_str() {
            // hatlint: allow(drift-config-validate) enums: Dataset/Framework::parse reject unknowns above
            "dataset" | "framework" => {}
            // hatlint: allow(drift-config-validate) any u64 is a valid seed
            "seed" => cfg.seed = us()? as u64,
            "min_chunk" => cfg.min_chunk = us()?,
            "max_chunk" => cfg.max_chunk = us()?,
            "workload.rate" => cfg.workload.rate = num()?,
            "workload.n_devices" => cfg.workload.n_devices = us()?,
            "workload.n_requests" => cfg.workload.n_requests = us()?,
            "workload.max_new_tokens" => cfg.workload.max_new_tokens = us()?,
            "workload.min_prompt" => cfg.workload.min_prompt = us()?,
            "workload.max_prompt" => cfg.workload.max_prompt = us()?,
            "cloud.pipeline_len" => cfg.cloud.pipeline_len = us()?,
            "cloud.max_batch_tokens" => cfg.cloud.max_batch_tokens = us()?,
            "cloud.alpha" => cfg.cloud.alpha = num()?,
            "specdec.eta" => cfg.specdec.eta = num()?,
            "specdec.max_draft" => cfg.specdec.max_draft = us()?,
            // hatlint: allow(drift-config-validate) 0 disables the draft top-k filter
            "specdec.top_k" => cfg.specdec.top_k = us()?,
            "specdec.max_new_tokens" => cfg.specdec.max_new_tokens = us()?,
            "specdec.temperature" => cfg.specdec.temperature = num()?,
            // hatlint: allow(drift-config-validate) 0 disables top-k sampling truncation
            "specdec.top_k_sample" => cfg.specdec.top_k_sample = us()?,
            "specdec.top_p" => cfg.specdec.top_p = num()?,
            "specdec.rep_penalty" => cfg.specdec.rep_penalty = num()?,
            // hatlint: allow(drift-config-validate) any u64 is a valid seed
            "specdec.seed" => cfg.specdec.seed = us()? as u64,
            // hatlint: allow(drift-config-validate) enum: SampleVerify::parse rejects unknowns here
            "specdec.verify_mode" => {
                let s = v.as_str().ok_or("specdec.verify_mode must be a string")?;
                cfg.specdec.verify_mode = super::SampleVerify::parse(s)
                    .ok_or_else(|| format!("unknown specdec.verify_mode {s:?} (coupled|rejection)"))?;
            }
            "serve.max_sessions" => cfg.serve.max_sessions = us()?,
            "serve.prefill_budget" => cfg.serve.prefill_budget = us()?,
            "serve.min_chunk" => cfg.serve.min_chunk = us()?,
            "serve.max_chunk" => cfg.serve.max_chunk = us()?,
            "serve.alpha" => cfg.serve.alpha = num()?,
            "serve.pipeline_len" => cfg.serve.pipeline_len = us()?,
            // hatlint: allow(drift-config-validate) bool toggle, both values valid
            "serve.learned_g" => cfg.serve.learned_g = b()?,
            // hatlint: allow(drift-config-validate) enum: AdmitPolicy::parse rejects unknowns here
            "serve.policy" => {
                let s = v.as_str().ok_or("serve.policy must be a string")?;
                cfg.serve.policy = super::AdmitPolicy::parse(s)
                    .ok_or_else(|| format!("unknown serve.policy {s:?} (fifo|sjf)"))?;
            }
            // hatlint: allow(drift-config-validate) 0 means every oldest waiter is instantly aged (FIFO)
            "serve.sjf_aging_ms" => cfg.serve.sjf_aging_ms = us()? as u64,
            // hatlint: allow(drift-config-validate) 0 disables deadlines
            "serve.deadline_ms" => cfg.serve.deadline_ms = us()? as u64,
            // hatlint: allow(drift-config-validate) enum: PriorityMode::parse rejects unknowns here
            "serve.priority" => {
                let s = v.as_str().ok_or("serve.priority must be a string")?;
                cfg.serve.priority = super::PriorityMode::parse(s)
                    .ok_or_else(|| format!("unknown serve.priority {s:?} (none|preempt)"))?;
            }
            "serve.prefill_workers" => cfg.serve.prefill_workers = us()?,
            "serve.decode_workers" => cfg.serve.decode_workers = us()?,
            "serve.rate_limit_rps" => cfg.serve.rate_limit_rps = num()?,
            "serve.burst" => cfg.serve.burst = us()?,
            "serve.admit_queue" => cfg.serve.admit_queue = us()?,
            "serve.outbox_lines" => cfg.serve.outbox_lines = us()?,
            "kv.block_tokens" => cfg.kv.block_tokens = us()?,
            "kv.kv_blocks" => cfg.kv.kv_blocks = us()?,
            // hatlint: allow(drift-config-validate) bool toggle, both values valid
            "strategies.sd" => cfg.strategies.sd = b()?,
            // hatlint: allow(drift-config-validate) bool toggle, both values valid
            "strategies.pc" => cfg.strategies.pc = b()?,
            // hatlint: allow(drift-config-validate) bool toggle, both values valid
            "strategies.pd" => cfg.strategies.pd = b()?,
            _ => return Err(format!("unknown config key '{k}'")),
        }
    }
    // Re-derive baseline strategies if framework given but strategies not
    // overridden is already handled by preset; explicit overrides win.
    let _ = Strategies::for_framework(framework, dataset);
    cfg.validate().map_err(|e| e.join("; "))?;
    Ok(cfg)
}

pub fn load_file(path: &str) -> Result<ExperimentConfig, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let map = parse(&text).map_err(|e| e.to_string())?;
    build(&map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_comments_types() {
        let m = parse(
            "# experiment\nseed = 7\n[workload]\nrate = 4.5  # req/s\n\n[strategies]\npd = false\nname = \"x\"\n",
        )
        .unwrap();
        assert_eq!(m["seed"], Scalar::Num(7.0));
        assert_eq!(m["workload.rate"], Scalar::Num(4.5));
        assert_eq!(m["strategies.pd"], Scalar::Bool(false));
        assert_eq!(m["strategies.name"], Scalar::Str("x".into()));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("key value\n").is_err());
        assert!(parse("[]\n").is_err());
        assert!(parse("a = 1\na = 2\n").is_err());
        assert!(parse("a = one\n").is_err());
    }

    #[test]
    fn builds_overlay_on_preset() {
        let m = parse(
            "framework = \"usarathi\"\ndataset = \"cnndm\"\n[workload]\nrate = 2.5\n[cloud]\npipeline_len = 8\n",
        )
        .unwrap();
        let cfg = build(&m).unwrap();
        assert_eq!(cfg.framework, Framework::USarathi);
        assert_eq!(cfg.workload.dataset, Dataset::CnnDm);
        assert_eq!(cfg.workload.rate, 2.5);
        assert_eq!(cfg.cloud.pipeline_len, 8);
        assert_eq!(cfg.strategies.server_chunk, Some(256));
    }

    #[test]
    fn serve_section_overlays_and_validates() {
        let m = parse(
            "[serve]\nmax_sessions = 4\nprefill_budget = 128\nmin_chunk = 8\nlearned_g = false\n",
        )
        .unwrap();
        let cfg = build(&m).unwrap();
        assert_eq!(cfg.serve.max_sessions, 4);
        assert_eq!(cfg.serve.prefill_budget, 128);
        assert_eq!(cfg.serve.min_chunk, 8);
        assert!(!cfg.serve.learned_g, "learned_g override ignored");
        assert!(
            crate::config::ServeConfig::default().learned_g,
            "learned predictor on by default"
        );
        let m = parse("[serve]\nmax_sessions = 0\n").unwrap();
        assert!(build(&m).unwrap_err().contains("serve.max_sessions"));
    }

    #[test]
    fn serve_lifecycle_keys_overlay() {
        let m = parse(
            "[serve]\npolicy = \"sjf\"\nsjf_aging_ms = 250\ndeadline_ms = 4000\n",
        )
        .unwrap();
        let cfg = build(&m).unwrap();
        assert_eq!(cfg.serve.policy, crate::config::AdmitPolicy::Sjf);
        assert_eq!(cfg.serve.sjf_aging_ms, 250);
        assert_eq!(cfg.serve.deadline_ms, 4000);
        let m = parse("[serve]\npolicy = \"lifo\"\n").unwrap();
        assert!(build(&m).unwrap_err().contains("serve.policy"));
        let m = parse("[serve]\npolicy = 3\n").unwrap();
        assert!(build(&m).unwrap_err().contains("string"));
    }

    #[test]
    fn pool_worker_keys_overlay_and_validate_together() {
        let m = parse("[serve]\nprefill_workers = 2\ndecode_workers = 6\n").unwrap();
        let cfg = build(&m).unwrap();
        assert_eq!(cfg.serve.prefill_workers, 2);
        assert_eq!(cfg.serve.decode_workers, 6);
        assert_eq!(crate::config::ServeConfig::default().prefill_workers, 0);
        assert_eq!(crate::config::ServeConfig::default().decode_workers, 0);
        // One without the other is a config error, both directions.
        let m = parse("[serve]\nprefill_workers = 2\n").unwrap();
        assert!(build(&m).unwrap_err().contains("serve.prefill_workers"));
        let m = parse("[serve]\ndecode_workers = 4\n").unwrap();
        assert!(build(&m).unwrap_err().contains("serve.decode_workers"));
    }

    #[test]
    fn kv_and_priority_keys_overlay() {
        let m = parse("[serve]\npriority = \"preempt\"\n[kv]\nblock_tokens = 32\nkv_blocks = 256\n")
            .unwrap();
        let cfg = build(&m).unwrap();
        assert_eq!(cfg.serve.priority, crate::config::PriorityMode::Preempt);
        assert_eq!(cfg.kv.block_tokens, 32);
        assert_eq!(cfg.kv.kv_blocks, 256);
        let m = parse("[serve]\npriority = \"kill\"\n").unwrap();
        assert!(build(&m).unwrap_err().contains("serve.priority"));
        let m = parse("[serve]\npriority = 1\n").unwrap();
        assert!(build(&m).unwrap_err().contains("string"));
        let m = parse("[kv]\nblock_tokens = 20\n").unwrap();
        assert!(build(&m).unwrap_err().contains("kv.block_tokens"), "multiple-of-8 rule");
        let m = parse("[kv]\nkv_blocks = 4\n").unwrap();
        assert!(build(&m).unwrap_err().contains("kv pool too small"), "pool-coverage rule");
    }

    #[test]
    fn unknown_key_is_error() {
        let m = parse("workloda.rate = 4\n").unwrap();
        assert!(build(&m).unwrap_err().contains("unknown config key"));
    }

    #[test]
    fn invalid_values_fail_validation() {
        let m = parse("[specdec]\neta = 2.0\n").unwrap();
        assert!(build(&m).is_err());
        let m = parse("[specdec]\ntop_p = 0.0\n").unwrap();
        assert!(build(&m).unwrap_err().contains("top_p"));
        let m = parse("[specdec]\ntemperature = -1\n").unwrap();
        assert!(build(&m).unwrap_err().contains("temperature"));
    }

    #[test]
    fn sampling_keys_overlay() {
        let m = parse(
            "[specdec]\ntemperature = 0.8\ntop_k_sample = 40\ntop_p = 0.95\nrep_penalty = 1.1\nseed = 99\nverify_mode = \"rejection\"\n",
        )
        .unwrap();
        let cfg = build(&m).unwrap();
        assert_eq!(cfg.specdec.temperature, 0.8);
        assert_eq!(cfg.specdec.top_k_sample, 40);
        assert_eq!(cfg.specdec.top_p, 0.95);
        assert_eq!(cfg.specdec.rep_penalty, 1.1);
        assert_eq!(cfg.specdec.seed, 99);
        assert_eq!(cfg.specdec.verify_mode, crate::config::SampleVerify::Rejection);
        let m = parse("[specdec]\nverify_mode = \"argmax\"\n").unwrap();
        assert!(build(&m).unwrap_err().contains("verify_mode"));
    }
}
