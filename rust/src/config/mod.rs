//! Configuration system: typed config structs, dataset/testbed presets
//! matching the paper's §4.1 setup, and a TOML-subset file parser
//! (`config::parser`) so experiments are reproducible from checked-in
//! files instead of flag soup.

pub mod parser;

use crate::util::rng::lognormal_params_from_mean_std;

/// Which dataset's workload statistics to emulate (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    /// SpecBench — mixed tasks, mean prompt 351.2 tokens (Vicuna-7B).
    SpecBench,
    /// CNN/DailyMail — summarization, mean prompt 1036.6 tokens (Vicuna-13B).
    CnnDm,
}

impl Dataset {
    pub fn name(self) -> &'static str {
        match self {
            Dataset::SpecBench => "specbench",
            Dataset::CnnDm => "cnndm",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        match s.to_ascii_lowercase().as_str() {
            "specbench" => Some(Dataset::SpecBench),
            "cnndm" | "cnn/dm" | "cnn_dm" => Some(Dataset::CnnDm),
            _ => None,
        }
    }

    /// (mean, std) of prompt token length — Table 3.
    pub fn prompt_stats(self) -> (f64, f64) {
        match self {
            Dataset::SpecBench => (351.2, 397.3),
            Dataset::CnnDm => (1036.6, 511.8),
        }
    }

    /// Lognormal parameters fit to Table 3 (see workload::PromptSampler).
    pub fn lognormal(self) -> (f64, f64) {
        let (m, s) = self.prompt_stats();
        lognormal_params_from_mean_std(m, s)
    }

    /// Hidden size of the *paper's* model for this dataset — used only by
    /// the wire-size / delay model (DESIGN.md §3, dual-scale principle).
    pub fn paper_hidden(self) -> usize {
        match self {
            Dataset::SpecBench => 4096, // Vicuna-7B
            Dataset::CnnDm => 5120,     // Vicuna-13B
        }
    }

    /// The paper's per-dataset fixed chunk size for U-Sarathi (§4.1).
    pub fn sarathi_chunk(self) -> usize {
        match self {
            Dataset::SpecBench => 128,
            Dataset::CnnDm => 256,
        }
    }
}

/// Cloud compute-delay model g(B): in-cloud computation delay (ms) of one
/// inference step over a batch of B tokens, through the whole middle
/// submodel (all pipeline stages).
///
/// Calibrated to the paper's preliminary experiments (Fig. 1):
/// - small batches: g(32) ≈ 1.101 · g(1)  (Fig. 1c: "only 10.1% higher");
/// - saturation: beyond ~`sat_tokens` the delay grows linearly
///   (Fig. 1c: "for prompt length more than 512 ... almost linearly");
/// - g(2048) ≈ 280 ms  (Fig. 1b: in-cloud computation 0.28 s at 2k).
#[derive(Debug, Clone, Copy)]
pub struct GModel {
    /// Base step delay at B→0, ms.
    pub base_ms: f64,
    /// Sub-saturation slope, ms/token (GPU fills up, little extra delay).
    pub sub_slope: f64,
    /// Saturation knee, tokens.
    pub sat_tokens: f64,
    /// Post-saturation slope, ms/token.
    pub sat_slope: f64,
}

impl GModel {
    /// Vicuna-7B on A6000 (SpecBench experiments).
    ///
    /// base_ms back-solves the paper's decode round: U-shape TBT ≈ 44 ms
    /// at P=4 (Fig. 6b) minus ~8 ms comm and device time leaves ≈ 25–35 ms
    /// in-cloud per step; Fig. 8's per-GPU 8.4 ms × P=4 agrees.
    pub fn vicuna7b() -> GModel {
        GModel { base_ms: 32.0, sub_slope: 0.08, sat_tokens: 48.0, sat_slope: 0.135 }
    }

    /// Vicuna-13B on A6000 (CNN/DM experiments) — ≈1.85× the 7B cost.
    pub fn vicuna13b() -> GModel {
        GModel { base_ms: 58.0, sub_slope: 0.15, sat_tokens: 40.0, sat_slope: 0.25 }
    }

    pub fn for_dataset(d: Dataset) -> GModel {
        match d {
            Dataset::SpecBench => GModel::vicuna7b(),
            Dataset::CnnDm => GModel::vicuna13b(),
        }
    }

    /// g(B) in ms.
    pub fn eval(&self, batch_tokens: f64) -> f64 {
        let b = batch_tokens.max(0.0);
        self.base_ms + self.sub_slope * b.min(self.sat_tokens)
            + self.sat_slope * (b - self.sat_tokens).max(0.0)
    }
}

/// Cloud configuration.
#[derive(Debug, Clone)]
pub struct CloudConfig {
    /// Pipeline-parallel length P (number of GPUs in the pipeline).
    pub pipeline_len: usize,
    /// Compute model g(·).
    pub g: GModel,
    /// Token budget per inference step (continuous batching cap).
    pub max_batch_tokens: usize,
    /// Moving-average factor α of Eqs. 1–2 (paper: 0.8).
    pub alpha: f64,
}

impl CloudConfig {
    pub fn preset(dataset: Dataset, pipeline_len: usize) -> CloudConfig {
        CloudConfig {
            pipeline_len,
            g: GModel::for_dataset(dataset),
            max_batch_tokens: 2048,
            alpha: 0.8,
        }
    }
}

/// Speculative-decoding configuration (paper §3.4–3.5).
#[derive(Debug, Clone)]
pub struct SpecDecConfig {
    /// Drafting threshold η (Eq. 5; paper: 0.6).
    pub eta: f64,
    /// Hard cap on draft sequence length.
    pub max_draft: usize,
    /// Top-k candidate continuations for parallel drafting (§3.5).
    pub top_k: usize,
    /// Per-request cap on generated tokens accepted by the serving
    /// front-end (`server::parse_line`) — configurable instead of the old
    /// hard-coded 512.
    pub max_new_tokens: usize,
    /// Sampling temperature; 0 (the default) keeps the greedy argmax
    /// paths bit-identical to the pre-sampling code.
    pub temperature: f64,
    /// Sampling top-k (0 = disabled).  Distinct from `top_k`, which is
    /// the §3.5 parallel-drafting candidate fan-out.
    pub top_k_sample: usize,
    /// Nucleus (top-p) sampling mass in (0, 1]; 1 = disabled.
    pub top_p: f64,
    /// Repetition penalty on already-generated tokens (> 0; 1 = off).
    pub rep_penalty: f64,
    /// Session sampling seed: every stochastic draw is derived from
    /// `(seed, context position)`, so same-seed runs are bit-identical.
    pub seed: u64,
    /// How stochastic rounds verify draft tokens (`SampleVerify`).
    pub verify_mode: SampleVerify,
}

impl Default for SpecDecConfig {
    fn default() -> Self {
        // The paper uses η = 0.6 for Vicuna-scale drafters; the tiny
        // model's top-probabilities sit lower (PCFG branching), so the
        // equivalent operating point — measured by sweeping η against
        // accept length (EXPERIMENTS.md §Table 4) — is ≈ 0.35.
        SpecDecConfig {
            eta: 0.35,
            max_draft: 8,
            top_k: 2,
            max_new_tokens: 512,
            temperature: 0.0,
            top_k_sample: 0,
            top_p: 1.0,
            rep_penalty: 1.0,
            seed: 0,
            verify_mode: SampleVerify::Coupled,
        }
    }
}

/// Draft-verification discipline for stochastic (temperature > 0)
/// speculative decoding.  Both are lossless; they differ in *which*
/// equivalence is exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SampleVerify {
    /// Common-random-number coupling: one uniform per position drives
    /// both the draft proposal (inverse-CDF of the draft distribution q)
    /// and the committed token (inverse-CDF of the target distribution
    /// p); a proposal is accepted iff the two coincide.  The committed
    /// stream is *token-identical* to direct seeded sampling from the
    /// target model — the executable losslessness oracle — and is
    /// invariant to round boundaries, draft budgets and chunking.
    Coupled,
    /// Canonical stochastic speculative sampling: accept draft token d
    /// when r <= p(d)/q(d), else resample from norm(max(p - q, 0)).
    /// Preserves the target *distribution* at every position (checked by
    /// the chi-squared/KS harness) but the realized stream depends on
    /// round shape, so only distribution-level oracles apply.
    Rejection,
}

impl SampleVerify {
    pub fn name(self) -> &'static str {
        match self {
            SampleVerify::Coupled => "coupled",
            SampleVerify::Rejection => "rejection",
        }
    }

    pub fn parse(s: &str) -> Option<SampleVerify> {
        match s.to_ascii_lowercase().as_str() {
            "coupled" => Some(SampleVerify::Coupled),
            "rejection" => Some(SampleVerify::Rejection),
            _ => None,
        }
    }
}

/// Slot-admission policy of the serve scheduler: the order in which
/// waiting requests take freed session slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitPolicy {
    /// Arrival order (the original behaviour).
    Fifo,
    /// Shortest-prompt-first, bounded by aging: once the *oldest* waiting
    /// request has waited `ServeConfig::sjf_aging_ms`, it is admitted
    /// next regardless of length, so long prompts cannot starve behind a
    /// stream of short ones.
    Sjf,
}

impl AdmitPolicy {
    pub fn name(self) -> &'static str {
        match self {
            AdmitPolicy::Fifo => "fifo",
            AdmitPolicy::Sjf => "sjf",
        }
    }

    pub fn parse(s: &str) -> Option<AdmitPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(AdmitPolicy::Fifo),
            "sjf" => Some(AdmitPolicy::Sjf),
            _ => None,
        }
    }
}

/// Preemption policy of the serve scheduler under slot pressure
/// (`[serve] priority = "none" | "preempt"`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PriorityMode {
    /// Never preempt: waiting requests wait for a naturally freed slot.
    None,
    /// When every slot is busy and requests are waiting, pause the live
    /// session with the least committed progress: its staged state is
    /// aborted, its KV blocks are swapped out to the host freelist, and it
    /// re-enters the wait queue to be swapped back in and resumed once a
    /// slot frees — instead of being cancelled.  Byte-identity of the
    /// resumed stream to serial `generate()` is preserved (the KV pages
    /// are restored bit-for-bit).
    Preempt,
}

impl PriorityMode {
    pub fn name(self) -> &'static str {
        match self {
            PriorityMode::None => "none",
            PriorityMode::Preempt => "preempt",
        }
    }

    pub fn parse(s: &str) -> Option<PriorityMode> {
        match s.to_ascii_lowercase().as_str() {
            "none" => Some(PriorityMode::None),
            "preempt" => Some(PriorityMode::Preempt),
            _ => None,
        }
    }
}

/// Paged KV-cache configuration (`[kv]`): the block pool backing every
/// stream's skv/akv/mkv caches (see the `kv` module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvConfig {
    /// Rows (token positions) per block.  Must be a multiple of 8 — the
    /// same alignment quantum the chunk optimizer rounds to, so sealed
    /// block boundaries land on chunk-commit boundaries.
    pub block_tokens: usize,
    /// Total physical blocks in the pool, shared by all live sessions.
    pub kv_blocks: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        // 64-token blocks x 512 blocks = 32k pooled rows: comfortably
        // covers the default 8-session serve scheduler on the synthetic
        // model (3 caches x 10 blocks per session) and the workload
        // presets' max-length session floor checked in validate().
        KvConfig { block_tokens: 64, kv_blocks: 512 }
    }
}

/// Real-serving configuration (`hat serve`): the continuous-batching
/// scheduler that interleaves live sessions at chunk/round granularity
/// (server::scheduler).  The Eq. 3 chunk optimizer needs a wire model and
/// a delay predictor; defaults follow the paper's §4.1 testbed.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max sessions the engine worker decodes concurrently
    /// (`--max-sessions`).
    pub max_sessions: usize,
    /// Prefill token budget per scheduler iteration, Sarathi-style
    /// (`--prefill-budget`).
    pub prefill_budget: usize,
    /// Chunk-size bounds for the Eq. 3 optimizer (the upper bound is
    /// additionally clamped to the engine's largest compiled bucket).
    pub min_chunk: usize,
    pub max_chunk: usize,
    /// EWMA factor α for the batched-token-size moving average μ^t (Eq. 1).
    pub alpha: f64,
    /// Pipeline length P assumed by the Eq. 3 optimizer.
    pub pipeline_len: usize,
    /// Hidden-state wire bytes per uploaded token (A in Eq. 3).
    pub a_bytes: f64,
    /// Assumed device uplink bandwidth β_up, bytes/ms.
    pub up_bytes_per_ms: f64,
    /// In-cloud delay predictor g(·) for the optimizer — the *static*
    /// calibration curve, used directly when `learned_g` is off and as the
    /// cold-start fallback when it is on.
    pub g: GModel,
    /// Drive the Eq. 3 optimizer with the learned state-monitor delay
    /// curve g^t(·) (Eq. 2 EWMA over observed iteration delays), falling
    /// back to the static `g` until observations arrive.
    pub learned_g: bool,
    /// Slot-admission policy (`[serve] policy = "fifo" | "sjf"`).
    pub policy: AdmitPolicy,
    /// Aging bound (ms) for the `sjf` policy: the oldest waiting request
    /// is admitted FIFO once it has waited this long, so shortest-first
    /// cannot starve long prompts.  0 degenerates sjf to pure FIFO.
    pub sjf_aging_ms: u64,
    /// Per-request wall-clock deadline (ms, measured from arrival) after
    /// which the scheduler cancels the session with an `ERR deadline`
    /// reply — waiting or live, the request is torn down at the next
    /// iteration boundary.  0 disables deadlines.
    pub deadline_ms: u64,
    /// Preemption policy under slot pressure
    /// (`[serve] priority = "none" | "preempt"`).
    pub priority: PriorityMode,
    /// Prefill-pool slots for disaggregated serving
    /// (`--prefill-workers`).  0 (with `decode_workers = 0`) keeps the
    /// single co-scheduled pool; both must be set together.
    pub prefill_workers: usize,
    /// Decode-pool slots for disaggregated serving (`--decode-workers`).
    pub decode_workers: usize,
    /// Per-connection token-bucket refill rate, GENERATEs per second
    /// (`--rate-limit`); excess requests get `ERR rate limited`.
    /// 0 (the default) disables rate limiting.
    pub rate_limit_rps: f64,
    /// Token-bucket capacity: the burst of GENERATEs a connection may
    /// spend before the `rate_limit_rps` refill gates it.
    pub burst: usize,
    /// Bound on the executor's queued-request depth above which new
    /// GENERATEs are shed with `ERR busy`.
    pub admit_queue: usize,
    /// Bound on a connection's queued-but-unwritten reply lines; a
    /// client exceeding it (a reader that stopped reading) is dropped.
    pub outbox_lines: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_sessions: 8,
            prefill_budget: 256,
            min_chunk: 16,
            max_chunk: 256,
            alpha: 0.8,
            pipeline_len: 4,
            // Paper-scale wire model: f16 elements of a 4096-wide hidden
            // state over a ~56 Mbit/s uplink (§4.1).
            a_bytes: 2.0 * 4096.0,
            up_bytes_per_ms: 7000.0,
            g: GModel::vicuna7b(),
            learned_g: true,
            policy: AdmitPolicy::Fifo,
            sjf_aging_ms: 1000,
            deadline_ms: 0,
            priority: PriorityMode::None,
            prefill_workers: 0,
            decode_workers: 0,
            rate_limit_rps: 0.0,
            burst: 8,
            admit_queue: 1024,
            outbox_lines: 64,
        }
    }
}

/// Which collaborative-inference framework to run (§4.1 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    /// HAT (ours): U-shape + adapter SD + dynamic device-side chunking + PD.
    Hat,
    /// U-shape: plain U-shaped inference.
    UShape,
    /// U-Medusa: U-shape + Medusa heads on device.
    UMedusa,
    /// U-Sarathi: U-shape + server-side fixed-size chunking.
    USarathi,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::Hat => "HAT",
            Framework::UShape => "U-shape",
            Framework::UMedusa => "U-Medusa",
            Framework::USarathi => "U-Sarathi",
        }
    }

    pub fn parse(s: &str) -> Option<Framework> {
        match s.to_ascii_lowercase().as_str() {
            "hat" => Some(Framework::Hat),
            "ushape" | "u-shape" => Some(Framework::UShape),
            "umedusa" | "u-medusa" => Some(Framework::UMedusa),
            "usarathi" | "u-sarathi" => Some(Framework::USarathi),
            _ => None,
        }
    }

    pub fn all() -> [Framework; 4] {
        [Framework::Hat, Framework::USarathi, Framework::UMedusa, Framework::UShape]
    }
}

/// Ablation switches (Table 5): the three key strategies of HAT layered on
/// top of U-shaped inference.
#[derive(Debug, Clone, Copy)]
pub struct Strategies {
    /// Speculative decoding via the adapter draft model.
    pub sd: bool,
    /// Prompt chunking with dynamic chunk-size optimization (Eq. 3).
    pub pc: bool,
    /// Parallel drafting during verification (Eq. 6).
    pub pd: bool,
    /// Medusa-head drafting instead of the adapter (U-Medusa baseline).
    pub medusa: bool,
    /// Server-side fixed chunking (U-Sarathi baseline).
    pub server_chunk: Option<usize>,
}

impl Strategies {
    pub fn for_framework(fw: Framework, dataset: Dataset) -> Strategies {
        match fw {
            Framework::Hat => Strategies { sd: true, pc: true, pd: true, medusa: false, server_chunk: None },
            Framework::UShape => Strategies { sd: false, pc: false, pd: false, medusa: false, server_chunk: None },
            Framework::UMedusa => Strategies { sd: true, pc: false, pd: false, medusa: true, server_chunk: None },
            Framework::USarathi => Strategies {
                sd: false,
                pc: false,
                pd: false,
                medusa: false,
                server_chunk: Some(dataset.sarathi_chunk()),
            },
        }
    }
}

/// Workload configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub dataset: Dataset,
    /// Aggregate request generation rate (requests/s, Poisson — §4.2).
    pub rate: f64,
    pub n_devices: usize,
    /// Total requests to simulate.
    pub n_requests: usize,
    /// Max generation length (paper: 128).
    pub max_new_tokens: usize,
    /// Clamp prompt lengths into [min, max].
    pub min_prompt: usize,
    pub max_prompt: usize,
}

impl WorkloadConfig {
    pub fn preset(dataset: Dataset) -> WorkloadConfig {
        WorkloadConfig {
            dataset,
            rate: match dataset {
                Dataset::SpecBench => 6.0,
                Dataset::CnnDm => 3.0,
            },
            n_devices: 30,
            n_requests: 300,
            max_new_tokens: 128,
            min_prompt: 16,
            max_prompt: 3000,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub framework: Framework,
    pub strategies: Strategies,
    pub workload: WorkloadConfig,
    pub cloud: CloudConfig,
    pub specdec: SpecDecConfig,
    /// Real-serving scheduler settings (`hat serve`).
    pub serve: ServeConfig,
    /// Paged KV block-pool settings (`[kv]`).
    pub kv: KvConfig,
    /// Chunk-size bounds for the Eq. 3 optimizer.
    pub min_chunk: usize,
    pub max_chunk: usize,
}

impl ExperimentConfig {
    pub fn preset(framework: Framework, dataset: Dataset) -> ExperimentConfig {
        ExperimentConfig {
            seed: 42,
            framework,
            strategies: Strategies::for_framework(framework, dataset),
            workload: WorkloadConfig::preset(dataset),
            cloud: CloudConfig::preset(dataset, 4),
            specdec: SpecDecConfig::default(),
            serve: ServeConfig::default(),
            kv: KvConfig::default(),
            min_chunk: 16,
            max_chunk: 512,
        }
    }

    /// Sanity checks; returns a human-readable error list.
    pub fn validate(&self) -> Result<(), Vec<String>> {
        let mut errs = vec![];
        if self.workload.rate <= 0.0 {
            errs.push("workload.rate must be > 0".into());
        }
        if self.workload.n_devices == 0 {
            errs.push("workload.n_devices must be > 0".into());
        }
        if self.workload.n_requests == 0 {
            errs.push("workload.n_requests must be > 0".into());
        }
        if self.workload.max_new_tokens == 0 {
            errs.push("workload.max_new_tokens must be > 0".into());
        }
        if self.cloud.pipeline_len == 0 {
            errs.push("cloud.pipeline_len must be > 0".into());
        }
        if self.cloud.max_batch_tokens == 0 {
            errs.push("cloud.max_batch_tokens must be > 0".into());
        }
        if !(0.0..=1.0).contains(&self.cloud.alpha) {
            errs.push("cloud.alpha must be in [0,1]".into());
        }
        if !(0.0..=1.0).contains(&self.specdec.eta) {
            errs.push("specdec.eta must be in [0,1]".into());
        }
        if self.specdec.max_draft == 0 {
            errs.push("specdec.max_draft must be > 0".into());
        }
        if self.specdec.max_new_tokens == 0 {
            errs.push("specdec.max_new_tokens must be > 0".into());
        }
        if self.specdec.temperature < 0.0 {
            errs.push("specdec.temperature must be >= 0".into());
        }
        if !(self.specdec.top_p > 0.0 && self.specdec.top_p <= 1.0) {
            errs.push("specdec.top_p must be in (0,1]".into());
        }
        if self.specdec.rep_penalty <= 0.0 {
            errs.push("specdec.rep_penalty must be > 0".into());
        }
        if self.min_chunk == 0 || self.min_chunk > self.max_chunk {
            errs.push("chunk bounds invalid".into());
        }
        if self.serve.max_sessions == 0 {
            errs.push("serve.max_sessions must be > 0".into());
        }
        if self.serve.prefill_budget == 0 {
            errs.push("serve.prefill_budget must be > 0".into());
        }
        if (self.serve.prefill_workers == 0) != (self.serve.decode_workers == 0) {
            errs.push(
                "serve.prefill_workers and serve.decode_workers must be set together \
                 (both 0 = single pool, both > 0 = disaggregated)"
                    .into(),
            );
        }
        if self.serve.min_chunk == 0 || self.serve.min_chunk > self.serve.max_chunk {
            errs.push("serve chunk bounds invalid".into());
        }
        if !(0.0..=1.0).contains(&self.serve.alpha) {
            errs.push("serve.alpha must be in [0,1]".into());
        }
        if self.serve.pipeline_len == 0 {
            errs.push("serve.pipeline_len must be > 0".into());
        }
        if !self.serve.rate_limit_rps.is_finite() || self.serve.rate_limit_rps < 0.0 {
            errs.push("serve.rate_limit_rps must be >= 0 (0 disables limiting)".into());
        }
        if self.serve.burst == 0 {
            errs.push("serve.burst must be > 0".into());
        }
        if self.serve.admit_queue == 0 {
            errs.push("serve.admit_queue must be > 0".into());
        }
        if self.serve.outbox_lines == 0 {
            errs.push("serve.outbox_lines must be > 0".into());
        }
        if self.workload.min_prompt > self.workload.max_prompt {
            errs.push("prompt bounds invalid".into());
        }
        if self.kv.block_tokens < 8 || self.kv.block_tokens % 8 != 0 {
            errs.push("kv.block_tokens must be a multiple of 8".into());
        }
        if self.kv.kv_blocks == 0 {
            errs.push("kv.kv_blocks must be > 0".into());
        } else if self.kv.block_tokens * self.kv.kv_blocks
            < 3 * (self.workload.max_prompt + self.workload.max_new_tokens)
        {
            // One session needs three caches (skv/akv/mkv) of up to
            // max_prompt + max_new_tokens rows each; a pool that cannot
            // hold even one such session deadlocks admission.  (The
            // manifest-aware per-cache check lives in kv::KvPool::new.)
            errs.push(format!(
                "kv pool too small: block_tokens x kv_blocks = {} rows cannot hold one \
                 max-length session (3 x {} rows)",
                self.kv.block_tokens * self.kv.kv_blocks,
                self.workload.max_prompt + self.workload.max_new_tokens
            ));
        }
        if errs.is_empty() { Ok(()) } else { Err(errs) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn g_model_matches_fig1_calibration() {
        let g = GModel::vicuna7b();
        // Fig 1c: 32-token prompt only ~10.1% above 1-token.
        let ratio = g.eval(32.0) / g.eval(1.0);
        assert!((1.05..1.15).contains(&ratio), "ratio {ratio}");
        // Fig 1b: in-cloud compute ≈ 0.28 s for 2k-token prompt.
        let g2k = g.eval(2048.0);
        assert!((250.0..310.0).contains(&g2k), "g(2048) = {g2k}");
        // Monotone.
        assert!(g.eval(100.0) < g.eval(200.0));
    }

    #[test]
    fn presets_validate() {
        for fw in Framework::all() {
            for ds in [Dataset::SpecBench, Dataset::CnnDm] {
                ExperimentConfig::preset(fw, ds).validate().unwrap();
            }
        }
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
        c.workload.rate = 0.0;
        c.cloud.pipeline_len = 0;
        c.specdec.eta = 1.5;
        let errs = c.validate().unwrap_err();
        assert_eq!(errs.len(), 3, "{errs:?}");
    }

    #[test]
    fn validation_catches_bad_sampling_values() {
        let mut c = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
        c.specdec.temperature = -0.5;
        c.specdec.top_p = 0.0;
        c.specdec.rep_penalty = 0.0;
        let errs = c.validate().unwrap_err();
        assert_eq!(errs.len(), 3, "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("temperature")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("top_p")), "{errs:?}");
        assert!(errs.iter().any(|e| e.contains("rep_penalty")), "{errs:?}");
    }

    #[test]
    fn validation_catches_bad_kv_values() {
        let mut c = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
        c.kv.block_tokens = 12; // not a multiple of 8
        let errs = c.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.contains("block_tokens")), "{errs:?}");

        let mut c = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
        c.kv.kv_blocks = 0;
        let errs = c.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.contains("kv.kv_blocks")), "{errs:?}");

        let mut c = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
        c.kv.kv_blocks = 4; // 64 x 4 rows << one max-length session
        let errs = c.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.contains("kv pool too small")), "{errs:?}");
    }

    #[test]
    fn framework_strategies_match_baseline_definitions() {
        let hat = Strategies::for_framework(Framework::Hat, Dataset::SpecBench);
        assert!(hat.sd && hat.pc && hat.pd);
        let us = Strategies::for_framework(Framework::USarathi, Dataset::CnnDm);
        assert_eq!(us.server_chunk, Some(256));
        assert!(!us.sd);
        let um = Strategies::for_framework(Framework::UMedusa, Dataset::SpecBench);
        assert!(um.medusa && !um.pc);
    }

    #[test]
    fn dataset_parse_roundtrip() {
        for d in [Dataset::SpecBench, Dataset::CnnDm] {
            assert_eq!(Dataset::parse(d.name()), Some(d));
        }
        assert_eq!(Dataset::parse("nope"), None);
        for f in Framework::all() {
            assert_eq!(Framework::parse(f.name()), Some(f));
        }
        for p in [AdmitPolicy::Fifo, AdmitPolicy::Sjf] {
            assert_eq!(AdmitPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AdmitPolicy::parse("lifo"), None);
        assert_eq!(ServeConfig::default().policy, AdmitPolicy::Fifo);
        assert_eq!(ServeConfig::default().deadline_ms, 0, "deadlines default off");
        for m in [PriorityMode::None, PriorityMode::Preempt] {
            assert_eq!(PriorityMode::parse(m.name()), Some(m));
        }
        assert_eq!(PriorityMode::parse("evict"), None);
        assert_eq!(ServeConfig::default().priority, PriorityMode::None, "preemption defaults off");
        assert_eq!(KvConfig::default(), KvConfig { block_tokens: 64, kv_blocks: 512 });
        for m in [SampleVerify::Coupled, SampleVerify::Rejection] {
            assert_eq!(SampleVerify::parse(m.name()), Some(m));
        }
        assert_eq!(SampleVerify::parse("argmax"), None);
        let sd = SpecDecConfig::default();
        assert_eq!(sd.verify_mode, SampleVerify::Coupled);
        assert_eq!(sd.temperature, 0.0, "sampling defaults off (greedy)");
        assert_eq!((sd.top_k_sample, sd.top_p, sd.rep_penalty, sd.seed), (0, 1.0, 1.0, 0));
    }

    #[test]
    fn cnndm_is_heavier_than_specbench() {
        let g7 = GModel::vicuna7b();
        let g13 = GModel::vicuna13b();
        assert!(g13.eval(100.0) > g7.eval(100.0));
        assert!(Dataset::CnnDm.paper_hidden() > Dataset::SpecBench.paper_hidden());
    }
}
