//! Execution backends: the seam between the HAT protocol layers and
//! whatever actually runs the split-model artifacts.
//!
//! Everything above this module (engine, specdec, server, cli, the fleet
//! simulator) speaks plain [`Tensor`]s and artifact names; everything
//! accelerator-specific lives behind the [`ExecBackend`] trait:
//!
//! - [`reference`] — deterministic pure-Rust backend (default).  Executes
//!   the manifest's artifact shapes with the same bucket/padding/KV
//!   semantics as the real runtime, from seeded pseudo-weights, so the
//!   whole stack — speculative decoding, the TCP server, the fleet
//!   simulator profiles — runs end-to-end on a machine with nothing
//!   installed.  Can synthesize its own manifest when no artifacts exist.
//! - [`pjrt`] (cargo feature `pjrt`) — the real path: AOT HLO artifacts
//!   compiled and executed through the PJRT C API (`xla` crate).
//!
//! Backend choice at runtime: `HAT_BACKEND=reference|pjrt` (default
//! `reference`; `pjrt` requires the feature).
//!
//! ## The `run_batch` contract
//!
//! [`ExecBackend::run_batch`] executes one artifact over a *batch* of
//! independent input sets — the serving scheduler's cross-session verify
//! rounds and prefill chunks:
//!
//! - every item is a full dynamic-input set for the *same* artifact name
//!   (same manifest shapes: callers group work by token bucket first);
//! - item `i`'s outputs land at index `i` of the result, in manifest
//!   output order — exactly what `run(name, &inputs[i])` would return,
//!   bit-for-bit;
//! - items are independent: KV caches and positions are per-item inputs,
//!   so no state leaks between batch lanes;
//! - an empty batch returns an empty vec and touches no counters.
//!
//! Stats accounting: the default implementation loops over
//! [`ExecBackend::run`], so it counts one execution *per item*; a
//! vectorized override (the reference backend) makes one pass over the
//! stacked batch and counts a *single* execution whose
//! [`RuntimeStats::batch_occupancy`] grows by the item count — mean
//! occupancy `batch_occupancy / executions` is the batching win.
//!
//! ## The paged KV contract (`run_paged` / `run_batch_paged`)
//!
//! KV storage lives in `kv::KvCache` block tables, not in per-call dense
//! tensors.  [`ExecBackend::run_paged`] executes one artifact with
//! `inputs` = the manifest's dynamic inputs *minus* KV tensors (KV
//! entries are any `TensorSpec` whose name contains `"kv"` — see
//! [`is_kv`]) and `kvs` = one cache per KV input, in spec order; KV
//! *outputs* pair up with the KV inputs in order, are written through the
//! cache tables, and are dropped from the returned list.  Lanes of
//! [`ExecBackend::run_batch_paged`] are independent, exactly like
//! `run_batch`.
//!
//! The default implementations are a *dense shim*: gather each cache to
//! its dense tensor, call `run`/`run_batch`, and scatter back only the
//! rows the artifact wrote (`[pos, pos + spec.t)`, clipped to `max_seq`)
//! — never the whole tensor, which would sever copy-on-write sharing and
//! void the prefix-sum checkpoints.  Backends that know nothing about
//! paging (PJRT) therefore keep working unchanged; the reference backend
//! overrides both to read/write blocks directly with checkpointed prefix
//! sums (amortized O(block) per step instead of O(position)).

pub mod reference;

#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::{anyhow, bail, Result};

use crate::kv::KvCache;
use crate::runtime::manifest::{ArtifactSpec, Manifest, TensorSpec};

/// A plain host tensor: row-major f32 data plus dims.  Integer inputs
/// (token ids, positions) are carried as exactly-representable f32 values
/// and converted at the backend boundary per the manifest's dtype spec.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("tensor dims {:?} need {} elements, got {}", dims, n, data.len());
        }
        Ok(Tensor { dims, data })
    }

    /// Zero-filled tensor.
    pub fn zeros(dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    /// Rank-0 scalar.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { dims: Vec::new(), data: vec![v] }
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Scalar value (rank-0 / single-element tensors).
    pub fn scalar_value(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("expected scalar tensor, got dims {:?}", self.dims);
        }
        Ok(self.data[0])
    }
}

/// Build an i32-valued tensor of shape [n] from tokens, padding with 0.
pub fn tokens_tensor(tokens: &[u32], n: usize) -> Result<Tensor> {
    if tokens.len() > n {
        bail!("{} tokens > bucket {n}", tokens.len());
    }
    let mut v: Vec<f32> = tokens.iter().map(|&t| t as f32).collect();
    v.resize(n, 0.0);
    Tensor::new(vec![n], v)
}

/// Build an f32 tensor of shape [rows_total, row] from row-major data,
/// zero-padding missing rows.
pub fn f32_tensor_padded(data: &[f32], row: usize, rows_total: usize) -> Result<Tensor> {
    if row == 0 || data.len() % row != 0 {
        bail!("data len {} not a multiple of row width {row}", data.len());
    }
    if data.len() / row > rows_total {
        bail!("{} rows > {rows_total}", data.len() / row);
    }
    let mut v = data.to_vec();
    v.resize(rows_total * row, 0.0);
    Tensor::new(vec![rows_total, row], v)
}

/// Scalar i32 position tensor.
pub fn pos_tensor(pos: usize) -> Tensor {
    Tensor::scalar(pos as f32)
}

/// Zero-filled f32 tensor with the given dims.
pub fn zeros_tensor(dims: &[usize]) -> Tensor {
    Tensor::zeros(dims)
}

/// Extract the f32 data of a tensor.
pub fn to_f32_vec(t: &Tensor) -> Vec<f32> {
    t.data.clone()
}

/// Compile/execute counters shared by all backends (perf harness).
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_ms: f64,
    pub execute_ms: f64,
    /// Input sets processed across all executions: a single `run` counts 1,
    /// a vectorized `run_batch` counts its item count against *one*
    /// execution — so `batch_occupancy / executions` is the mean batch
    /// occupancy (1.0 when nothing batches).
    pub batch_occupancy: usize,
}

impl RuntimeStats {
    /// Mean input sets per execution (1.0 when idle or nothing batched).
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.executions == 0 {
            1.0
        } else {
            self.batch_occupancy as f64 / self.executions as f64
        }
    }
}

/// The execution seam: everything a backend must provide to serve the HAT
/// protocol.  Implementations own their manifest, weights and compiled
/// artifacts; callers thread [`Tensor`]s through named artifacts.
pub trait ExecBackend {
    /// Short backend identifier ("reference", "pjrt").
    fn name(&self) -> &'static str;

    /// The manifest this backend executes.
    fn manifest(&self) -> &Manifest;

    /// Load (or synthesize) the model weights.  Called once by
    /// `ArtifactRegistry::load` before any `run`; must be idempotent.
    fn load_weights(&mut self) -> Result<()>;

    /// Ensure artifact `name` is ready to execute (compile + cache).
    /// `run` compiles lazily on first use; this is the eager entry point.
    fn compile(&self, name: &str) -> Result<()>;

    /// Execute artifact `name` on `inputs` (manifest input order, weights
    /// excluded) and return its outputs in manifest output order.
    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Execute artifact `name` over a batch of independent input sets and
    /// return each item's outputs at the matching index (see the module
    /// docs for the full contract).  The default implementation loops over
    /// [`ExecBackend::run`] — correct for any backend, counting one
    /// execution per item; vectorizing backends override it to make one
    /// pass and count one execution for the whole batch.
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        inputs.iter().map(|item| self.run(name, item)).collect()
    }

    /// Execute artifact `name` against paged KV caches: `inputs` carries
    /// the non-KV dynamic inputs (manifest order with KV entries removed),
    /// `kvs` one cache per KV input in spec order.  KV outputs are applied
    /// to the caches and dropped from the returned list (see the module
    /// docs for the full contract).  The default is the dense shim over
    /// [`ExecBackend::run`]; paged-native backends override it.
    fn run_paged(
        &self,
        name: &str,
        inputs: &[&Tensor],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest()
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let dense: Vec<Tensor> =
            kvs.iter().map(|c| c.gather_dense()).collect::<Result<_>>()?;
        let full = splice_kv_inputs(spec, inputs, &dense)?;
        let outs = self.run(name, &full)?;
        scatter_kv_outputs(spec, inputs, outs, kvs)
    }

    /// Batched [`ExecBackend::run_paged`]: one lane per [`PagedItem`],
    /// independent lanes, outputs at matching indices with KV entries
    /// applied to each lane's caches and dropped.  The default is the
    /// dense shim over [`ExecBackend::run_batch`].
    fn run_batch_paged(
        &self,
        name: &str,
        items: &mut [PagedItem<'_>],
    ) -> Result<Vec<Vec<Tensor>>> {
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let spec = self
            .manifest()
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let dense: Vec<Vec<Tensor>> = items
            .iter()
            .map(|it| it.kvs.iter().map(|c| c.gather_dense()).collect::<Result<Vec<_>>>())
            .collect::<Result<_>>()?;
        let full: Vec<Vec<&Tensor>> = items
            .iter()
            .zip(&dense)
            .map(|(it, ds)| splice_kv_inputs(spec, &it.inputs, ds))
            .collect::<Result<_>>()?;
        let outs = self.run_batch(name, &full)?;
        drop(full);
        items
            .iter_mut()
            .zip(outs)
            .map(|(it, o)| scatter_kv_outputs(spec, &it.inputs, o, &mut it.kvs))
            .collect()
    }

    /// Host copy of a named weight, if the backend materializes it
    /// (used by the privacy audit's inversion attack).
    fn weight(&self, name: &str) -> Option<Tensor>;

    /// Snapshot of the compile/execute counters.
    fn stats(&self) -> RuntimeStats;
}

/// One lane of a [`ExecBackend::run_batch_paged`] call: the lane's non-KV
/// dynamic inputs plus its KV caches (matching the artifact's KV inputs
/// in spec order).
pub struct PagedItem<'a> {
    pub inputs: Vec<&'a Tensor>,
    pub kvs: Vec<&'a mut KvCache>,
}

/// KV tensors are identified by spec name — the `"skv"`/`"akv"`/`"mkv"`
/// manifest convention shared by both backends and the AOT compiler.
pub fn is_kv(spec: &TensorSpec) -> bool {
    spec.name.contains("kv")
}

/// Interleave the caller's non-KV inputs with freshly gathered dense KV
/// tensors, restoring the artifact's full manifest input order.
fn splice_kv_inputs<'t>(
    spec: &ArtifactSpec,
    inputs: &[&'t Tensor],
    dense: &'t [Tensor],
) -> Result<Vec<&'t Tensor>> {
    let mut full = Vec::with_capacity(spec.inputs.len());
    let (mut ki, mut ii) = (0usize, 0usize);
    for ts in &spec.inputs {
        if is_kv(ts) {
            let d = dense.get(ki).ok_or_else(|| {
                anyhow!("artifact {}: only {} KV caches supplied", spec.name, dense.len())
            })?;
            full.push(d);
            ki += 1;
        } else {
            let t = inputs.get(ii).ok_or_else(|| {
                anyhow!("artifact {}: non-KV input '{}' missing", spec.name, ts.name)
            })?;
            full.push(*t);
            ii += 1;
        }
    }
    if ki != dense.len() || ii != inputs.len() {
        bail!(
            "artifact {}: paged input arity mismatch (kv {}/{}, non-kv {}/{})",
            spec.name,
            ki,
            dense.len(),
            ii,
            inputs.len()
        );
    }
    Ok(full)
}

/// The absolute row the artifact writes from: its scalar `pos` input.
fn paged_write_start(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<usize> {
    let mut ii = 0usize;
    for ts in &spec.inputs {
        if is_kv(ts) {
            continue;
        }
        if ts.name == "pos" {
            let t = inputs
                .get(ii)
                .ok_or_else(|| anyhow!("artifact {}: 'pos' input missing", spec.name))?;
            return Ok(t.scalar_value()?.round() as usize);
        }
        ii += 1;
    }
    bail!("artifact {} has KV outputs but no 'pos' input", spec.name)
}

/// Apply a dense run's KV output tensors back to the caches (only the
/// rows the artifact wrote: `[pos, pos + t)`, clipped by the cache) and
/// return the non-KV outputs in manifest order.
fn scatter_kv_outputs(
    spec: &ArtifactSpec,
    inputs: &[&Tensor],
    outs: Vec<Tensor>,
    kvs: &mut [&mut KvCache],
) -> Result<Vec<Tensor>> {
    if outs.len() != spec.outputs.len() {
        bail!(
            "artifact {}: expected {} outputs, got {}",
            spec.name,
            spec.outputs.len(),
            outs.len()
        );
    }
    if !spec.outputs.iter().any(is_kv) {
        return Ok(outs);
    }
    let start = paged_write_start(spec, inputs)?;
    let mut kept = Vec::new();
    let mut ki = 0usize;
    for (t, ts) in outs.into_iter().zip(&spec.outputs) {
        if is_kv(ts) {
            let c = kvs.get_mut(ki).ok_or_else(|| {
                anyhow!("artifact {}: KV output '{}' has no cache", spec.name, ts.name)
            })?;
            c.scatter_rows(&t.data, start, spec.t)?;
            ki += 1;
        } else {
            kept.push(t);
        }
    }
    Ok(kept)
}

/// Paged twin of [`validate_inputs`]: non-KV inputs must match the non-KV
/// specs, and there must be exactly one cache (of matching dense size)
/// per KV input.
pub fn validate_inputs_paged(
    spec: &ArtifactSpec,
    inputs: &[&Tensor],
    kvs: &[&mut KvCache],
) -> Result<()> {
    let (mut ki, mut ii) = (0usize, 0usize);
    for is in &spec.inputs {
        if is_kv(is) {
            let c = kvs
                .get(ki)
                .ok_or_else(|| anyhow!("artifact {}: KV input '{}' has no cache", spec.name, is.name))?;
            let want: usize = is.shape.iter().product();
            let got: usize = c.dims().iter().product();
            if want != got {
                bail!(
                    "artifact {} KV '{}': cache dims {:?} != spec shape {:?}",
                    spec.name,
                    is.name,
                    c.dims(),
                    is.shape
                );
            }
            ki += 1;
        } else {
            let t = inputs.get(ii).ok_or_else(|| {
                anyhow!("artifact {}: missing non-KV input '{}'", spec.name, is.name)
            })?;
            let want: usize = is.shape.iter().product();
            if t.element_count() != want {
                bail!(
                    "artifact {} input '{}': expected shape {:?} ({} elems), got {:?}",
                    spec.name,
                    is.name,
                    is.shape,
                    want,
                    t.dims
                );
            }
            ii += 1;
        }
    }
    if ii != inputs.len() || ki != kvs.len() {
        bail!(
            "artifact {}: paged arity mismatch (kv {}/{}, non-kv {}/{})",
            spec.name,
            ki,
            kvs.len(),
            ii,
            inputs.len()
        );
    }
    Ok(())
}

/// Shared arity/shape validation against the manifest spec.
pub fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "artifact {}: expected {} dynamic inputs, got {}",
            spec.name,
            spec.inputs.len(),
            inputs.len()
        );
    }
    for (t, is) in inputs.iter().zip(&spec.inputs) {
        let want: usize = is.shape.iter().product();
        if t.element_count() != want {
            bail!(
                "artifact {} input '{}': expected shape {:?} ({} elems), got {:?}",
                spec.name,
                is.name,
                is.shape,
                want,
                t.dims
            );
        }
    }
    Ok(())
}

/// Which backend `ArtifactRegistry::load` should construct.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Reference,
    Pjrt,
}

impl BackendKind {
    /// Resolve from the `HAT_BACKEND` env var; the reference backend is
    /// the default so a clean machine runs everything out of the box.
    pub fn from_env() -> Result<BackendKind> {
        match std::env::var("HAT_BACKEND").as_deref() {
            Err(_) | Ok("") | Ok("reference") => Ok(BackendKind::Reference),
            Ok("pjrt") => {
                if cfg!(feature = "pjrt") {
                    Ok(BackendKind::Pjrt)
                } else {
                    Err(anyhow!("HAT_BACKEND=pjrt but the 'pjrt' feature is not compiled in"))
                }
            }
            Ok(other) => Err(anyhow!("unknown HAT_BACKEND '{other}' (reference|pjrt)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_helpers_shapes() {
        let t = tokens_tensor(&[1, 2, 3], 8).unwrap();
        assert_eq!(t.element_count(), 8);
        assert_eq!(t.data[2], 3.0);
        assert_eq!(t.data[5], 0.0);
        let f = f32_tensor_padded(&[1.0, 2.0, 3.0, 4.0], 2, 4).unwrap();
        assert_eq!(f.element_count(), 8);
        assert_eq!(f.dims, vec![4, 2]);
        let z = zeros_tensor(&[2, 3, 4]);
        assert_eq!(z.element_count(), 24);
        assert_eq!(to_f32_vec(&z)[5], 0.0);
        assert_eq!(pos_tensor(7).scalar_value().unwrap(), 7.0);
    }

    #[test]
    fn tensor_rejects_bad_shapes() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(tokens_tensor(&[1, 2, 3], 2).is_err());
        assert!(f32_tensor_padded(&[1.0, 2.0, 3.0], 2, 4).is_err());
        assert!(f32_tensor_padded(&[1.0; 10], 2, 4).is_err());
    }

    #[test]
    fn clone_is_deep() {
        let a = f32_tensor_padded(&[1.0, 2.0], 2, 1).unwrap();
        let mut b = a.clone();
        b.data[0] = 9.0;
        assert_eq!(a.data[0], 1.0);
    }

    #[test]
    fn backend_kind_default_is_reference() {
        // No env var manipulation (tests run in parallel): just check the
        // default resolution path when HAT_BACKEND is unset or empty.
        if std::env::var("HAT_BACKEND").is_err() {
            assert_eq!(BackendKind::from_env().unwrap(), BackendKind::Reference);
        }
    }
}
