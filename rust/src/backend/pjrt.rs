//! PJRT backend (cargo feature `pjrt`): loads the AOT artifacts (HLO text
//! + weights.npz + manifest) and executes them through the PJRT C API
//! (`xla` crate, CPU client).  This is the only module in the crate that
//! may name `xla::` types.
//!
//! Key properties (carried over from the original runtime):
//! - HLO **text** interchange (xla_extension 0.5.1 rejects jax≥0.5's
//!   64-bit-id serialized protos; the text parser reassigns ids);
//! - weights are uploaded once as device-resident `PjRtBuffer`s and shared
//!   by every executable variant (`execute_b` mixes weight buffers with
//!   staged per-call dynamic inputs);
//! - executables are compiled lazily per (kind, token-bucket) on first use
//!   and cached — a fleet simulation only pays for the buckets it touches.
//!
//! Plain [`Tensor`]s cross this boundary; token/position inputs are
//! converted to i32 literals per the manifest's per-input dtype.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use xla::FromRawBytes as _;

use super::{validate_inputs, ExecBackend, RuntimeStats, Tensor};
use crate::runtime::manifest::{Manifest, TensorSpec};

pub struct PjrtBackend {
    manifest: Manifest,
    dir: PathBuf,
    client: xla::PjRtClient,
    /// Weight name -> device-resident buffer.
    weights: HashMap<String, xla::PjRtBuffer>,
    /// Host copies backing the weight buffers.  TFRT-CPU
    /// `BufferFromHostLiteral` copies *asynchronously*: the source literal
    /// must outlive the copy, so we keep them for the backend's lifetime
    /// (declared after `weights` → dropped after the buffers).
    weight_literals: Vec<(String, xla::Literal)>,
    /// Artifact name -> compiled executable (lazy).
    executables: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl PjrtBackend {
    /// Open `dir` (usually `artifacts/`): parse the manifest and create
    /// the CPU client.  Weights are uploaded by `load_weights`.
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtBackend {
            manifest,
            dir: dir.to_path_buf(),
            client,
            weights: HashMap::new(),
            weight_literals: Vec::new(),
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&spec.file);
        let t0 = crate::util::clock::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = std::rc::Rc::new(exe);
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        self.executables.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Convert a host tensor into a literal per the manifest dtype.
    fn to_literal(t: &Tensor, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
        match spec.dtype.as_str() {
            "i32" => {
                let v: Vec<i32> = t.data.iter().map(|&x| x.round() as i32).collect();
                if dims.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(&v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("i32 literal '{}': {e:?}", spec.name))
            }
            _ => {
                if dims.is_empty() {
                    return Ok(xla::Literal::scalar(t.data[0]));
                }
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("f32 literal '{}': {e:?}", spec.name))
            }
        }
    }

    /// Convert an output literal back into a host tensor.
    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<Tensor> {
        let data: Vec<f32> = match spec.dtype.as_str() {
            "i32" => lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("output '{}' to_vec: {e:?}", spec.name))?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            _ => lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output '{}' to_vec: {e:?}", spec.name))?,
        };
        Tensor::new(spec.shape.clone(), data)
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_weights(&mut self) -> Result<()> {
        if !self.weights.is_empty() {
            return Ok(());
        }
        let npz = self.dir.join(&self.manifest.weights_file);
        let literals = xla::Literal::read_npz(&npz, &())
            .map_err(|e| anyhow!("read {}: {e:?}", npz.display()))?;
        for (name, lit) in literals {
            let name = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            let buf = self
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("upload weight {name}: {e:?}"))?;
            self.weights.insert(name.clone(), buf);
            self.weight_literals.push((name, lit));
        }
        for art in &self.manifest.artifacts {
            for w in &art.weights {
                if !self.weights.contains_key(w) {
                    bail!("artifact {} references missing weight {w}", art.name);
                }
            }
        }
        Ok(())
    }

    fn compile(&self, name: &str) -> Result<()> {
        self.executable(name).map(|_| ())
    }

    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        validate_inputs(spec, inputs)?;
        let exe = self.executable(name)?;
        let t0 = crate::util::clock::now();

        // Mixed-input execute: weights are device-resident buffers, dynamic
        // inputs are staged from host literals per call.
        let dynamic: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(t, is)| Self::to_literal(t, is))
            .collect::<Result<_>>()?;
        let staged: Vec<xla::PjRtBuffer> = dynamic
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("stage input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(spec.weights.len() + staged.len());
        for w in &spec.weights {
            args.push(
                self.weights
                    .get(w)
                    .ok_or_else(|| anyhow!("weights not loaded (missing {w})"))?,
            );
        }
        for b in &staged {
            args.push(b);
        }
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output of {name}: {e:?}"))?;
        // Lowered with return_tuple=True: single tuple output.
        let mut lit = lit;
        let outs = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: expected {} outputs, got {}",
                spec.outputs.len(),
                outs.len()
            );
        }
        let tensors: Vec<Tensor> = outs
            .iter()
            .zip(&spec.outputs)
            .map(|(l, os)| Self::from_literal(l, os))
            .collect::<Result<_>>()?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(tensors)
    }

    fn weight(&self, name: &str) -> Option<Tensor> {
        let (_, lit) = self.weight_literals.iter().find(|(n, _)| n == name)?;
        let shape = lit.array_shape().ok()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>().ok()?;
        Tensor::new(dims, data).ok()
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}
