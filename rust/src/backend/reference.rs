//! Deterministic pure-Rust reference backend.
//!
//! Executes the manifest's artifact set — same names, same bucket/padding
//! shapes, same KV-threading contract as the PJRT path — from seeded
//! pseudo-weights, entirely in safe Rust.  Two properties matter:
//!
//! 1. **Determinism**: every value is a pure function of (seed, token,
//!    position, dim), so same-seed runs are bit-identical — the fleet
//!    profiles, the golden-style protocol tests and the metrics pipeline
//!    all reproduce exactly.
//! 2. **KV faithfulness**: each submodel keeps a per-position cache; a row
//!    at position `p` depends only on rows `< p`, so speculative rollback
//!    (rewinding a write head and overwriting the stale tail) behaves
//!    exactly like the real runtime, and chunked prefill is
//!    chunk-size-invariant.
//!
//! The draft path (shallow → adapter Λ → head) intentionally approximates
//! the verify path (shallow → middle → head) with a small position-keyed
//! perturbation, so speculative decoding exhibits realistic partial
//! acceptance instead of degenerate all-or-nothing behaviour.
//!
//! When no artifacts are on disk, [`ReferenceBackend::synthetic`] builds a
//! tiny in-memory manifest (vocab 256, hidden 64, buckets 1..256) so the
//! whole stack runs with zero build steps.

use std::cell::RefCell;
use std::collections::HashSet;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::{
    is_kv, validate_inputs, validate_inputs_paged, ExecBackend, PagedItem, RuntimeStats, Tensor,
};
use crate::kv::KvCache;
use crate::runtime::manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec, TrainMeta};

// Hash-stream tags for the pseudo-weight families.
const TAG_EMBED: u64 = 0xE0BED;
const TAG_POS: u64 = 0x90511;
const TAG_MID: u64 = 0x3D1DD;
const TAG_NOISE: u64 = 0xAD0A7;
const TAG_HEAD: u64 = 0x4EAD0;
const TAG_MEDUSA: u64 = 0x3ED05A00;

/// Logit gain: spreads head outputs so the Eq. 5 top-probability stop rule
/// operates in a realistic regime (neither uniformly tiny nor saturated).
const LOGIT_GAIN: f32 = 6.0;
/// Draft-path perturbation amplitude (controls the acceptance rate).
const DRAFT_NOISE: f32 = 0.25;

fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

pub struct ReferenceBackend {
    manifest: Manifest,
    seed: u64,
    // Pseudo-weight tables, precomputed once so the execute hot paths are
    // pure arithmetic (matters for debug-mode test runs).
    embed: Vec<f32>,       // [V, H]
    pos_noise: Vec<f32>,   // [S, H]
    draft_noise: Vec<f32>, // [S, H]
    mid_bias: Vec<f32>,    // [H]
    head_w: Vec<f32>,      // [V, H]
    medusa_w: Vec<f32>,    // [n_medusa, V, H]
    stats: RefCell<RuntimeStats>,
    compiled: RefCell<HashSet<String>>,
}

impl ReferenceBackend {
    /// Backend over an explicit manifest (weights are synthesized from
    /// `seed`; nothing is read from disk).
    pub fn new(manifest: Manifest, seed: u64) -> ReferenceBackend {
        let m = manifest.model.clone();
        let (v, h, s, n) = (m.vocab, m.hidden, m.max_seq, m.n_medusa);
        let unit = |tag: u64, i: usize, j: usize| -> f32 {
            let k = (i as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (j as u64).wrapping_mul(0xD1342543DE82EF95);
            let z = mix(seed ^ mix(tag) ^ mix(k));
            ((z >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32
        };
        let table = |tag: u64, rows: usize, cols: usize| -> Vec<f32> {
            let mut t = Vec::with_capacity(rows * cols);
            for i in 0..rows {
                for j in 0..cols {
                    t.push(unit(tag, i, j));
                }
            }
            t
        };
        ReferenceBackend {
            embed: table(TAG_EMBED, v, h),
            pos_noise: table(TAG_POS, s, h),
            draft_noise: table(TAG_NOISE, s, h),
            mid_bias: table(TAG_MID, 1, h),
            head_w: table(TAG_HEAD, v, h),
            medusa_w: (0..n).flat_map(|j| table(TAG_MEDUSA + j as u64, v, h)).collect(),
            manifest,
            seed,
            stats: RefCell::new(RuntimeStats::default()),
            compiled: RefCell::new(HashSet::new()),
        }
    }

    /// Backend over `dir/manifest.json` (the artifact files themselves are
    /// not needed — only the shapes).
    pub fn load(dir: &Path, seed: u64) -> Result<ReferenceBackend> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Ok(ReferenceBackend::new(manifest, seed))
    }

    /// Backend over a self-contained synthetic manifest — no files at all.
    pub fn synthetic(seed: u64) -> ReferenceBackend {
        ReferenceBackend::new(synthetic_manifest(), seed)
    }

    /// The pseudo-weight seed this backend was built from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    // -- pseudo-weight model -----------------------------------------------

    fn embed_row(&self, tok: u32, h: usize) -> &[f32] {
        let t = (tok as usize).min(self.manifest.model.vocab - 1);
        &self.embed[t * h..(t + 1) * h]
    }

    /// Shallow submodel, one token at absolute position `p` given the mean
    /// of the previous KV rows.
    fn shallow_core(&self, tok: u32, p: usize, prev_mean: &[f32]) -> Vec<f32> {
        let h = prev_mean.len();
        let e = self.embed_row(tok, h);
        let pn = &self.pos_noise[p * h..(p + 1) * h];
        (0..h)
            .map(|d| (e[d] + 0.8 * prev_mean[d] + 0.3 * pn[d]).tanh())
            .collect()
    }

    /// Middle submodel / adapter Λ shared core over one shallow row.  The
    /// two paths differ only in which KV history feeds `prev_mean` and in
    /// the adapter's extra draft perturbation.
    fn deep_core(&self, s: &[f32], prev_mean: &[f32]) -> Vec<f32> {
        (0..s.len())
            .map(|d| (1.1 * s[d] + 0.7 * prev_mean[d] + 0.1 * self.mid_bias[d]).tanh())
            .collect()
    }

    /// Output head: deep hidden row × weight table [vocab, H] → logits.
    fn head_row(&self, deep: &[f32], w: &[f32], vocab: usize) -> Vec<f32> {
        let h = deep.len();
        let scale = LOGIT_GAIN / (h as f32).sqrt();
        (0..vocab)
            .map(|v| {
                let row = &w[v * h..(v + 1) * h];
                scale * deep.iter().zip(row).map(|(a, b)| a * b).sum::<f32>()
            })
            .collect()
    }

    // -- KV helpers --------------------------------------------------------

    /// Sum of KV rows 0..p (row stride = hidden; rows live in the leading
    /// max_seq×hidden region of the cache tensor, the rest stays zero).
    fn kv_prefix_sum(kv: &[f32], p: usize, h: usize) -> Vec<f32> {
        let mut sum = vec![0.0f32; h];
        for q in 0..p {
            for d in 0..h {
                sum[d] += kv[q * h + d];
            }
        }
        sum
    }

    fn mean_of(sum: &[f32], rows: usize) -> Vec<f32> {
        let n = rows.max(1) as f32;
        sum.iter().map(|&x| x / n).collect()
    }

    /// Strict bound for a single real row (draft step).
    fn check_pos(&self, p: usize, rows: usize) -> Result<()> {
        let s = self.manifest.model.max_seq;
        if p + rows > s {
            bail!("KV position {p}+{rows} exceeds max_seq {s}");
        }
        Ok(())
    }

    /// Start-position bound for bucketed chunk artifacts.  The bucket may
    /// pad past `max_seq` near the end of the context (real tokens are
    /// bounded by the callers; padding rows are sliced off by the engine),
    /// so only the start must be in range — rows beyond `max_seq` are
    /// clipped, mirroring the real runtime's clamped dynamic-update-slice.
    fn check_start(&self, pos: usize) -> Result<()> {
        let s = self.manifest.model.max_seq;
        if pos > s {
            bail!("KV start position {pos} exceeds max_seq {s}");
        }
        Ok(())
    }

    fn pos_of(t: &Tensor) -> Result<usize> {
        Ok(t.scalar_value()?.round() as usize)
    }

    /// The compute core shared by [`ExecBackend::run`] and the vectorized
    /// [`ExecBackend::run_batch`]: one artifact over one validated input
    /// set, no stats accounting.
    fn execute_spec(&self, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let h = self.manifest.model.hidden;
        let v = self.manifest.model.vocab;
        let b = spec.t;

        let outs: Vec<Tensor> = match spec.kind.as_str() {
            "device_input" => {
                // [tokens(b), skv, pos] -> [hidden(b,H), skv']
                let tokens = &inputs[0].data;
                let mut skv = inputs[1].data.clone();
                let pos = Self::pos_of(inputs[2])?;
                self.check_start(pos)?;
                let s_max = self.manifest.model.max_seq;
                let mut sum = Self::kv_prefix_sum(&skv, pos, h);
                let mut hidden = Vec::with_capacity(b * h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        hidden.resize((i + 1) * h, 0.0); // clipped padding row
                        continue;
                    }
                    let tok = tokens[i].round() as u32;
                    let s = self.shallow_core(tok, p, &Self::mean_of(&sum, p));
                    for d in 0..h {
                        skv[p * h + d] = s[d];
                        sum[d] += s[d];
                    }
                    hidden.extend_from_slice(&s);
                }
                vec![
                    Tensor::new(vec![b, h], hidden)?,
                    Tensor::new(inputs[1].dims.clone(), skv)?,
                ]
            }
            "adapter_prefill" => {
                // [hidden(b,H), akv, pos] -> [akv']
                let hidden = &inputs[0].data;
                let mut akv = inputs[1].data.clone();
                let pos = Self::pos_of(inputs[2])?;
                self.check_start(pos)?;
                let s_max = self.manifest.model.max_seq;
                let mut sum = Self::kv_prefix_sum(&akv, pos, h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        continue; // clipped padding row
                    }
                    let a = self.deep_core(&hidden[i * h..(i + 1) * h], &Self::mean_of(&sum, p));
                    for d in 0..h {
                        akv[p * h + d] = a[d];
                        sum[d] += a[d];
                    }
                }
                vec![Tensor::new(inputs[1].dims.clone(), akv)?]
            }
            "cloud_middle" => {
                // [hidden(b,H), mkv, pos] -> [deep(b,H), mkv']
                let hidden = &inputs[0].data;
                let mut mkv = inputs[1].data.clone();
                let pos = Self::pos_of(inputs[2])?;
                self.check_start(pos)?;
                let s_max = self.manifest.model.max_seq;
                let mut sum = Self::kv_prefix_sum(&mkv, pos, h);
                let mut deep = Vec::with_capacity(b * h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        deep.resize((i + 1) * h, 0.0); // clipped padding row
                        continue;
                    }
                    let m = self.deep_core(&hidden[i * h..(i + 1) * h], &Self::mean_of(&sum, p));
                    for d in 0..h {
                        mkv[p * h + d] = m[d];
                        sum[d] += m[d];
                    }
                    deep.extend_from_slice(&m);
                }
                vec![
                    Tensor::new(vec![b, h], deep)?,
                    Tensor::new(inputs[1].dims.clone(), mkv)?,
                ]
            }
            "device_head" => {
                // [deep(b,H)] -> [logits(b,V)]
                let deep = &inputs[0].data;
                let mut logits = Vec::with_capacity(b * v);
                for i in 0..b {
                    logits.extend(self.head_row(&deep[i * h..(i + 1) * h], &self.head_w, v));
                }
                vec![Tensor::new(vec![b, v], logits)?]
            }
            "draft_step" => {
                // [token(1), skv, akv, pos] -> [logits(V), skv', akv', shallow(H)]
                let tok = inputs[0].data[0].round() as u32;
                let mut skv = inputs[1].data.clone();
                let mut akv = inputs[2].data.clone();
                let p = Self::pos_of(inputs[3])?;
                self.check_pos(p, 1)?;
                let ssum = Self::kv_prefix_sum(&skv, p, h);
                let s = self.shallow_core(tok, p, &Self::mean_of(&ssum, p));
                skv[p * h..(p + 1) * h].copy_from_slice(&s);
                let asum = Self::kv_prefix_sum(&akv, p, h);
                let a = self.deep_core(&s, &Self::mean_of(&asum, p));
                akv[p * h..(p + 1) * h].copy_from_slice(&a);
                // Draft deep ≈ verify deep + position-keyed perturbation.
                let dn = &self.draft_noise[p * h..(p + 1) * h];
                let draft_deep: Vec<f32> =
                    (0..h).map(|d| a[d] + DRAFT_NOISE * dn[d]).collect();
                let logits = self.head_row(&draft_deep, &self.head_w, v);
                vec![
                    Tensor::new(vec![v], logits)?,
                    Tensor::new(inputs[1].dims.clone(), skv)?,
                    Tensor::new(inputs[2].dims.clone(), akv)?,
                    Tensor::new(vec![h], s)?,
                ]
            }
            "medusa_decode" => {
                // [deep(1,H)] -> [logits(n_medusa, V)]
                let n = self.manifest.model.n_medusa;
                let deep = &inputs[0].data[..h];
                let mut logits = Vec::with_capacity(n * v);
                for j in 0..n {
                    let w = &self.medusa_w[j * v * h..(j + 1) * v * h];
                    logits.extend(self.head_row(deep, w, v));
                }
                vec![Tensor::new(vec![n, v], logits)?]
            }
            other => bail!("reference backend: unknown artifact kind '{other}'"),
        };

        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, produced {}",
                spec.name,
                spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Paged twin of [`Self::execute_spec`]: KV history is read through the
    /// caches' checkpointed prefix sums and rows are written back through
    /// the block tables (copy-on-write), so no dense KV tensor is ever
    /// materialized.  Bit-identity with the dense path follows from the
    /// prefix-sum contract in [`crate::kv`]: `KvCache::prefix_sum(p)`
    /// reproduces `kv_prefix_sum(dense, p, h)` bit-for-bit, and
    /// `write_row_accumulate` folds each new row into the running sum in
    /// the same order the dense loop does — while decode steps drop from
    /// O(position) to amortized O(block_tokens).
    fn execute_paged(
        &self,
        spec: &ArtifactSpec,
        inputs: &[&Tensor],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Tensor>> {
        let h = self.manifest.model.hidden;
        let v = self.manifest.model.vocab;
        let s_max = self.manifest.model.max_seq;
        let b = spec.t;

        let outs: Vec<Tensor> = match spec.kind.as_str() {
            "device_input" => {
                // inputs [tokens(b), pos], kvs [skv] -> [hidden(b,H)]
                let tokens = &inputs[0].data;
                let pos = Self::pos_of(inputs[1])?;
                self.check_start(pos)?;
                let skv = &mut *kvs[0];
                let mut sum = skv.prefix_sum(pos);
                let mut hidden = Vec::with_capacity(b * h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        hidden.resize((i + 1) * h, 0.0); // clipped padding row
                        continue;
                    }
                    let tok = tokens[i].round() as u32;
                    let s = self.shallow_core(tok, p, &Self::mean_of(&sum, p));
                    skv.write_row_accumulate(p, &s, &mut sum)?;
                    hidden.extend_from_slice(&s);
                }
                vec![Tensor::new(vec![b, h], hidden)?]
            }
            "adapter_prefill" => {
                // inputs [hidden(b,H), pos], kvs [akv] -> []
                let hidden = &inputs[0].data;
                let pos = Self::pos_of(inputs[1])?;
                self.check_start(pos)?;
                let akv = &mut *kvs[0];
                let mut sum = akv.prefix_sum(pos);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        continue; // clipped padding row
                    }
                    let a = self.deep_core(&hidden[i * h..(i + 1) * h], &Self::mean_of(&sum, p));
                    akv.write_row_accumulate(p, &a, &mut sum)?;
                }
                Vec::new()
            }
            "cloud_middle" => {
                // inputs [hidden(b,H), pos], kvs [mkv] -> [deep(b,H)]
                let hidden = &inputs[0].data;
                let pos = Self::pos_of(inputs[1])?;
                self.check_start(pos)?;
                let mkv = &mut *kvs[0];
                let mut sum = mkv.prefix_sum(pos);
                let mut deep = Vec::with_capacity(b * h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        deep.resize((i + 1) * h, 0.0); // clipped padding row
                        continue;
                    }
                    let m = self.deep_core(&hidden[i * h..(i + 1) * h], &Self::mean_of(&sum, p));
                    mkv.write_row_accumulate(p, &m, &mut sum)?;
                    deep.extend_from_slice(&m);
                }
                vec![Tensor::new(vec![b, h], deep)?]
            }
            "draft_step" => {
                // inputs [token(1), pos], kvs [skv, akv] -> [logits(V), shallow(H)]
                let tok = inputs[0].data[0].round() as u32;
                let p = Self::pos_of(inputs[1])?;
                self.check_pos(p, 1)?;
                let (sk, ak) = kvs.split_at_mut(1);
                let skv = &mut *sk[0];
                let akv = &mut *ak[0];
                let mut ssum = skv.prefix_sum(p);
                let s = self.shallow_core(tok, p, &Self::mean_of(&ssum, p));
                skv.write_row_accumulate(p, &s, &mut ssum)?;
                let mut asum = akv.prefix_sum(p);
                let a = self.deep_core(&s, &Self::mean_of(&asum, p));
                akv.write_row_accumulate(p, &a, &mut asum)?;
                // Draft deep ≈ verify deep + position-keyed perturbation.
                let dn = &self.draft_noise[p * h..(p + 1) * h];
                let draft_deep: Vec<f32> =
                    (0..h).map(|d| a[d] + DRAFT_NOISE * dn[d]).collect();
                let logits = self.head_row(&draft_deep, &self.head_w, v);
                vec![Tensor::new(vec![v], logits)?, Tensor::new(vec![h], s)?]
            }
            // Artifacts with no KV tensors run the dense core unchanged.
            "device_head" | "medusa_decode" => self.execute_spec(spec, inputs)?,
            other => bail!("reference backend: unknown artifact kind '{other}'"),
        };

        let want = spec.outputs.iter().filter(|o| !is_kv(o)).count();
        if outs.len() != want {
            bail!(
                "artifact {}: expected {} non-KV outputs, produced {}",
                spec.name,
                want,
                outs.len()
            );
        }
        Ok(outs)
    }
}

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_weights(&mut self) -> Result<()> {
        Ok(()) // pseudo-weights are derived on the fly from the seed
    }

    fn compile(&self, name: &str) -> Result<()> {
        if self.manifest.artifact(name).is_none() {
            bail!("unknown artifact {name}");
        }
        if self.compiled.borrow_mut().insert(name.to_string()) {
            self.stats.borrow_mut().compiles += 1;
        }
        Ok(())
    }

    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        validate_inputs(spec, inputs)?;
        self.compile(name)?;
        let t0 = crate::util::clock::now();
        let outs = self.execute_spec(spec, inputs)?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }

    /// Vectorized batch execution: the batch dimension is stacked as the
    /// outer loop of a single pass (each lane carries its own KV tensors
    /// and position, so lanes stay independent — the `run_batch` contract
    /// in the module docs), validated and timed once, counted as *one*
    /// execution with `batch_occupancy += items`.
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        for item in inputs {
            validate_inputs(spec, item)?;
        }
        self.compile(name)?;
        let t0 = crate::util::clock::now();
        let outs: Vec<Vec<Tensor>> = inputs
            .iter()
            .map(|item| self.execute_spec(spec, item))
            .collect::<Result<_>>()?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += inputs.len();
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }

    /// Paged-native execution: reads KV history through the caches'
    /// checkpointed prefix sums instead of gathering a dense tensor —
    /// same outputs as the dense shim, bit-for-bit, without the O(S·H)
    /// gather/scatter per call.
    fn run_paged(
        &self,
        name: &str,
        inputs: &[&Tensor],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        validate_inputs_paged(spec, inputs, kvs)?;
        self.compile(name)?;
        let t0 = crate::util::clock::now();
        let outs = self.execute_paged(spec, inputs, kvs)?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }

    /// Vectorized paged batch: mirrors [`ExecBackend::run_batch`]'s stats
    /// contract — validated and timed once, one execution,
    /// `batch_occupancy += items`.
    fn run_batch_paged(
        &self,
        name: &str,
        items: &mut [PagedItem<'_>],
    ) -> Result<Vec<Vec<Tensor>>> {
        if items.is_empty() {
            return Ok(Vec::new());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        for it in items.iter() {
            validate_inputs_paged(spec, &it.inputs, &it.kvs)?;
        }
        self.compile(name)?;
        let t0 = crate::util::clock::now();
        let outs: Vec<Vec<Tensor>> = items
            .iter_mut()
            .map(|it| self.execute_paged(spec, &it.inputs, &mut it.kvs))
            .collect::<Result<_>>()?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += outs.len();
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }

    fn weight(&self, name: &str) -> Option<Tensor> {
        let m = &self.manifest.model;
        match name {
            "embed" => {
                Some(Tensor { dims: vec![m.vocab, m.hidden], data: self.embed.clone() })
            }
            "head" => {
                Some(Tensor { dims: vec![m.vocab, m.hidden], data: self.head_w.clone() })
            }
            _ => None,
        }
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}

/// Tiny self-contained manifest: same artifact naming scheme as
/// `python/compile/aot.py` (kind_bucket), buckets 1..256, vocab 256,
/// hidden 64 — small enough that everything is fast, big enough that the
/// protocol paths (bucket selection, padding, chunking) are exercised.
pub fn synthetic_manifest() -> Manifest {
    let model = ModelSpec {
        vocab: 256,
        hidden: 64,
        layers: 4,
        shallow_layers: 1,
        heads: 4,
        head_dim: 16,
        ffn: 128,
        max_seq: 640,
        n_medusa: 4,
    };
    let buckets = vec![1usize, 4, 16, 64, 256];
    let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.into(),
        shape,
        dtype: "f32".into(),
    };
    let i32s = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.into(),
        shape,
        dtype: "i32".into(),
    };
    let mut artifacts = Vec::new();
    for &b in &buckets {
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("device_input", b),
            kind: "device_input".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![
                i32s("tokens", vec![b]),
                f32s("skv", model.shallow_kv_dims()),
                i32s("pos", vec![]),
            ],
            outputs: vec![
                f32s("hidden", vec![b, model.hidden]),
                f32s("skv", model.shallow_kv_dims()),
            ],
        });
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("adapter_prefill", b),
            kind: "adapter_prefill".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![
                f32s("hidden", vec![b, model.hidden]),
                f32s("akv", model.adapter_kv_dims()),
                i32s("pos", vec![]),
            ],
            outputs: vec![f32s("akv", model.adapter_kv_dims())],
        });
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("cloud_middle", b),
            kind: "cloud_middle".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![
                f32s("hidden", vec![b, model.hidden]),
                f32s("mkv", model.middle_kv_dims()),
                i32s("pos", vec![]),
            ],
            outputs: vec![
                f32s("deep", vec![b, model.hidden]),
                f32s("mkv", model.middle_kv_dims()),
            ],
        });
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("device_head", b),
            kind: "device_head".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![f32s("deep", vec![b, model.hidden])],
            outputs: vec![f32s("logits", vec![b, model.vocab])],
        });
    }
    artifacts.push(ArtifactSpec {
        name: "draft_step_1".into(),
        kind: "draft_step".into(),
        t: 1,
        file: String::new(),
        weights: Vec::new(),
        inputs: vec![
            i32s("token", vec![1]),
            f32s("skv", model.shallow_kv_dims()),
            f32s("akv", model.adapter_kv_dims()),
            i32s("pos", vec![]),
        ],
        outputs: vec![
            f32s("logits", vec![model.vocab]),
            f32s("skv", model.shallow_kv_dims()),
            f32s("akv", model.adapter_kv_dims()),
            f32s("shallow", vec![model.hidden]),
        ],
    });
    artifacts.push(ArtifactSpec {
        name: "medusa_decode_1".into(),
        kind: "medusa_decode".into(),
        t: 1,
        file: String::new(),
        weights: Vec::new(),
        inputs: vec![f32s("deep", vec![1, model.hidden])],
        outputs: vec![f32s("logits", vec![model.n_medusa, model.vocab])],
    });
    Manifest {
        model,
        buckets,
        weights_file: "synthetic".into(),
        prompts_file: "synthetic".into(),
        artifacts,
        train_meta: TrainMeta {
            accept_length_probe: 0.0,
            lm_params: 500_000,
            adapter_params: 20_000,
            medusa_params: 120_000,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{pos_tensor, tokens_tensor, zeros_tensor};

    fn backend() -> ReferenceBackend {
        ReferenceBackend::synthetic(42)
    }

    #[test]
    fn synthetic_manifest_is_complete() {
        let m = synthetic_manifest();
        assert_eq!(m.artifacts.len(), 4 * m.buckets.len() + 2);
        for kind in ["device_input", "cloud_middle", "device_head", "adapter_prefill"] {
            for &b in &m.buckets {
                assert!(m.artifact(&Manifest::artifact_name(kind, b)).is_some());
            }
        }
        assert!(m.artifact("draft_step_1").is_some());
        assert!(m.artifact("medusa_decode_1").is_some());
        assert_eq!(m.model.heads * m.model.head_dim, m.model.hidden);
    }

    #[test]
    fn device_input_threads_kv_and_is_deterministic() {
        let be = backend();
        let h = be.manifest().model.hidden;
        let skv = zeros_tensor(&be.manifest().model.shallow_kv_dims());
        let toks = tokens_tensor(&[3, 5, 7], 4).unwrap();
        let o1 = be.run("device_input_4", &[&toks, &skv, &pos_tensor(0)]).unwrap();
        let o2 = be.run("device_input_4", &[&toks, &skv, &pos_tensor(0)]).unwrap();
        assert_eq!(o1[0], o2[0], "same inputs must give bit-identical outputs");
        assert_eq!(o1[0].dims, vec![4, h]);
        // KV rows 0..4 were written, row 4 untouched.
        let kv = &o1[1].data;
        assert!(kv[..4 * h].iter().any(|&x| x != 0.0));
        assert!(kv[4 * h..5 * h].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn position_masking_ignores_stale_tail() {
        // Writing garbage beyond position p must not affect a row computed
        // at p — the invariant speculative rollback relies on.
        let be = backend();
        let h = be.manifest().model.hidden;
        let skv = zeros_tensor(&be.manifest().model.shallow_kv_dims());
        let toks = tokens_tensor(&[9], 1).unwrap();
        let clean = be.run("device_input_1", &[&toks, &skv, &pos_tensor(2)]).unwrap();
        let mut dirty = skv.clone();
        for d in 0..h {
            dirty.data[3 * h + d] = 123.0; // stale row past the write head
        }
        let with_stale = be.run("device_input_1", &[&toks, &dirty, &pos_tensor(2)]).unwrap();
        assert_eq!(clean[0], with_stale[0]);
    }

    #[test]
    fn bucket_padding_past_max_seq_is_clipped() {
        // A chunk whose *bucket* pads past max_seq must not error or write
        // out of the KV region — only the start position is bounded; the
        // padded tail rows are clipped (they are sliced off by the engine).
        let be = backend();
        let m = be.manifest().model.clone();
        let h = m.hidden;
        let skv = zeros_tensor(&m.shallow_kv_dims());
        let toks = tokens_tensor(&[7], 4).unwrap();
        let pos = m.max_seq - 2; // bucket rows land on S-2, S-1, S, S+1
        let outs = be.run("device_input_4", &[&toks, &skv, &pos_tensor(pos)]).unwrap();
        assert_eq!(outs[0].element_count(), 4 * h);
        assert!(outs[0].data[2 * h..].iter().all(|&x| x == 0.0), "clipped rows are zero");
        assert!(outs[1].data[pos * h..(pos + 1) * h].iter().any(|&x| x != 0.0));
        // A start position beyond max_seq is still an error.
        let far = pos_tensor(m.max_seq + 1);
        assert!(be.run("device_input_4", &[&toks, &skv, &far]).is_err());
    }

    #[test]
    fn head_is_zero_on_zero_hidden() {
        let be = backend();
        let m = be.manifest().model.clone();
        let deep = zeros_tensor(&[1, m.hidden]);
        let outs = be.run("device_head_1", &[&deep]).unwrap();
        assert_eq!(outs[0].element_count(), m.vocab);
        assert!(outs[0].data.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn rejects_wrong_arity_and_unknown() {
        let be = backend();
        assert!(be.run("device_head_1", &[]).is_err());
        assert!(be.run("nonexistent", &[]).is_err());
        let bad = zeros_tensor(&[3, 3]);
        assert!(be.run("device_head_1", &[&bad]).is_err());
    }

    #[test]
    fn embed_weight_rows_are_distinct() {
        let be = backend();
        let w = be.weight("embed").unwrap();
        let m = be.manifest().model.clone();
        assert_eq!(w.dims, vec![m.vocab, m.hidden]);
        assert_ne!(w.data[..m.hidden], w.data[m.hidden..2 * m.hidden]);
        assert!(be.weight("nope").is_none());
    }

    #[test]
    fn stats_count_compiles_once_per_artifact() {
        let be = backend();
        let deep = zeros_tensor(&[1, be.manifest().model.hidden]);
        be.run("device_head_1", &[&deep]).unwrap();
        be.run("device_head_1", &[&deep]).unwrap();
        let s = be.stats();
        assert_eq!(s.compiles, 1);
        assert_eq!(s.executions, 2);
        assert_eq!(s.batch_occupancy, 2);
        assert_eq!(s.mean_batch_occupancy(), 1.0);
    }

    #[test]
    fn run_batch_matches_per_item_run_bitwise() {
        // The run_batch contract: item i's outputs are exactly what
        // run(name, &inputs[i]) returns, KV lanes independent.
        let be = backend();
        let m = be.manifest().model.clone();
        let h = m.hidden;
        // Two lanes with *different* KV histories and positions.
        let toks_a = tokens_tensor(&[3, 5, 7], 4).unwrap();
        let toks_b = tokens_tensor(&[9], 4).unwrap();
        let kv_a = zeros_tensor(&m.shallow_kv_dims());
        let mut kv_b = zeros_tensor(&m.shallow_kv_dims());
        for d in 0..h {
            kv_b.data[d] = 0.25; // lane B attends a non-zero row 0
        }
        let (pos_a, pos_b) = (pos_tensor(0), pos_tensor(1));
        let serial_a = be.run("device_input_4", &[&toks_a, &kv_a, &pos_a]).unwrap();
        let serial_b = be.run("device_input_4", &[&toks_b, &kv_b, &pos_b]).unwrap();
        let batched = be
            .run_batch(
                "device_input_4",
                &[vec![&toks_a, &kv_a, &pos_a], vec![&toks_b, &kv_b, &pos_b]],
            )
            .unwrap();
        assert_eq!(batched.len(), 2);
        assert_eq!(batched[0], serial_a, "lane A diverged from serial run");
        assert_eq!(batched[1], serial_b, "lane B diverged from serial run");
    }

    #[test]
    fn run_batch_counts_one_execution_with_full_occupancy() {
        let be = backend();
        let deep = zeros_tensor(&[1, be.manifest().model.hidden]);
        let items: Vec<Vec<&Tensor>> = (0..3).map(|_| vec![&deep]).collect();
        be.run_batch("device_head_1", &items).unwrap();
        let s = be.stats();
        assert_eq!(s.executions, 1, "a batch is one execution");
        assert_eq!(s.batch_occupancy, 3);
        assert_eq!(s.compiles, 1);
        assert_eq!(s.mean_batch_occupancy(), 3.0);
    }

    #[test]
    fn run_batch_empty_and_invalid_items() {
        let be = backend();
        assert!(be.run_batch("device_head_1", &[]).unwrap().is_empty());
        assert_eq!(be.stats().executions, 0, "empty batch touches no counters");
        let bad = zeros_tensor(&[3, 3]);
        assert!(be.run_batch("device_head_1", &[vec![&bad]]).is_err());
        assert!(be.run_batch("nonexistent", &[vec![&bad]]).is_err());
    }

    // -- paged KV path -----------------------------------------------------

    use crate::config::KvConfig;
    use crate::kv::{KvCache, KvPool};

    fn paged_caches(be: &ReferenceBackend) -> (KvPool, KvCache, KvCache, KvCache) {
        let m = be.manifest().model.clone();
        let pool =
            KvPool::new(&KvConfig { block_tokens: 8, kv_blocks: 512 }, m.hidden, m.max_seq)
                .unwrap();
        let skv = pool.new_cache(m.shallow_kv_dims(), m.max_seq);
        let akv = pool.new_cache(m.adapter_kv_dims(), m.max_seq);
        let mkv = pool.new_cache(m.middle_kv_dims(), m.max_seq);
        (pool, skv, akv, mkv)
    }

    /// The satellite-1 equivalence oracle: the paged path (incremental
    /// checkpointed prefix sums) must be bit-identical to the dense path
    /// (O(p·h) recomputation) through prefill, decode *and* speculative
    /// overwrite of a stale tail.
    #[test]
    fn paged_matches_dense_bitwise_through_prefill_decode_and_rollback() {
        let be = backend();
        let m = be.manifest().model.clone();
        let (_pool, mut skv, mut akv, mut mkv) = paged_caches(&be);
        let mut d_skv = zeros_tensor(&m.shallow_kv_dims());
        let mut d_akv = zeros_tensor(&m.adapter_kv_dims());
        let mut d_mkv = zeros_tensor(&m.middle_kv_dims());

        // Prefill chunk of 16 tokens at position 0.
        let toks: Vec<u32> = (0..16).map(|i| (i * 7 + 3) as u32).collect();
        let tt = tokens_tensor(&toks, 16).unwrap();
        let p0 = pos_tensor(0);
        let dense = be.run("device_input_16", &[&tt, &d_skv, &p0]).unwrap();
        let paged = be.run_paged("device_input_16", &[&tt, &p0], &mut [&mut skv]).unwrap();
        assert_eq!(paged.len(), 1, "KV output is applied to the cache, not returned");
        assert_eq!(paged[0], dense[0], "hidden rows");
        d_skv = dense[1].clone();
        assert_eq!(skv.gather_dense().unwrap(), d_skv, "skv after prefill");
        let hidden = dense[0].clone();

        let dense_a = be.run("adapter_prefill_16", &[&hidden, &d_akv, &p0]).unwrap();
        let paged_a =
            be.run_paged("adapter_prefill_16", &[&hidden, &p0], &mut [&mut akv]).unwrap();
        assert!(paged_a.is_empty(), "adapter_prefill has only a KV output");
        d_akv = dense_a[0].clone();
        assert_eq!(akv.gather_dense().unwrap(), d_akv, "akv after prefill");

        let dense_m = be.run("cloud_middle_16", &[&hidden, &d_mkv, &p0]).unwrap();
        let paged_m = be.run_paged("cloud_middle_16", &[&hidden, &p0], &mut [&mut mkv]).unwrap();
        assert_eq!(paged_m[0], dense_m[0], "deep rows");
        d_mkv = dense_m[1].clone();
        assert_eq!(mkv.gather_dense().unwrap(), d_mkv, "mkv after prefill");

        // Decode: draft steps crossing a block boundary (bt=8, rows 16..25).
        for p in 16..26 {
            let t1 = tokens_tensor(&[(p * 11 % 256) as u32], 1).unwrap();
            let pp = pos_tensor(p);
            let dense_d = be.run("draft_step_1", &[&t1, &d_skv, &d_akv, &pp]).unwrap();
            let paged_d = be
                .run_paged("draft_step_1", &[&t1, &pp], &mut [&mut skv, &mut akv])
                .unwrap();
            assert_eq!(paged_d.len(), 2);
            assert_eq!(paged_d[0], dense_d[0], "draft logits at {p}");
            assert_eq!(paged_d[1], dense_d[3], "shallow row at {p}");
            d_skv = dense_d[1].clone();
            d_akv = dense_d[2].clone();
        }
        assert_eq!(skv.gather_dense().unwrap(), d_skv, "skv after decode");
        assert_eq!(akv.gather_dense().unwrap(), d_akv, "akv after decode");

        // Speculative rollback: a verify chunk overwrites the drafted tail
        // (invalidates checkpoints past row 16, still bit-identical).
        let vt = tokens_tensor(&[9, 8], 4).unwrap();
        let vp = pos_tensor(16);
        let dense_v = be.run("device_input_4", &[&vt, &d_skv, &vp]).unwrap();
        let paged_v = be.run_paged("device_input_4", &[&vt, &vp], &mut [&mut skv]).unwrap();
        assert_eq!(paged_v[0], dense_v[0], "verify hidden after overwrite");
        assert_eq!(skv.gather_dense().unwrap(), dense_v[1], "skv after overwrite");
    }

    /// Wrapper that deliberately does NOT override the paged methods, so
    /// the trait's dense-shim defaults run — they must agree bit-for-bit
    /// with the paged-native path.
    struct ShimOnly(ReferenceBackend);

    impl ExecBackend for ShimOnly {
        fn name(&self) -> &'static str {
            "shim"
        }
        fn manifest(&self) -> &Manifest {
            self.0.manifest()
        }
        fn load_weights(&mut self) -> Result<()> {
            Ok(())
        }
        fn compile(&self, name: &str) -> Result<()> {
            self.0.compile(name)
        }
        fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
            self.0.run(name, inputs)
        }
        fn weight(&self, name: &str) -> Option<Tensor> {
            self.0.weight(name)
        }
        fn stats(&self) -> RuntimeStats {
            self.0.stats()
        }
    }

    #[test]
    fn dense_shim_default_matches_paged_native_bitwise() {
        let native = backend();
        let shim = ShimOnly(backend());
        let (_pn, mut n_skv, mut n_akv, _nm) = paged_caches(&native);
        let (_ps, mut s_skv, mut s_akv, _sm) = paged_caches(&native);

        let toks: Vec<u32> = (0..7).map(|i| (i * 13 + 1) as u32).collect();
        let tt = tokens_tensor(&toks, 16).unwrap();
        let p0 = pos_tensor(0);
        let n1 = native.run_paged("device_input_16", &[&tt, &p0], &mut [&mut n_skv]).unwrap();
        let s1 = shim.run_paged("device_input_16", &[&tt, &p0], &mut [&mut s_skv]).unwrap();
        assert_eq!(n1, s1, "prefill hidden");

        for p in 7..10 {
            let t1 = tokens_tensor(&[(p * 3) as u32], 1).unwrap();
            let pp = pos_tensor(p);
            let n = native
                .run_paged("draft_step_1", &[&t1, &pp], &mut [&mut n_skv, &mut n_akv])
                .unwrap();
            let s = shim
                .run_paged("draft_step_1", &[&t1, &pp], &mut [&mut s_skv, &mut s_akv])
                .unwrap();
            assert_eq!(n, s, "draft outputs at {p}");
        }
        assert_eq!(
            n_skv.gather_dense().unwrap(),
            s_skv.gather_dense().unwrap(),
            "skv state native vs shim"
        );
        assert_eq!(
            n_akv.gather_dense().unwrap(),
            s_akv.gather_dense().unwrap(),
            "akv state native vs shim"
        );
    }

    #[test]
    fn run_batch_paged_matches_serial_and_counts_one_execution() {
        let be = backend();
        let m = be.manifest().model.clone();
        let (pool, mut a, _akv, _mkv) = paged_caches(&be);
        let mut b = pool.new_cache(m.shallow_kv_dims(), m.max_seq);
        // Give lane B a different history (one row at position 0).
        let seed_t = tokens_tensor(&[42], 1).unwrap();
        be.run_paged("device_input_1", &[&seed_t, &pos_tensor(0)], &mut [&mut b]).unwrap();

        // Serial oracle on copy-on-write forks of the same caches.
        let (mut a2, mut b2) = (a.fork(), b.fork());
        let ta = tokens_tensor(&[3, 5, 7], 4).unwrap();
        let tb = tokens_tensor(&[9], 4).unwrap();
        let (pa, pb) = (pos_tensor(0), pos_tensor(1));
        let sa = be.run_paged("device_input_4", &[&ta, &pa], &mut [&mut a2]).unwrap();
        let sb = be.run_paged("device_input_4", &[&tb, &pb], &mut [&mut b2]).unwrap();

        let before = be.stats().executions;
        let mut items = vec![
            PagedItem { inputs: vec![&ta, &pa], kvs: vec![&mut a] },
            PagedItem { inputs: vec![&tb, &pb], kvs: vec![&mut b] },
        ];
        let outs = be.run_batch_paged("device_input_4", &mut items).unwrap();
        drop(items);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0], sa, "lane A diverged from serial paged run");
        assert_eq!(outs[1], sb, "lane B diverged from serial paged run");
        assert_eq!(a.gather_dense().unwrap(), a2.gather_dense().unwrap());
        assert_eq!(b.gather_dense().unwrap(), b2.gather_dense().unwrap());
        let s = be.stats();
        assert_eq!(s.executions, before + 1, "a paged batch is one execution");
        assert!(be.run_batch_paged("device_input_4", &mut []).unwrap().is_empty());
    }

    #[test]
    fn run_paged_rejects_bad_arity() {
        let be = backend();
        let (_pool, mut skv, _akv, _mkv) = paged_caches(&be);
        let tt = tokens_tensor(&[1], 1).unwrap();
        let p0 = pos_tensor(0);
        // Missing cache.
        assert!(be.run_paged("device_input_1", &[&tt, &p0], &mut []).is_err());
        // Missing non-KV input.
        assert!(be.run_paged("device_input_1", &[&tt], &mut [&mut skv]).is_err());
        // Dense KV tensor passed where the cache should be (extra input).
        let dense = zeros_tensor(&be.manifest().model.shallow_kv_dims());
        assert!(be
            .run_paged("device_input_1", &[&tt, &dense, &p0], &mut [&mut skv])
            .is_err());
        assert!(be.run_paged("nonexistent", &[], &mut []).is_err());
    }
}
