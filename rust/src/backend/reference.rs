//! Deterministic pure-Rust reference backend.
//!
//! Executes the manifest's artifact set — same names, same bucket/padding
//! shapes, same KV-threading contract as the PJRT path — from seeded
//! pseudo-weights, entirely in safe Rust.  Two properties matter:
//!
//! 1. **Determinism**: every value is a pure function of (seed, token,
//!    position, dim), so same-seed runs are bit-identical — the fleet
//!    profiles, the golden-style protocol tests and the metrics pipeline
//!    all reproduce exactly.
//! 2. **KV faithfulness**: each submodel keeps a per-position cache; a row
//!    at position `p` depends only on rows `< p`, so speculative rollback
//!    (rewinding a write head and overwriting the stale tail) behaves
//!    exactly like the real runtime, and chunked prefill is
//!    chunk-size-invariant.
//!
//! The draft path (shallow → adapter Λ → head) intentionally approximates
//! the verify path (shallow → middle → head) with a small position-keyed
//! perturbation, so speculative decoding exhibits realistic partial
//! acceptance instead of degenerate all-or-nothing behaviour.
//!
//! When no artifacts are on disk, [`ReferenceBackend::synthetic`] builds a
//! tiny in-memory manifest (vocab 256, hidden 64, buckets 1..256) so the
//! whole stack runs with zero build steps.

use std::cell::RefCell;
use std::collections::HashSet;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::{validate_inputs, ExecBackend, RuntimeStats, Tensor};
use crate::runtime::manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec, TrainMeta};

// Hash-stream tags for the pseudo-weight families.
const TAG_EMBED: u64 = 0xE0BED;
const TAG_POS: u64 = 0x90511;
const TAG_MID: u64 = 0x3D1DD;
const TAG_NOISE: u64 = 0xAD0A7;
const TAG_HEAD: u64 = 0x4EAD0;
const TAG_MEDUSA: u64 = 0x3ED05A00;

/// Logit gain: spreads head outputs so the Eq. 5 top-probability stop rule
/// operates in a realistic regime (neither uniformly tiny nor saturated).
const LOGIT_GAIN: f32 = 6.0;
/// Draft-path perturbation amplitude (controls the acceptance rate).
const DRAFT_NOISE: f32 = 0.25;

fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

pub struct ReferenceBackend {
    manifest: Manifest,
    seed: u64,
    // Pseudo-weight tables, precomputed once so the execute hot paths are
    // pure arithmetic (matters for debug-mode test runs).
    embed: Vec<f32>,       // [V, H]
    pos_noise: Vec<f32>,   // [S, H]
    draft_noise: Vec<f32>, // [S, H]
    mid_bias: Vec<f32>,    // [H]
    head_w: Vec<f32>,      // [V, H]
    medusa_w: Vec<f32>,    // [n_medusa, V, H]
    stats: RefCell<RuntimeStats>,
    compiled: RefCell<HashSet<String>>,
}

impl ReferenceBackend {
    /// Backend over an explicit manifest (weights are synthesized from
    /// `seed`; nothing is read from disk).
    pub fn new(manifest: Manifest, seed: u64) -> ReferenceBackend {
        let m = manifest.model.clone();
        let (v, h, s, n) = (m.vocab, m.hidden, m.max_seq, m.n_medusa);
        let unit = |tag: u64, i: usize, j: usize| -> f32 {
            let k = (i as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (j as u64).wrapping_mul(0xD1342543DE82EF95);
            let z = mix(seed ^ mix(tag) ^ mix(k));
            ((z >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32
        };
        let table = |tag: u64, rows: usize, cols: usize| -> Vec<f32> {
            let mut t = Vec::with_capacity(rows * cols);
            for i in 0..rows {
                for j in 0..cols {
                    t.push(unit(tag, i, j));
                }
            }
            t
        };
        ReferenceBackend {
            embed: table(TAG_EMBED, v, h),
            pos_noise: table(TAG_POS, s, h),
            draft_noise: table(TAG_NOISE, s, h),
            mid_bias: table(TAG_MID, 1, h),
            head_w: table(TAG_HEAD, v, h),
            medusa_w: (0..n).flat_map(|j| table(TAG_MEDUSA + j as u64, v, h)).collect(),
            manifest,
            seed,
            stats: RefCell::new(RuntimeStats::default()),
            compiled: RefCell::new(HashSet::new()),
        }
    }

    /// Backend over `dir/manifest.json` (the artifact files themselves are
    /// not needed — only the shapes).
    pub fn load(dir: &Path, seed: u64) -> Result<ReferenceBackend> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        Ok(ReferenceBackend::new(manifest, seed))
    }

    /// Backend over a self-contained synthetic manifest — no files at all.
    pub fn synthetic(seed: u64) -> ReferenceBackend {
        ReferenceBackend::new(synthetic_manifest(), seed)
    }

    /// The pseudo-weight seed this backend was built from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    // -- pseudo-weight model -----------------------------------------------

    fn embed_row(&self, tok: u32, h: usize) -> &[f32] {
        let t = (tok as usize).min(self.manifest.model.vocab - 1);
        &self.embed[t * h..(t + 1) * h]
    }

    /// Shallow submodel, one token at absolute position `p` given the mean
    /// of the previous KV rows.
    fn shallow_core(&self, tok: u32, p: usize, prev_mean: &[f32]) -> Vec<f32> {
        let h = prev_mean.len();
        let e = self.embed_row(tok, h);
        let pn = &self.pos_noise[p * h..(p + 1) * h];
        (0..h)
            .map(|d| (e[d] + 0.8 * prev_mean[d] + 0.3 * pn[d]).tanh())
            .collect()
    }

    /// Middle submodel / adapter Λ shared core over one shallow row.  The
    /// two paths differ only in which KV history feeds `prev_mean` and in
    /// the adapter's extra draft perturbation.
    fn deep_core(&self, s: &[f32], prev_mean: &[f32]) -> Vec<f32> {
        (0..s.len())
            .map(|d| (1.1 * s[d] + 0.7 * prev_mean[d] + 0.1 * self.mid_bias[d]).tanh())
            .collect()
    }

    /// Output head: deep hidden row × weight table [vocab, H] → logits.
    fn head_row(&self, deep: &[f32], w: &[f32], vocab: usize) -> Vec<f32> {
        let h = deep.len();
        let scale = LOGIT_GAIN / (h as f32).sqrt();
        (0..vocab)
            .map(|v| {
                let row = &w[v * h..(v + 1) * h];
                scale * deep.iter().zip(row).map(|(a, b)| a * b).sum::<f32>()
            })
            .collect()
    }

    // -- KV helpers --------------------------------------------------------

    /// Sum of KV rows 0..p (row stride = hidden; rows live in the leading
    /// max_seq×hidden region of the cache tensor, the rest stays zero).
    fn kv_prefix_sum(kv: &[f32], p: usize, h: usize) -> Vec<f32> {
        let mut sum = vec![0.0f32; h];
        for q in 0..p {
            for d in 0..h {
                sum[d] += kv[q * h + d];
            }
        }
        sum
    }

    fn mean_of(sum: &[f32], rows: usize) -> Vec<f32> {
        let n = rows.max(1) as f32;
        sum.iter().map(|&x| x / n).collect()
    }

    /// Strict bound for a single real row (draft step).
    fn check_pos(&self, p: usize, rows: usize) -> Result<()> {
        let s = self.manifest.model.max_seq;
        if p + rows > s {
            bail!("KV position {p}+{rows} exceeds max_seq {s}");
        }
        Ok(())
    }

    /// Start-position bound for bucketed chunk artifacts.  The bucket may
    /// pad past `max_seq` near the end of the context (real tokens are
    /// bounded by the callers; padding rows are sliced off by the engine),
    /// so only the start must be in range — rows beyond `max_seq` are
    /// clipped, mirroring the real runtime's clamped dynamic-update-slice.
    fn check_start(&self, pos: usize) -> Result<()> {
        let s = self.manifest.model.max_seq;
        if pos > s {
            bail!("KV start position {pos} exceeds max_seq {s}");
        }
        Ok(())
    }

    fn pos_of(t: &Tensor) -> Result<usize> {
        Ok(t.scalar_value()?.round() as usize)
    }

    /// The compute core shared by [`ExecBackend::run`] and the vectorized
    /// [`ExecBackend::run_batch`]: one artifact over one validated input
    /// set, no stats accounting.
    fn execute_spec(&self, spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let h = self.manifest.model.hidden;
        let v = self.manifest.model.vocab;
        let b = spec.t;

        let outs: Vec<Tensor> = match spec.kind.as_str() {
            "device_input" => {
                // [tokens(b), skv, pos] -> [hidden(b,H), skv']
                let tokens = &inputs[0].data;
                let mut skv = inputs[1].data.clone();
                let pos = Self::pos_of(inputs[2])?;
                self.check_start(pos)?;
                let s_max = self.manifest.model.max_seq;
                let mut sum = Self::kv_prefix_sum(&skv, pos, h);
                let mut hidden = Vec::with_capacity(b * h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        hidden.resize((i + 1) * h, 0.0); // clipped padding row
                        continue;
                    }
                    let tok = tokens[i].round() as u32;
                    let s = self.shallow_core(tok, p, &Self::mean_of(&sum, p));
                    for d in 0..h {
                        skv[p * h + d] = s[d];
                        sum[d] += s[d];
                    }
                    hidden.extend_from_slice(&s);
                }
                vec![
                    Tensor::new(vec![b, h], hidden)?,
                    Tensor::new(inputs[1].dims.clone(), skv)?,
                ]
            }
            "adapter_prefill" => {
                // [hidden(b,H), akv, pos] -> [akv']
                let hidden = &inputs[0].data;
                let mut akv = inputs[1].data.clone();
                let pos = Self::pos_of(inputs[2])?;
                self.check_start(pos)?;
                let s_max = self.manifest.model.max_seq;
                let mut sum = Self::kv_prefix_sum(&akv, pos, h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        continue; // clipped padding row
                    }
                    let a = self.deep_core(&hidden[i * h..(i + 1) * h], &Self::mean_of(&sum, p));
                    for d in 0..h {
                        akv[p * h + d] = a[d];
                        sum[d] += a[d];
                    }
                }
                vec![Tensor::new(inputs[1].dims.clone(), akv)?]
            }
            "cloud_middle" => {
                // [hidden(b,H), mkv, pos] -> [deep(b,H), mkv']
                let hidden = &inputs[0].data;
                let mut mkv = inputs[1].data.clone();
                let pos = Self::pos_of(inputs[2])?;
                self.check_start(pos)?;
                let s_max = self.manifest.model.max_seq;
                let mut sum = Self::kv_prefix_sum(&mkv, pos, h);
                let mut deep = Vec::with_capacity(b * h);
                for i in 0..b {
                    let p = pos + i;
                    if p >= s_max {
                        deep.resize((i + 1) * h, 0.0); // clipped padding row
                        continue;
                    }
                    let m = self.deep_core(&hidden[i * h..(i + 1) * h], &Self::mean_of(&sum, p));
                    for d in 0..h {
                        mkv[p * h + d] = m[d];
                        sum[d] += m[d];
                    }
                    deep.extend_from_slice(&m);
                }
                vec![
                    Tensor::new(vec![b, h], deep)?,
                    Tensor::new(inputs[1].dims.clone(), mkv)?,
                ]
            }
            "device_head" => {
                // [deep(b,H)] -> [logits(b,V)]
                let deep = &inputs[0].data;
                let mut logits = Vec::with_capacity(b * v);
                for i in 0..b {
                    logits.extend(self.head_row(&deep[i * h..(i + 1) * h], &self.head_w, v));
                }
                vec![Tensor::new(vec![b, v], logits)?]
            }
            "draft_step" => {
                // [token(1), skv, akv, pos] -> [logits(V), skv', akv', shallow(H)]
                let tok = inputs[0].data[0].round() as u32;
                let mut skv = inputs[1].data.clone();
                let mut akv = inputs[2].data.clone();
                let p = Self::pos_of(inputs[3])?;
                self.check_pos(p, 1)?;
                let ssum = Self::kv_prefix_sum(&skv, p, h);
                let s = self.shallow_core(tok, p, &Self::mean_of(&ssum, p));
                skv[p * h..(p + 1) * h].copy_from_slice(&s);
                let asum = Self::kv_prefix_sum(&akv, p, h);
                let a = self.deep_core(&s, &Self::mean_of(&asum, p));
                akv[p * h..(p + 1) * h].copy_from_slice(&a);
                // Draft deep ≈ verify deep + position-keyed perturbation.
                let dn = &self.draft_noise[p * h..(p + 1) * h];
                let draft_deep: Vec<f32> =
                    (0..h).map(|d| a[d] + DRAFT_NOISE * dn[d]).collect();
                let logits = self.head_row(&draft_deep, &self.head_w, v);
                vec![
                    Tensor::new(vec![v], logits)?,
                    Tensor::new(inputs[1].dims.clone(), skv)?,
                    Tensor::new(inputs[2].dims.clone(), akv)?,
                    Tensor::new(vec![h], s)?,
                ]
            }
            "medusa_decode" => {
                // [deep(1,H)] -> [logits(n_medusa, V)]
                let n = self.manifest.model.n_medusa;
                let deep = &inputs[0].data[..h];
                let mut logits = Vec::with_capacity(n * v);
                for j in 0..n {
                    let w = &self.medusa_w[j * v * h..(j + 1) * v * h];
                    logits.extend(self.head_row(deep, w, v));
                }
                vec![Tensor::new(vec![n, v], logits)?]
            }
            other => bail!("reference backend: unknown artifact kind '{other}'"),
        };

        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {}: expected {} outputs, produced {}",
                spec.name,
                spec.outputs.len(),
                outs.len()
            );
        }
        Ok(outs)
    }
}

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_weights(&mut self) -> Result<()> {
        Ok(()) // pseudo-weights are derived on the fly from the seed
    }

    fn compile(&self, name: &str) -> Result<()> {
        if self.manifest.artifact(name).is_none() {
            bail!("unknown artifact {name}");
        }
        if self.compiled.borrow_mut().insert(name.to_string()) {
            self.stats.borrow_mut().compiles += 1;
        }
        Ok(())
    }

    fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        validate_inputs(spec, inputs)?;
        self.compile(name)?;
        let t0 = crate::util::clock::now();
        let outs = self.execute_spec(spec, inputs)?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }

    /// Vectorized batch execution: the batch dimension is stacked as the
    /// outer loop of a single pass (each lane carries its own KV tensors
    /// and position, so lanes stay independent — the `run_batch` contract
    /// in the module docs), validated and timed once, counted as *one*
    /// execution with `batch_occupancy += items`.
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        if inputs.is_empty() {
            return Ok(Vec::new());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        for item in inputs {
            validate_inputs(spec, item)?;
        }
        self.compile(name)?;
        let t0 = crate::util::clock::now();
        let outs: Vec<Vec<Tensor>> = inputs
            .iter()
            .map(|item| self.execute_spec(spec, item))
            .collect::<Result<_>>()?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.batch_occupancy += inputs.len();
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }

    fn weight(&self, name: &str) -> Option<Tensor> {
        let m = &self.manifest.model;
        match name {
            "embed" => {
                Some(Tensor { dims: vec![m.vocab, m.hidden], data: self.embed.clone() })
            }
            "head" => {
                Some(Tensor { dims: vec![m.vocab, m.hidden], data: self.head_w.clone() })
            }
            _ => None,
        }
    }

    fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }
}

/// Tiny self-contained manifest: same artifact naming scheme as
/// `python/compile/aot.py` (kind_bucket), buckets 1..256, vocab 256,
/// hidden 64 — small enough that everything is fast, big enough that the
/// protocol paths (bucket selection, padding, chunking) are exercised.
pub fn synthetic_manifest() -> Manifest {
    let model = ModelSpec {
        vocab: 256,
        hidden: 64,
        layers: 4,
        shallow_layers: 1,
        heads: 4,
        head_dim: 16,
        ffn: 128,
        max_seq: 640,
        n_medusa: 4,
    };
    let buckets = vec![1usize, 4, 16, 64, 256];
    let f32s = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.into(),
        shape,
        dtype: "f32".into(),
    };
    let i32s = |name: &str, shape: Vec<usize>| TensorSpec {
        name: name.into(),
        shape,
        dtype: "i32".into(),
    };
    let mut artifacts = Vec::new();
    for &b in &buckets {
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("device_input", b),
            kind: "device_input".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![
                i32s("tokens", vec![b]),
                f32s("skv", model.shallow_kv_dims()),
                i32s("pos", vec![]),
            ],
            outputs: vec![
                f32s("hidden", vec![b, model.hidden]),
                f32s("skv", model.shallow_kv_dims()),
            ],
        });
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("adapter_prefill", b),
            kind: "adapter_prefill".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![
                f32s("hidden", vec![b, model.hidden]),
                f32s("akv", model.adapter_kv_dims()),
                i32s("pos", vec![]),
            ],
            outputs: vec![f32s("akv", model.adapter_kv_dims())],
        });
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("cloud_middle", b),
            kind: "cloud_middle".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![
                f32s("hidden", vec![b, model.hidden]),
                f32s("mkv", model.middle_kv_dims()),
                i32s("pos", vec![]),
            ],
            outputs: vec![
                f32s("deep", vec![b, model.hidden]),
                f32s("mkv", model.middle_kv_dims()),
            ],
        });
        artifacts.push(ArtifactSpec {
            name: Manifest::artifact_name("device_head", b),
            kind: "device_head".into(),
            t: b,
            file: String::new(),
            weights: Vec::new(),
            inputs: vec![f32s("deep", vec![b, model.hidden])],
            outputs: vec![f32s("logits", vec![b, model.vocab])],
        });
    }
    artifacts.push(ArtifactSpec {
        name: "draft_step_1".into(),
        kind: "draft_step".into(),
        t: 1,
        file: String::new(),
        weights: Vec::new(),
        inputs: vec![
            i32s("token", vec![1]),
            f32s("skv", model.shallow_kv_dims()),
            f32s("akv", model.adapter_kv_dims()),
            i32s("pos", vec![]),
        ],
        outputs: vec![
            f32s("logits", vec![model.vocab]),
            f32s("skv", model.shallow_kv_dims()),
            f32s("akv", model.adapter_kv_dims()),
            f32s("shallow", vec![model.hidden]),
        ],
    });
    artifacts.push(ArtifactSpec {
        name: "medusa_decode_1".into(),
        kind: "medusa_decode".into(),
        t: 1,
        file: String::new(),
        weights: Vec::new(),
        inputs: vec![f32s("deep", vec![1, model.hidden])],
        outputs: vec![f32s("logits", vec![model.n_medusa, model.vocab])],
    });
    Manifest {
        model,
        buckets,
        weights_file: "synthetic".into(),
        prompts_file: "synthetic".into(),
        artifacts,
        train_meta: TrainMeta {
            accept_length_probe: 0.0,
            lm_params: 500_000,
            adapter_params: 20_000,
            medusa_params: 120_000,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{pos_tensor, tokens_tensor, zeros_tensor};

    fn backend() -> ReferenceBackend {
        ReferenceBackend::synthetic(42)
    }

    #[test]
    fn synthetic_manifest_is_complete() {
        let m = synthetic_manifest();
        assert_eq!(m.artifacts.len(), 4 * m.buckets.len() + 2);
        for kind in ["device_input", "cloud_middle", "device_head", "adapter_prefill"] {
            for &b in &m.buckets {
                assert!(m.artifact(&Manifest::artifact_name(kind, b)).is_some());
            }
        }
        assert!(m.artifact("draft_step_1").is_some());
        assert!(m.artifact("medusa_decode_1").is_some());
        assert_eq!(m.model.heads * m.model.head_dim, m.model.hidden);
    }

    #[test]
    fn device_input_threads_kv_and_is_deterministic() {
        let be = backend();
        let h = be.manifest().model.hidden;
        let skv = zeros_tensor(&be.manifest().model.shallow_kv_dims());
        let toks = tokens_tensor(&[3, 5, 7], 4).unwrap();
        let o1 = be.run("device_input_4", &[&toks, &skv, &pos_tensor(0)]).unwrap();
        let o2 = be.run("device_input_4", &[&toks, &skv, &pos_tensor(0)]).unwrap();
        assert_eq!(o1[0], o2[0], "same inputs must give bit-identical outputs");
        assert_eq!(o1[0].dims, vec![4, h]);
        // KV rows 0..4 were written, row 4 untouched.
        let kv = &o1[1].data;
        assert!(kv[..4 * h].iter().any(|&x| x != 0.0));
        assert!(kv[4 * h..5 * h].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn position_masking_ignores_stale_tail() {
        // Writing garbage beyond position p must not affect a row computed
        // at p — the invariant speculative rollback relies on.
        let be = backend();
        let h = be.manifest().model.hidden;
        let skv = zeros_tensor(&be.manifest().model.shallow_kv_dims());
        let toks = tokens_tensor(&[9], 1).unwrap();
        let clean = be.run("device_input_1", &[&toks, &skv, &pos_tensor(2)]).unwrap();
        let mut dirty = skv.clone();
        for d in 0..h {
            dirty.data[3 * h + d] = 123.0; // stale row past the write head
        }
        let with_stale = be.run("device_input_1", &[&toks, &dirty, &pos_tensor(2)]).unwrap();
        assert_eq!(clean[0], with_stale[0]);
    }

    #[test]
    fn bucket_padding_past_max_seq_is_clipped() {
        // A chunk whose *bucket* pads past max_seq must not error or write
        // out of the KV region — only the start position is bounded; the
        // padded tail rows are clipped (they are sliced off by the engine).
        let be = backend();
        let m = be.manifest().model.clone();
        let h = m.hidden;
        let skv = zeros_tensor(&m.shallow_kv_dims());
        let toks = tokens_tensor(&[7], 4).unwrap();
        let pos = m.max_seq - 2; // bucket rows land on S-2, S-1, S, S+1
        let outs = be.run("device_input_4", &[&toks, &skv, &pos_tensor(pos)]).unwrap();
        assert_eq!(outs[0].element_count(), 4 * h);
        assert!(outs[0].data[2 * h..].iter().all(|&x| x == 0.0), "clipped rows are zero");
        assert!(outs[1].data[pos * h..(pos + 1) * h].iter().any(|&x| x != 0.0));
        // A start position beyond max_seq is still an error.
        let far = pos_tensor(m.max_seq + 1);
        assert!(be.run("device_input_4", &[&toks, &skv, &far]).is_err());
    }

    #[test]
    fn head_is_zero_on_zero_hidden() {
        let be = backend();
        let m = be.manifest().model.clone();
        let deep = zeros_tensor(&[1, m.hidden]);
        let outs = be.run("device_head_1", &[&deep]).unwrap();
        assert_eq!(outs[0].element_count(), m.vocab);
        assert!(outs[0].data.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn rejects_wrong_arity_and_unknown() {
        let be = backend();
        assert!(be.run("device_head_1", &[]).is_err());
        assert!(be.run("nonexistent", &[]).is_err());
        let bad = zeros_tensor(&[3, 3]);
        assert!(be.run("device_head_1", &[&bad]).is_err());
    }

    #[test]
    fn embed_weight_rows_are_distinct() {
        let be = backend();
        let w = be.weight("embed").unwrap();
        let m = be.manifest().model.clone();
        assert_eq!(w.dims, vec![m.vocab, m.hidden]);
        assert_ne!(w.data[..m.hidden], w.data[m.hidden..2 * m.hidden]);
        assert!(be.weight("nope").is_none());
    }

    #[test]
    fn stats_count_compiles_once_per_artifact() {
        let be = backend();
        let deep = zeros_tensor(&[1, be.manifest().model.hidden]);
        be.run("device_head_1", &[&deep]).unwrap();
        be.run("device_head_1", &[&deep]).unwrap();
        let s = be.stats();
        assert_eq!(s.compiles, 1);
        assert_eq!(s.executions, 2);
        assert_eq!(s.batch_occupancy, 2);
        assert_eq!(s.mean_batch_occupancy(), 1.0);
    }

    #[test]
    fn run_batch_matches_per_item_run_bitwise() {
        // The run_batch contract: item i's outputs are exactly what
        // run(name, &inputs[i]) returns, KV lanes independent.
        let be = backend();
        let m = be.manifest().model.clone();
        let h = m.hidden;
        // Two lanes with *different* KV histories and positions.
        let toks_a = tokens_tensor(&[3, 5, 7], 4).unwrap();
        let toks_b = tokens_tensor(&[9], 4).unwrap();
        let kv_a = zeros_tensor(&m.shallow_kv_dims());
        let mut kv_b = zeros_tensor(&m.shallow_kv_dims());
        for d in 0..h {
            kv_b.data[d] = 0.25; // lane B attends a non-zero row 0
        }
        let (pos_a, pos_b) = (pos_tensor(0), pos_tensor(1));
        let serial_a = be.run("device_input_4", &[&toks_a, &kv_a, &pos_a]).unwrap();
        let serial_b = be.run("device_input_4", &[&toks_b, &kv_b, &pos_b]).unwrap();
        let batched = be
            .run_batch(
                "device_input_4",
                &[vec![&toks_a, &kv_a, &pos_a], vec![&toks_b, &kv_b, &pos_b]],
            )
            .unwrap();
        assert_eq!(batched.len(), 2);
        assert_eq!(batched[0], serial_a, "lane A diverged from serial run");
        assert_eq!(batched[1], serial_b, "lane B diverged from serial run");
    }

    #[test]
    fn run_batch_counts_one_execution_with_full_occupancy() {
        let be = backend();
        let deep = zeros_tensor(&[1, be.manifest().model.hidden]);
        let items: Vec<Vec<&Tensor>> = (0..3).map(|_| vec![&deep]).collect();
        be.run_batch("device_head_1", &items).unwrap();
        let s = be.stats();
        assert_eq!(s.executions, 1, "a batch is one execution");
        assert_eq!(s.batch_occupancy, 3);
        assert_eq!(s.compiles, 1);
        assert_eq!(s.mean_batch_occupancy(), 3.0);
    }

    #[test]
    fn run_batch_empty_and_invalid_items() {
        let be = backend();
        assert!(be.run_batch("device_head_1", &[]).unwrap().is_empty());
        assert_eq!(be.stats().executions, 0, "empty batch touches no counters");
        let bad = zeros_tensor(&[3, 3]);
        assert!(be.run_batch("device_head_1", &[vec![&bad]]).is_err());
        assert!(be.run_batch("nonexistent", &[vec![&bad]]).is_err());
    }
}
