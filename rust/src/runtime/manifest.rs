//! Manifest parsing: the contract between `python/compile/aot.py` and the
//! rust runtime (model dims, token buckets, per-artifact input/output specs
//! and weight ordering).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Value};

/// Model dimensions of the executable tiny model (NOT the paper-scale
/// delay-model dims — see DESIGN.md §3 dual-scale principle).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub shallow_layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub n_medusa: usize,
}

impl ModelSpec {
    pub fn middle_layers(&self) -> usize {
        self.layers - self.shallow_layers
    }

    /// Dims of a shallow-KV tensor: [m, 2, S, nh, hd].
    pub fn shallow_kv_dims(&self) -> Vec<usize> {
        vec![self.shallow_layers, 2, self.max_seq, self.heads, self.head_dim]
    }

    /// Dims of a middle-KV tensor: [L-m, 2, S, nh, hd].
    pub fn middle_kv_dims(&self) -> Vec<usize> {
        vec![self.middle_layers(), 2, self.max_seq, self.heads, self.head_dim]
    }

    /// Dims of the adapter-KV tensor: [2, S, nh, hd].
    pub fn adapter_kv_dims(&self) -> Vec<usize> {
        vec![2, self.max_seq, self.heads, self.head_dim]
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: String,
    pub t: usize,
    pub file: String,
    pub weights: Vec<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelSpec,
    pub buckets: Vec<usize>,
    pub weights_file: String,
    pub prompts_file: String,
    pub artifacts: Vec<ArtifactSpec>,
    /// Training metadata (losses, param counts, accept-length probe).
    pub train_meta: TrainMeta,
}

#[derive(Debug, Clone, Default)]
pub struct TrainMeta {
    pub accept_length_probe: f64,
    pub lm_params: usize,
    pub adapter_params: usize,
    pub medusa_params: usize,
}

fn req<'a>(v: &'a Value, key: &str) -> Result<&'a Value> {
    v.get(key).ok_or_else(|| anyhow!("manifest missing key '{key}'"))
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    req(v, key)?.as_usize().ok_or_else(|| anyhow!("'{key}' not a number"))
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(req(v, key)?.as_str().ok_or_else(|| anyhow!("'{key}' not a string"))?.to_string())
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = json::parse(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let m = req(&v, "model")?;
        let model = ModelSpec {
            vocab: req_usize(m, "vocab")?,
            hidden: req_usize(m, "hidden")?,
            layers: req_usize(m, "layers")?,
            shallow_layers: req_usize(m, "shallow_layers")?,
            heads: req_usize(m, "heads")?,
            head_dim: req_usize(m, "head_dim")?,
            ffn: req_usize(m, "ffn")?,
            max_seq: req_usize(m, "max_seq")?,
            n_medusa: req_usize(m, "n_medusa")?,
        };
        anyhow::ensure!(model.shallow_layers < model.layers, "m >= n layers");
        anyhow::ensure!(model.heads * model.head_dim == model.hidden, "head dims mismatch");

        let buckets: Vec<usize> = req(&v, "buckets")?
            .as_arr()
            .ok_or_else(|| anyhow!("buckets not an array"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow!("bad bucket")))
            .collect::<Result<_>>()?;
        anyhow::ensure!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets not sorted");

        let tensor_list = |val: &Value| -> Result<Vec<TensorSpec>> {
            val.as_arr()
                .ok_or_else(|| anyhow!("tensor list not an array"))?
                .iter()
                .map(|t| {
                    Ok(TensorSpec {
                        name: req_str(t, "name")?,
                        shape: req(t, "shape")?
                            .as_arr()
                            .ok_or_else(|| anyhow!("shape not an array"))?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<_>>()?,
                        dtype: t
                            .get("dtype")
                            .and_then(|d| d.as_str())
                            .unwrap_or("f32")
                            .to_string(),
                    })
                })
                .collect()
        };

        let artifacts = req(&v, "artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts not an array"))?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: req_str(a, "name")?,
                    kind: req_str(a, "kind")?,
                    t: req_usize(a, "t")?,
                    file: req_str(a, "file")?,
                    weights: req(a, "weights")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("weights not an array"))?
                        .iter()
                        .map(|w| {
                            w.as_str()
                                .map(String::from)
                                .ok_or_else(|| anyhow!("bad weight name"))
                        })
                        .collect::<Result<_>>()?,
                    inputs: tensor_list(req(a, "inputs")?)?,
                    outputs: tensor_list(req(a, "outputs")?)?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(!artifacts.is_empty(), "no artifacts in manifest");

        let tm = v.get("train_meta");
        let train_meta = TrainMeta {
            accept_length_probe: tm
                .and_then(|t| t.get("accept_length_probe"))
                .and_then(|x| x.as_f64())
                .unwrap_or(0.0),
            lm_params: tm
                .and_then(|t| t.get("lm_params"))
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            adapter_params: tm
                .and_then(|t| t.get("adapter_params"))
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            medusa_params: tm
                .and_then(|t| t.get("medusa_params"))
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
        };

        Ok(Manifest {
            model,
            buckets,
            weights_file: req_str(&v, "weights_file")?,
            prompts_file: req_str(&v, "prompts_file")?,
            artifacts,
            train_meta,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Artifact name for a (kind, bucket) pair.
    pub fn artifact_name(kind: &str, bucket: usize) -> String {
        format!("{kind}_{bucket}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "model": {"vocab": 512, "hidden": 128, "layers": 8, "shallow_layers": 1,
                "heads": 4, "head_dim": 32, "ffn": 256, "max_seq": 640, "n_medusa": 4},
      "buckets": [1, 4, 16],
      "weights_file": "weights.npz",
      "prompts_file": "prompts.bin",
      "train_meta": {"accept_length_probe": 1.62, "lm_params": 1443968,
                     "adapter_params": 65664, "medusa_params": 330240},
      "artifacts": [
        {"name": "device_head_1", "kind": "device_head", "t": 1,
         "file": "device_head_1.hlo.txt", "weights": ["final_ln", "head"],
         "inputs": [{"name": "deep", "shape": [1, 128], "dtype": "f32"}],
         "outputs": [{"name": "logits", "shape": [1, 512]}]}
      ]
    }"#;

    #[test]
    fn parses_mini_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.model.vocab, 512);
        assert_eq!(m.model.middle_layers(), 7);
        assert_eq!(m.buckets, vec![1, 4, 16]);
        assert_eq!(m.artifacts.len(), 1);
        let a = m.artifact("device_head_1").unwrap();
        assert_eq!(a.weights, vec!["final_ln", "head"]);
        assert_eq!(a.inputs[0].shape, vec![1, 128]);
        assert!((m.train_meta.accept_length_probe - 1.62).abs() < 1e-9);
    }

    #[test]
    fn kv_dims() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.model.shallow_kv_dims(), vec![1, 2, 640, 4, 32]);
        assert_eq!(m.model.middle_kv_dims(), vec![7, 2, 640, 4, 32]);
        assert_eq!(m.model.adapter_kv_dims(), vec![2, 640, 4, 32]);
    }

    #[test]
    fn rejects_inconsistent_model() {
        let bad = MINI.replace("\"head_dim\": 32", "\"head_dim\": 16");
        assert!(Manifest::parse(&bad).is_err());
        let bad = MINI.replace("[1, 4, 16]", "[4, 1, 16]");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let p = crate::runtime::ArtifactRegistry::default_dir().join("manifest.json");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.artifacts.len(), 4 * m.buckets.len() + 2);
        for kind in ["device_input", "cloud_middle", "device_head", "adapter_prefill"] {
            for &b in &m.buckets {
                assert!(m.artifact(&Manifest::artifact_name(kind, b)).is_some());
            }
        }
        assert!(m.artifact("draft_step_1").is_some());
        assert!(m.artifact("medusa_decode_1").is_some());
    }
}
