//! Runtime: loads the AOT artifacts (HLO text + weights.npz + manifest)
//! and executes them through the PJRT C API (`xla` crate, CPU client).
//!
//! Key properties:
//! - HLO **text** interchange (xla_extension 0.5.1 rejects jax≥0.5's
//!   64-bit-id serialized protos; the text parser reassigns ids);
//! - weights are uploaded once as device-resident `PjRtBuffer`s and shared
//!   by every executable variant (`execute_b` mixes weight buffers with
//!   staged per-call dynamic inputs);
//! - executables are compiled lazily per (kind, token-bucket) on first use
//!   and cached — a fleet simulation only pays for the buckets it touches.

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use xla::FromRawBytes as _;

pub use manifest::{ArtifactSpec, Manifest, ModelSpec};

/// A loaded artifact registry bound to one PJRT client.
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    dir: PathBuf,
    client: xla::PjRtClient,
    /// Weight name -> device-resident buffer.
    weights: HashMap<String, xla::PjRtBuffer>,
    /// Host copies backing the weight buffers.  TFRT-CPU
    /// `BufferFromHostLiteral` copies *asynchronously*: the source literal
    /// must outlive the copy, so we keep them for the registry's lifetime
    /// (declared after `weights` → dropped after the buffers).
    _weight_literals: Vec<xla::Literal>,
    /// Artifact name -> compiled executable (lazy).
    executables: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Compile/execute counters for the perf harness.
    pub stats: RefCell<RuntimeStats>,
}

#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub compiles: usize,
    pub executions: usize,
    pub compile_ms: f64,
    pub execute_ms: f64,
}

impl ArtifactRegistry {
    /// Load manifest + weights from `dir` (usually `artifacts/`).
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;

        // Upload weights once; they are shared across all executables.
        let npz = dir.join(&manifest.weights_file);
        let literals = xla::Literal::read_npz(&npz, &())
            .map_err(|e| anyhow!("read {}: {e:?}", npz.display()))?;
        let mut weights = HashMap::new();
        let mut weight_literals = Vec::with_capacity(literals.len());
        for (name, lit) in literals {
            let name = name.strip_suffix(".npy").unwrap_or(&name).to_string();
            let buf = client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("upload weight {name}: {e:?}"))?;
            weights.insert(name, buf);
            weight_literals.push(lit);
        }
        for art in &manifest.artifacts {
            for w in &art.weights {
                if !weights.contains_key(w) {
                    bail!("artifact {} references missing weight {w}", art.name);
                }
            }
        }
        Ok(ArtifactRegistry {
            manifest,
            dir: dir.to_path_buf(),
            client,
            weights,
            _weight_literals: weight_literals,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Default artifact directory: $HAT_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn model(&self) -> &ModelSpec {
        &self.manifest.model
    }

    /// Smallest compiled token bucket >= `t`.
    pub fn bucket_for(&self, t: usize) -> Result<usize> {
        self.manifest
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= t)
            .ok_or_else(|| anyhow!("no bucket >= {t} (max {:?})", self.manifest.buckets.last()))
    }

    fn compile(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.executables.borrow().get(name) {
            return Ok(exe.clone());
        }
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&spec.file);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let exe = std::rc::Rc::new(exe);
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        self.executables.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name`: weight buffers (manifest order) followed by
    /// `dynamic` inputs.  Returns the decomposed output tuple as literals.
    ///
    /// Inputs are borrowed — callers keep ownership of their KV literals
    /// and swap in the returned ones (zero host-side copies beyond the
    /// unavoidable PJRT staging; see EXPERIMENTS.md §Perf).
    pub fn run(&self, name: &str, dynamic: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if dynamic.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} dynamic inputs, got {}",
                spec.inputs.len(),
                dynamic.len()
            );
        }
        let exe = self.compile(name)?;
        let t0 = std::time::Instant::now();

        // Mixed-input execute: weights are device-resident buffers, dynamic
        // inputs are staged from host literals per call.
        let staged: Vec<xla::PjRtBuffer> = dynamic
            .iter()
            .map(|l| {
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("stage input: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(spec.weights.len() + dynamic.len());
        for w in &spec.weights {
            args.push(&self.weights[w]);
        }
        for b in &staged {
            args.push(b);
        }
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output of {name}: {e:?}"))?;
        // Lowered with return_tuple=True: single tuple output.
        let mut lit = lit;
        let outs = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: expected {} outputs, got {}",
                spec.outputs.len(),
                outs.len()
            );
        }
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// Build an i32 literal of shape [n] from tokens, padding with 0 to `n`.
pub fn tokens_literal(tokens: &[u32], n: usize) -> Result<xla::Literal> {
    assert!(tokens.len() <= n, "{} tokens > bucket {n}", tokens.len());
    let mut v: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    v.resize(n, 0);
    xla::Literal::vec1(&v)
        .reshape(&[n as i64])
        .map_err(|e| anyhow!("tokens literal: {e:?}"))
}

/// Build an f32 literal of shape [rows_total, row] from row-major data,
/// zero-padding missing rows.
pub fn f32_literal_padded(data: &[f32], row: usize, rows_total: usize) -> Result<xla::Literal> {
    assert!(data.len() % row == 0, "data not a multiple of row width");
    assert!(data.len() / row <= rows_total);
    let mut v = data.to_vec();
    v.resize(rows_total * row, 0.0);
    xla::Literal::vec1(&v)
        .reshape(&[rows_total as i64, row as i64])
        .map_err(|e| anyhow!("f32 literal: {e:?}"))
}

/// Scalar i32 position literal.
pub fn pos_literal(pos: usize) -> xla::Literal {
    xla::Literal::scalar(pos as i32)
}

/// Zero-filled f32 literal with the given dims.
pub fn zeros_literal(dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    let v = vec![0f32; n];
    let dims_i: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(&v)
        .reshape(&dims_i)
        .map_err(|e| anyhow!("zeros literal: {e:?}"))
}

/// Extract an f32 literal into a Vec.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Deep-copy an f32 literal (parallel-drafting KV branches need copies).
pub fn clone_literal(lit: &xla::Literal) -> Result<xla::Literal> {
    let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
    let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
    xla::Literal::vec1(&v)
        .reshape(shape.dims())
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = ArtifactRegistry::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn literal_helpers_shapes() {
        let t = tokens_literal(&[1, 2, 3], 8).unwrap();
        assert_eq!(t.element_count(), 8);
        let f = f32_literal_padded(&[1.0, 2.0, 3.0, 4.0], 2, 4).unwrap();
        assert_eq!(f.element_count(), 8);
        let z = zeros_literal(&[2, 3, 4]).unwrap();
        assert_eq!(z.element_count(), 24);
        assert_eq!(to_f32_vec(&z).unwrap()[5], 0.0);
    }

    #[test]
    fn clone_literal_is_deep() {
        let a = f32_literal_padded(&[1.0, 2.0], 2, 1).unwrap();
        let b = clone_literal(&a).unwrap();
        assert_eq!(to_f32_vec(&a).unwrap(), to_f32_vec(&b).unwrap());
    }

    #[test]
    fn registry_loads_and_buckets() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.model().hidden, 128);
        assert_eq!(reg.bucket_for(1).unwrap(), 1);
        assert_eq!(reg.bucket_for(3).unwrap(), 4);
        assert_eq!(reg.bucket_for(200).unwrap(), 256);
        assert!(reg.bucket_for(10_000).is_err());
    }

    #[test]
    fn run_device_head_artifact() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let reg = ArtifactRegistry::load(&dir).unwrap();
        let h = reg.model().hidden;
        let deep = zeros_literal(&[1, h]).unwrap();
        let outs = reg.run("device_head_1", &[&deep]).unwrap();
        assert_eq!(outs.len(), 1);
        let logits = to_f32_vec(&outs[0]).unwrap();
        assert_eq!(logits.len(), reg.model().vocab);
        // zero hidden → rmsnorm(0)@head = 0 logits
        assert!(logits.iter().all(|x| x.abs() < 1e-3));
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert!(reg.run("device_head_1", &[]).is_err());
        assert!(reg.run("nonexistent", &[]).is_err());
    }
}
