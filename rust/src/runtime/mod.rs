//! Runtime facade: the artifact registry binds a manifest to an
//! [`ExecBackend`](crate::backend::ExecBackend) and is what the engine
//! layer talks to.  No accelerator types appear here — the PJRT path
//! lives in `backend::pjrt` behind the `pjrt` cargo feature, and the
//! deterministic pure-Rust path in `backend::reference` is the default,
//! so a clean machine with no XLA libraries runs the full stack.
//!
//! Backend selection: `HAT_BACKEND=reference|pjrt` (default `reference`).
//! When no artifacts exist on disk at all, `load_or_synthetic` falls back
//! to the reference backend's self-contained synthetic manifest.

pub mod manifest;

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::backend::{BackendKind, ExecBackend, PagedItem, RuntimeStats, Tensor};
use crate::backend::reference::ReferenceBackend;
use crate::kv::KvCache;

pub use crate::backend::{
    f32_tensor_padded, pos_tensor, to_f32_vec, tokens_tensor, zeros_tensor,
};
pub use manifest::{ArtifactSpec, Manifest, ModelSpec};

/// Seed for the reference backend's pseudo-weights — fixed so every run
/// (and every test) sees the same model.
const REFERENCE_SEED: u64 = 42;

/// A loaded artifact registry bound to one execution backend.
pub struct ArtifactRegistry {
    backend: Box<dyn ExecBackend>,
}

impl ArtifactRegistry {
    /// Load manifest + weights from `dir` (usually `artifacts/`), picking
    /// the backend from `HAT_BACKEND` (default: reference).
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let mut backend: Box<dyn ExecBackend> = match BackendKind::from_env()? {
            BackendKind::Reference => Box::new(ReferenceBackend::load(dir, REFERENCE_SEED)?),
            #[cfg(feature = "pjrt")]
            BackendKind::Pjrt => Box::new(crate::backend::pjrt::PjrtBackend::load(dir)?),
            #[cfg(not(feature = "pjrt"))]
            BackendKind::Pjrt => unreachable!("BackendKind::from_env rejects pjrt without the feature"),
        };
        backend.load_weights()?;
        Ok(ArtifactRegistry { backend })
    }

    /// Registry over the reference backend's synthetic manifest — no
    /// files needed at all.
    pub fn synthetic() -> ArtifactRegistry {
        ArtifactRegistry { backend: Box::new(ReferenceBackend::synthetic(REFERENCE_SEED)) }
    }

    /// Registry over an explicit backend instance — the injection point
    /// for tests that exercise the failure paths with a fault-injecting
    /// backend (weights are loaded here, as in [`ArtifactRegistry::load`]).
    pub fn with_backend(mut backend: Box<dyn ExecBackend>) -> Result<ArtifactRegistry> {
        backend.load_weights()?;
        Ok(ArtifactRegistry { backend })
    }

    /// `load(dir)` when a manifest exists there, else the synthetic
    /// reference registry.  An explicit `HAT_BACKEND=pjrt` (or an invalid
    /// value) still errors rather than silently serving the toy model.
    pub fn load_or_synthetic(dir: &Path) -> Result<ArtifactRegistry> {
        if dir.join("manifest.json").exists() {
            return ArtifactRegistry::load(dir);
        }
        match BackendKind::from_env()? {
            BackendKind::Reference => Ok(ArtifactRegistry::synthetic()),
            BackendKind::Pjrt => Err(anyhow::anyhow!(
                "HAT_BACKEND=pjrt but no artifacts at {} (run `make artifacts`)",
                dir.display()
            )),
        }
    }

    /// Default artifact directory: $HAT_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Which backend this registry executes on ("reference", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The manifest this registry executes.
    pub fn manifest(&self) -> &Manifest {
        self.backend.manifest()
    }

    pub fn model(&self) -> &ModelSpec {
        &self.manifest().model
    }

    /// Smallest compiled token bucket >= `t`.
    pub fn bucket_for(&self, t: usize) -> Result<usize> {
        let buckets = &self.manifest().buckets;
        buckets
            .iter()
            .copied()
            .find(|&b| b >= t)
            .ok_or_else(|| anyhow::anyhow!("no bucket >= {t} (max {:?})", buckets.last()))
    }

    /// Eagerly compile artifact `name` (run compiles lazily on first use).
    pub fn compile(&self, name: &str) -> Result<()> {
        self.backend.compile(name)
    }

    /// Execute artifact `name` on dynamic inputs (manifest input order,
    /// weights excluded); returns outputs in manifest output order.
    pub fn run(&self, name: &str, dynamic: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.backend.run(name, dynamic)
    }

    /// Execute artifact `name` over a batch of independent input sets —
    /// one backend call for the whole batch (see the `run_batch` contract
    /// in [`crate::backend`]).  Item `i`'s outputs land at index `i`.
    pub fn run_batch(&self, name: &str, items: &[Vec<&Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        self.backend.run_batch(name, items)
    }

    /// Execute artifact `name` against paged KV caches: non-KV dynamic
    /// inputs plus one [`KvCache`] per KV input in spec order (the paged
    /// contract in [`crate::backend`]).  KV outputs are applied to the
    /// caches and dropped from the returned list.
    pub fn run_paged(
        &self,
        name: &str,
        dynamic: &[&Tensor],
        kvs: &mut [&mut KvCache],
    ) -> Result<Vec<Tensor>> {
        self.backend.run_paged(name, dynamic, kvs)
    }

    /// Batched [`ArtifactRegistry::run_paged`]: one lane per
    /// [`PagedItem`], outputs at matching indices.
    pub fn run_batch_paged(
        &self,
        name: &str,
        items: &mut [PagedItem<'_>],
    ) -> Result<Vec<Vec<Tensor>>> {
        self.backend.run_batch_paged(name, items)
    }

    /// Host copy of a named weight, if the backend materializes it.
    pub fn weight(&self, name: &str) -> Option<Tensor> {
        self.backend.weight(name)
    }

    /// Compile/execute counters for the perf harness.
    pub fn stats(&self) -> RuntimeStats {
        self.backend.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = ArtifactRegistry::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn synthetic_registry_loads_and_buckets() {
        let reg = ArtifactRegistry::synthetic();
        assert_eq!(reg.backend_name(), "reference");
        assert_eq!(reg.bucket_for(1).unwrap(), 1);
        assert_eq!(reg.bucket_for(3).unwrap(), 4);
        assert_eq!(reg.bucket_for(200).unwrap(), 256);
        assert!(reg.bucket_for(10_000).is_err());
    }

    #[test]
    fn synthetic_run_device_head() {
        let reg = ArtifactRegistry::synthetic();
        let h = reg.model().hidden;
        let deep = zeros_tensor(&[1, h]);
        let outs = reg.run("device_head_1", &[&deep]).unwrap();
        assert_eq!(outs.len(), 1);
        let logits = to_f32_vec(&outs[0]);
        assert_eq!(logits.len(), reg.model().vocab);
        // zero hidden → zero logits (linear head)
        assert!(logits.iter().all(|x| x.abs() < 1e-3));
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let reg = ArtifactRegistry::synthetic();
        assert!(reg.run("device_head_1", &[]).is_err());
        assert!(reg.run("nonexistent", &[]).is_err());
    }

    #[test]
    fn load_or_synthetic_falls_back() {
        // With HAT_BACKEND=pjrt set (the golden-test workflow), the
        // fallback deliberately errors instead — only check the default.
        if std::env::var("HAT_BACKEND").is_err() {
            let reg =
                ArtifactRegistry::load_or_synthetic(Path::new("/definitely/not/a/dir")).unwrap();
            assert_eq!(reg.backend_name(), "reference");
        }
    }

    #[test]
    fn registry_loads_real_artifacts_if_built() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.model().hidden, 128);
        assert_eq!(reg.bucket_for(1).unwrap(), 1);
        assert_eq!(reg.bucket_for(3).unwrap(), 4);
    }
}
