//! Metrics: per-request TTFT/TBT recording, per-GPU computation-delay
//! tracking (Fig. 8), SLA compliance CDFs (Figs. 9–10), and paper-style
//! report tables.

use crate::sim::SimTime;
use crate::util::stats::{cdf_at, quantile, Summary, Welford};

/// Lifecycle record of one request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: usize,
    pub device: usize,
    pub prompt_len: usize,
    pub arrived: SimTime,
    pub first_token: Option<SimTime>,
    /// Virtual times of each generated token (including the first).
    pub token_times: Vec<SimTime>,
    pub finished: Option<SimTime>,
    /// Speculative-decoding accounting.
    pub sd_rounds: usize,
    pub sd_accepted: usize,
    pub pd_hits: usize,
}

impl RequestRecord {
    pub fn new(id: usize, device: usize, prompt_len: usize, arrived: SimTime) -> Self {
        RequestRecord {
            id,
            device,
            prompt_len,
            arrived,
            first_token: None,
            token_times: Vec::new(),
            finished: None,
            sd_rounds: 0,
            sd_accepted: 0,
            pd_hits: 0,
        }
    }

    /// Time-to-first-token, ms.
    pub fn ttft_ms(&self) -> Option<f64> {
        self.first_token.map(|t| (t - self.arrived).as_ms())
    }

    /// Mean time-between-tokens, ms (intervals between consecutive tokens
    /// in the decode phase).
    pub fn mean_tbt_ms(&self) -> Option<f64> {
        if self.token_times.len() < 2 {
            return None;
        }
        let total = (*self.token_times.last().unwrap() - self.token_times[0]).as_ms();
        Some(total / (self.token_times.len() - 1) as f64)
    }

    /// Per-interval TBTs, ms.
    pub fn tbt_intervals_ms(&self) -> Vec<f64> {
        self.token_times.windows(2).map(|w| (w[1] - w[0]).as_ms()).collect()
    }

    pub fn tokens_generated(&self) -> usize {
        self.token_times.len()
    }
}

/// Collects everything one experiment run produces.
#[derive(Debug, Default)]
pub struct Recorder {
    pub requests: Vec<RequestRecord>,
    /// Per-GPU (pipeline-stage) computation delay per inference step, ms —
    /// the quantity of Fig. 8.
    pub gpu_step_delays: Vec<f64>,
    /// Batched token size per step (state-monitoring μ̂ trace).
    pub batch_token_sizes: Vec<usize>,
    /// Chunk sizes chosen by the Eq. 3 optimizer (HAT only).
    pub chunk_sizes: Vec<usize>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn finished_requests(&self) -> impl Iterator<Item = &RequestRecord> {
        self.requests.iter().filter(|r| r.finished.is_some())
    }

    pub fn ttfts_ms(&self) -> Vec<f64> {
        self.finished_requests().filter_map(|r| r.ttft_ms()).collect()
    }

    pub fn mean_tbts_ms(&self) -> Vec<f64> {
        self.finished_requests().filter_map(|r| r.mean_tbt_ms()).collect()
    }

    pub fn all_tbt_intervals_ms(&self) -> Vec<f64> {
        self.finished_requests().flat_map(|r| r.tbt_intervals_ms()).collect()
    }

    /// Mean accept length across SD rounds (tokens produced per
    /// verification round, Table 4).
    pub fn accept_length(&self) -> f64 {
        let rounds: usize = self.requests.iter().map(|r| r.sd_rounds).sum();
        let acc: usize = self.requests.iter().map(|r| r.sd_accepted).sum();
        if rounds == 0 { 0.0 } else { acc as f64 / rounds as f64 }
    }

    /// Fraction of verification rounds whose parallel-drafting candidate hit.
    pub fn pd_hit_rate(&self) -> f64 {
        let rounds: usize = self.requests.iter().map(|r| r.sd_rounds).sum();
        let hits: usize = self.requests.iter().map(|r| r.pd_hits).sum();
        if rounds == 0 { 0.0 } else { hits as f64 / rounds as f64 }
    }

    /// Per-GPU computation-delay mean/std (Fig. 8).
    pub fn gpu_delay_stats(&self) -> (f64, f64) {
        let mut w = Welford::new();
        for &d in &self.gpu_step_delays {
            w.push(d);
        }
        (w.mean(), w.std())
    }

    /// Prefill-SLA sample: delay per 128 prompt tokens, one value per
    /// request (Figs. 9–10: "the prefill SLA is defined as the delay for
    /// processing per 128 prompt tokens").
    pub fn prefill_sla_sample(&self) -> Vec<f64> {
        self.finished_requests()
            .filter_map(|r| {
                let ttft = r.ttft_ms()?;
                let units = (r.prompt_len as f64 / 128.0).max(1.0);
                Some(ttft / units)
            })
            .collect()
    }

    /// Decode-SLA sample: delay per 10 generated tokens, sliding windows.
    pub fn decode_sla_sample(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for r in self.finished_requests() {
            let ts = &r.token_times;
            if ts.len() < 11 {
                continue;
            }
            for w in ts.windows(11) {
                out.push((w[10] - w[0]).as_ms());
            }
        }
        out
    }

    /// Compliance rate (fraction ≤ sla_ms) for a sample.
    pub fn compliance(sample: &[f64], sla_ms: f64) -> f64 {
        if sample.is_empty() {
            return 0.0;
        }
        cdf_at(sample, &[sla_ms])[0]
    }

    /// "q of requests meet an SLA of X ms": the q-quantile of the sample.
    pub fn sla_at_quantile(sample: &[f64], q: f64) -> f64 {
        quantile(sample, q)
    }

    /// One-line summary for report tables.
    pub fn summary(&self) -> RunSummary {
        let ttft = Summary::of(&self.ttfts_ms());
        let tbt = Summary::of(&self.mean_tbts_ms());
        let (gmean, gstd) = self.gpu_delay_stats();
        RunSummary {
            n_finished: self.finished_requests().count(),
            ttft_mean_ms: ttft.mean,
            ttft_p90_ms: ttft.p90,
            tbt_mean_ms: tbt.mean,
            tbt_p90_ms: tbt.p90,
            gpu_delay_mean_ms: gmean,
            gpu_delay_std_ms: gstd,
            accept_length: self.accept_length(),
            pd_hit_rate: self.pd_hit_rate(),
        }
    }
}

/// Speculative-decoding acceptance rate: Σ accepted / Σ proposed, 0 when
/// nothing was proposed.  The single definition shared by per-request
/// GENERATE replies (`server::Generation`) and the STATS aggregates
/// ([`ServeStats`]) — per-proposal acceptance, independent of truncation.
pub fn accept_rate(accepted: usize, proposed: usize) -> f64 {
    if proposed == 0 { 0.0 } else { accepted as f64 / proposed as f64 }
}

/// Aggregate metrics of the real serving path's continuous-batching
/// scheduler, surfaced through the TCP `STATS` command.  Unlike
/// [`Recorder`] (virtual time, fleet simulator) these are wall-clock
/// measurements of the engine worker.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Completed GENERATE requests.
    pub finished: usize,
    /// Scheduler iterations (batches formed).
    pub iterations: u64,
    /// Per-request wait between arrival and slot admission, ms.
    pub queue_wait_ms: Welford,
    /// Per-request time to first token (arrival → first token), ms.
    pub ttft_ms: Welford,
    /// Per-request mean time between tokens in the decode phase, ms.
    pub tbt_ms: Welford,
    /// Σ SD rounds across finished requests.
    pub rounds: usize,
    /// Σ draft tokens proposed across finished requests' rounds.
    pub proposed: usize,
    /// Σ draft tokens accepted across finished requests' rounds.
    pub accepted: usize,
    /// Chunk sizes picked by the Eq. 3 optimizer.
    pub chunk_sizes: Welford,
    /// Sessions per batched engine-call group: one sample per job group
    /// the scheduler executed batched, sized by the group's lane count (a
    /// decode group's middle and head `run_batch` calls share one sample)
    /// — `batch_mean` in the STATS reply.
    pub batch_occupancy: Welford,
    /// Batched cloud calls that failed and degraded to per-lane serial
    /// execution.  Non-zero means the backend is rejecting `run_batch`
    /// groups and the server is quietly running at serial throughput —
    /// `fallbacks` in the STATS reply.
    pub fallbacks: u64,
    /// Requests cancelled mid-flight (client disconnect noticed by the
    /// connection thread, or an explicit `CANCEL`) — waiting or live,
    /// torn down without a result.
    pub cancelled: u64,
    /// Requests that failed with an `ERR` reply: scheduler job-runner
    /// failures, failed session construction at admission, and
    /// submit-time validation rejections.  Without this, `finished`
    /// alone cannot reconcile submissions against
    /// `finished + queued + live`.
    pub failed: u64,
    /// Requests reaped without a reply because their client was already
    /// gone: waiting-queue entries whose reply channel died before they
    /// took a slot, plus everything torn down when the worker's command
    /// channel disconnects (no connections left).
    pub reaped: u64,
    /// Requests cancelled because their wall-clock deadline
    /// (`serve.deadline_ms`, measured from arrival) passed — the client
    /// got `ERR deadline`.
    pub deadline_expired: u64,
    /// Stale batcher jobs dropped by the slot-epoch identity check: the
    /// job's admission epoch disagreed with the slot's current occupant
    /// (the slot was freed by a cancel/expiry and re-admitted before the
    /// job was popped).  Job-level, not request-level, so it is not part
    /// of the request reconciliation and stays off the STATS wire line.
    pub stale_dropped: u64,
    /// Per-round acceptance histogram: `accept_hist[a]` counts verify
    /// rounds that accepted exactly `a` proposals — `accept_hist` in the
    /// STATS reply (comma-joined counts, `-` while empty).  Where
    /// `accept` gives the aggregate rate, this shows the shape: greedy
    /// vs stochastic verification move mass between the `a = k` bin and
    /// the early-rejection bins.
    pub accept_hist: Vec<u64>,
    /// The `[specdec] seed` the scheduler's sessions sample with — `seed`
    /// in the STATS reply, so clients can reproduce a stochastic run.
    pub sampler_seed: u64,
    /// Sessions preempted under `[serve] priority = preempt`: parked off
    /// their slot with KV paged out to the host store, later resumed
    /// (never cancelled) — `preempted` in the STATS reply.
    pub preemptions: u64,
    /// Bytes of KV moved by preemption swap-out plus resume swap-in
    /// (dedup re-shares move zero) — `kv_swap_bytes` in the STATS reply.
    pub kv_swap_bytes: u64,
    /// KV pool blocks currently mapped by at least one cache table,
    /// refreshed from the pool each scheduler iteration — `kv_blocks` in
    /// the STATS reply.
    pub kv_blocks_in_use: usize,
    /// KV pool blocks mapped by more than one table (copy-on-write prefix
    /// sharing) — `kv_shared` in the STATS reply.
    pub kv_blocks_shared: usize,
    /// Prefill→decode pool handoffs completed (disaggregated mode only;
    /// 0 in single-pool mode) — `handoffs` in the STATS reply.
    pub handoffs: u64,
    /// Queue-wait split by phase: arrival → prefill-slot admission, ms —
    /// `pf_wait_ms` in the STATS reply.  In single-pool mode this equals
    /// `queue_wait_ms`.
    pub prefill_wait_ms: Welford,
    /// Handoff-ready → decode-slot adoption wait, ms — `dc_wait_ms` in
    /// the STATS reply (0-sample in single-pool mode).
    pub decode_wait_ms: Welford,
    /// Occupied-slot fraction of the prefill pool, sampled once per
    /// scheduler iteration — `pf_occ` in the STATS reply.
    pub prefill_occ: Welford,
    /// Occupied-slot fraction of the decode pool, sampled once per
    /// scheduler iteration — `dc_occ` in the STATS reply.
    pub decode_occ: Welford,
    /// Per-request mean TBT keyed by request id — the bench harness reads
    /// this to attribute tail latency to specific streams (e.g. interactive
    /// vs aggressor).  Off the STATS wire line.
    pub tbt_by_request: Vec<(u64, f64)>,
    /// GENERATEs refused `ERR rate limited` by a connection's token
    /// bucket (`serve.rate_limit_rps` / `serve.burst`) — `rate_limited`
    /// in the STATS reply.
    pub rate_limited: u64,
    /// GENERATEs refused `ERR busy` because the executor already held
    /// `serve.admit_queue` queued requests — `shed_busy` in the STATS
    /// reply.
    pub shed_busy: u64,
    /// Connections dropped because their bounded reply outbox
    /// (`serve.outbox_lines`) overflowed — a client that stopped reading
    /// — `slow_reader_dropped` in the STATS reply.
    pub slow_reader_dropped: u64,
    /// Connections currently held by the serve event loop — a gauge, not
    /// a counter; `open_conns` in the STATS reply.
    pub open_conns: usize,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    /// Record one finished request.
    pub fn record_finish(
        &mut self,
        queue_wait_ms: f64,
        ttft_ms: f64,
        mean_tbt_ms: Option<f64>,
        rounds: usize,
        proposed: usize,
        accepted: usize,
    ) {
        self.finished += 1;
        self.queue_wait_ms.push(queue_wait_ms);
        self.ttft_ms.push(ttft_ms);
        if let Some(t) = mean_tbt_ms {
            self.tbt_ms.push(t);
        }
        self.rounds += rounds;
        self.proposed += proposed;
        self.accepted += accepted;
    }

    /// Record one completed verify round's acceptance count (growing the
    /// histogram as deeper rounds appear).
    pub fn record_round(&mut self, accepted: usize) {
        if self.accept_hist.len() <= accepted {
            self.accept_hist.resize(accepted + 1, 0);
        }
        self.accept_hist[accepted] += 1;
    }

    /// Aggregate acceptance rate over all finished requests' rounds.
    pub fn accept_rate(&self) -> f64 {
        accept_rate(self.accepted, self.proposed)
    }

    /// Fold another pool's stats into this one — the disaggregated serve
    /// path merges the prefill and decode schedulers' aggregates into one
    /// STATS view.  Counters sum, Welford streams merge losslessly, and
    /// the acceptance histogram adds elementwise.  The KV snapshots take
    /// the max, not the sum: both pools snapshot the *same* shared block
    /// pool each iteration, so summing would double-count every block.
    pub fn merge(&mut self, other: &ServeStats) {
        self.finished += other.finished;
        self.iterations += other.iterations;
        self.queue_wait_ms.merge(&other.queue_wait_ms);
        self.ttft_ms.merge(&other.ttft_ms);
        self.tbt_ms.merge(&other.tbt_ms);
        self.rounds += other.rounds;
        self.proposed += other.proposed;
        self.accepted += other.accepted;
        self.chunk_sizes.merge(&other.chunk_sizes);
        self.batch_occupancy.merge(&other.batch_occupancy);
        self.fallbacks += other.fallbacks;
        self.cancelled += other.cancelled;
        self.failed += other.failed;
        self.reaped += other.reaped;
        self.deadline_expired += other.deadline_expired;
        self.stale_dropped += other.stale_dropped;
        if self.accept_hist.len() < other.accept_hist.len() {
            self.accept_hist.resize(other.accept_hist.len(), 0);
        }
        for (i, &c) in other.accept_hist.iter().enumerate() {
            self.accept_hist[i] += c;
        }
        self.preemptions += other.preemptions;
        self.kv_swap_bytes += other.kv_swap_bytes;
        self.kv_blocks_in_use = self.kv_blocks_in_use.max(other.kv_blocks_in_use);
        self.kv_blocks_shared = self.kv_blocks_shared.max(other.kv_blocks_shared);
        self.handoffs += other.handoffs;
        self.prefill_wait_ms.merge(&other.prefill_wait_ms);
        self.decode_wait_ms.merge(&other.decode_wait_ms);
        self.prefill_occ.merge(&other.prefill_occ);
        self.decode_occ.merge(&other.decode_occ);
        self.tbt_by_request.extend_from_slice(&other.tbt_by_request);
        self.rate_limited += other.rate_limited;
        self.shed_busy += other.shed_busy;
        self.slow_reader_dropped += other.slow_reader_dropped;
        // A gauge: both pools see the same front end, so merging takes
        // the max (the non-zero side), like the shared-KV snapshots.
        self.open_conns = self.open_conns.max(other.open_conns);
    }

    /// Scheduler fields of the `STATS` reply line.
    pub fn stats_fields(&self) -> String {
        let hist = if self.accept_hist.is_empty() {
            "-".to_string()
        } else {
            self.accept_hist.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
        };
        format!(
            "requests={} iterations={} queue_wait_ms={:.1} ttft_ms={:.1} tbt_ms={:.1} \
             rounds={} accept={:.3} accept_hist={} seed={} chunk_mean={:.1} batch_mean={:.2} \
             fallbacks={} cancelled={} failed={} reaped={} deadline_expired={} \
             preempted={} kv_swap_bytes={} kv_blocks={} kv_shared={} handoffs={} \
             pf_wait_ms={:.1} dc_wait_ms={:.1} pf_occ={:.2} dc_occ={:.2} \
             rate_limited={} shed_busy={} slow_reader_dropped={} open_conns={}",
            self.finished,
            self.iterations,
            self.queue_wait_ms.mean(),
            self.ttft_ms.mean(),
            self.tbt_ms.mean(),
            self.rounds,
            self.accept_rate(),
            hist,
            self.sampler_seed,
            self.chunk_sizes.mean(),
            self.batch_occupancy.mean(),
            self.fallbacks,
            self.cancelled,
            self.failed,
            self.reaped,
            self.deadline_expired,
            self.preemptions,
            self.kv_swap_bytes,
            self.kv_blocks_in_use,
            self.kv_blocks_shared,
            self.handoffs,
            self.prefill_wait_ms.mean(),
            self.decode_wait_ms.mean(),
            self.prefill_occ.mean(),
            self.decode_occ.mean(),
            self.rate_limited,
            self.shed_busy,
            self.slow_reader_dropped,
            self.open_conns,
        )
    }
}

/// Flat result row for the bench harnesses.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub n_finished: usize,
    pub ttft_mean_ms: f64,
    pub ttft_p90_ms: f64,
    pub tbt_mean_ms: f64,
    pub tbt_p90_ms: f64,
    pub gpu_delay_mean_ms: f64,
    pub gpu_delay_std_ms: f64,
    pub accept_length: f64,
    pub pd_hit_rate: f64,
}

impl RunSummary {
    pub fn header() -> String {
        format!(
            "{:<12} {:>10} {:>10} {:>9} {:>9} {:>10} {:>9} {:>7}",
            "run", "TTFT(ms)", "p90", "TBT(ms)", "p90", "gpu(ms)", "±std", "accept"
        )
    }

    pub fn row(&self, name: &str) -> String {
        format!(
            "{:<12} {:>10.1} {:>10.1} {:>9.1} {:>9.1} {:>10.2} {:>9.2} {:>7.2}",
            name,
            self.ttft_mean_ms,
            self.ttft_p90_ms,
            self.tbt_mean_ms,
            self.tbt_p90_ms,
            self.gpu_delay_mean_ms,
            self.gpu_delay_std_ms,
            self.accept_length
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec_with_tokens(times_ms: &[f64], arrived_ms: f64) -> RequestRecord {
        let mut r = RequestRecord::new(0, 0, 128, SimTime::from_ms(arrived_ms));
        for &t in times_ms {
            let st = SimTime::from_ms(t);
            if r.first_token.is_none() {
                r.first_token = Some(st);
            }
            r.token_times.push(st);
        }
        r.finished = r.token_times.last().copied();
        r
    }

    #[test]
    fn ttft_and_tbt() {
        let r = rec_with_tokens(&[100.0, 120.0, 150.0, 170.0], 40.0);
        assert!((r.ttft_ms().unwrap() - 60.0).abs() < 1e-9);
        // total 70ms over 3 intervals
        assert!((r.mean_tbt_ms().unwrap() - 70.0 / 3.0).abs() < 1e-9);
        assert_eq!(r.tbt_intervals_ms(), vec![20.0, 30.0, 20.0]);
    }

    #[test]
    fn unfinished_requests_excluded() {
        let mut rec = Recorder::new();
        rec.requests.push(rec_with_tokens(&[100.0, 110.0], 0.0));
        let mut unfinished = rec_with_tokens(&[200.0], 0.0);
        unfinished.finished = None;
        rec.requests.push(unfinished);
        assert_eq!(rec.finished_requests().count(), 1);
        assert_eq!(rec.ttfts_ms(), vec![100.0]);
    }

    #[test]
    fn accept_length_weighted_over_rounds() {
        let mut rec = Recorder::new();
        let mut a = rec_with_tokens(&[1.0], 0.0);
        a.sd_rounds = 10;
        a.sd_accepted = 20;
        let mut b = rec_with_tokens(&[1.0], 0.0);
        b.sd_rounds = 5;
        b.sd_accepted = 5;
        rec.requests.push(a);
        rec.requests.push(b);
        assert!((rec.accept_length() - 25.0 / 15.0).abs() < 1e-12);
    }

    #[test]
    fn prefill_sla_normalizes_by_prompt_units() {
        let mut rec = Recorder::new();
        let mut r = rec_with_tokens(&[512.0], 0.0);
        r.prompt_len = 256; // 2 units of 128
        rec.requests.push(r);
        assert_eq!(rec.prefill_sla_sample(), vec![256.0]);
    }

    #[test]
    fn decode_sla_windows_of_ten() {
        let times: Vec<f64> = (0..=12).map(|i| i as f64 * 10.0).collect();
        let mut rec = Recorder::new();
        rec.requests.push(rec_with_tokens(&times, 0.0));
        let s = rec.decode_sla_sample();
        // 13 tokens -> 3 sliding windows of 11 points, each spanning 100ms
        assert_eq!(s.len(), 3);
        assert!(s.iter().all(|&x| (x - 100.0).abs() < 1e-9));
        assert!((Recorder::compliance(&s, 100.0) - 1.0).abs() < 1e-12);
        assert_eq!(Recorder::compliance(&s, 99.0), 0.0);
    }

    #[test]
    fn serve_stats_aggregate_and_accept_rate() {
        let mut s = ServeStats::new();
        assert_eq!(s.accept_rate(), 0.0, "no rounds yet");
        s.record_finish(2.0, 10.0, Some(4.0), 3, 10, 4);
        s.record_finish(4.0, 20.0, None, 2, 5, 2);
        assert_eq!(s.finished, 2);
        assert!((s.queue_wait_ms.mean() - 3.0).abs() < 1e-12);
        assert!((s.ttft_ms.mean() - 15.0).abs() < 1e-12);
        assert_eq!(s.tbt_ms.count(), 1, "1-token requests have no TBT");
        assert!((s.accept_rate() - 6.0 / 15.0).abs() < 1e-12);
        s.batch_occupancy.push(3.0);
        s.cancelled = 2;
        s.failed = 1;
        s.reaped = 3;
        s.deadline_expired = 4;
        s.preemptions = 2;
        s.kv_swap_bytes = 4096;
        s.kv_blocks_in_use = 12;
        s.kv_blocks_shared = 5;
        s.rate_limited = 6;
        s.shed_busy = 7;
        s.slow_reader_dropped = 8;
        s.open_conns = 9;
        assert!(s.stats_fields().contains("accept_hist=- "), "empty histogram renders as -");
        s.record_round(2);
        s.record_round(0);
        s.record_round(2);
        s.record_round(4);
        assert_eq!(s.accept_hist, vec![1, 0, 2, 0, 1]);
        s.sampler_seed = 7;
        let f = s.stats_fields();
        for key in [
            "requests=2",
            "rounds=5",
            "accept=0.400",
            "accept_hist=1,0,2,0,1",
            "seed=7",
            "queue_wait_ms=3.0",
            "batch_mean=3.00",
            "fallbacks=0",
            "cancelled=2",
            "failed=1",
            "reaped=3",
            "deadline_expired=4",
            "preempted=2",
            "kv_swap_bytes=4096",
            "kv_blocks=12",
            "kv_shared=5",
            "handoffs=0",
            "pf_wait_ms=",
            "dc_wait_ms=",
            "pf_occ=",
            "dc_occ=",
            "rate_limited=6",
            "shed_busy=7",
            "slow_reader_dropped=8",
            "open_conns=9",
        ] {
            assert!(f.contains(key), "missing {key} in {f}");
        }
    }

    #[test]
    fn serve_stats_merge_pools() {
        let mut a = ServeStats::new();
        a.record_finish(2.0, 10.0, Some(4.0), 3, 10, 4);
        a.record_round(2);
        a.handoffs = 3;
        a.kv_blocks_in_use = 12;
        a.kv_blocks_shared = 2;
        a.kv_swap_bytes = 100;
        a.tbt_by_request.push((1, 4.0));
        let mut b = ServeStats::new();
        b.record_finish(4.0, 20.0, Some(6.0), 2, 5, 2);
        b.record_round(0);
        b.record_round(4);
        b.kv_blocks_in_use = 9;
        b.kv_blocks_shared = 5;
        b.kv_swap_bytes = 50;
        b.tbt_by_request.push((2, 6.0));
        b.rate_limited = 2;
        b.shed_busy = 3;
        b.slow_reader_dropped = 1;
        b.open_conns = 4;
        a.merge(&b);
        assert_eq!(a.finished, 2);
        assert_eq!(a.rounds, 5);
        assert!((a.queue_wait_ms.mean() - 3.0).abs() < 1e-12);
        assert_eq!(a.tbt_ms.count(), 2);
        assert_eq!(a.accept_hist, vec![1, 0, 1, 0, 1]);
        assert_eq!(a.handoffs, 3);
        // Shared-pool snapshots take the max (summing would double-count),
        // swap traffic (per-pool work) sums.
        assert_eq!(a.kv_blocks_in_use, 12);
        assert_eq!(a.kv_blocks_shared, 5);
        assert_eq!(a.kv_swap_bytes, 150);
        assert_eq!(a.tbt_by_request.len(), 2);
        // Front-end flow-control counters sum; open_conns is a gauge of
        // the one shared front end, so merging takes the max.
        assert_eq!(a.rate_limited, 2);
        assert_eq!(a.shed_busy, 3);
        assert_eq!(a.slow_reader_dropped, 1);
        assert_eq!(a.open_conns, 4);
    }

    #[test]
    fn gpu_delay_stats_fig8_shape() {
        let mut rec = Recorder::new();
        rec.gpu_step_delays = vec![6.0, 7.0, 8.0, 7.0, 6.0];
        let (m, s) = rec.gpu_delay_stats();
        assert!((m - 6.8).abs() < 1e-9);
        assert!(s > 0.0 && s < 1.0);
    }
}
