//! Device fleet model: the 30 heterogeneous NVIDIA Jetson kits.
//!
//! Paper §4.1: 20 Jetson AGX Xavier (32 TOPS) + 10 AGX Orin (200 TOPS);
//! each device runs in one of several power modes, and "the AGX Orin with
//! the highest performance mode can achieve inference 10× faster than the
//! AGX Xavier with the lowest performance mode"; modes are re-randomized
//! every 5 requests to emulate time-varying resources.
//!
//! The *numerics* of every device run through the same PJRT artifacts; the
//! class/mode only scales the device-side compute-delay model (γ_i^t in
//! Eq. 6 — the per-draft-token delay the state monitor collects).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    AgxXavier,
    AgxOrin,
}

impl DeviceClass {
    /// Paper fleet: 20 Xavier + 10 Orin out of 30.
    pub fn for_device(device_id: usize, n_devices: usize) -> DeviceClass {
        // Interleave so distance groups (net::DistanceGroup, assigned by
        // contiguous id ranges) contain both classes.
        if device_id % 3 == 2 {
            DeviceClass::AgxOrin
        } else {
            DeviceClass::AgxXavier
        }
        .scaled(n_devices)
    }

    fn scaled(self, _n: usize) -> DeviceClass {
        self
    }

    pub fn n_modes(self) -> usize {
        match self {
            DeviceClass::AgxXavier => 4,
            DeviceClass::AgxOrin => 3,
        }
    }

    /// Per-draft-token compute delay (ms) of the SLM at a given mode.
    ///
    /// Calibration (DESIGN.md §3): a Vicuna-68M-class drafter on AGX Orin
    /// mode 0 runs ≈ 4 ms/token (Table 5 back-solves to a fleet-average
    /// γ ≈ 10–15 ms); the paper's 10× spread puts Xavier at its lowest
    /// mode at ≈ 37 ms/token.  Modes interpolate geometrically.
    pub fn draft_ms_per_token(self, mode: usize) -> f64 {
        let (fastest, steps): (f64, f64) = match self {
            DeviceClass::AgxOrin => (3.0, 1.4),    // modes 0..2 → 3, 4.2, 5.9
            DeviceClass::AgxXavier => (7.0, 1.55), // modes 0..3 → 7, 10.9, 16.8, 26.1
        };
        fastest * steps.powi(mode as i32)
    }

    /// Delay (ms) for the device-side *prefill* compute of a chunk of
    /// `tokens` through the input submodel (+ adapter).  Parallel within
    /// the chunk, so far cheaper per token than autoregressive drafting
    /// (Fig. 1b: local computation ≈ 0.09 s for a 2k prompt on Orin).
    pub fn prefill_ms(self, mode: usize, tokens: usize) -> f64 {
        let per_tok = self.draft_ms_per_token(mode) * 0.011;
        1.0 + per_tok * tokens as f64
    }

    /// Delay (ms) for the output-head pass over `tokens` verified tokens.
    pub fn head_ms(self, mode: usize, tokens: usize) -> f64 {
        0.3 + self.draft_ms_per_token(mode) * 0.02 * tokens as f64
    }
}

/// Mutable per-device compute state: current power mode, re-randomized
/// every `MODE_SWITCH_PERIOD` requests (paper: every 5 requests).
pub const MODE_SWITCH_PERIOD: usize = 5;

#[derive(Debug, Clone)]
pub struct DeviceCompute {
    pub class: DeviceClass,
    pub mode: usize,
    requests_since_switch: usize,
    rng: Rng,
}

impl DeviceCompute {
    pub fn new(device_id: usize, n_devices: usize, root: &Rng) -> Self {
        let class = DeviceClass::for_device(device_id, n_devices);
        let mut rng = root.substream(0x0DE0 + device_id as u64);
        let mode = rng.below(class.n_modes());
        DeviceCompute { class, mode, requests_since_switch: 0, rng }
    }

    /// Called when the device starts a new request; possibly switches mode.
    pub fn on_request(&mut self) {
        self.requests_since_switch += 1;
        if self.requests_since_switch >= MODE_SWITCH_PERIOD {
            self.requests_since_switch = 0;
            self.mode = self.rng.below(self.class.n_modes());
        }
    }

    /// γ_i^t — current drafting delay per token, ms.
    pub fn gamma_ms(&self) -> f64 {
        self.class.draft_ms_per_token(self.mode)
    }

    pub fn prefill_ms(&self, tokens: usize) -> f64 {
        self.class.prefill_ms(self.mode, tokens)
    }

    pub fn head_ms(&self, tokens: usize) -> f64 {
        self.class.head_ms(self.mode, tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_composition_roughly_paper() {
        let n = 30;
        let orin = (0..n).filter(|&i| DeviceClass::for_device(i, n) == DeviceClass::AgxOrin).count();
        assert_eq!(orin, 10, "10 Orin of 30 (paper §4.1)");
    }

    #[test]
    fn ten_x_spread_between_extremes() {
        let fast = DeviceClass::AgxOrin.draft_ms_per_token(0);
        let slow = DeviceClass::AgxXavier.draft_ms_per_token(3);
        let ratio = slow / fast;
        assert!((8.0..12.0).contains(&ratio), "spread {ratio} (paper: 10×)");
    }

    #[test]
    fn modes_monotone_slower() {
        for class in [DeviceClass::AgxOrin, DeviceClass::AgxXavier] {
            let mut last = 0.0;
            for m in 0..class.n_modes() {
                let d = class.draft_ms_per_token(m);
                assert!(d > last);
                last = d;
            }
        }
    }

    #[test]
    fn mode_switches_every_five_requests() {
        let root = Rng::new(5);
        let mut d = DeviceCompute::new(0, 30, &root);
        let mut switches = 0;
        let mut last_mode = d.mode;
        for i in 1..=100 {
            d.on_request();
            if i % MODE_SWITCH_PERIOD == 0 {
                // mode *may* resample to the same value; just count changes
                if d.mode != last_mode {
                    switches += 1;
                }
                last_mode = d.mode;
            } else {
                assert_eq!(d.mode, last_mode, "switched off-period at {i}");
            }
        }
        assert!(switches > 0, "never switched in 100 requests");
    }

    #[test]
    fn prefill_cheaper_than_drafting_per_token() {
        let d = DeviceClass::AgxOrin;
        let per_tok_prefill = d.prefill_ms(0, 128) / 128.0;
        assert!(per_tok_prefill < d.draft_ms_per_token(0) / 4.0);
    }
}
