//! Execution engines: typed wrappers over the artifact registry that
//! implement the device-side and cloud-side primitives of the HAT protocol
//! against whichever [`ExecBackend`](crate::backend::ExecBackend) the
//! registry selected (bucket selection, padding, KV threading).
//!
//! These are *primitives*; the protocol logic (speculative decoding rounds,
//! chunked prefill, parallel drafting) lives in `specdec` and `frameworks`.

use std::path::Path;

use anyhow::Result;

use crate::backend::{PagedItem, Tensor};
use crate::config::KvConfig;
use crate::kv::KvPool;
use crate::model::{CloudStream, DeviceStream, TokenId};
use crate::runtime::{
    f32_tensor_padded, pos_tensor, tokens_tensor, ArtifactRegistry, Manifest, ModelSpec,
};

/// One shared engine: in the real deployment the input/head/draft artifacts
/// run on the device and the middle artifact in the cloud; here one backend
/// executes both sides (the *timing* separation is the
/// simulator's job, the *data-flow* separation is enforced by the artifact
/// boundaries — see `examples/privacy_audit.rs`).
///
/// The engine owns the paged [`KvPool`] every stream's caches draw from
/// (`[kv] block_tokens` / `kv_blocks`); KV tensors never surface above the
/// backend seam — primitives thread block-table handles through
/// `run_paged`/`run_batch_paged`.
pub struct Engine {
    pub reg: ArtifactRegistry,
    pool: KvPool,
}

/// Output of one draft-model step.
pub struct DraftStepOut {
    pub logits: Vec<f32>,
    /// Shallow hidden state of the processed token — buffered by the
    /// device and uploaded for verification (never recomputed).
    pub shallow: Vec<f32>,
}

impl Engine {
    pub fn load(dir: &Path) -> Result<Engine> {
        Engine::with_registry(ArtifactRegistry::load(dir)?)
    }

    /// Load from the default artifact dir, falling back to the reference
    /// backend's synthetic model when no artifacts are built — the server
    /// and examples run end-to-end on a clean machine.
    pub fn load_default() -> Result<Engine> {
        Engine::with_registry(ArtifactRegistry::load_or_synthetic(
            &ArtifactRegistry::default_dir(),
        )?)
    }

    /// Engine over the synthetic reference model (no files needed).
    pub fn synthetic() -> Engine {
        Engine::with_registry(ArtifactRegistry::synthetic())
            .expect("default kv config covers the synthetic manifest")
    }

    /// Engine over an explicit registry with the default KV pool geometry
    /// — the injection point for tests with fault-injecting backends.
    pub fn with_registry(reg: ArtifactRegistry) -> Result<Engine> {
        Engine::with_registry_kv(reg, &KvConfig::default())
    }

    /// Engine over an explicit registry and `[kv]` pool geometry.  Errors
    /// when the pool cannot hold one max-length session (three caches).
    pub fn with_registry_kv(reg: ArtifactRegistry, kv: &KvConfig) -> Result<Engine> {
        let (hidden, max_seq) = (reg.model().hidden, reg.model().max_seq);
        let pool = KvPool::new(kv, hidden, max_seq)?;
        Ok(Engine { reg, pool })
    }

    /// Engine over an explicit registry that *shares* an existing KV pool
    /// instead of allocating its own.  This is what prefill/decode
    /// disaggregation needs: the two pool executors run separate engines
    /// (separate backend clients and runtime stats) but a session's paged
    /// block tables must stay valid across the prefill→decode handoff, so
    /// both engines allocate from one physical pool and the handoff moves
    /// block-table handles, never dense KV bytes.  Errors when the pool
    /// row width cannot be shared (different hidden size).
    pub fn with_registry_shared(reg: ArtifactRegistry, pool: &KvPool) -> Result<Engine> {
        let hidden = reg.model().hidden;
        anyhow::ensure!(
            pool.block_bytes() == pool.block_tokens() * hidden * 4,
            "shared kv pool row width does not match model hidden size {hidden}"
        );
        Ok(Engine { reg, pool: pool.clone() })
    }

    /// A sibling engine: fresh registry (own backend client + compile/exec
    /// stats) over the *same* artifacts and the *same* KV pool as `self`.
    /// Deterministic backends make siblings bit-identical executors, so a
    /// session can be handed from one to the other mid-stream.
    pub fn sibling(&self) -> Result<Engine> {
        let reg = ArtifactRegistry::load_or_synthetic(&ArtifactRegistry::default_dir())?;
        anyhow::ensure!(
            reg.model() == self.reg.model(),
            "sibling registry resolved a different model spec"
        );
        Engine::with_registry_shared(reg, &self.pool)
    }

    /// The paged KV pool all of this engine's streams draw from.
    pub fn kv_pool(&self) -> &KvPool {
        &self.pool
    }

    /// Fresh device-side stream (shallow + adapter caches) on the pool.
    pub fn new_device_stream(&self) -> DeviceStream {
        DeviceStream::new(self.reg.model(), &self.pool)
    }

    /// Fresh cloud-side stream (middle cache) on the pool.
    pub fn new_cloud_stream(&self) -> CloudStream {
        CloudStream::new(self.reg.model(), &self.pool)
    }

    pub fn spec(&self) -> &ModelSpec {
        self.reg.model()
    }

    // -- device side -------------------------------------------------------

    /// Input submodel over a token chunk: returns the shallow hidden states
    /// [T, H] and updates the stream's shallow KV at its write position.
    pub fn device_input(&self, st: &mut DeviceStream, tokens: &[TokenId]) -> Result<Vec<f32>> {
        let t = tokens.len();
        let b = self.reg.bucket_for(t)?;
        let name = Manifest::artifact_name("device_input", b);
        let pos = st.skv.write_pos();
        let toks = tokens_tensor(tokens, b)?;
        let posl = pos_tensor(pos);
        let mut outs = self.reg.run_paged(&name, &[&toks, &posl], &mut [&mut st.skv])?;
        let mut hidden = outs.swap_remove(0).data;
        hidden.truncate(t * self.spec().hidden);
        st.skv.wrote(t);
        Ok(hidden)
    }

    /// Adapter prefill over shallow hidden states [T, H]: fills Λ's KV.
    pub fn adapter_prefill(&self, st: &mut DeviceStream, hidden: &[f32]) -> Result<()> {
        let h = self.spec().hidden;
        let t = hidden.len() / h;
        let b = self.reg.bucket_for(t)?;
        let name = Manifest::artifact_name("adapter_prefill", b);
        let pos = st.akv.write_pos();
        let hid = f32_tensor_padded(hidden, h, b)?;
        let posl = pos_tensor(pos);
        let outs = self.reg.run_paged(&name, &[&hid, &posl], &mut [&mut st.akv])?;
        debug_assert!(outs.is_empty(), "adapter_prefill has only a KV output");
        st.akv.wrote(t);
        Ok(())
    }

    /// One autoregressive draft-model step (w_S = H_L ∘ Λ ∘ w_L^m).
    /// Advances both shallow and adapter KV write positions by 1.
    pub fn draft_step(&self, st: &mut DeviceStream, token: TokenId) -> Result<DraftStepOut> {
        debug_assert_eq!(st.skv.write_pos(), st.akv.write_pos());
        let pos = st.skv.write_pos();
        let toks = tokens_tensor(&[token], 1)?;
        let posl = pos_tensor(pos);
        let mut outs = self.reg.run_paged(
            "draft_step_1",
            &[&toks, &posl],
            &mut [&mut st.skv, &mut st.akv],
        )?;
        // Pop from the back so earlier indices stay stable (no copies).
        let shallow = outs.swap_remove(1).data;
        let logits = outs.swap_remove(0).data;
        st.skv.wrote(1);
        st.akv.wrote(1);
        Ok(DraftStepOut { logits, shallow })
    }

    /// Output submodel: deep hidden [T, H] → logits [T, V].  Batch-of-1
    /// wrapper over [`Engine::head_batch`].
    pub fn head(&self, deep: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.head_batch(&[deep])?;
        Ok(out.swap_remove(0))
    }

    /// Output submodel over a batch of independent deep-hidden uploads
    /// ([T_i, H] each): one backend call for the whole batch.  Every item
    /// must pad into the *same* token bucket (the scheduler groups jobs by
    /// bucket before calling); returns per-item logits [T_i, V].
    pub fn head_batch(&self, deeps: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if deeps.is_empty() {
            return Ok(Vec::new());
        }
        let h = self.spec().hidden;
        let v = self.spec().vocab;
        let ts: Vec<usize> = deeps.iter().map(|d| d.len() / h).collect();
        let b = self.common_bucket(&ts, "head_batch")?;
        let name = Manifest::artifact_name("device_head", b);
        let ds: Vec<Tensor> = deeps
            .iter()
            .map(|d| f32_tensor_padded(d, h, b))
            .collect::<Result<_>>()?;
        let items: Vec<Vec<&Tensor>> = ds.iter().map(|d| vec![d]).collect();
        let outs = self.reg.run_batch(&name, &items)?;
        Ok(outs
            .into_iter()
            .zip(&ts)
            .map(|(mut o, &t)| {
                let mut logits = o.swap_remove(0).data;
                logits.truncate(t * v);
                logits
            })
            .collect())
    }

    /// Medusa heads over one deep hidden state [H] → [n_medusa][V] logits.
    pub fn medusa(&self, deep: &[f32]) -> Result<Vec<Vec<f32>>> {
        let h = self.spec().hidden;
        let v = self.spec().vocab;
        assert_eq!(deep.len(), h);
        let d = f32_tensor_padded(deep, h, 1)?;
        let mut outs = self.reg.run("medusa_decode_1", &[&d])?;
        let flat = outs.swap_remove(0).data;
        Ok((0..self.spec().n_medusa).map(|j| flat[j * v..(j + 1) * v].to_vec()).collect())
    }

    // -- cloud side ----------------------------------------------------------

    /// Middle submodel over uploaded shallow hidden states [T, H] → deep
    /// hidden states [T, H]; updates the stream's middle KV.  Batch-of-1
    /// wrapper over [`Engine::cloud_middle_batch`].
    pub fn cloud_middle(&self, st: &mut CloudStream, hidden: &[f32]) -> Result<Vec<f32>> {
        let mut sts = [st];
        let mut out = self.cloud_middle_batch(&mut sts, &[hidden])?;
        Ok(out.swap_remove(0))
    }

    /// Middle submodel over a batch of per-session uploads: one backend
    /// call executes every session's chunk, threading each session's
    /// middle KV and write position independently (lane `i` reads and
    /// updates only `sts[i]`).  All items must pad into the *same* token
    /// bucket — the serve scheduler groups jobs by bucket before calling.
    /// Returns per-session deep hidden rows [T_i, H].
    pub fn cloud_middle_batch(
        &self,
        sts: &mut [&mut CloudStream],
        hiddens: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(
            sts.len() == hiddens.len(),
            "cloud_middle_batch: {} streams vs {} uploads",
            sts.len(),
            hiddens.len()
        );
        if sts.is_empty() {
            return Ok(Vec::new());
        }
        let h = self.spec().hidden;
        let ts: Vec<usize> = hiddens.iter().map(|x| x.len() / h).collect();
        let b = self.common_bucket(&ts, "cloud_middle_batch")?;
        let name = Manifest::artifact_name("cloud_middle", b);
        let hids: Vec<Tensor> = hiddens
            .iter()
            .map(|x| f32_tensor_padded(x, h, b))
            .collect::<Result<_>>()?;
        let poss: Vec<Tensor> =
            sts.iter().map(|st| pos_tensor(st.mkv.write_pos())).collect();
        let outs = {
            let mut items: Vec<PagedItem<'_>> = sts
                .iter_mut()
                .zip(hids.iter().zip(&poss))
                .map(|(st, (hid, pos))| PagedItem {
                    inputs: vec![hid, pos],
                    kvs: vec![&mut st.mkv],
                })
                .collect();
            self.reg.run_batch_paged(&name, &mut items)?
        };
        let mut deeps = Vec::with_capacity(sts.len());
        for (i, mut out) in outs.into_iter().enumerate() {
            let mut deep = out.swap_remove(0).data;
            deep.truncate(ts[i] * h);
            sts[i].mkv.wrote(ts[i]);
            deeps.push(deep);
        }
        Ok(deeps)
    }

    /// Batched verify upload: middle submodel then output head over each
    /// session's uploaded shallow rows — one backend call per stage for
    /// the whole group.  Returns per-session `(deep, logits)`.
    ///
    /// Error contract: a middle failure mutates nothing (the batched call
    /// is all-or-nothing); a head failure after the middle advanced the
    /// streams rolls every write head back to its committed prefix (the
    /// stale KV rows are masked and overwritten by the next write).  A
    /// verify round starts with all writes committed, so either way a
    /// failed round leaves the streams as it found them and can simply be
    /// re-driven.
    pub fn verify_batch(
        &self,
        sts: &mut [&mut CloudStream],
        shallows: &[&[f32]],
    ) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
        let deeps = self.cloud_middle_batch(sts, shallows)?;
        let refs: Vec<&[f32]> = deeps.iter().map(|d| d.as_slice()).collect();
        match self.head_batch(&refs) {
            Ok(logits) => Ok(deeps.into_iter().zip(logits).collect()),
            Err(e) => {
                for st in sts.iter_mut() {
                    st.mkv.rollback();
                }
                Err(e)
            }
        }
    }

    // -- helpers -------------------------------------------------------------

    /// The single token bucket a batch of per-item row counts pads into.
    /// Errors when the items mix buckets — the batched primitives' shared
    /// contract (callers group work by bucket first).
    fn common_bucket(&self, ts: &[usize], ctx: &str) -> Result<usize> {
        let b = self.reg.bucket_for(ts[0])?;
        for &t in &ts[1..] {
            let bi = self.reg.bucket_for(t)?;
            anyhow::ensure!(
                bi == b,
                "{ctx}: mixed buckets ({bi} vs {b}); group items by bucket first"
            );
        }
        Ok(b)
    }

    /// Argmax over a logit row.  NaN-tolerant: NaN entries rank below every
    /// real value (a numerically-poisoned row degrades to the first finite
    /// maximum instead of panicking or sticking at index 0).
    pub fn argmax(logits: &[f32]) -> TokenId {
        let mut best = 0;
        for (i, &x) in logits.iter().enumerate() {
            let b = logits[best];
            if b.is_nan() || (!x.is_nan() && x > b) {
                best = i;
            }
        }
        best as TokenId
    }

    /// Softmax probability of the argmax token (the Eq. 5 stop signal).
    pub fn top_prob(logits: &[f32]) -> f32 {
        Self::prob_of_argmax(logits)
    }

    /// Softmax probability of the argmax token, computed safely: the max
    /// logit is subtracted before exponentiating (a raw `exp` overflows
    /// to inf for logits ≳ 88 and the ratio collapses to NaN), and NaN
    /// entries are excluded from both the max and the sum instead of
    /// poisoning the row.  Degenerate rows (empty / all-NaN) yield 0.
    pub fn prob_of_argmax(logits: &[f32]) -> f32 {
        let m = logits
            .iter()
            .cloned()
            .filter(|x| !x.is_nan())
            .fold(f32::NEG_INFINITY, f32::max);
        if !m.is_finite() {
            return 0.0;
        }
        let sum: f32 = logits
            .iter()
            .filter(|x| !x.is_nan())
            .map(|&x| (x - m).exp())
            .sum();
        1.0 / sum
    }

    /// Top-k token ids by logit, descending.  Total order via
    /// `f32::total_cmp` with NaN mapped below every real value — the old
    /// `partial_cmp().unwrap()` panicked on any NaN logit.
    pub fn top_k(logits: &[f32], k: usize) -> Vec<TokenId> {
        let key = |x: f32| if x.is_nan() { f32::NEG_INFINITY } else { x };
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| key(logits[b]).total_cmp(&key(logits[a])));
        idx.truncate(k);
        idx.into_iter().map(|i| i as TokenId).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_topk() {
        let l = [0.1f32, 3.0, -1.0, 2.5];
        assert_eq!(Engine::argmax(&l), 1);
        assert_eq!(Engine::top_k(&l, 2), vec![1, 3]);
    }

    #[test]
    fn argmax_and_topk_tolerate_nan() {
        // Regression: partial_cmp().unwrap() panicked on NaN logits, and
        // argmax stuck at index 0 when logits[0] was NaN.
        let l = [f32::NAN, 1.0, 3.0, f32::NAN, 2.0];
        assert_eq!(Engine::argmax(&l), 2);
        assert_eq!(Engine::top_k(&l, 3), vec![2, 4, 1]);
        // All-NaN rows must not panic either.
        let all_nan = [f32::NAN; 4];
        assert!((Engine::argmax(&all_nan) as usize) < all_nan.len());
        assert_eq!(Engine::top_k(&all_nan, 2).len(), 2);
    }

    #[test]
    fn top_prob_matches_softmax() {
        let l = [1.0f32, 2.0, 3.0];
        let exp: f32 = (1.0f32.exp() + 2.0f32.exp() + 3.0f32.exp()) / 3.0f32.exp();
        assert!((Engine::top_prob(&l) - 1.0 / exp).abs() < 1e-6);
        // uniform logits → 1/n
        assert!((Engine::top_prob(&[0.0; 4]) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn prob_of_argmax_survives_large_magnitude_and_nan_rows() {
        // Regression: exponentiating without the max shift overflows for
        // logits beyond ~88, turning the probability into inf/inf = NaN.
        let huge = [3000.0f32, 2990.0, -3000.0];
        let p = Engine::prob_of_argmax(&huge);
        assert!(p.is_finite(), "overflowed: {p}");
        // Shift-invariance: the same gaps at small magnitude agree.
        let small = [10.0f32, 0.0, -5990.0];
        assert!((p - Engine::prob_of_argmax(&small)).abs() < 1e-6);
        // A NaN entry must not poison the whole row...
        let poisoned = [1.0f32, f32::NAN, 3.0];
        let q = Engine::prob_of_argmax(&poisoned);
        assert!(q.is_finite() && q > 0.5, "NaN poisoned the row: {q}");
        // ...and fully degenerate rows degrade to 0, not NaN.
        assert_eq!(Engine::prob_of_argmax(&[f32::NAN; 3]), 0.0);
        assert_eq!(Engine::prob_of_argmax(&[]), 0.0);
        // top_prob is the same computation (Eq. 5 callers see the fix).
        assert_eq!(Engine::top_prob(&huge), p);
    }

    #[test]
    fn synthetic_engine_runs_device_and_cloud_primitives() {
        let e = Engine::synthetic();
        let spec = e.spec().clone();
        let mut dev = e.new_device_stream();
        let mut cloud = e.new_cloud_stream();

        let hidden = e.device_input(&mut dev, &[1, 2, 3]).unwrap();
        assert_eq!(hidden.len(), 3 * spec.hidden);
        assert_eq!(dev.skv.write_pos(), 3);

        e.adapter_prefill(&mut dev, &hidden).unwrap();
        assert_eq!(dev.akv.write_pos(), 3);

        let deep = e.cloud_middle(&mut cloud, &hidden).unwrap();
        assert_eq!(deep.len(), 3 * spec.hidden);
        assert_eq!(cloud.mkv.write_pos(), 3);

        let logits = e.head(&deep[2 * spec.hidden..]).unwrap();
        assert_eq!(logits.len(), spec.vocab);

        let out = e.draft_step(&mut dev, 7).unwrap();
        assert_eq!(out.logits.len(), spec.vocab);
        assert_eq!(out.shallow.len(), spec.hidden);
        assert_eq!(dev.spos().write_pos(), 4);
        assert_eq!(dev.apos().write_pos(), 4);

        let heads = e.medusa(&deep[..spec.hidden]).unwrap();
        assert_eq!(heads.len(), spec.n_medusa);
        assert!(heads.iter().all(|l| l.len() == spec.vocab));
    }

    #[test]
    fn cloud_middle_batch_threads_each_stream_independently() {
        // Two sessions with different chunk lengths (2 and 3 tokens — the
        // same bucket, 4) in one batched call must produce exactly what
        // two independent single calls produce, including the KV updates.
        let e = Engine::synthetic();
        let mut d1 = e.new_device_stream();
        let mut d2 = e.new_device_stream();
        let h1 = e.device_input(&mut d1, &[1, 2, 3]).unwrap();
        let h2 = e.device_input(&mut d2, &[9, 8]).unwrap();

        let mut s1 = e.new_cloud_stream();
        let mut s2 = e.new_cloud_stream();
        let deep1 = e.cloud_middle(&mut s1, &h1).unwrap();
        let deep2 = e.cloud_middle(&mut s2, &h2).unwrap();

        let mut c1 = e.new_cloud_stream();
        let mut c2 = e.new_cloud_stream();
        let mut sts = [&mut c1, &mut c2];
        let deeps = e.cloud_middle_batch(&mut sts, &[&h1, &h2]).unwrap();
        assert_eq!(deeps[0], deep1, "lane 0 diverged from single call");
        assert_eq!(deeps[1], deep2, "lane 1 diverged from single call");
        assert_eq!(c1.mkv.write_pos(), 3);
        assert_eq!(c2.mkv.write_pos(), 2);
        assert_eq!(
            c1.mkv.gather_dense().unwrap(),
            s1.mkv.gather_dense().unwrap(),
            "lane 0 KV diverged"
        );
        assert_eq!(
            c2.mkv.gather_dense().unwrap(),
            s2.mkv.gather_dense().unwrap(),
            "lane 1 KV diverged"
        );
    }

    #[test]
    fn head_batch_matches_singles_and_rejects_mixed_buckets() {
        let e = Engine::synthetic();
        let h = e.spec().hidden;
        let a: Vec<f32> = (0..2 * h).map(|i| (i as f32 * 0.01).sin()).collect();
        let b: Vec<f32> = (0..3 * h).map(|i| (i as f32 * 0.02).cos()).collect();
        let la = e.head(&a).unwrap();
        let lb = e.head(&b).unwrap();
        let batched = e.head_batch(&[a.as_slice(), b.as_slice()]).unwrap();
        assert_eq!(batched, vec![la, lb]);
        assert!(e.head_batch(&[]).unwrap().is_empty());
        // 1 row (bucket 1) and 2 rows (bucket 4) cannot share a call.
        let c = vec![0.5f32; h];
        assert!(e.head_batch(&[c.as_slice(), a.as_slice()]).is_err());
    }

    #[test]
    fn verify_batch_is_middle_then_head() {
        let e = Engine::synthetic();
        let mut dev = e.new_device_stream();
        let hidden = e.device_input(&mut dev, &[5, 6]).unwrap();

        let mut serial = e.new_cloud_stream();
        let deep = e.cloud_middle(&mut serial, &hidden).unwrap();
        let logits = e.head(&deep).unwrap();

        let mut batched = e.new_cloud_stream();
        let mut sts = [&mut batched];
        let outs = e.verify_batch(&mut sts, &[&hidden]).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].0, deep);
        assert_eq!(outs[0].1, logits);
    }

    #[test]
    fn synthetic_engine_is_deterministic() {
        let run = || {
            let e = Engine::synthetic();
            let mut dev = e.new_device_stream();
            let h = e.device_input(&mut dev, &[4, 4, 2, 9]).unwrap();
            let o = e.draft_step(&mut dev, 11).unwrap();
            (h, o.logits)
        };
        assert_eq!(run(), run());
    }
}
