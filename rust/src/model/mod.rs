//! Per-request model state: token streams and KV-cache handles.
//!
//! KV storage is paged: streams hold [`KvCache`] block tables drawing from
//! a shared [`KvPool`] (see [`crate::kv`]), and backends read/write rows
//! through the table.  Masking is by absolute position, so *rolling back
//! rejected draft tokens is just rewinding a position counter* (the stale
//! cache rows are overwritten by the next contiguous write and can never
//! be attended before that).  `KvPos` encodes that state machine and its
//! invariants; each cache carries its own.

use crate::kv::{KvCache, KvPool};
use crate::runtime::ModelSpec;

/// Token id in the tiny model's vocab.
pub type TokenId = u32;

/// Position-counter state machine for one KV cache.
///
/// Invariants (property-tested):
/// - `committed <= written`: you can only commit what was written;
/// - rollback sets `written = committed` (stale tail abandoned);
/// - writes are contiguous: each write starts at `written`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPos {
    /// Tokens whose cache rows are verified/kept.
    pub committed: usize,
    /// Tokens written into the cache (>= committed; the tail may be
    /// speculative).
    pub written: usize,
}

impl KvPos {
    pub fn new() -> KvPos {
        KvPos::default()
    }

    /// Position at which the next write lands.
    pub fn write_pos(&self) -> usize {
        self.written
    }

    /// Record a contiguous write of `n` tokens.
    pub fn wrote(&mut self, n: usize) {
        self.written += n;
    }

    /// Commit `n` additional tokens (≤ speculative tail).
    pub fn commit(&mut self, n: usize) {
        assert!(
            self.committed + n <= self.written,
            "commit past written: {} + {n} > {}",
            self.committed,
            self.written
        );
        self.committed += n;
    }

    /// Abandon the speculative tail (rejected draft tokens).
    pub fn rollback(&mut self) {
        self.written = self.committed;
    }

    /// Re-align the write head to an absolute position `p` (used when the
    /// next verified write overwrites a speculative region).  Requires
    /// committed <= p <= written.
    pub fn seek(&mut self, p: usize) {
        assert!(
            (self.committed..=self.written).contains(&p),
            "seek {p} outside [{}, {}]",
            self.committed,
            self.written
        );
        self.written = p;
    }
}

/// Device-side state of one request stream: shallow-layer KV + adapter KV.
/// Each cache carries its own [`KvPos`] (shallow position is shared by the
/// drafting and verification paths — they produce identical rows for
/// identical tokens).
pub struct DeviceStream {
    pub skv: KvCache,
    pub akv: KvCache,
}

impl DeviceStream {
    pub fn new(spec: &ModelSpec, pool: &KvPool) -> DeviceStream {
        DeviceStream {
            skv: pool.new_cache(spec.shallow_kv_dims(), spec.max_seq),
            akv: pool.new_cache(spec.adapter_kv_dims(), spec.max_seq),
        }
    }

    /// Shallow KV position state.
    pub fn spos(&self) -> KvPos {
        self.skv.pos()
    }

    /// Adapter KV position state.
    pub fn apos(&self) -> KvPos {
        self.akv.pos()
    }
}

/// Cloud-side state of one request stream: middle-submodel KV.
pub struct CloudStream {
    pub mkv: KvCache,
}

impl CloudStream {
    pub fn new(spec: &ModelSpec, pool: &KvPool) -> CloudStream {
        CloudStream { mkv: pool.new_cache(spec.middle_kv_dims(), spec.max_seq) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{cases, forall};

    #[test]
    fn kvpos_commit_and_rollback() {
        let mut p = KvPos::new();
        p.wrote(5);
        p.commit(3);
        assert_eq!(p, KvPos { committed: 3, written: 5 });
        p.rollback();
        assert_eq!(p, KvPos { committed: 3, written: 3 });
        assert_eq!(p.write_pos(), 3);
    }

    #[test]
    #[should_panic(expected = "commit past written")]
    fn kvpos_cannot_commit_unwritten() {
        let mut p = KvPos::new();
        p.wrote(2);
        p.commit(3);
    }

    #[test]
    fn kvpos_seek_bounds() {
        let mut p = KvPos::new();
        p.wrote(10);
        p.commit(4);
        p.seek(7);
        assert_eq!(p.written, 7);
    }

    #[test]
    #[should_panic(expected = "seek")]
    fn kvpos_seek_below_committed_panics() {
        let mut p = KvPos::new();
        p.wrote(10);
        p.commit(4);
        p.seek(3);
    }

    #[test]
    fn prop_kvpos_invariant_under_random_ops() {
        forall(cases(100), |rng| {
            let mut p = KvPos::new();
            for _ in 0..200 {
                match rng.below(3) {
                    0 => p.wrote(rng.range_usize(0, 8)),
                    1 => {
                        let room = p.written - p.committed;
                        if room > 0 {
                            p.commit(rng.range_usize(0, room));
                        }
                    }
                    _ => p.rollback(),
                }
                if p.committed > p.written {
                    return Err(format!("invariant broken: {p:?}"));
                }
            }
            Ok(())
        });
    }
}
