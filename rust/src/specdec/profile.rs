//! SD round-shape profiles: the bridge between the real-execution protocol
//! and the fleet simulator (DESIGN.md §3, dual-scale principle).
//!
//! A `RoundShape` is what one decode round *looked like* algorithmically:
//! how many draft steps ran, how many tokens were uploaded for
//! verification, how many tokens came out, and whether the parallel-
//! drafting candidate hit.  `SdProfile::measure` records these from real
//! engine sessions over in-distribution prompts; the fleet simulator then
//! replays them against the calibrated testbed timing models.  A built-in
//! table (recorded from a reference run; regenerate with
//! `hat profile`) keeps the simulator usable without artifacts.

use anyhow::Result;

use crate::config::SpecDecConfig;
use crate::engine::Engine;
use crate::specdec::Session;
use crate::util::rng::Rng;
use crate::workload::PromptPool;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundShape {
    pub draft_steps: usize,
    pub verify_tokens: usize,
    pub emitted: usize,
    pub pd_hit: bool,
}

#[derive(Debug, Clone)]
pub struct SdProfile {
    /// HAT rounds (adapter drafting, PD enabled).
    pub hat: Vec<RoundShape>,
    /// U-Medusa rounds (head drafting).
    pub medusa: Vec<RoundShape>,
}

impl SdProfile {
    /// Measure round shapes by running real sessions.
    pub fn measure(
        engine: &Engine,
        pool: &PromptPool,
        cfg: &SpecDecConfig,
        n_requests: usize,
        gen_len: usize,
        seed: u64,
    ) -> Result<SdProfile> {
        let mut rng = Rng::new(seed);
        let mut hat = Vec::new();
        let mut medusa = Vec::new();
        let max_prompt = engine.spec().max_seq.saturating_sub(gen_len + 8);
        for _ in 0..n_requests {
            let plen = rng.range_usize(32, 96.min(max_prompt));
            let prompt = pool.sample(plen, &mut rng);

            let mut s = Session::new(engine, cfg.clone())?;
            s.prefill(&prompt, &[prompt.len()])?;
            while s.generated() < gen_len {
                let r = s.hat_round(true, cfg.max_draft)?;
                hat.push(RoundShape {
                    draft_steps: r.draft_steps.max(if r.pd_hit { 0 } else { 1 }),
                    verify_tokens: r.verify_tokens,
                    emitted: r.emitted.len(),
                    pd_hit: r.pd_hit,
                });
            }

            let mut s = Session::new(engine, cfg.clone())?;
            s.prefill(&prompt, &[prompt.len()])?;
            while s.generated() < gen_len {
                let r = s.medusa_round()?;
                medusa.push(RoundShape {
                    draft_steps: 0,
                    verify_tokens: r.verify_tokens,
                    emitted: r.emitted.len(),
                    pd_hit: false,
                });
            }
        }
        anyhow::ensure!(!hat.is_empty() && !medusa.is_empty(), "profile came out empty");
        Ok(SdProfile { hat, medusa })
    }

    /// Built-in table recorded from the reference artifact build
    /// (seed 42; accept lengths ≈ 1.8 / 1.4 — see EXPERIMENTS.md Table 4).
    /// Used when artifacts are absent (pure-simulation benches).
    pub fn default_table() -> SdProfile {
        // (draft_steps, verify_tokens, emitted, pd_hit)
        let hat_rows: &[(usize, usize, usize, u8)] = &[
            (2, 2, 2, 0), (3, 3, 3, 0), (1, 1, 1, 0), (4, 4, 3, 1),
            (2, 2, 1, 0), (5, 5, 4, 0), (1, 1, 1, 1), (3, 3, 2, 0),
            (2, 2, 2, 1), (6, 6, 4, 0), (1, 1, 1, 0), (2, 2, 2, 0),
            (4, 4, 2, 0), (3, 3, 3, 1), (1, 1, 1, 0), (2, 2, 1, 0),
        ];
        let med_rows: &[(usize, usize, usize, u8)] = &[
            (0, 4, 2, 0), (0, 4, 1, 0), (0, 4, 2, 0), (0, 4, 1, 0),
            (0, 4, 3, 0), (0, 4, 1, 0), (0, 4, 2, 0), (0, 4, 1, 0),
        ];
        let mk = |rows: &[(usize, usize, usize, u8)]| {
            rows.iter()
                .map(|&(d, v, e, p)| RoundShape {
                    draft_steps: d,
                    verify_tokens: v,
                    emitted: e,
                    pd_hit: p != 0,
                })
                .collect()
        };
        SdProfile { hat: mk(hat_rows), medusa: mk(med_rows) }
    }

    /// Load the measured profile from artifacts if available, else the
    /// built-in table.  `n_requests` bounds the measuring cost.
    pub fn load_or_default(cfg: &SpecDecConfig, n_requests: usize) -> SdProfile {
        let dir = crate::runtime::ArtifactRegistry::default_dir();
        if dir.join("manifest.json").exists() {
            if let Ok(engine) = Engine::load(&dir) {
                if let Ok(pool) = PromptPool::load(&dir.join(&engine.reg.manifest().prompts_file)) {
                    if let Ok(p) = SdProfile::measure(&engine, &pool, cfg, n_requests, 32, 42) {
                        return p;
                    }
                }
            }
        }
        SdProfile::default_table()
    }

    /// Mean tokens emitted per verification round (Table 4 "accept").
    pub fn accept_length(rounds: &[RoundShape]) -> f64 {
        if rounds.is_empty() {
            return 0.0;
        }
        rounds.iter().map(|r| r.emitted as f64).sum::<f64>() / rounds.len() as f64
    }

    /// Deterministic per-request round iterator.
    pub fn round(&self, medusa: bool, req_seed: u64, idx: usize) -> RoundShape {
        let rows = if medusa { &self.medusa } else { &self.hat };
        rows[((req_seed as usize).wrapping_add(idx * 7)) % rows.len()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_table_sane() {
        let p = SdProfile::default_table();
        let hat_acc = SdProfile::accept_length(&p.hat);
        let med_acc = SdProfile::accept_length(&p.medusa);
        assert!(hat_acc > 1.0, "hat accept {hat_acc}");
        assert!(med_acc > 1.0, "medusa accept {med_acc}");
        assert!(hat_acc > med_acc, "paper shape: HAT > Medusa-chain");
        for r in p.hat.iter().chain(&p.medusa) {
            assert!(r.emitted >= 1 && r.emitted <= r.verify_tokens.max(1) + 1);
        }
    }

    #[test]
    fn round_iterator_deterministic_and_in_range() {
        let p = SdProfile::default_table();
        for seed in 0..5u64 {
            for i in 0..20 {
                let a = p.round(false, seed, i);
                let b = p.round(false, seed, i);
                assert_eq!(a, b);
            }
        }
    }
}
