//! Speculative decoding protocol (real-execution path).
//!
//! Implements HAT's §3.4–3.5 data path with actual backend calls: threshold
//! drafting (Eq. 5), hidden-state verification through the cloud middle
//! submodel, KV rollback of rejected tokens, and parallel drafting with
//! top-k candidate branches (§3.5).  Also the U-shape per-token decode and
//! the U-Medusa head-drafting round, so all four frameworks share one
//! session abstraction.
//!
//! Losslessness (tested in tests/golden.rs and tests/sampling_stats.rs):
//! under greedy decoding (temperature 0, the default) the emitted token
//! stream equals full-model autoregressive greedy decoding, regardless
//! of draft quality.  With temperature > 0 the same guarantee holds in
//! seeded form — the committed stream is token-identical to direct
//! seeded sampling from the target model under `SampleVerify::Coupled`
//! (common-random-number verification), and distribution-identical at
//! every position under `SampleVerify::Rejection` (canonical stochastic
//! speculative sampling).  All draws are keyed by `(seed, context
//! position)`, so round shape, scheduler interleaving and aborted
//! rounds never reorder them.
//!
//! Timing is *not* this module's concern — the fleet simulator replays
//! round shapes against the calibrated testbed models; this module is what
//! `examples/quickstart.rs` runs end-to-end for real.

pub mod profile;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::{SampleVerify, SpecDecConfig};
use crate::engine::Engine;
use crate::kv::KvCache;
use crate::model::{CloudStream, DeviceStream, TokenId};
use crate::sampler::Sampler;

/// Outcome of one decode round (one device-cloud interaction).
#[derive(Debug, Clone)]
pub struct RoundResult {
    /// Tokens proposed by the drafter this round (d_1..d_k).
    pub proposed: Vec<TokenId>,
    /// How many proposals were accepted (a).
    pub accepted: usize,
    /// Tokens emitted into the context: d_1..d_a + correction (or all k).
    pub emitted: Vec<TokenId>,
    /// Draft-model steps spent in the drafting stage (0 on a PD hit).
    pub draft_steps: usize,
    /// Tokens uploaded for verification (= hidden-state rows).
    pub verify_tokens: usize,
    /// Parallel-drafting hit: this round's draft was pre-computed during
    /// the previous round's verification wait.
    pub pd_hit: bool,
}

/// Pre-drafted continuation from a parallel-drafting branch.
struct PreDraft {
    /// The d_0 this branch assumed.
    base: TokenId,
    /// The commit depth (rows) this branch's start position assumes —
    /// adoption requires both token and position to match.
    assumed_rows: usize,
    proposed: Vec<TokenId>,
    /// Shallow hiddens of the tokens the branch processed.
    shallow: Vec<f32>,
    /// Draft distributions each proposal was sampled from (empty under
    /// greedy decoding; needed for `SampleVerify::Rejection`).
    q_dists: Vec<Vec<f64>>,
    /// Copy-on-write forks of the device caches with the branch's
    /// speculative tail written past the fork point; adoption is a move.
    skv: KvCache,
    akv: KvCache,
    steps: usize,
}

/// In-flight chunked-prefill state (between [`Session::prefill_begin`] and
/// the final [`Session::prefill_step`]).
struct PrefillState {
    prompt: Vec<TokenId>,
    /// Prompt tokens already processed.
    off: usize,
    /// Deep hidden of the last processed row (head input once complete).
    last_deep: Vec<f32>,
    /// Rows of the chunk staged by [`Session::prefill_chunk_begin`] whose
    /// verified deep rows [`Session::prefill_chunk_finish`] still awaits.
    staged: Option<usize>,
}

/// A verify round staged between [`Session::verify_begin`] and
/// [`Session::verify_finish`] — the device-side halves of a HAT round,
/// split out so the serve scheduler can batch the cloud-side middle+head
/// calls across sessions.
struct PendingVerify {
    proposed: Vec<TokenId>,
    /// k+1 shallow hidden rows to upload.
    shallow: Vec<f32>,
    draft_steps: usize,
    pd_hit: bool,
    /// Draft distributions each proposal was sampled from (empty under
    /// greedy decoding; consumed by `SampleVerify::Rejection`).
    q_dists: Vec<Vec<f64>>,
    /// Parallel-drafting branches speculated during the verification wait.
    branches: Vec<PreDraft>,
}

/// One request's end-to-end inference session over the real engine.
///
/// The session is a *resumable step machine*: the serve scheduler drives it
/// one prefill chunk ([`Session::prefill_step`]) or one decode round
/// ([`Session::hat_round_capped`]) at a time, interleaving many sessions at
/// chunk/round granularity.  The one-shot [`Session::prefill`] wrapper
/// preserves the original monolithic API for offline callers.
pub struct Session<'e> {
    pub engine: &'e Engine,
    pub dev: DeviceStream,
    pub cloud: CloudStream,
    /// Full context: prompt + generated tokens.
    pub ctx: Vec<TokenId>,
    n_prompt: usize,
    /// Staged chunked prefill, if one is in flight.
    prefill: Option<PrefillState>,
    /// Staged verify round, if one is in flight.
    verify: Option<PendingVerify>,
    /// First undrafted token (the d_0 of the next round).
    pending: Option<TokenId>,
    /// Deep hidden of the last verified row (Medusa state).
    last_deep: Vec<f32>,
    /// Top-k candidates for the correction slot (from the step that
    /// proposed the last draft token) — PD inputs (§3.5).
    corr_candidates: Vec<TokenId>,
    /// Top-k candidates for the bonus slot (from processing the last
    /// draft token).
    bonus_candidates: Vec<TokenId>,
    prebuilt: Option<PreDraft>,
    /// Seeded sampler; all stochastic draws are keyed by context position
    /// so they are invariant to round shape and scheduler interleaving.
    sampler: Sampler,
    cfg: SpecDecConfig,
}

impl<'e> Session<'e> {
    pub fn new(engine: &'e Engine, cfg: SpecDecConfig) -> Result<Session<'e>> {
        let sampler = Sampler::from_cfg(&cfg);
        Ok(Session {
            engine,
            dev: engine.new_device_stream(),
            cloud: engine.new_cloud_stream(),
            ctx: Vec::new(),
            n_prompt: 0,
            prefill: None,
            verify: None,
            pending: None,
            last_deep: Vec::new(),
            corr_candidates: Vec::new(),
            bonus_candidates: Vec::new(),
            prebuilt: None,
            sampler,
            cfg,
        })
    }

    /// Generated-token context for the repetition penalty: committed
    /// tokens past the prompt plus `extra` in-round tokens assumed
    /// committed.  A deterministic function of the committed stream, so
    /// penalty state never needs separate bookkeeping (and survives
    /// cancellation / re-drafting for free).
    fn rep_ctx(&self, extra: &[TokenId]) -> Vec<TokenId> {
        let start = self.n_prompt.min(self.ctx.len());
        let mut out = self.ctx[start..].to_vec();
        out.extend_from_slice(extra);
        out
    }

    /// The target model's seeded sample at absolute context position
    /// `pos`: inverse-CDF of the processed distribution of `row` under
    /// that position's coupling uniform.  Every committed token is
    /// exactly this — the invariant that makes coupled speculative
    /// verification token-identical to direct seeded sampling.
    fn p_sample_row(&self, row: &[f32], extra_ctx: &[TokenId], pos: usize) -> TokenId {
        let dist = self.sampler.dist(row, &self.rep_ctx(extra_ctx));
        Sampler::pick(&dist, self.sampler.u_at(pos))
    }

    /// Stage a prompt for resumable chunked prefill without processing
    /// anything yet.  Drive it with [`Session::prefill_step`]; the serve
    /// scheduler calls that once per batcher-admitted prefill chunk.
    ///
    /// Misuse (re-prefill of a used session, double staging, empty prompt)
    /// is an `Err`, not a panic: the serve worker owns many sessions, so a
    /// protocol bug in one lane must fail that lane, not the process.
    pub fn prefill_begin(&mut self, prompt: &[TokenId]) -> Result<()> {
        ensure!(self.ctx.is_empty(), "prefill on a used session");
        ensure!(self.prefill.is_none(), "prefill already staged");
        ensure!(!prompt.is_empty(), "empty prompt");
        self.prefill = Some(PrefillState {
            prompt: prompt.to_vec(),
            off: 0,
            last_deep: Vec::new(),
            staged: None,
        });
        Ok(())
    }

    /// Prompt tokens not yet prefilled (0 when no prefill is staged).
    pub fn prefill_remaining(&self) -> usize {
        self.prefill.as_ref().map_or(0, |p| p.prompt.len() - p.off)
    }

    /// Process the next prefill chunk of up to `max_tokens` prompt tokens.
    /// Each chunk flows device_input → adapter_prefill → cloud_middle
    /// (exactly HAT's pipelined prefill data path, Fig. 4 — the
    /// virtual-time overlap is the simulator's job).  Returns
    /// `Some(first_token)` when the last chunk completes (the head runs on
    /// that chunk's final row), `None` while prompt tokens remain.
    ///
    /// Batch-of-1 wrapper over the [`Session::prefill_chunk_begin`] /
    /// [`Session::prefill_chunk_finish`] halves the serve scheduler uses
    /// to batch the cloud-side middle call across sessions.
    pub fn prefill_step(&mut self, max_tokens: usize) -> Result<Option<TokenId>> {
        let hidden = self.prefill_chunk_begin(max_tokens)?;
        let result = self
            .engine
            .cloud_middle(&mut self.cloud, &hidden)
            .and_then(|deep| self.prefill_chunk_finish(&deep));
        match result {
            Ok(r) => Ok(r),
            Err(e) => {
                // Abandon the staged chunk and roll every write head back
                // to the committed prefix (the cloud head too — it
                // advances when the middle succeeds but the final head
                // fails in prefill_chunk_finish), so the session stays
                // usable (the chunk can be re-driven from scratch)
                // instead of panicking "already staged" on the next call.
                if let Some(st) = self.prefill.as_mut() {
                    st.staged = None;
                }
                self.dev.skv.rollback();
                self.dev.akv.rollback();
                self.cloud.mkv.rollback();
                Err(e)
            }
        }
    }

    /// Device half of one prefill chunk: input + adapter submodels over
    /// the next up-to-`max_tokens` prompt tokens.  Returns the shallow
    /// hidden rows [c, H] to upload; complete the chunk by passing the
    /// verified deep rows to [`Session::prefill_chunk_finish`].
    pub fn prefill_chunk_begin(&mut self, max_tokens: usize) -> Result<Vec<f32>> {
        ensure!(max_tokens > 0, "empty prefill chunk");
        let mut st = match self.prefill.take() {
            Some(st) => st,
            None => bail!("no prefill staged (call prefill_begin first)"),
        };
        if st.staged.is_some() {
            // Put the state back before erroring: the staged chunk is
            // still completable (or abortable) by the caller.
            self.prefill = Some(st);
            bail!("prefill chunk already staged");
        }
        let c = max_tokens.min(st.prompt.len() - st.off);
        let tokens = &st.prompt[st.off..st.off + c];
        let staged = self.engine.device_input(&mut self.dev, tokens).and_then(|hidden| {
            self.engine.adapter_prefill(&mut self.dev, &hidden)?;
            Ok(hidden)
        });
        match staged {
            Ok(hidden) => {
                st.staged = Some(c);
                self.prefill = Some(st);
                Ok(hidden)
            }
            Err(e) => {
                // Restore the staged prompt and roll the device write
                // heads back, so the chunk stays re-drivable instead of
                // the prefill state vanishing with the error.  Rolling
                // back abandons any rows the failed chunk already wrote:
                // they sit past the committed prefix in blocks this table
                // still owns, so the re-driven chunk overwrites them and
                // no pool block leaks.
                self.prefill = Some(st);
                self.dev.skv.rollback();
                self.dev.akv.rollback();
                Err(e)
            }
        }
    }

    /// Cloud-download half of one prefill chunk: commits the chunk's KV
    /// rows given its verified deep hidden rows [c, H] (from
    /// [`Engine::cloud_middle`] or a batched
    /// [`Engine::cloud_middle_batch`] lane).  Returns `Some(first_token)`
    /// when the prompt is fully prefilled, `None` otherwise.
    pub fn prefill_chunk_finish(&mut self, deep: &[f32]) -> Result<Option<TokenId>> {
        let mut st = match self.prefill.take() {
            Some(st) => st,
            None => bail!("no prefill staged (call prefill_begin first)"),
        };
        let c = match st.staged.take() {
            Some(c) => c,
            None => {
                self.prefill = Some(st);
                bail!("no prefill chunk staged (call prefill_chunk_begin first)");
            }
        };
        let h = self.engine.spec().hidden;
        if deep.len() < c * h {
            // A short deep buffer is a backend bug; leave the chunk staged
            // (re-drivable / abortable) instead of slicing out of bounds.
            let got = deep.len();
            st.staged = Some(c);
            self.prefill = Some(st);
            bail!("prefill deep rows too short: got {got} floats, need {c}x{h}");
        }
        st.last_deep = deep[(c - 1) * h..c * h].to_vec();
        // Final chunk: run the (fallible) head *before* committing
        // anything, so a head failure leaves the chunk staged and the
        // session re-drivable instead of half-completed.
        let last = st.off + c == st.prompt.len();
        let logits = if last {
            match self.engine.head(&st.last_deep) {
                Ok(l) => Some(l),
                Err(e) => {
                    st.staged = Some(c);
                    self.prefill = Some(st);
                    return Err(e);
                }
            }
        } else {
            None
        };
        st.off += c;
        self.dev.skv.commit(c);
        self.dev.akv.commit(c);
        self.cloud.mkv.commit(c);
        let Some(logits) = logits else {
            self.prefill = Some(st);
            return Ok(None);
        };
        self.n_prompt = st.prompt.len();
        self.ctx.extend_from_slice(&st.prompt);
        let t1 = if self.sampler.greedy() {
            Engine::argmax(&logits)
        } else {
            // First generated token: position n_prompt, empty rep context.
            self.p_sample_row(&logits, &[], self.ctx.len())
        };
        self.ctx.push(t1);
        self.pending = Some(t1);
        self.last_deep = st.last_deep;
        Ok(Some(t1))
    }

    /// One-shot prefill of the whole prompt in `chunks` (sizes summing to
    /// prompt.len()), returning the first output token.  Wrapper over the
    /// resumable [`Session::prefill_begin`] / [`Session::prefill_step`]
    /// machine — the emitted stream is chunk-size-invariant either way.
    pub fn prefill(&mut self, prompt: &[TokenId], chunks: &[usize]) -> Result<TokenId> {
        ensure!(
            chunks.iter().sum::<usize>() == prompt.len(),
            "chunks must cover prompt: {} tokens vs {} chunked",
            prompt.len(),
            chunks.iter().sum::<usize>()
        );
        self.prefill_begin(prompt)?;
        let mut first = None;
        for &c in chunks {
            ensure!(c > 0, "empty chunk");
            first = self.prefill_step(c)?;
        }
        first.ok_or_else(|| anyhow!("chunks cover a non-empty prompt"))
    }

    /// Tokens generated so far (beyond the prompt, including the first).
    pub fn generated(&self) -> usize {
        self.ctx.len() - self.n_prompt
    }

    /// HAT decode round: threshold drafting + hidden-state verification.
    ///
    /// Drafting processes d_0..d_k through the draft model (k proposals
    /// from the Eq. 5 stop rule, plus the last proposal itself so its
    /// shallow hidden — and the adapter-KV row the next round needs — is
    /// available).  Verification uploads all k+1 hidden states; head row i
    /// targets proposed[i] for i<k, and row k yields the *bonus token*
    /// after full acceptance ("the LLM's inference result following the
    /// last accepted draft token serves as the input for the subsequent
    /// round", §2.2).
    ///
    /// With `parallel_draft`, top-k candidate branches are drafted for
    /// `lambda` steps each (the work the paper overlaps with the
    /// verification wait): candidates for the correction slot (from the
    /// step that proposed d_k) and for the bonus slot (from processing
    /// d_k).
    pub fn hat_round(&mut self, parallel_draft: bool, lambda: usize) -> Result<RoundResult> {
        self.hat_round_capped(parallel_draft, lambda, usize::MAX)
    }

    /// [`Session::hat_round`] with this round's draft length additionally
    /// capped at `draft_budget` proposals (≥ 1).  The serve path passes the
    /// request's remaining token budget so the *final* round does not spend
    /// device draft steps and KV writes on tokens that would only be
    /// truncated away: a round with k proposals emits at most k+1 tokens,
    /// so `draft_budget = remaining - 1` makes the last round exact.
    ///
    /// Batch-of-1 wrapper over the [`Session::verify_begin`] /
    /// [`Session::verify_finish`] halves the serve scheduler uses to batch
    /// the cloud-side verification (middle + head) across sessions.
    pub fn hat_round_capped(
        &mut self,
        parallel_draft: bool,
        lambda: usize,
        draft_budget: usize,
    ) -> Result<RoundResult> {
        self.verify_begin(parallel_draft, lambda, draft_budget)?;
        let shallow = self.take_verify_shallow();
        let verified = self
            .engine
            .verify_batch(&mut [&mut self.cloud], &[&shallow])
            .map(|mut outs| outs.swap_remove(0));
        match verified {
            Ok((deep, logits)) => self.verify_finish(&deep, &logits),
            Err(e) => {
                // Abandon the staged round and roll the speculative device
                // KV tail back to the committed prefix (verify_batch's
                // error contract already restored the cloud stream), so
                // the session stays usable — a fresh round can be drafted
                // — instead of panicking "already staged" on the next
                // call.
                self.verify = None;
                self.dev.skv.rollback();
                self.dev.akv.rollback();
                Err(e)
            }
        }
    }

    /// Device half of a HAT decode round: threshold drafting (or adoption
    /// of a parallel-drafted branch) capped at `draft_budget` proposals,
    /// plus the next round's parallel-drafting branches.  Stages the k+1
    /// shallow hidden rows for upload ([`Session::verify_shallow`]) and
    /// returns their count — the verify job's token size, which the serve
    /// scheduler buckets on before issuing one batched cloud call for the
    /// whole group.
    pub fn verify_begin(
        &mut self,
        parallel_draft: bool,
        lambda: usize,
        draft_budget: usize,
    ) -> Result<usize> {
        ensure!(self.verify.is_none(), "verify round already staged");
        let d0 = match self.pending {
            Some(d0) => d0,
            None => bail!("no pending token (call prefill first)"),
        };
        let h = self.engine.spec().hidden;
        let max_k = self.cfg.max_draft.min(draft_budget).max(1);

        // --- drafting stage (or adopt a parallel-drafting branch) ---------
        let (proposed, shallow, draft_steps, pd_hit, q_dists) = match self.prebuilt.take() {
            Some(pb) if pb.base == d0 && !pb.proposed.is_empty() => {
                // The branch forked before the last round's verification
                // committed its rows; re-apply that commit to the adopted
                // tables so their committed prefix matches the live
                // stream's.  The rows are bit-identical (the branch only
                // wrote past its fork point), so sealing re-seals the same
                // physical blocks — a no-op — or dedups the boundary copy.
                let committed = self.dev.skv.committed();
                let mut skv = pb.skv;
                let mut akv = pb.akv;
                skv.commit(committed - skv.committed());
                akv.commit(committed - akv.committed());
                self.dev.skv = skv;
                self.dev.akv = akv;
                // No fresh candidates were computed this round: PD pauses
                // for one round after a hit.
                self.corr_candidates.clear();
                self.bonus_candidates.clear();
                let mut proposed = pb.proposed;
                let mut shallow = pb.shallow;
                let mut q_dists = pb.q_dists;
                if proposed.len() > max_k {
                    // A branch drafted past this round's budget: verify only
                    // the first max_k proposals (shallow row i belongs to
                    // token d_i, so the prefix is exactly the rows needed;
                    // the over-drafted KV tail is rolled back after the
                    // round like any rejected speculation).
                    proposed.truncate(max_k);
                    shallow.truncate((max_k + 1) * h);
                    q_dists.truncate(max_k);
                }
                (proposed, shallow, 0usize, true, q_dists)
            }
            _ => {
                let (p, s, n, q) = self.draft_live(d0, max_k)?;
                (p, s, n, false, q)
            }
        };
        let k = proposed.len();
        debug_assert!(k >= 1);
        debug_assert_eq!(shallow.len(), (k + 1) * h, "need k+1 hidden rows");

        // --- parallel drafting branches (overlap with verification) -------
        // Correction case: next d_0 = c at the last draft slot (rows = k).
        // Bonus case: next d_0 = b one past it (rows = k+1).
        let mut branches: Vec<PreDraft> = Vec::new();
        if parallel_draft && lambda > 0 {
            let base_pos = self.dev.skv.committed(); // p
            for &c in self.corr_candidates.clone().iter().take(self.cfg.top_k) {
                // Correction case: rows 0..k-1 emitted as d_1..d_{k-1}, c.
                let mut em: Vec<TokenId> = proposed[..k - 1].to_vec();
                em.push(c);
                branches.push(self.draft_branch(c, k, base_pos + k, lambda, &em)?);
            }
            for &b in self.bonus_candidates.clone().iter().take(self.cfg.top_k) {
                // Bonus case: all k proposals emitted, then b.
                let mut em: Vec<TokenId> = proposed.clone();
                em.push(b);
                branches.push(self.draft_branch(b, k + 1, base_pos + k + 1, lambda, &em)?);
            }
        }

        self.verify =
            Some(PendingVerify { proposed, shallow, draft_steps, pd_hit, q_dists, branches });
        Ok(k + 1)
    }

    /// The shallow hidden rows staged by [`Session::verify_begin`]
    /// ([k+1, H] row-major) — the round's upload.  Empty when no round is
    /// staged (the caller drives the step machine; an empty upload fails
    /// downstream with an Err instead of panicking the worker here).
    pub fn verify_shallow(&self) -> &[f32] {
        self.verify.as_ref().map_or(&[], |pv| &pv.shallow)
    }

    /// Move the staged upload out of the session.  The rows are consumed
    /// by the cloud call and never read again after upload, so the serve
    /// scheduler takes them instead of copying ([k+1, H] per session per
    /// round is hot-path traffic); [`Session::verify_finish`] is
    /// unaffected.
    pub fn take_verify_shallow(&mut self) -> Vec<f32> {
        self.verify.as_mut().map(|pv| std::mem::take(&mut pv.shallow)).unwrap_or_default()
    }

    /// Cloud-download half of a HAT decode round: acceptance against the
    /// verified logits [k+1, V], KV commit/rollback, and parallel-draft
    /// branch adoption.  `deep` is the middle submodel's output for the
    /// staged upload ([k+1, H]), `logits` the head's output on it.
    pub fn verify_finish(&mut self, deep: &[f32], logits: &[f32]) -> Result<RoundResult> {
        let h = self.engine.spec().hidden;
        let v = self.engine.spec().vocab;
        // Shape-check the verified buffers *before* consuming the staged
        // round: on a short backend buffer the round stays staged (the
        // caller can abort_staged and re-drive) and nothing is sliced out
        // of bounds.
        {
            let staged_k = match self.verify.as_ref() {
                Some(pv) => pv.proposed.len(),
                None => bail!("no verify round staged"),
            };
            ensure!(
                logits.len() >= (staged_k + 1) * v,
                "verify logits too short: got {}, need {}x{v}",
                logits.len(),
                staged_k + 1
            );
            ensure!(
                deep.len() >= (staged_k + 1) * h,
                "verify deep rows too short: got {}, need {}x{h}",
                deep.len(),
                staged_k + 1
            );
        }
        let pv = match self.verify.take() {
            Some(pv) => pv,
            None => bail!("no verify round staged"),
        };
        let proposed = pv.proposed;
        let k = proposed.len();
        // Absolute context position of the first proposal (ctx currently
        // ends with this round's d_0).
        let base = self.ctx.len();
        let (accepted, next_d0) = if self.sampler.greedy() {
            let mut a = 0;
            while a < k && Engine::argmax(&logits[a * v..(a + 1) * v]) == proposed[a] {
                a += 1;
            }
            // Correction (a<k) or bonus (a==k) — either way the LLM's own
            // output at row `a` is the next token.
            (a, Engine::argmax(&logits[a * v..(a + 1) * v]))
        } else {
            match self.cfg.verify_mode {
                SampleVerify::Coupled => {
                    // Common-random-number verification: accept while the
                    // target's coupled sample reproduces the proposal.  The
                    // first disagreement *is* the correction, and full
                    // acceptance samples the bonus the same way — so the
                    // committed token at base+i is always the target's
                    // seeded sample there, making the stream token-identical
                    // to direct seeded sampling.
                    let mut a = 0;
                    let mut next = None;
                    while a < k {
                        let t = self.p_sample_row(
                            &logits[a * v..(a + 1) * v],
                            &proposed[..a],
                            base + a,
                        );
                        if t == proposed[a] {
                            a += 1;
                        } else {
                            next = Some(t);
                            break;
                        }
                    }
                    let next = next.unwrap_or_else(|| {
                        self.p_sample_row(&logits[a * v..(a + 1) * v], &proposed[..a], base + a)
                    });
                    (a, next)
                }
                SampleVerify::Rejection => {
                    // Canonical stochastic speculative sampling: accept d
                    // with probability min(1, p(d)/q(d)); on rejection,
                    // resample from the residual norm(max(p-q, 0)).
                    // Distribution-preserving at every position.
                    debug_assert_eq!(pv.q_dists.len(), k, "rejection verify needs draft q-dists");
                    let mut a = 0;
                    let mut next = None;
                    while a < k {
                        let p = self
                            .sampler
                            .dist(&logits[a * v..(a + 1) * v], &self.rep_ctx(&proposed[..a]));
                        let q = &pv.q_dists[a];
                        let d = proposed[a] as usize;
                        if self.sampler.r_at(base + a) * q[d] <= p[d] {
                            a += 1;
                            continue;
                        }
                        let mut res: Vec<f64> =
                            p.iter().zip(q).map(|(&pi, &qi)| (pi - qi).max(0.0)).collect();
                        let mass: f64 = res.iter().sum();
                        let tok = if mass > 0.0 {
                            for x in res.iter_mut() {
                                *x /= mass;
                            }
                            Sampler::pick(&res, self.sampler.v_at(base + a))
                        } else {
                            // p <= q everywhere on p's support means p == q:
                            // any p-sample preserves the distribution.
                            Sampler::pick(&p, self.sampler.v_at(base + a))
                        };
                        next = Some(tok);
                        break;
                    }
                    let next = next.unwrap_or_else(|| {
                        // Full acceptance: bonus token sampled from the
                        // target at the bonus row.
                        self.p_sample_row(&logits[k * v..(k + 1) * v], &proposed, base + k)
                    });
                    (a, next)
                }
            }
        };

        let mut emitted: Vec<TokenId> = proposed[..accepted].to_vec();
        emitted.push(next_d0);
        let committed_rows = accepted + 1;
        self.last_deep = deep[(committed_rows - 1) * h..committed_rows * h].to_vec();

        // --- KV bookkeeping: commit verified rows, roll back the rest -----
        self.dev.skv.commit(committed_rows);
        self.dev.skv.rollback();
        self.dev.akv.commit(committed_rows);
        self.dev.akv.rollback();
        self.cloud.mkv.commit(committed_rows);
        self.cloud.mkv.rollback();

        // Adopt a branch whose assumed (token, position) both match.
        self.prebuilt = pv
            .branches
            .into_iter()
            .find(|pb| pb.base == next_d0 && pb.assumed_rows == committed_rows);

        self.ctx.extend_from_slice(&emitted);
        self.pending = Some(next_d0);
        Ok(RoundResult {
            proposed,
            accepted,
            emitted,
            draft_steps: pv.draft_steps,
            verify_tokens: k + 1,
            pd_hit: pv.pd_hit,
        })
    }

    /// Threshold drafting on the live device stream: proposes up to `max`
    /// tokens (Eq. 5 stop rule), then processes the last proposal too.
    /// Returns (proposals, k+1 shallow hidden rows, steps = k+1, draft
    /// sampling distributions — empty under greedy decoding).
    ///
    /// With sampling active, proposal i is drawn from the *processed*
    /// draft distribution with the coupling uniform of the position it
    /// would commit to; the Eq. 5 stop rule stays on the raw top
    /// probability (a drafting-length heuristic, not a sampling rule).
    #[allow(clippy::type_complexity)]
    fn draft_live(
        &mut self,
        d0: TokenId,
        max: usize,
    ) -> Result<(Vec<TokenId>, Vec<f32>, usize, Vec<Vec<f64>>)> {
        let mut proposed = Vec::new();
        let mut shallow = Vec::new();
        let mut q_dists: Vec<Vec<f64>> = Vec::new();
        let mut cur = d0;
        // Proposal i commits (if accepted) at this absolute position + i.
        let base = self.ctx.len();
        self.corr_candidates.clear();
        self.bonus_candidates.clear();
        for _ in 0..max {
            let out = self.engine.draft_step(&mut self.dev, cur)?;
            shallow.extend_from_slice(&out.shallow);
            let next = if self.sampler.greedy() {
                Engine::argmax(&out.logits)
            } else {
                let q = self.sampler.dist(&out.logits, &self.rep_ctx(&proposed));
                let t = Sampler::pick(&q, self.sampler.u_at(base + proposed.len()));
                q_dists.push(q);
                t
            };
            let prob = Engine::top_prob(&out.logits);
            proposed.push(next);
            self.corr_candidates = Engine::top_k(&out.logits, self.cfg.top_k.max(1));
            cur = next;
            if (prob as f64) < self.cfg.eta {
                break;
            }
        }
        // Process the last proposal itself: its hidden row is needed for
        // verification (bonus logits) and its adapter-KV row for the next
        // round.  Its own proposal distribution seeds the bonus-slot
        // candidates for parallel drafting.
        let out = self.engine.draft_step(&mut self.dev, cur)?;
        shallow.extend_from_slice(&out.shallow);
        self.bonus_candidates = Engine::top_k(&out.logits, self.cfg.top_k.max(1));
        let steps = proposed.len() + 1;
        Ok((proposed, shallow, steps, q_dists))
    }

    /// Draft a candidate branch on cloned device KVs: `base` assumed at
    /// absolute position `write_pos` (commit depth `assumed_rows`), with
    /// `assumed_emitted` the in-round tokens the branch assumes committed
    /// (d_1..d_a plus `base`) — needed so sampled branch proposals use the
    /// exact rep-penalty context and positions the adopting round will
    /// have, keeping PD hits bit-identical to live redrafting.
    fn draft_branch(
        &self,
        base: TokenId,
        assumed_rows: usize,
        write_pos: usize,
        lambda: usize,
        assumed_emitted: &[TokenId],
    ) -> Result<PreDraft> {
        // Copy-on-write forks share every block with the live stream; the
        // branch's writes land in private copies.  The live stream has
        // written past this branch's start, so rewind the forked write
        // head (stale rows are overwritten, never attended).
        let mut dev = DeviceStream { skv: self.dev.skv.fork(), akv: self.dev.akv.fork() };
        dev.skv.seek(write_pos);
        dev.akv.seek(write_pos);
        let mut proposed = Vec::new();
        let mut shallow = Vec::new();
        let mut q_dists: Vec<Vec<f64>> = Vec::new();
        let mut cur = base;
        // If adopted, branch proposal i commits at this position + i.
        let base_ctx = self.ctx.len() + assumed_rows;
        for _ in 0..lambda {
            let out = self.engine.draft_step(&mut dev, cur)?;
            shallow.extend_from_slice(&out.shallow);
            let next = if self.sampler.greedy() {
                Engine::argmax(&out.logits)
            } else {
                let mut rep: Vec<TokenId> = assumed_emitted.to_vec();
                rep.extend_from_slice(&proposed);
                let q = self.sampler.dist(&out.logits, &self.rep_ctx(&rep));
                let t = Sampler::pick(&q, self.sampler.u_at(base_ctx + proposed.len()));
                q_dists.push(q);
                t
            };
            let prob = Engine::top_prob(&out.logits);
            proposed.push(next);
            cur = next;
            if (prob as f64) < self.cfg.eta {
                break;
            }
        }
        // Mirror draft_live: process the last proposal for its hidden row.
        if !proposed.is_empty() {
            let out = self.engine.draft_step(&mut dev, cur)?;
            shallow.extend_from_slice(&out.shallow);
        }
        let steps = proposed.len() + 1;
        Ok(PreDraft {
            base,
            assumed_rows,
            proposed,
            shallow,
            q_dists,
            skv: dev.skv,
            akv: dev.akv,
            steps,
        })
    }

    /// Tear down any mid-round staged state — an in-flight prefill chunk
    /// ([`Session::prefill_chunk_begin`] awaiting its finish) or a staged
    /// verify round ([`Session::verify_begin`] awaiting verification) —
    /// rolling every KV write head back to the committed prefix.  The
    /// serve scheduler calls this when a session is cancelled, so the
    /// teardown is clean no matter where the step machine stopped; the
    /// session stays re-drivable (the abandoned chunk/round can simply be
    /// issued afresh) and greedy losslessness keeps the emitted stream
    /// unchanged.  Returns whether anything was staged.
    pub fn abort_staged(&mut self) -> bool {
        let mut any = self.verify.take().is_some();
        if let Some(st) = self.prefill.as_mut() {
            any |= st.staged.take().is_some();
        }
        if any {
            self.dev.skv.rollback();
            self.dev.akv.rollback();
            self.cloud.mkv.rollback();
        }
        any
    }

    /// Re-home this session onto another engine — the prefill→decode
    /// handoff of the disaggregated serve pools.  The session's hidden
    /// state (`last_deep`, the pending d_0) and its paged KV block tables
    /// move by ownership: the caches hold their own pool handles, so no
    /// dense KV bytes are copied — only the engine reference changes.
    ///
    /// Sound only between *sibling* engines: the target must draw from the
    /// same physical [`KvPool`](crate::kv::KvPool) (block indices are
    /// meaningless in any other pool) and present the same model spec
    /// (deterministic backends then make the two engines bit-identical
    /// executors).  Nothing may be staged mid-flight — a staged prefill
    /// chunk or verify round holds rows the old engine's call must finish;
    /// the scheduler tears those down (or completes them) before handing
    /// off.  All violations are `Err`s, not panics: a handoff bug must
    /// fail one lane, not the serve worker.
    pub fn rebind(&mut self, engine: &'e Engine) -> Result<()> {
        ensure!(self.verify.is_none(), "rebind with a staged verify round");
        if let Some(st) = self.prefill.as_ref() {
            ensure!(st.staged.is_none(), "rebind with a staged prefill chunk");
        }
        ensure!(
            engine.kv_pool().same_pool(self.engine.kv_pool()),
            "rebind across different kv pools"
        );
        ensure!(engine.spec() == self.engine.spec(), "rebind across different model specs");
        self.engine = engine;
        Ok(())
    }

    /// Page this session's entire KV state (shallow, adapter and cloud
    /// middle caches) out to the pool's host-side store, releasing every
    /// resident block.  The serve scheduler preempts a session with this
    /// under slot pressure; any staged round must be torn down first
    /// ([`Session::abort_staged`]).  Idempotent — swapping an already
    /// parked session moves zero bytes.  Returns bytes copied host-ward.
    pub fn swap_out(&mut self) -> u64 {
        // A prebuilt branch holds CoW forks of the device caches; parking
        // must release those block refs too so the session pins nothing.
        // Dropping it only discards a speculated branch — the round is
        // re-drafted after resume, and losslessness keeps the emitted
        // stream identical.
        self.prebuilt = None;
        self.dev.skv.swap_out() + self.dev.akv.swap_out() + self.cloud.mkv.swap_out()
    }

    /// Restore a parked session's caches from the host store, re-sharing
    /// sealed blocks with bit-identical live content where the pool can
    /// dedup them.  All-or-nothing: if any cache cannot obtain blocks
    /// (pool exhausted), the caches already restored are swapped back out
    /// so a parked session never holds resident blocks, and the caller
    /// retries once live sessions release pressure.  Returns bytes copied
    /// back in (dedup re-shares count as zero).
    pub fn swap_in(&mut self) -> Result<u64> {
        let mut total = self.dev.skv.swap_in()?;
        match self.dev.akv.swap_in() {
            Ok(b) => total += b,
            Err(e) => {
                self.dev.skv.swap_out();
                return Err(e);
            }
        }
        match self.cloud.mkv.swap_in() {
            Ok(b) => total += b,
            Err(e) => {
                self.dev.skv.swap_out();
                self.dev.akv.swap_out();
                return Err(e);
            }
        }
        Ok(total)
    }

    /// U-shape decode step: one token per device-cloud interaction.
    pub fn ushape_step(&mut self) -> Result<TokenId> {
        let d0 = match self.pending {
            Some(d0) => d0,
            None => bail!("no pending token (call prefill first)"),
        };
        let hidden = self.engine.device_input(&mut self.dev, &[d0])?;
        let deep = self.engine.cloud_middle(&mut self.cloud, &hidden)?;
        let logits = self.engine.head(&deep)?;
        let next = if self.sampler.greedy() {
            Engine::argmax(&logits)
        } else {
            self.p_sample_row(&logits, &[], self.ctx.len())
        };
        self.dev.skv.commit(1);
        self.cloud.mkv.commit(1);
        self.last_deep = deep;
        self.ctx.push(next);
        self.pending = Some(next);
        Ok(next)
    }

    /// U-Medusa decode round: the heads applied to the deep hidden of the
    /// last verified row propose n_medusa tokens; verification uploads the
    /// hidden states of [d_0, m_1..m_{n-1}] like a HAT round (no adapter).
    pub fn medusa_round(&mut self) -> Result<RoundResult> {
        let d0 = match self.pending {
            Some(d0) => d0,
            None => bail!("no pending token (call prefill first)"),
        };
        let n = self.engine.spec().n_medusa;
        let h = self.engine.spec().hidden;
        let v = self.engine.spec().vocab;

        let head_logits = self.engine.medusa(&self.last_deep)?;
        let proposed: Vec<TokenId> = head_logits.iter().map(|l| Engine::argmax(l)).collect();
        debug_assert_eq!(proposed.len(), n);

        // Process [d_0, m_1..m_n]: row i targets m_{i+1}, row n yields the
        // bonus token after full acceptance (same contract as hat_round).
        let mut toks = vec![d0];
        toks.extend_from_slice(&proposed);
        let hidden = self.engine.device_input(&mut self.dev, &toks)?;
        let deep = self.engine.cloud_middle(&mut self.cloud, &hidden)?;
        let logits = self.engine.head(&deep)?;

        let k = proposed.len();
        ensure!(
            logits.len() >= (k + 1) * v,
            "medusa verify logits too short: got {}, need {}x{v}",
            logits.len(),
            k + 1
        );
        ensure!(
            deep.len() >= (k + 1) * h,
            "medusa verify deep rows too short: got {}, need {}x{h}",
            deep.len(),
            k + 1
        );
        let base = self.ctx.len();
        let greedy = self.sampler.greedy();
        // The heads always draft greedily, but with sampling active the
        // acceptance couples to the target's seeded sample (in both verify
        // modes — head proposals carry no q-distribution, so rejection
        // sampling does not apply), keeping the stochastic stream
        // token-identical to direct seeded sampling.
        let target = |row: &[f32], prefix: &[TokenId], pos: usize| {
            if greedy { Engine::argmax(row) } else { self.p_sample_row(row, prefix, pos) }
        };
        let mut accepted = 0;
        let mut correction = None;
        while accepted < k {
            let row = &logits[accepted * v..(accepted + 1) * v];
            let t = target(row, &proposed[..accepted], base + accepted);
            if t == proposed[accepted] {
                accepted += 1;
            } else {
                correction = Some(t);
                break;
            }
        }
        let next_d0 = correction.unwrap_or_else(|| {
            let row = &logits[accepted * v..(accepted + 1) * v];
            target(row, &proposed[..accepted], base + accepted)
        });
        let mut emitted: Vec<TokenId> = proposed[..accepted].to_vec();
        emitted.push(next_d0);
        let committed_rows = accepted + 1;
        self.last_deep = deep[(committed_rows - 1) * h..committed_rows * h].to_vec();

        self.dev.skv.commit(committed_rows);
        self.dev.skv.rollback();
        self.cloud.mkv.commit(committed_rows);
        self.cloud.mkv.rollback();

        self.ctx.extend_from_slice(&emitted);
        self.pending = Some(next_d0);
        Ok(RoundResult { proposed, accepted, emitted, draft_steps: 0, verify_tokens: k + 1, pd_hit: false })
    }
}

/// Even chunking helper: split `n` into chunks of at most `size`.
pub fn chunk_sizes(n: usize, size: usize) -> Vec<usize> {
    // hatlint: allow(panic-path) size = 0 is a caller bug; every chunk planner clamps to >= 1
    assert!(size > 0);
    let mut out = Vec::new();
    let mut left = n;
    while left > 0 {
        let c = left.min(size);
        out.push(c);
        left -= c;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resumable_prefill_matches_one_shot() {
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt: Vec<TokenId> = (0u32..37).map(|i| (i * 7 + 3) % 256).collect();

        let mut a = Session::new(&engine, cfg.clone()).unwrap();
        let t_a = a.prefill(&prompt, &[prompt.len()]).unwrap();

        let mut b = Session::new(&engine, cfg.clone()).unwrap();
        b.prefill_begin(&prompt).unwrap();
        assert_eq!(b.prefill_remaining(), prompt.len());
        let mut last = None;
        let mut guard = 0;
        while b.prefill_remaining() > 0 {
            last = b.prefill_step(10).unwrap();
            guard += 1;
            assert!(guard < 100, "prefill_step does not make progress");
        }
        assert_eq!(last, Some(t_a), "chunked prefill must be chunk-size-invariant");

        // Both sessions continue through decode identically.
        for _ in 0..3 {
            let ra = a.hat_round(true, 4).unwrap();
            let rb = b.hat_round(true, 4).unwrap();
            assert_eq!(ra.emitted, rb.emitted);
        }
        assert_eq!(a.ctx, b.ctx);
    }

    #[test]
    fn hat_round_capped_respects_draft_budget() {
        let engine = Engine::synthetic();
        let mut s = Session::new(&engine, SpecDecConfig::default()).unwrap();
        s.prefill(&[5, 9, 2, 14], &[4]).unwrap();
        // Budget 1: exactly one proposal, two uploaded rows, two draft steps
        // (the proposal plus the processing of the proposal itself).
        let r = s.hat_round_capped(true, 4, 1).unwrap();
        assert_eq!(r.proposed.len(), 1);
        assert_eq!(r.verify_tokens, 2);
        assert!(r.emitted.len() <= 2);
        // A follow-up round (possibly adopting a parallel-drafted branch
        // longer than the budget) still respects the cap.
        let r = s.hat_round_capped(true, 4, 3).unwrap();
        assert!(r.proposed.len() <= 3, "budget exceeded: {}", r.proposed.len());
        assert_eq!(r.verify_tokens, r.proposed.len() + 1);
    }

    #[test]
    fn capped_rounds_emit_same_stream_as_uncapped() {
        // Greedy losslessness means the draft budget must never change the
        // emitted tokens — only how much speculative work each round does.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt = [7u32, 3, 200, 41, 5];

        let gen = |budgets: &mut dyn FnMut(usize) -> usize| -> Vec<TokenId> {
            let mut s = Session::new(&engine, cfg.clone()).unwrap();
            let t1 = s.prefill(&prompt, &[prompt.len()]).unwrap();
            let mut out = vec![t1];
            while out.len() < 12 {
                let r = s.hat_round_capped(true, 4, budgets(out.len())).unwrap();
                out.extend_from_slice(&r.emitted);
            }
            out.truncate(12);
            out
        };
        let uncapped = gen(&mut |_| usize::MAX);
        let capped = gen(&mut |len| (12 - len).saturating_sub(1).max(1));
        assert_eq!(uncapped, capped);
    }

    #[test]
    fn split_verify_round_matches_wrapper() {
        // Driving the verify_begin/verify_shallow/verify_finish halves by
        // hand (what the serve scheduler does, with the cloud calls
        // batched) must reproduce hat_round_capped exactly.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt = [7u32, 3, 200, 41, 5];

        let mut a = Session::new(&engine, cfg.clone()).unwrap();
        let mut b = Session::new(&engine, cfg.clone()).unwrap();
        a.prefill(&prompt, &[prompt.len()]).unwrap();
        b.prefill(&prompt, &[prompt.len()]).unwrap();

        for _ in 0..4 {
            let ra = a.hat_round_capped(true, 4, usize::MAX).unwrap();

            let rows = b.verify_begin(true, 4, usize::MAX).unwrap();
            let shallow = b.verify_shallow().to_vec();
            assert_eq!(shallow.len(), rows * engine.spec().hidden);
            let deep = engine.cloud_middle(&mut b.cloud, &shallow).unwrap();
            let logits = engine.head(&deep).unwrap();
            let rb = b.verify_finish(&deep, &logits).unwrap();

            assert_eq!(ra.proposed, rb.proposed);
            assert_eq!(ra.emitted, rb.emitted);
            assert_eq!(ra.accepted, rb.accepted);
            assert_eq!(ra.pd_hit, rb.pd_hit);
        }
        assert_eq!(a.ctx, b.ctx);
    }

    #[test]
    fn split_prefill_chunk_matches_wrapper() {
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt: Vec<TokenId> = (0u32..23).map(|i| (i * 5 + 2) % 256).collect();

        let mut a = Session::new(&engine, cfg.clone()).unwrap();
        a.prefill_begin(&prompt).unwrap();
        let mut first_a = None;
        while a.prefill_remaining() > 0 {
            first_a = a.prefill_step(8).unwrap();
        }

        let mut b = Session::new(&engine, cfg).unwrap();
        b.prefill_begin(&prompt).unwrap();
        let mut first_b = None;
        while b.prefill_remaining() > 0 {
            let hidden = b.prefill_chunk_begin(8).unwrap();
            let deep = engine.cloud_middle(&mut b.cloud, &hidden).unwrap();
            first_b = b.prefill_chunk_finish(&deep).unwrap();
        }
        assert_eq!(first_a, first_b);
        assert_eq!(a.ctx, b.ctx);
    }

    #[test]
    fn abort_staged_leaves_session_redrivable_and_lossless() {
        // Cancellation can land with a prefill chunk or a verify round
        // staged between its device half and its cloud half; abort must
        // roll the write heads back so the session can be dropped *or*
        // re-driven — and re-driving must not change the greedy stream.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig::default();
        let prompt: Vec<TokenId> = (0u32..23).map(|i| (i * 5 + 2) % 256).collect();

        // Reference: the same session driven with no aborts.
        let mut a = Session::new(&engine, cfg.clone()).unwrap();
        a.prefill(&prompt, &[prompt.len()]).unwrap();
        for _ in 0..4 {
            a.hat_round(true, 4).unwrap();
        }

        let mut b = Session::new(&engine, cfg).unwrap();
        assert!(!b.abort_staged(), "nothing staged on a fresh session");
        b.prefill_begin(&prompt).unwrap();
        let _upload = b.prefill_chunk_begin(8).unwrap();
        assert!(b.abort_staged(), "a staged prefill chunk was live");
        assert!(!b.abort_staged(), "abort is idempotent");
        assert_eq!(b.prefill_remaining(), prompt.len(), "aborted chunk not re-owed");
        while b.prefill_remaining() > 0 {
            b.prefill_step(8).unwrap();
        }
        b.hat_round(true, 4).unwrap();
        b.verify_begin(true, 4, usize::MAX).unwrap();
        assert!(b.abort_staged(), "a staged verify round was live");
        for _ in 0..3 {
            b.hat_round(true, 4).unwrap();
        }

        // Both contexts are prefixes of the same greedy stream (round
        // boundaries may differ: the aborted round's parallel-draft
        // branch is gone, so b redrafts live).
        let n = a.ctx.len().min(b.ctx.len());
        assert!(n > prompt.len() + 4, "sessions made no decode progress");
        assert_eq!(a.ctx[..n], b.ctx[..n], "abort changed the greedy stream");
    }

    #[test]
    fn stochastic_coupled_hat_matches_direct_seeded_sampling() {
        // The coupled-verification losslessness oracle at module level:
        // with temperature > 0 the speculative stream is token-identical
        // to direct (u-shape) seeded sampling from the target model.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig {
            temperature: 0.9,
            top_p: 0.95,
            rep_penalty: 1.1,
            seed: 1234,
            ..SpecDecConfig::default()
        };
        let prompt = [7u32, 3, 200, 41, 5];

        let mut direct = Session::new(&engine, cfg.clone()).unwrap();
        let t1 = direct.prefill(&prompt, &[prompt.len()]).unwrap();
        let mut want = vec![t1];
        for _ in 0..20 {
            want.push(direct.ushape_step().unwrap());
        }

        let mut spec = Session::new(&engine, cfg).unwrap();
        let t1b = spec.prefill(&prompt, &[prompt.len()]).unwrap();
        assert_eq!(t1b, t1);
        let mut got = vec![t1b];
        while got.len() < want.len() {
            got.extend(spec.hat_round(true, 4).unwrap().emitted);
        }
        got.truncate(want.len());
        assert_eq!(got, want, "coupled speculative sampling diverged from direct sampling");
    }

    #[test]
    fn rejection_mode_rounds_are_deterministic_and_budget_safe() {
        // Rejection sampling is distribution- (not token-) identical to
        // direct sampling, but it must still be bit-reproducible under a
        // fixed seed and respect the round invariants.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig {
            temperature: 0.8,
            verify_mode: SampleVerify::Rejection,
            seed: 42,
            ..SpecDecConfig::default()
        };
        let prompt = [5u32, 9, 2, 14];
        let run = || {
            let mut s = Session::new(&engine, cfg.clone()).unwrap();
            let t1 = s.prefill(&prompt, &[prompt.len()]).unwrap();
            let mut out = vec![t1];
            for _ in 0..6 {
                let r = s.hat_round(true, 4).unwrap();
                assert_eq!(r.emitted.len(), r.accepted + 1);
                out.extend_from_slice(&r.emitted);
            }
            out
        };
        let (a, b) = (run(), run());
        assert_eq!(a, b, "same-seed rejection sampling must be bit-identical");
        assert!(a.iter().all(|&t| (t as usize) < engine.spec().vocab));
    }

    #[test]
    fn stochastic_stream_is_invariant_to_round_shape_and_aborts() {
        // Position-keyed draws: capping budgets and aborting staged rounds
        // must not change the coupled stochastic stream, exactly as for
        // the greedy stream.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig { temperature: 1.0, top_p: 0.9, seed: 7, ..SpecDecConfig::default() };
        let prompt = [11u32, 42, 250, 8];

        let gen = |budget: &mut dyn FnMut(usize) -> usize, abort_at: Option<usize>| {
            let mut s = Session::new(&engine, cfg.clone()).unwrap();
            let t1 = s.prefill(&prompt, &[prompt.len()]).unwrap();
            let mut out = vec![t1];
            let mut round = 0;
            while out.len() < 16 {
                if Some(round) == abort_at {
                    s.verify_begin(true, 4, usize::MAX).unwrap();
                    s.abort_staged();
                }
                let r = s.hat_round_capped(true, 4, budget(out.len())).unwrap();
                out.extend_from_slice(&r.emitted);
                round += 1;
            }
            out.truncate(16);
            out
        };
        let uncapped = gen(&mut |_| usize::MAX, None);
        let capped = gen(&mut |len| (16 - len).saturating_sub(1).max(1), None);
        let aborted = gen(&mut |_| usize::MAX, Some(2));
        assert_eq!(uncapped, capped, "draft budget changed the sampled stream");
        assert_eq!(uncapped, aborted, "aborting a staged round changed the sampled stream");
    }

    #[test]
    fn stochastic_medusa_and_ushape_agree() {
        // U-Medusa acceptance couples to the same position-keyed target
        // samples, so its stream equals direct seeded sampling too.
        let engine = Engine::synthetic();
        let cfg = SpecDecConfig { temperature: 0.7, top_k_sample: 40, seed: 99, ..SpecDecConfig::default() };
        let prompt = [3u32, 77, 130, 9, 21];

        let mut direct = Session::new(&engine, cfg.clone()).unwrap();
        let t1 = direct.prefill(&prompt, &[prompt.len()]).unwrap();
        let mut want = vec![t1];
        for _ in 0..14 {
            want.push(direct.ushape_step().unwrap());
        }

        let mut med = Session::new(&engine, cfg).unwrap();
        med.prefill(&prompt, &[prompt.len()]).unwrap();
        let mut got = vec![t1];
        while got.len() < want.len() {
            got.extend(med.medusa_round().unwrap().emitted);
        }
        got.truncate(want.len());
        assert_eq!(got, want, "medusa sampled stream diverged from direct sampling");
    }

    #[test]
    fn chunk_sizes_cover() {
        assert_eq!(chunk_sizes(10, 4), vec![4, 4, 2]);
        assert_eq!(chunk_sizes(4, 4), vec![4]);
        assert_eq!(chunk_sizes(3, 8), vec![3]);
        assert_eq!(chunk_sizes(0, 8), Vec::<usize>::new());
    }

    #[test]
    fn prop_chunks_sum_and_bounds() {
        use crate::util::proptest::{cases, forall};
        forall(cases(100), |rng| {
            let n = rng.range_usize(1, 2000);
            let s = rng.range_usize(1, 300);
            let ch = chunk_sizes(n, s);
            if ch.iter().sum::<usize>() != n {
                return Err("chunks do not sum to n".into());
            }
            if ch.iter().any(|&c| c == 0 || c > s) {
                return Err("chunk out of bounds".into());
            }
            Ok(())
        });
    }
}
