//! The fleet simulator: 30 heterogeneous devices + the pipeline-parallel
//! cloud, in virtual time (DES), for HAT and all three baselines.
//!
//! Everything the paper *measures* happens here: request arrivals (Poisson),
//! device-side chunked prefill with upload/compute overlap (Fig. 4), the
//! continuous batcher with prefill/decode mixing, dynamic chunk sizing
//! (Eq. 3), state monitoring (Eqs. 1–2), speculative-decoding rounds
//! (shapes replayed from real-engine profiles), parallel drafting gated by
//! Eq. 6, and the per-GPU delay accounting of Fig. 8.
//!
//! Framework differences are entirely in `Strategies` (Table 5):
//!
//! | framework  | sd | pc | pd | medusa | server_chunk |
//! |------------|----|----|----|--------|--------------|
//! | HAT        | ✓  | ✓  | ✓  |        |              |
//! | U-shape    |    |    |    |        |              |
//! | U-Medusa   | ✓  |    |    | ✓      |              |
//! | U-Sarathi  |    |    |    |        | fixed        |

use std::collections::HashMap;

use crate::cloud::{optimal_chunk, Batcher, Job, JobKind, Pipeline, StateMonitor};
use crate::config::ExperimentConfig;
use crate::devices::DeviceCompute;
use crate::metrics::{Recorder, RequestRecord};
use crate::net::{hidden_state_bytes, DeviceLink, Dir};
use crate::sim::{EventQueue, SimTime};
use crate::specdec::chunk_sizes;
use crate::specdec::profile::SdProfile;
use crate::util::rng::Rng;
use crate::workload::{generate_trace, Request};

/// U-Medusa's tree-verification size (paper §4.1: "tree verification of
/// size 8"): tokens per verification step in the cloud and on the wire.
const MEDUSA_TREE: usize = 8;

#[derive(Debug, Clone, Copy)]
enum Ev {
    Arrive(usize),
    /// Device finished computing prefill chunk `c` of request `r`.
    ChunkComputed { r: usize, c: usize },
    /// A payload finished its uplink transfer.
    UploadArrived { r: usize, kind: JobKind, tokens: usize },
    /// Try to admit a batch in the cloud.
    CloudTryStep,
    /// Cloud step `id` fully completed (all pipeline stages).
    StepDone { id: u64 },
    /// Result downlink reached the device.
    DownloadArrived { r: usize },
    /// Device finished drafting for the next round.
    DraftDone { r: usize },
    /// Device head done — tokens emitted.
    Emit { r: usize, count: usize, finish: bool },
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Prefill,
    Decode,
    Done,
}

struct ReqSim {
    req: Request,
    phase: Phase,
    chunks: Vec<usize>,
    next_chunk_compute: usize,
    chunks_processed: usize,
    /// Rounds completed (indexes the SD profile).
    round_idx: usize,
    /// Current round's shape while in flight.
    cur_emit: usize,
    cur_verify: usize,
    /// PD: the λ budget computed last round and whether the profile says
    /// the candidate hit.
    pd_lambda: usize,
    pd_hit_pending: bool,
    generated: usize,
}

pub struct FleetSim {
    pub cfg: ExperimentConfig,
    pub profile: SdProfile,
}

/// Convenience: build + run + summarize.
pub fn run_experiment(cfg: &ExperimentConfig, profile: &SdProfile) -> Recorder {
    FleetSim { cfg: cfg.clone(), profile: profile.clone() }.run()
}

impl FleetSim {
    pub fn run(&self) -> Recorder {
        let cfg = &self.cfg;
        let n_dev = cfg.workload.n_devices;
        let root = Rng::new(cfg.seed);
        let mut g_noise = root.substream(0x6001);

        // --- substrate state ------------------------------------------------
        let mut links: Vec<DeviceLink> =
            (0..n_dev).map(|i| DeviceLink::new(i, n_dev, &root)).collect();
        let mut devs: Vec<DeviceCompute> =
            (0..n_dev).map(|i| DeviceCompute::new(i, n_dev, &root)).collect();
        let mut dev_compute_free = vec![SimTime::ZERO; n_dev];
        let mut dev_up_free = vec![SimTime::ZERO; n_dev];
        let mut dev_down_free = vec![SimTime::ZERO; n_dev];

        let mut pipeline = Pipeline::new(cfg.cloud.pipeline_len);
        let mut batcher = Batcher::new();
        let mut monitor = StateMonitor::new(cfg.cloud.alpha, n_dev, cfg.cloud.max_batch_tokens * 4);
        let mut step_batches: HashMap<u64, Vec<Job>> = HashMap::new();
        let mut next_step_id = 0u64;
        let mut try_scheduled = false;

        let a_bytes = hidden_state_bytes(1, cfg.workload.dataset.paper_hidden());
        let g_model = cfg.cloud.g;
        let strat = cfg.strategies;
        // Per-step prefill token budget (Sarathi iteration semantics):
        // the fixed chunk for U-Sarathi, the Eq. 3 upper bound for HAT,
        // effectively unlimited for the unchunked baselines (whole prompts
        // are single jobs — their interference is the point, Fig. 8).
        let prefill_budget = match strat.server_chunk {
            Some(sc) => sc,
            None if strat.pc => cfg.max_chunk,
            None => cfg.cloud.max_batch_tokens.max(4096),
        };

        // --- workload + records ----------------------------------------------
        let trace = generate_trace(&cfg.workload, cfg.seed);
        let mut rec = Recorder::new();
        let mut reqs: Vec<ReqSim> = Vec::with_capacity(trace.len());
        let mut q: EventQueue<Ev> = EventQueue::new();
        for r in &trace {
            rec.requests.push(RequestRecord::new(r.id, r.device, r.prompt_len, r.arrival));
            reqs.push(ReqSim {
                req: r.clone(),
                phase: Phase::Prefill,
                chunks: Vec::new(),
                next_chunk_compute: 0,
                chunks_processed: 0,
                round_idx: 0,
                cur_emit: 0,
                cur_verify: 0,
                pd_lambda: 0,
                pd_hit_pending: false,
                generated: 0,
            });
            q.schedule_at(r.arrival, Ev::Arrive(r.id));
        }
        let mut finished = 0usize;

        // --- the event loop ---------------------------------------------------
        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Arrive(r) => {
                    let dev = reqs[r].req.device;
                    devs[dev].on_request();
                    // Device reports its state (γ, β) to the monitor (§3.2).
                    monitor.observe_device(
                        dev,
                        devs[dev].gamma_ms(),
                        links[dev].up_bytes_per_ms(),
                        links[dev].down_bytes_per_ms(),
                    );
                    // Decide chunking.
                    let plen = reqs[r].req.prompt_len;
                    let chunks = if strat.pc {
                        let x = optimal_chunk(
                            a_bytes as f64,
                            monitor.devices[dev].up_bytes_per_ms.get().unwrap_or(7000.0),
                            |b| monitor.g_t(b, |t| g_model.eval(t)),
                            monitor.mu_t(),
                            cfg.cloud.pipeline_len,
                            (cfg.min_chunk, cfg.max_chunk),
                        );
                        rec.chunk_sizes.push(x);
                        chunk_sizes(plen, x)
                    } else {
                        // Whole prompt in one device-side piece (server may
                        // still chunk it — U-Sarathi).
                        vec![plen]
                    };
                    reqs[r].chunks = chunks;
                    // Start computing the first chunk on the device.
                    self.schedule_chunk_compute(&mut q, &mut dev_compute_free, &devs, &mut reqs[r], r, now, 0);
                }

                Ev::ChunkComputed { r, c } => {
                    let dev = reqs[r].req.device;
                    // Pipeline: next chunk's compute starts immediately
                    // (overlaps this chunk's upload — Fig. 4).
                    if c + 1 < reqs[r].chunks.len() {
                        self.schedule_chunk_compute(&mut q, &mut dev_compute_free, &devs, &mut reqs[r], r, now, c + 1);
                    }
    // Upload this chunk's hidden states.  Chunks after the
                    // first ride the same stream (no per-message latency).
                    let tokens = reqs[r].chunks[c];
                    let bytes = tokens * a_bytes;
                    let start = now.max(dev_up_free[dev]);
                    let dur = if c == 0 {
                        links[dev].transfer_ms(bytes, Dir::Up)
                    } else {
                        links[dev].streamed_ms(bytes, Dir::Up)
                    };
                    dev_up_free[dev] = start.add_ms(dur);
                    q.schedule_at(
                        dev_up_free[dev],
                        Ev::UploadArrived { r, kind: JobKind::PrefillChunk, tokens },
                    );
                }

                Ev::UploadArrived { r, kind, tokens } => {
                    match (kind, strat.server_chunk) {
                        (JobKind::PrefillChunk, Some(sc)) => {
                            // U-Sarathi: the server splits the uploaded
                            // prompt into fixed-size chunks processed over
                            // multiple steps.
                            for piece in chunk_sizes(tokens, sc) {
                                batcher.push(Job { req: r, kind, tokens: piece, epoch: 0 });
                            }
                            // chunks bookkeeping: treat server pieces as
                            // the chunk count for completion tracking.
                            reqs[r].chunks = chunk_sizes(tokens, sc);
                        }
                        _ => batcher.push(Job { req: r, kind, tokens, epoch: 0 }),
                    }
                    if !try_scheduled {
                        try_scheduled = true;
                        q.schedule_at(now, Ev::CloudTryStep);
                    }
                }

                Ev::CloudTryStep => {
                    try_scheduled = false;
                    while pipeline.can_admit(now) && !batcher.is_empty() {
                        let batch = batcher.form_batch(prefill_budget);
                        let tokens = Batcher::batch_tokens(&batch);
                        let noise = 1.0 + 0.05 * g_noise.normal();
                        let g_ms = g_model.eval(tokens as f64) * noise.clamp(0.7, 1.3);
                        let adm = pipeline.admit(now, g_ms);
                        rec.gpu_step_delays.push(adm.per_gpu_ms);
                        rec.batch_token_sizes.push(tokens);
                        monitor.observe_step(tokens, g_ms);
                        let id = next_step_id;
                        next_step_id += 1;
                        step_batches.insert(id, batch);
                        q.schedule_at(adm.done, Ev::StepDone { id });
                    }
                    if !batcher.is_empty() && !try_scheduled {
                        try_scheduled = true;
                        q.schedule_at(pipeline.stage1_free_at().max(now), Ev::CloudTryStep);
                    }
                }

                Ev::StepDone { id } => {
                    let batch = step_batches.remove(&id).expect("unknown step");
                    for job in batch {
                        let r = job.req;
                        match job.kind {
                            JobKind::PrefillChunk => {
                                reqs[r].chunks_processed += 1;
                                if reqs[r].chunks_processed == reqs[r].chunks.len() {
                                    // Last chunk processed → send the result
                                    // row back (first-token path).
                                    self.schedule_download(
                                        &mut q, &mut links, &mut dev_down_free, &reqs[r], r, now, 1,
                                    );
                                }
                            }
                            JobKind::Decode => {
                                let k = reqs[r].cur_verify;
                                self.schedule_download(
                                    &mut q, &mut links, &mut dev_down_free, &reqs[r], r, now, k,
                                );
                            }
                        }
                    }
                }

                Ev::DownloadArrived { r } => {
                    let dev = reqs[r].req.device;
                    // Device head pass, then emission.
                    let (count, verify) = match reqs[r].phase {
                        Phase::Prefill => (1, 1),
                        Phase::Decode => (reqs[r].cur_emit, reqs[r].cur_verify),
                        Phase::Done => continue,
                    };
                    let start = now.max(dev_compute_free[dev]);
                    let dur = devs[dev].head_ms(verify.max(1));
                    dev_compute_free[dev] = start.add_ms(dur);
                    let will_have = reqs[r].generated + count;
                    let finish = will_have >= reqs[r].req.max_new_tokens;
                    q.schedule_at(dev_compute_free[dev], Ev::Emit { r, count, finish });
                }

                Ev::Emit { r, count, finish } => {
                    let rr = &mut rec.requests[r];
                    for _ in 0..count {
                        if rr.first_token.is_none() {
                            rr.first_token = Some(now);
                        }
                        rr.token_times.push(now);
                    }
                    reqs[r].generated += count;
                    if reqs[r].phase == Phase::Decode {
                        rr.sd_rounds += 1;
                        rr.sd_accepted += count;
                    }
                    if finish {
                        rr.finished = Some(now);
                        reqs[r].phase = Phase::Done;
                        finished += 1;
                        continue;
                    }
                    reqs[r].phase = Phase::Decode;
                    // Start the next decode round: drafting on the device.
                    self.start_round(
                        &mut q, &mut dev_compute_free, &devs, &monitor, &mut rec, &mut reqs[r], r,
                        now, a_bytes,
                    );
                }

                Ev::DraftDone { r } => {
                    // Upload the draft hidden states for verification.
                    let dev = reqs[r].req.device;
                    let k = reqs[r].cur_verify;
                    let bytes = k * a_bytes;
                    let start = now.max(dev_up_free[dev]);
                    let dur = links[dev].transfer_ms(bytes, Dir::Up);
                    dev_up_free[dev] = start.add_ms(dur);
                    q.schedule_at(
                        dev_up_free[dev],
                        Ev::UploadArrived { r, kind: JobKind::Decode, tokens: reqs[r].cur_verify },
                    );
                }
            }
            if finished == reqs.len() {
                break;
            }
        }
        rec
    }

    #[allow(clippy::too_many_arguments)]
    fn schedule_chunk_compute(
        &self,
        q: &mut EventQueue<Ev>,
        compute_free: &mut [SimTime],
        devs: &[DeviceCompute],
        rs: &mut ReqSim,
        r: usize,
        now: SimTime,
        c: usize,
    ) {
        let dev = rs.req.device;
        let start = now.max(compute_free[dev]);
        let dur = devs[dev].prefill_ms(rs.chunks[c]);
        compute_free[dev] = start.add_ms(dur);
        rs.next_chunk_compute = c + 1;
        q.schedule_at(compute_free[dev], Ev::ChunkComputed { r, c });
    }

    #[allow(clippy::too_many_arguments)]
    fn schedule_download(
        &self,
        q: &mut EventQueue<Ev>,
        links: &mut [DeviceLink],
        down_free: &mut [SimTime],
        rs: &ReqSim,
        r: usize,
        now: SimTime,
        tokens: usize,
    ) {
        let dev = rs.req.device;
        let a = hidden_state_bytes(1, self.cfg.workload.dataset.paper_hidden());
        let start = now.max(down_free[dev]);
        let dur = links[dev].transfer_ms(tokens.max(1) * a, Dir::Down);
        down_free[dev] = start.add_ms(dur);
        q.schedule_at(down_free[dev], Ev::DownloadArrived { r });
    }

    /// Begin one decode round for request `r` at `now`: decide the round
    /// shape from the profile, account drafting time (zero on a parallel-
    /// drafting hit gated by Eq. 6), then hand over to the uplink.
    #[allow(clippy::too_many_arguments)]
    fn start_round(
        &self,
        q: &mut EventQueue<Ev>,
        compute_free: &mut [SimTime],
        devs: &[DeviceCompute],
        monitor: &StateMonitor,
        rec: &mut Recorder,
        rs: &mut ReqSim,
        r: usize,
        now: SimTime,
        a_bytes: usize,
    ) {
        let cfg = &self.cfg;
        let strat = cfg.strategies;
        let dev = rs.req.device;
        let shape = if strat.medusa {
            self.profile.round(true, cfg.seed ^ r as u64, rs.round_idx)
        } else if strat.sd {
            self.profile.round(false, cfg.seed ^ r as u64, rs.round_idx)
        } else {
            // Plain U-shape / U-Sarathi: one token per interaction.
            crate::specdec::profile::RoundShape {
                draft_steps: 0,
                verify_tokens: 1,
                emitted: 1,
                pd_hit: false,
            }
        };
        rs.round_idx += 1;
        rs.cur_emit = shape.emitted.max(1);
        rs.cur_verify = if strat.medusa { MEDUSA_TREE } else { shape.verify_tokens.max(1) };

        // Drafting time.
        let gamma = devs[dev].gamma_ms();
        let draft_ms = if strat.medusa {
            // Medusa heads + shallow pass over the draft tokens: one cheap
            // device step (the heads are a single matmul each).
            devs[dev].prefill_ms(self.profile.medusa_verify_len())
        } else if strat.sd {
            let hit = strat.pd && rs.pd_hit_pending && rs.pd_lambda >= shape.draft_steps;
            if hit {
                rec.requests[r].pd_hits += 1;
                0.0
            } else {
                gamma * shape.draft_steps as f64
            }
        } else {
            // U-shape/U-Sarathi: the device still runs the input submodel
            // over the single token.
            devs[dev].prefill_ms(1)
        };

        // Parallel drafting budget for the *next* round (Eq. 6):
        //   λ_i = ⌊( μ_i·A/β_up + g^t(μ^t) + μ_i·A/β_down ) / γ_i⌋
        if strat.pd && strat.sd && !strat.medusa {
            let k = rs.cur_verify as f64;
            let up = monitor.devices[dev].up_bytes_per_ms.get().unwrap_or(7000.0);
            let down = monitor.devices[dev].down_bytes_per_ms.get().unwrap_or(12000.0);
            let g_mu = monitor.g_t(monitor.mu_t(), |t| self.cfg.cloud.g.eval(t));
            let lambda = ((k * a_bytes as f64 / up + g_mu + k * a_bytes as f64 / down)
                / gamma.max(1e-6))
            .floor() as usize;
            rs.pd_lambda = lambda.min(cfg.specdec.max_draft);
            rs.pd_hit_pending = shape.pd_hit;
        }

        let start = now.max(compute_free[dev]);
        compute_free[dev] = start.add_ms(draft_ms);
        q.schedule_at(compute_free[dev], Ev::DraftDone { r });
    }
}

impl SdProfile {
    /// Device-side verify length for a Medusa round (tokens processed
    /// through the input submodel): n_medusa.
    pub fn medusa_verify_len(&self) -> usize {
        self.medusa.first().map(|r| r.verify_tokens).unwrap_or(4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Dataset, ExperimentConfig, Framework};

    fn small_cfg(fw: Framework) -> ExperimentConfig {
        // The paper's operating point (Fig. 6): 30 devices, 6 req/s, P=4,
        // 128 generated tokens — trimmed to 100 requests for test speed.
        let mut c = ExperimentConfig::preset(fw, Dataset::SpecBench);
        c.workload.n_requests = 100;
        c
    }

    fn run(fw: Framework) -> Recorder {
        run_experiment(&small_cfg(fw), &SdProfile::default_table())
    }

    #[test]
    fn all_frameworks_finish_all_requests() {
        for fw in Framework::all() {
            let rec = run(fw);
            assert_eq!(rec.finished_requests().count(), 100, "{}", fw.name());
            for r in rec.finished_requests() {
                assert!(r.tokens_generated() >= 128, "{} generated {}", fw.name(), r.tokens_generated());
                assert!(r.ttft_ms().unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run(Framework::Hat).summary();
        let b = run(Framework::Hat).summary();
        assert_eq!(a.ttft_mean_ms, b.ttft_mean_ms);
        assert_eq!(a.tbt_mean_ms, b.tbt_mean_ms);
    }

    #[test]
    fn hat_beats_ushape_on_both_metrics() {
        // The paper's headline (Figs. 6–7): HAT lowest TTFT and TBT.
        let hat = run(Framework::Hat).summary();
        let ushape = run(Framework::UShape).summary();
        assert!(
            hat.ttft_mean_ms < ushape.ttft_mean_ms,
            "TTFT: HAT {} vs U-shape {}",
            hat.ttft_mean_ms,
            ushape.ttft_mean_ms
        );
        assert!(
            hat.tbt_mean_ms < ushape.tbt_mean_ms,
            "TBT: HAT {} vs U-shape {}",
            hat.tbt_mean_ms,
            ushape.tbt_mean_ms
        );
    }

    #[test]
    fn chunking_reduces_gpu_delay_variance() {
        // Fig. 8: HAT/U-Sarathi keep per-GPU delay stable; U-shape/U-Medusa
        // are volatile under long prompts.
        let hat = run(Framework::Hat).summary();
        let ushape = run(Framework::UShape).summary();
        assert!(
            hat.gpu_delay_std_ms < ushape.gpu_delay_std_ms,
            "std: HAT {} vs U-shape {}",
            hat.gpu_delay_std_ms,
            ushape.gpu_delay_std_ms
        );
    }

    #[test]
    fn hat_records_chunk_sizes_and_pd_hits() {
        let rec = run(Framework::Hat);
        assert!(!rec.chunk_sizes.is_empty(), "Eq. 3 optimizer never ran");
        assert!(rec.accept_length() > 1.0, "accept length {}", rec.accept_length());
    }
}
