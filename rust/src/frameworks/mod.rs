//! The four device-cloud collaborative inference frameworks (§4.1):
//! HAT and the three baselines, all driven by one fleet simulator
//! parameterized by the ablation switches of Table 5.

pub mod fleet;

pub use fleet::{run_experiment, FleetSim};
