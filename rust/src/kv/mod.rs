//! Paged KV storage: fixed-size block pool, copy-on-write prefix sharing,
//! and host-side swap for preemptible sessions.
//!
//! Dense per-session KV tensors (`max_seq × hidden` per submodel, alive for
//! the whole session) cap `max_sessions` by worst-case sequence length.
//! This module replaces them with a [`KvPool`] of fixed-size blocks
//! (`[kv] block_tokens` rows each) and per-stream [`KvCache`] block tables:
//!
//! - **Lazy allocation** — a cache starts with an empty table; blocks are
//!   taken from the pool on first write, so short sequences use few blocks.
//! - **Copy-on-write sharing** — when a cache *commits* past a block
//!   boundary the block is *sealed*: hashed over `(block index, contents)`
//!   and deduplicated against resident sealed blocks, so sessions admitted
//!   with an identical prompt prefix map the same physical blocks.  Sealing
//!   happens *after* the rows are computed (write-then-dedup), so shared
//!   prefixes are bit-identical by construction, not by trust in the hash
//!   (candidates are verified bit-for-bit before merging).  Speculative
//!   forks ([`KvCache::fork`]) share the unsealed tail refcounted; the
//!   first divergent write triggers a private copy.
//! - **Swap** — [`KvCache::swap_out`] moves a stream's blocks to a
//!   host-side store and returns them to the pool freelist, so the
//!   scheduler can pause a session under slot pressure instead of
//!   cancelling it; [`KvCache::swap_in`] restores them (re-deduplicating
//!   sealed blocks against residents) and fails cleanly when the pool is
//!   full, leaving the host copy intact for a later retry.
//!
//! **Bit-identity contract.**  The reference model's row at position `p`
//! depends on the *sequential* f32 sum of rows `0..p` (f32 addition is not
//! associative, so the summation order is part of the contract).  A cache
//! therefore keeps per-block-boundary checkpoints of that exact running
//! sum (`psums[j]` = rows `0..(j+1)·block_tokens` accumulated left to
//! right); [`KvCache::prefix_sum`] seeds from the deepest valid checkpoint
//! and continues sequentially, which reproduces the dense recomputation
//! bit-for-bit while making decode steps O(block_tokens) amortized instead
//! of O(position).  Any write at position `p` invalidates checkpoints
//! covering rows ≥ `p`; checkpoints survive swap because they describe the
//! stream, not the physical blocks.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{anyhow, bail, Result};

use crate::backend::Tensor;
use crate::config::KvConfig;
use crate::model::KvPos;

/// One physical block: `block_tokens` rows of `row` f32s.
struct Block {
    data: Vec<f32>,
    /// Reference count: how many cache tables map this block.
    rc: u32,
    /// Content hash once sealed (fully committed + dedup-registered);
    /// sealed blocks are immutable — writes trigger copy-on-write.
    hash: Option<u64>,
}

struct PoolInner {
    block_tokens: usize,
    row: usize,
    blocks: Vec<Block>,
    free: Vec<usize>,
    /// Sealed-content registry: hash → block indices (bit-verified on use).
    dedup: HashMap<u64, Vec<usize>>,
    peak_in_use: usize,
    swap_out_bytes: u64,
    swap_in_bytes: u64,
}

impl PoolInner {
    fn in_use(&self) -> usize {
        self.blocks.len() - self.free.len()
    }

    fn alloc(&mut self) -> Result<usize> {
        let idx = self.free.pop().ok_or_else(|| {
            anyhow!(
                "kv pool exhausted ({} blocks of {} tokens)",
                self.blocks.len(),
                self.block_tokens
            )
        })?;
        let len = self.block_tokens * self.row;
        let b = &mut self.blocks[idx];
        b.data.clear();
        b.data.resize(len, 0.0);
        b.rc = 1;
        b.hash = None;
        let used = self.in_use();
        self.peak_in_use = self.peak_in_use.max(used);
        Ok(idx)
    }

    fn release(&mut self, idx: usize) {
        debug_assert!(self.blocks[idx].rc > 0, "double release of kv block {idx}");
        self.blocks[idx].rc -= 1;
        if self.blocks[idx].rc == 0 {
            if let Some(h) = self.blocks[idx].hash.take() {
                if let Some(v) = self.dedup.get_mut(&h) {
                    v.retain(|&i| i != idx);
                    if v.is_empty() {
                        self.dedup.remove(&h);
                    }
                }
            }
            self.free.push(idx);
        }
    }

    /// Seal a fully-committed block: register its content hash, or merge
    /// with a resident bit-identical sealed block.  Returns the index the
    /// caller's table should map (possibly a shared sibling).
    fn seal(&mut self, idx: usize, k: usize) -> usize {
        if self.blocks[idx].hash.is_some() {
            return idx; // already sealed (e.g. adopted via fork/swap-in)
        }
        let h = block_hash(k, &self.blocks[idx].data);
        let hit = self.dedup.get(&h).and_then(|cands| {
            cands
                .iter()
                .copied()
                .find(|&c| c != idx && bits_eq(&self.blocks[c].data, &self.blocks[idx].data))
        });
        if let Some(c) = hit {
            self.blocks[c].rc += 1;
            self.release(idx);
            return c;
        }
        self.blocks[idx].hash = Some(h);
        self.dedup.entry(h).or_default().push(idx);
        idx
    }
}

/// FNV-1a over the block index and the row bits.  The index is mixed in so
/// identical contents at *different* positions never alias (a prefix match
/// must match positionally, mirroring chunk-granular prompt hashing).
fn block_hash(k: usize, data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ (k as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    for &x in data {
        h ^= u64::from(x.to_bits());
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Bit-level equality (distinguishes -0.0/0.0 and NaN payloads — sharing
/// must never change what a gather would read back).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Pool-level occupancy and swap-traffic counters (see
/// [`KvPool::stats`]; surfaced through `metrics::ServeStats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    pub total_blocks: usize,
    pub blocks_in_use: usize,
    /// Physical blocks mapped by more than one cache table.
    pub shared_blocks: usize,
    pub peak_in_use: usize,
    pub swap_out_bytes: u64,
    pub swap_in_bytes: u64,
}

/// Shared handle to a fixed-size block pool.  Cloning is cheap (`Arc`);
/// all caches of one engine draw from the same pool.
#[derive(Clone)]
pub struct KvPool(Arc<Mutex<PoolInner>>);

impl KvPool {
    /// Pool of `cfg.kv_blocks` blocks of `cfg.block_tokens` rows of `row`
    /// f32s.  `max_rows` is the longest stream the model can hold
    /// (`max_seq`); the pool must cover at least one max-length session
    /// across its three caches (skv/akv/mkv), or sizing is rejected here —
    /// the manifest-aware complement of `config::validate()`'s
    /// workload-level floor.
    pub fn new(cfg: &KvConfig, row: usize, max_rows: usize) -> Result<KvPool> {
        if cfg.block_tokens == 0 || row == 0 {
            bail!("kv.block_tokens and row width must be > 0");
        }
        let per_cache = max_rows.div_ceil(cfg.block_tokens);
        if 3 * per_cache > cfg.kv_blocks {
            bail!(
                "kv pool too small: kv_blocks = {} cannot hold one max-length session \
                 (3 caches x {per_cache} blocks for {max_rows} rows of {} tokens)",
                cfg.kv_blocks,
                cfg.block_tokens
            );
        }
        let blocks = (0..cfg.kv_blocks)
            .map(|_| Block { data: Vec::new(), rc: 0, hash: None })
            .collect();
        let free = (0..cfg.kv_blocks).rev().collect();
        Ok(KvPool(Arc::new(Mutex::new(PoolInner {
            block_tokens: cfg.block_tokens,
            row,
            blocks,
            free,
            dedup: HashMap::new(),
            peak_in_use: 0,
            swap_out_bytes: 0,
            swap_in_bytes: 0,
        }))))
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        // A poisoned pool is still structurally sound (all mutations keep
        // the freelist/refcount invariants at every await-free step).
        self.0.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// New empty cache over `rows` logical rows, presenting `dims` to the
    /// dense shim (`dims` must contain a leading `rows × row` region, the
    /// layout contract of the KV tensors).
    pub fn new_cache(&self, dims: Vec<usize>, rows: usize) -> KvCache {
        let (bt, row) = {
            let p = self.lock();
            (p.block_tokens, p.row)
        };
        debug_assert!(dims.iter().product::<usize>() >= rows * row);
        KvCache {
            pool: self.clone(),
            table: vec![None; rows.div_ceil(bt)],
            dims,
            rows,
            row,
            bt,
            pos: KvPos::new(),
            psums: Vec::new(),
            sealed: 0,
            swapped: None,
        }
    }

    pub fn block_tokens(&self) -> usize {
        self.lock().block_tokens
    }

    /// Bytes of one physical block.
    pub fn block_bytes(&self) -> usize {
        let p = self.lock();
        p.block_tokens * p.row * 4
    }

    pub fn stats(&self) -> KvPoolStats {
        let p = self.lock();
        KvPoolStats {
            total_blocks: p.blocks.len(),
            blocks_in_use: p.in_use(),
            shared_blocks: p.blocks.iter().filter(|b| b.rc > 1).count(),
            peak_in_use: p.peak_in_use,
            swap_out_bytes: p.swap_out_bytes,
            swap_in_bytes: p.swap_in_bytes,
        }
    }

    /// Whether two handles refer to the *same* physical pool (Arc
    /// identity).  The prefill→decode handoff uses this to prove a
    /// session's block tables stay valid across the engine switch: block
    /// indices are only meaningful within the pool that allocated them.
    pub fn same_pool(&self, other: &KvPool) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }

    /// True when every block is free, no refcount is stuck and the dedup
    /// registry is empty — the zero-leak invariant the lifecycle property
    /// tests assert after all sessions quiesce.
    pub fn quiesced(&self) -> bool {
        let p = self.lock();
        p.free.len() == p.blocks.len()
            && p.dedup.is_empty()
            && p.blocks.iter().all(|b| b.rc == 0)
    }
}

/// Host-side copy of one swapped-out block (contents + seal hash, so
/// swap-in can re-deduplicate against resident siblings without rehashing).
struct SwapBlock {
    data: Vec<f32>,
    hash: Option<u64>,
}

/// One stream's paged KV cache: a block table mapping logical row ranges
/// to pool blocks, the stream's [`KvPos`] write/commit state machine, and
/// the prefix-sum checkpoints that keep reference-model attention
/// bit-identical to the dense recomputation.
pub struct KvCache {
    pool: KvPool,
    /// Dense tensor dims presented to the shim (`gather_dense`).
    dims: Vec<usize>,
    /// Logical rows (`max_seq`).
    rows: usize,
    /// Row width (`hidden`).
    row: usize,
    /// Block size in rows (copied out of the pool to avoid locking for
    /// arithmetic).
    bt: usize,
    table: Vec<Option<usize>>,
    pos: KvPos,
    /// `psums[j]` = the exact sequential f32 sum of rows `0..(j+1)·bt`.
    psums: Vec<Vec<f32>>,
    /// Blocks `0..sealed` have been sealed (dedup-registered) — strictly
    /// below the committed head, so they are never written again.
    sealed: usize,
    /// Host-side store while preempted; `None` when resident.
    swapped: Option<Vec<Option<SwapBlock>>>,
}

impl KvCache {
    // -- position state machine (delegates to KvPos) -----------------------

    pub fn pos(&self) -> KvPos {
        self.pos
    }

    pub fn write_pos(&self) -> usize {
        self.pos.write_pos()
    }

    pub fn committed(&self) -> usize {
        self.pos.committed
    }

    pub fn wrote(&mut self, n: usize) {
        self.pos.wrote(n);
    }

    /// Commit `n` tokens and seal every block that became fully committed:
    /// sealed blocks are hashed and deduplicated against resident sealed
    /// blocks of other caches (copy-on-write prefix sharing).
    pub fn commit(&mut self, n: usize) {
        self.pos.commit(n);
        let full = (self.pos.committed / self.bt).min(self.table.len());
        if full > self.sealed {
            let mut pool = self.pool.lock();
            for k in self.sealed..full {
                if let Some(idx) = self.table[k] {
                    self.table[k] = Some(pool.seal(idx, k));
                }
            }
            self.sealed = full;
        }
    }

    pub fn rollback(&mut self) {
        self.pos.rollback();
    }

    pub fn seek(&mut self, p: usize) {
        self.pos.seek(p);
    }

    // -- geometry ----------------------------------------------------------

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Physical blocks currently mapped by this cache's table.
    pub fn resident_blocks(&self) -> usize {
        self.table.iter().flatten().count()
    }

    pub fn is_swapped(&self) -> bool {
        self.swapped.is_some()
    }

    // -- row access --------------------------------------------------------

    /// The block backing row-group `k`, made privately writable: allocates
    /// on first touch, copies on write when the block is shared (`rc > 1`)
    /// or sealed (immutable by contract — mutating it would corrupt the
    /// dedup registry under every sibling).  Free function over the table
    /// so it can run while the pool guard is held.
    fn writable_block(
        table: &mut [Option<usize>],
        k: usize,
        pool: &mut PoolInner,
    ) -> Result<usize> {
        match table[k] {
            Some(i) if pool.blocks[i].rc == 1 && pool.blocks[i].hash.is_none() => Ok(i),
            Some(i) => {
                let n = pool.alloc()?;
                let src = pool.blocks[i].data.clone();
                pool.blocks[n].data.copy_from_slice(&src);
                pool.release(i);
                table[k] = Some(n);
                Ok(n)
            }
            None => {
                let n = pool.alloc()?;
                table[k] = Some(n);
                Ok(n)
            }
        }
    }

    /// Write one row at absolute position `p` (copy-on-write), and
    /// invalidate prefix-sum checkpoints covering it.
    pub fn write_row(&mut self, p: usize, vals: &[f32]) -> Result<()> {
        if p >= self.rows {
            bail!("kv write at row {p} out of range {}", self.rows);
        }
        if vals.len() != self.row {
            bail!("kv row width {} != {}", vals.len(), self.row);
        }
        if self.swapped.is_some() {
            bail!("kv write on a swapped-out cache");
        }
        let k = p / self.bt;
        let off = (p % self.bt) * self.row;
        let mut pool = self.pool.lock();
        let idx = Self::writable_block(&mut self.table, k, &mut pool)?;
        pool.blocks[idx].data[off..off + self.row].copy_from_slice(vals);
        drop(pool);
        // Checkpoint j covers rows 0..(j+1)·bt — stale once any of those
        // rows changes, so keep only checkpoints ending at or before p.
        self.psums.truncate(k);
        Ok(())
    }

    /// Write a row *and* fold it into the caller's running sequential sum,
    /// recording a checkpoint when the write lands exactly on a block
    /// boundary continuing the valid-checkpoint prefix.  `sum` must be the
    /// exact sequential sum of rows `0..p` (as returned by
    /// [`Self::prefix_sum`] and threaded through the compute loop).
    pub fn write_row_accumulate(&mut self, p: usize, vals: &[f32], sum: &mut [f32]) -> Result<()> {
        self.write_row(p, vals)?;
        for (s, v) in sum.iter_mut().zip(vals) {
            *s += v;
        }
        if (p + 1) % self.bt == 0 && self.psums.len() == (p + 1) / self.bt - 1 {
            self.psums.push(sum.to_vec());
        }
        Ok(())
    }

    /// Exact sequential f32 sum of rows `0..p`, bit-identical to summing a
    /// dense gather left to right: seeds from the deepest checkpoint not
    /// past `p` and accumulates the remainder in order (recording any
    /// checkpoints crossed, so repeated calls amortize to O(bt)).
    pub fn prefix_sum(&mut self, p: usize) -> Vec<f32> {
        debug_assert!(self.swapped.is_none(), "prefix_sum on a swapped-out cache");
        let n = self.psums.len().min(p / self.bt);
        let mut sum = if n > 0 { self.psums[n - 1].clone() } else { vec![0.0; self.row] };
        if n * self.bt >= p {
            return sum;
        }
        let pool = self.pool.lock();
        for q in n * self.bt..p {
            if let Some(idx) = self.table[q / self.bt] {
                let off = (q % self.bt) * self.row;
                let r = &pool.blocks[idx].data[off..off + self.row];
                for (s, v) in sum.iter_mut().zip(r) {
                    *s += v;
                }
            }
            if (q + 1) % self.bt == 0 && self.psums.len() == (q + 1) / self.bt - 1 {
                self.psums.push(sum.clone());
            }
        }
        sum
    }

    // -- dense shim --------------------------------------------------------

    /// Materialize the dense KV tensor (`dims`, leading `rows × row`
    /// region gathered from the table, unmapped blocks and the tail zero)
    /// — the input shape backends without a paged path expect.
    pub fn gather_dense(&self) -> Result<Tensor> {
        if self.swapped.is_some() {
            bail!("gather on a swapped-out cache");
        }
        let mut data = vec![0.0f32; self.dims.iter().product()];
        let pool = self.pool.lock();
        for (k, slot) in self.table.iter().enumerate() {
            if let Some(idx) = *slot {
                let n_rows = self.bt.min(self.rows - k * self.bt);
                let dst = k * self.bt * self.row;
                let len = n_rows * self.row;
                data[dst..dst + len].copy_from_slice(&pool.blocks[idx].data[..len]);
            }
        }
        drop(pool);
        Tensor::new(self.dims.clone(), data)
    }

    /// Scatter rows `start..start+count` (clipped to `rows`) of a dense KV
    /// tensor's data back into the table — the write-back half of the
    /// dense shim.  Only the rows the artifact actually wrote may be
    /// scattered; re-writing the whole tensor would sever shared blocks
    /// and void every checkpoint.
    pub fn scatter_rows(&mut self, dense: &[f32], start: usize, count: usize) -> Result<()> {
        let end = (start + count).min(self.rows);
        for p in start..end {
            self.write_row(p, &dense[p * self.row..(p + 1) * self.row])?;
        }
        Ok(())
    }

    // -- speculative forks -------------------------------------------------

    /// A refcounted snapshot sharing every mapped block (copy-on-write):
    /// the parallel-drafting branches write their speculative tails into
    /// private copies, and adopting a branch is a move.  Checkpoints and
    /// position state ride along.
    pub fn fork(&self) -> KvCache {
        debug_assert!(self.swapped.is_none(), "fork of a swapped-out cache");
        let mut pool = self.pool.lock();
        for idx in self.table.iter().flatten() {
            pool.blocks[*idx].rc += 1;
        }
        drop(pool);
        KvCache {
            pool: self.pool.clone(),
            dims: self.dims.clone(),
            rows: self.rows,
            row: self.row,
            bt: self.bt,
            table: self.table.clone(),
            pos: self.pos,
            psums: self.psums.clone(),
            sealed: self.sealed,
            swapped: None,
        }
    }

    // -- swap --------------------------------------------------------------

    /// Copy every mapped block to a host-side store and return the blocks
    /// to the pool freelist.  Returns the bytes moved.  Idempotent.
    pub fn swap_out(&mut self) -> u64 {
        if self.swapped.is_some() {
            return 0;
        }
        let mut store: Vec<Option<SwapBlock>> = Vec::with_capacity(self.table.len());
        let mut bytes = 0u64;
        let mut pool = self.pool.lock();
        for slot in &mut self.table {
            match slot.take() {
                Some(idx) => {
                    let b = &pool.blocks[idx];
                    bytes += (b.data.len() * 4) as u64;
                    store.push(Some(SwapBlock { data: b.data.clone(), hash: b.hash }));
                    pool.release(idx);
                }
                None => store.push(None),
            }
        }
        pool.swap_out_bytes += bytes;
        drop(pool);
        self.swapped = Some(store);
        bytes
    }

    /// Restore a swapped-out cache: sealed blocks are first matched
    /// against resident sealed siblings (bit-verified) and shared instead
    /// of copied; the rest are re-allocated.  On pool exhaustion the
    /// partial restore is rolled back and the host store kept, so the
    /// caller can retry after pressure drops.  Returns bytes copied in
    /// (shared blocks move zero bytes).
    pub fn swap_in(&mut self) -> Result<u64> {
        let Some(store) = self.swapped.as_ref() else {
            return Ok(0);
        };
        let mut got: Vec<(usize, usize)> = Vec::new();
        let mut bytes = 0u64;
        let mut pool = self.pool.lock();
        for (k, entry) in store.iter().enumerate() {
            let Some(sb) = entry else { continue };
            if let Some(h) = sb.hash {
                let hit = pool.dedup.get(&h).and_then(|cands| {
                    cands.iter().copied().find(|&i| bits_eq(&pool.blocks[i].data, &sb.data))
                });
                if let Some(i) = hit {
                    pool.blocks[i].rc += 1;
                    got.push((k, i));
                    continue;
                }
            }
            match pool.alloc() {
                Ok(i) => {
                    pool.blocks[i].data.copy_from_slice(&sb.data);
                    if let Some(h) = sb.hash {
                        pool.blocks[i].hash = Some(h);
                        pool.dedup.entry(h).or_default().push(i);
                    }
                    bytes += (sb.data.len() * 4) as u64;
                    got.push((k, i));
                }
                Err(e) => {
                    for &(_, i) in &got {
                        pool.release(i);
                    }
                    return Err(e);
                }
            }
        }
        pool.swap_in_bytes += bytes;
        drop(pool);
        for (k, i) in got {
            self.table[k] = Some(i);
        }
        self.swapped = None;
        Ok(bytes)
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        let mut pool = self.pool.lock();
        for slot in &mut self.table {
            if let Some(idx) = slot.take() {
                pool.release(idx);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ROW: usize = 4;
    const BT: usize = 8;
    const ROWS: usize = 32;

    fn pool(blocks: usize) -> KvPool {
        KvPool::new(&KvConfig { block_tokens: BT, kv_blocks: blocks }, ROW, ROWS).unwrap()
    }

    fn cache(p: &KvPool) -> KvCache {
        p.new_cache(vec![2, ROWS, ROW], ROWS)
    }

    /// Deterministic pseudo-row keyed by (stream, position).
    fn row_vals(stream: u64, p: usize) -> Vec<f32> {
        (0..ROW)
            .map(|d| {
                let z = (stream ^ ((p as u64) << 8) ^ ((d as u64) << 20))
                    .wrapping_mul(0x9E3779B97F4A7C15);
                ((z >> 40) as f32) / (1u64 << 24) as f32 - 0.5
            })
            .collect()
    }

    fn naive_prefix_sum(c: &KvCache, p: usize) -> Vec<f32> {
        let dense = c.gather_dense().unwrap();
        let mut sum = vec![0.0f32; ROW];
        for q in 0..p {
            for d in 0..ROW {
                sum[d] += dense.data[q * ROW + d];
            }
        }
        sum
    }

    #[test]
    fn gather_starts_zero_and_roundtrips_writes() {
        let p = pool(64);
        let mut c = cache(&p);
        let dense = c.gather_dense().unwrap();
        assert_eq!(dense.dims, vec![2, ROWS, ROW]);
        assert!(dense.data.iter().all(|&x| x == 0.0));
        for q in 0..13 {
            c.write_row(q, &row_vals(1, q)).unwrap();
        }
        let dense = c.gather_dense().unwrap();
        for q in 0..13 {
            assert_eq!(&dense.data[q * ROW..(q + 1) * ROW], &row_vals(1, q)[..]);
        }
        assert!(dense.data[13 * ROW..].iter().all(|&x| x == 0.0));
        assert_eq!(c.resident_blocks(), 2, "13 rows at bt=8 touch 2 blocks");
        assert!(c.write_row(ROWS, &row_vals(1, 0)).is_err(), "out of range");
    }

    #[test]
    fn prefix_sum_matches_naive_bitwise_across_writes_and_checkpoints() {
        let p = pool(64);
        let mut c = cache(&p);
        let mut sum = c.prefix_sum(0);
        for q in 0..ROWS {
            c.write_row_accumulate(q, &row_vals(2, q), &mut sum).unwrap();
        }
        for q in 0..=ROWS {
            assert_eq!(c.prefix_sum(q), naive_prefix_sum(&c, q), "prefix {q}");
        }
        // Overwrite a mid-stream row: checkpoints past it must invalidate
        // and the recomputed sums must still match the naive recompute.
        c.write_row(9, &row_vals(3, 9)).unwrap();
        for q in [0, 8, 9, 10, 16, ROWS] {
            assert_eq!(c.prefix_sum(q), naive_prefix_sum(&c, q), "post-write prefix {q}");
        }
    }

    #[test]
    fn fork_is_copy_on_write() {
        let p = pool(64);
        let mut base = cache(&p);
        for q in 0..10 {
            base.write_row(q, &row_vals(4, q)).unwrap();
        }
        let mut fork = base.fork();
        assert!(p.stats().shared_blocks >= 2, "fork shares the mapped blocks");
        fork.write_row(9, &row_vals(5, 9)).unwrap();
        let b = base.gather_dense().unwrap();
        let f = fork.gather_dense().unwrap();
        assert_eq!(&b.data[9 * ROW..10 * ROW], &row_vals(4, 9)[..], "base untouched");
        assert_eq!(&f.data[9 * ROW..10 * ROW], &row_vals(5, 9)[..], "fork diverged");
        assert_eq!(&f.data[..9 * ROW], &b.data[..9 * ROW], "shared prefix intact");
    }

    #[test]
    fn commit_seals_and_shares_identical_prefixes() {
        let p = pool(64);
        let mut a = cache(&p);
        let mut b = cache(&p);
        for q in 0..16 {
            a.write_row(q, &row_vals(6, q)).unwrap();
            b.write_row(q, &row_vals(6, q)).unwrap();
        }
        assert_eq!(p.stats().blocks_in_use, 4, "private before sealing");
        a.wrote(16);
        a.commit(16);
        b.wrote(16);
        b.commit(16);
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 2, "identical sealed prefixes merge");
        assert_eq!(s.shared_blocks, 2);
        // Divergence past the shared prefix stays private.
        b.write_row(16, &row_vals(7, 16)).unwrap();
        assert_eq!(a.gather_dense().unwrap().data[..16 * ROW], b.gather_dense().unwrap().data[..16 * ROW]);
        assert_eq!(p.stats().blocks_in_use, 3);
    }

    #[test]
    fn same_content_different_position_does_not_alias() {
        let p = pool(64);
        let mut a = cache(&p);
        // Identical contents in blocks 0 and 1 of the *same* stream: the
        // positional hash tag must keep them distinct physical blocks.
        for q in 0..16 {
            a.write_row(q, &row_vals(8, q % BT)).unwrap();
        }
        a.wrote(16);
        a.commit(16);
        assert_eq!(p.stats().blocks_in_use, 2);
        assert_eq!(p.stats().shared_blocks, 0);
    }

    #[test]
    fn swap_roundtrip_preserves_contents_and_checkpoints() {
        let p = pool(64);
        let mut c = cache(&p);
        let mut sum = c.prefix_sum(0);
        for q in 0..20 {
            c.write_row_accumulate(q, &row_vals(9, q), &mut sum).unwrap();
        }
        c.wrote(20);
        c.commit(20);
        let before = c.gather_dense().unwrap();
        let bytes = c.swap_out();
        assert!(bytes > 0);
        assert!(c.is_swapped());
        assert_eq!(c.resident_blocks(), 0);
        assert!(c.gather_dense().is_err(), "swapped cache has no resident view");
        assert_eq!(c.swap_out(), 0, "swap_out is idempotent");
        c.swap_in().unwrap();
        assert_eq!(c.gather_dense().unwrap(), before, "bitwise restore");
        assert_eq!(c.prefix_sum(20), naive_prefix_sum(&c, 20), "checkpoints survive swap");
        let s = p.stats();
        assert_eq!(s.swap_out_bytes, bytes);
        assert_eq!(s.swap_in_bytes, bytes);
    }

    #[test]
    fn swap_in_rededups_against_resident_siblings() {
        let p = pool(64);
        let mut a = cache(&p);
        let mut b = cache(&p);
        for q in 0..16 {
            a.write_row(q, &row_vals(10, q)).unwrap();
            b.write_row(q, &row_vals(10, q)).unwrap();
        }
        a.wrote(16);
        a.commit(16);
        b.wrote(16);
        b.commit(16);
        assert_eq!(p.stats().blocks_in_use, 2);
        b.swap_out();
        assert_eq!(p.stats().blocks_in_use, 2, "a still holds the shared blocks");
        let copied = b.swap_in().unwrap();
        assert_eq!(copied, 0, "sealed blocks re-shared, not copied");
        assert_eq!(p.stats().blocks_in_use, 2);
        assert_eq!(p.stats().shared_blocks, 2);
    }

    #[test]
    fn pool_sizing_floor_and_exhaustion() {
        assert!(
            KvPool::new(&KvConfig { block_tokens: BT, kv_blocks: 11 }, ROW, ROWS).is_err(),
            "11 < 3 x ceil(32/8) blocks"
        );
        let p = pool(12);
        let mut a = p.new_cache(vec![ROWS, ROW], ROWS);
        let mut b = p.new_cache(vec![ROWS, ROW], ROWS);
        let mut c = p.new_cache(vec![ROWS, ROW], ROWS);
        for q in 0..ROWS {
            a.write_row(q, &row_vals(11, q)).unwrap();
            b.write_row(q, &row_vals(12, q)).unwrap();
            c.write_row(q, &row_vals(13, q)).unwrap();
        }
        let mut d = p.new_cache(vec![ROWS, ROW], ROWS);
        let err = d.write_row(0, &row_vals(14, 0)).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        drop(a);
        d.write_row(0, &row_vals(14, 0)).unwrap();
        // A failed swap_in keeps the host store for retry.
        for q in 1..ROWS {
            d.write_row(q, &row_vals(14, q)).unwrap();
        }
        b.swap_out();
        let mut e = p.new_cache(vec![ROWS, ROW], ROWS);
        for q in 0..ROWS {
            e.write_row(q, &row_vals(15, q)).unwrap();
        }
        assert!(b.swap_in().is_err(), "no room to swap back in");
        assert!(b.is_swapped(), "host store kept for retry");
        drop(e);
        b.swap_in().unwrap();
        for q in 0..ROWS {
            assert_eq!(
                &b.gather_dense().unwrap().data[q * ROW..(q + 1) * ROW],
                &row_vals(12, q)[..]
            );
        }
    }

    #[test]
    fn pool_quiesces_after_all_caches_drop() {
        let p = pool(64);
        {
            let mut a = cache(&p);
            let mut b = cache(&p);
            for q in 0..16 {
                a.write_row(q, &row_vals(16, q)).unwrap();
                b.write_row(q, &row_vals(16, q)).unwrap();
            }
            a.wrote(16);
            a.commit(16);
            b.wrote(16);
            b.commit(16);
            let f = a.fork();
            let mut s = b.fork();
            s.write_row(17, &row_vals(17, 17)).unwrap();
            s.swap_out();
            drop(f);
            assert!(!p.quiesced());
        }
        assert!(p.quiesced(), "all blocks free, no stuck refcounts, dedup empty");
        let s = p.stats();
        assert_eq!(s.blocks_in_use, 0);
        assert!(s.peak_in_use >= 4);
    }
}
