//! CLI entrypoint — see `hat::cli`.
fn main() {
    std::process::exit(hat::cli::main());
}
