pub fn validate(cfg: &Cfg) -> Result<(), String> {
    if cfg.alpha.beta == 0 {
        return Err("alpha.beta must be > 0".to_string());
    }
    let _ = cfg.gamma;
    Ok(())
}
