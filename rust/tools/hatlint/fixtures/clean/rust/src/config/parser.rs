pub fn set(cfg: &mut Cfg, key: &str, v: &str) -> Result<(), String> {
    match key {
        "alpha.beta" => cfg.alpha.beta = parse(v)?,
        "gamma" => cfg.gamma = parse(v)?,
        _ => return Err(format!("unknown key {key}")),
    }
    Ok(())
}
