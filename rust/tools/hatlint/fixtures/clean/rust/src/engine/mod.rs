//! Backend-agnostic: names no concrete backend type.

pub fn plan(n: usize) -> usize {
    n.max(1)
}
