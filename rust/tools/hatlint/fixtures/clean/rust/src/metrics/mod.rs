pub fn stats_fields(finished: u64, failed: u64) -> String {
    format!("finished={finished} failed={failed}")
}
