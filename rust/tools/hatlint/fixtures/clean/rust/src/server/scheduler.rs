//! Hot path: panic-free by construction.

pub fn drain(q: &mut Vec<u32>) -> Result<u32, String> {
    match q.pop() {
        Some(v) => Ok(v),
        None => Err("empty queue".to_string()),
    }
}

pub fn invariant(len: usize) {
    // hatlint: allow(panic-path) fixture: checked invariant, reason written out
    assert!(len < 1024, "length runaway");
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_modules_may_assert() {
        super::drain(&mut vec![1]).unwrap();
    }
}
