//! Serve protocol.
//!
//! STATS reply: `STATS finished=<n> failed=<n>`.

pub fn port_flag(args: &Args) -> u16 {
    match args.get("port") {
        Some(p) => p,
        None => 4000,
    }
}
