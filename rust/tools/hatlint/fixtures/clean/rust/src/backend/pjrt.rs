//! The one file where `xla::` may appear.

pub struct CleanBackend;

pub fn make_client() {
    let _c = xla::PjRtClient::cpu();
}
