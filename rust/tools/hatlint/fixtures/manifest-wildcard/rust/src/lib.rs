pub fn ok() {}
