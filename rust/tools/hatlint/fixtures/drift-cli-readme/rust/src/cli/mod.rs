pub fn parse(args: &Args) -> usize {
    match args.get_usize("rounds") {
        Some(n) => n,
        None => 1,
    }
}
