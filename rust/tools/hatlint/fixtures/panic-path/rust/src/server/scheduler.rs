pub fn take(x: Option<u32>) -> u32 {
    x.unwrap()
}
