pub fn take(x: Option<u32>) -> u32 {
    // hatlint: allow(panic-path) fixture: demonstrates the sanctioned escape hatch
    x.unwrap()
}
