// Seeds exactly one seam-kv violation: engine code reaching through a
// cache handle into raw KV tensor storage instead of passing the
// block-table handle down to the backend.
pub fn leak_rows(cache: &mut KvCache) -> Result<Tensor> {
    cache.gather_dense()
}
