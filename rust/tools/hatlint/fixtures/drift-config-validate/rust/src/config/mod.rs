pub fn validate(_cfg: &Cfg) -> Result<(), String> {
    Ok(())
}
