pub fn set(cfg: &mut Cfg, key: &str) -> Result<(), String> {
    match key {
        "alpha.beta" => cfg.alpha.beta = 1,
        _ => return Err("unknown".to_string()),
    }
    Ok(())
}
