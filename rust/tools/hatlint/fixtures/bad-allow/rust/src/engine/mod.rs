// hatlint: allow(panic-path)
pub fn noop() {}
