//! Serve protocol doc that forgot the stats line.

pub fn noop() {}
