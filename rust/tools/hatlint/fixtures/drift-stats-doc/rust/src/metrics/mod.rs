pub fn stats_fields(finished: u64) -> String {
    format!("finished={finished}")
}
