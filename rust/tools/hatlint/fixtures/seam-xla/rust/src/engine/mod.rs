pub fn make_client() {
    let _c = xla::PjRtClient::cpu();
}
