// Seeds exactly one seam-pool violation: admission code invoking the
// backend's execution entry point directly instead of driving the
// session through the Engine layer.
pub fn admit(backend: &dyn ExecBackend, prompt: &Tensor) -> Result<Vec<Tensor>> {
    backend.run("prefill_256", &[prompt])
}
