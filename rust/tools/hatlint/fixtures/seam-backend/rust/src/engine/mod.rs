pub fn make() {
    let _b = FixtureBackend;
}
