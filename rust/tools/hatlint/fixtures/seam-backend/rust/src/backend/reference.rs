pub struct FixtureBackend;
