// Seeds seam-conn violations: a per-connection thread and blocking
// socket calls outside server/conn.rs.  The serve front end is one
// non-blocking event loop; conn.rs is the only sanctioned home of
// socket I/O in the server tree.
pub fn handle(listener: TcpListener) {
    if let Ok((stream, _)) = listener.accept() {
        std::thread::spawn(move || {
            let mut reader = BufReader::new(stream);
            let mut line = String::new();
            let _ = reader.read_line(&mut line);
        });
    }
}
