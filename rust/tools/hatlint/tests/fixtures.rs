//! Fixture-based regression suite for hat-lint.
//!
//! Every lint ID has a minimal repo-shaped tree under `fixtures/<id>/`
//! seeding exactly that violation; the `clean/` tree walks every pass and
//! must come back empty, and `allowed/` proves both suppression syntaxes
//! (`// hatlint: allow(..)` in Rust, `# hatlint: allow(..)` in Cargo.toml).
//! The fixture `.rs` files are data, not code — they are never compiled.

use std::path::{Path, PathBuf};
use std::process::Command;

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
}

fn lint(name: &str) -> Vec<hatlint::Finding> {
    hatlint::run_lints(&fixture(name))
        .unwrap_or_else(|e| panic!("scanning fixture {name}: {e}"))
}

fn rendered(findings: &[hatlint::Finding]) -> String {
    findings.iter().map(|f| f.render()).collect()
}

#[test]
fn clean_tree_passes() {
    let findings = lint("clean");
    assert!(
        findings.is_empty(),
        "clean fixture should have no findings:\n{}",
        rendered(&findings)
    );
}

#[test]
fn allowed_suppressions_are_honored() {
    let findings = lint("allowed");
    assert!(
        findings.is_empty(),
        "allow annotations with reasons should suppress everything:\n{}",
        rendered(&findings)
    );
}

#[test]
fn every_seeded_violation_is_caught() {
    // One fixture per lint ID — iterating LINT_IDS keeps this test honest
    // when a new lint is added without a fixture.
    for &id in hatlint::LINT_IDS {
        let findings = lint(id);
        assert!(!findings.is_empty(), "fixture {id}: seeded violation not caught");
        assert!(
            findings.iter().all(|f| f.id == id),
            "fixture {id}: unexpected extra findings:\n{}",
            rendered(&findings)
        );
    }
}

#[test]
fn binary_exit_codes_and_json_output() {
    let exe = env!("CARGO_BIN_EXE_hatlint");

    let clean = Command::new(exe).arg("--root").arg(fixture("clean")).output().unwrap();
    assert!(
        clean.status.success(),
        "clean fixture must exit 0: {}",
        String::from_utf8_lossy(&clean.stderr)
    );
    assert!(String::from_utf8_lossy(&clean.stdout).contains("hat-lint: clean"));

    let bad = Command::new(exe)
        .arg("--root")
        .arg(fixture("panic-path"))
        .arg("--json")
        .output()
        .unwrap();
    assert_eq!(bad.status.code(), Some(1), "violations must exit 1");
    let out = String::from_utf8_lossy(&bad.stdout);
    assert!(out.trim_start().starts_with('['), "--json must emit an array: {out}");
    assert!(out.contains("\"id\":\"panic-path\""), "--json must carry the lint id: {out}");

    let usage = Command::new(exe).arg("--bogus").output().unwrap();
    assert_eq!(usage.status.code(), Some(2), "unknown flags must exit 2");
}
