//! Tier-1 self-check: hat-lint must run clean on the repo tree itself.
//!
//! This is the machine-checked form of the architecture invariants the
//! byte-identity and distribution-identity oracles rest on: the XLA seam
//! stays in backend/pjrt.rs, the serve hot path stays panic-free, and the
//! config/stats/CLI surfaces stay in sync with their documentation.  A
//! violation anywhere in `rust/src` fails this test with the same rendering
//! the CLI prints.

use std::path::Path;

#[test]
fn repo_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../../..")
        .canonicalize()
        .expect("repo root above rust/tools/hatlint");
    assert!(root.join("rust/src").is_dir(), "unexpected repo layout at {root:?}");
    let findings = hatlint::run_lints(&root).expect("scanning the repo tree");
    assert!(
        findings.is_empty(),
        "hat-lint found {} violation(s) on the repo tree:\n{}",
        findings.len(),
        findings.iter().map(|f| f.render()).collect::<String>()
    );
}
