//! hat-lint — machine-checked architecture invariants for the HAT repo.
//!
//! The repo's correctness story is dynamic (byte-identity and seeded
//! distribution-identity oracles); this crate checks the *static* invariants
//! those oracles rest on, with a hand-rolled token-level scanner (zero
//! external deps, in keeping with workspace convention) over
//! `rust/src/**/*.rs`, the workspace manifests, README.md and the serve
//! protocol doc comment.
//!
//! Lint IDs:
//!
//! | id                      | invariant                                                  |
//! |-------------------------|------------------------------------------------------------|
//! | `seam-xla`              | `xla::` appears only in `backend/pjrt.rs`                  |
//! | `seam-backend`          | `engine/`, `specdec/`, `server/` never name a concrete backend type |
//! | `seam-kv`               | raw KV data-plane accessors (`write_row`, `gather_dense`, …) only in `backend/` and `kv/` |
//! | `seam-pool`             | no direct ExecBackend execution calls (`run`, `run_batch`, …) in `server/` — pool code drives sessions, not the backend |
//! | `seam-conn`             | no `thread::spawn` and no blocking socket calls (`accept`, `read_line`, `write_all`, …) in `server/` outside `conn.rs` — the serve front end is one non-blocking event loop |
//! | `panic-path`            | no un-annotated `unwrap()`/`expect(`/`panic!`/`unreachable!`/`assert!` in the serve hot path (`server/`, `cloud/batcher.rs`, `specdec/mod.rs`) |
//! | `lock-unwrap`           | no `.lock().unwrap()` / `.lock().expect(` anywhere in `rust/src` (poisoned-lock recovery required) |
//! | `drift-config-readme`   | every key parsed in `config/parser.rs` is documented in README.md |
//! | `drift-config-validate` | every key parsed in `config/parser.rs` is referenced by `validate()` |
//! | `drift-stats-doc`       | every `stats_fields` entry appears in the serve protocol doc comment |
//! | `drift-cli-readme`      | every CLI flag read in `cli/mod.rs` / `server/mod.rs` is documented in README.md |
//! | `manifest-wildcard`     | no wildcard dependency versions in any Cargo.toml          |
//! | `bad-allow`             | allow annotations carry the mandatory reason               |
//!
//! Suppression: `// hatlint: allow(<id>) <reason>` on the offending line or
//! the line above (`# hatlint: allow(<id>) <reason>` in Cargo.toml).  The
//! reason is mandatory — a bare `allow(<id>)` suppresses nothing and is
//! itself reported as `bad-allow`.
//!
//! `#[cfg(test)]` module bodies are exempt from `panic-path` and
//! `lock-unwrap` (tests are supposed to assert) and from `drift-cli-readme`
//! flag extraction; the seam lints apply everywhere.

use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// All lint IDs, for `allow(...)` validation and docs.
pub const LINT_IDS: &[&str] = &[
    "seam-xla",
    "seam-backend",
    "seam-kv",
    "seam-pool",
    "seam-conn",
    "panic-path",
    "lock-unwrap",
    "drift-config-readme",
    "drift-config-validate",
    "drift-stats-doc",
    "drift-cli-readme",
    "manifest-wildcard",
    "bad-allow",
];

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path (forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub id: &'static str,
    pub message: String,
    /// The offending source line, trimmed.
    pub snippet: String,
}

impl Finding {
    /// Human diff-style rendering (`file:line: error[id]: message`).
    pub fn render(&self) -> String {
        let mut s = format!("{}:{}: error[{}]: {}\n", self.file, self.line, self.id, self.message);
        if !self.snippet.is_empty() {
            s.push_str(&format!("  |  {}\n", self.snippet));
        }
        s
    }

    /// Hand-rolled JSON object (the workspace has no serde).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"file\":{},\"line\":{},\"id\":{},\"message\":{},\"snippet\":{}}}",
            json_str(&self.file),
            self.line,
            json_str(self.id),
            json_str(&self.message),
            json_str(&self.snippet)
        )
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

// ---------------------------------------------------------------------------
// Token-level scanner
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    /// String literal *content* (escapes left verbatim).
    Str(String),
    Punct(char),
}

#[derive(Debug, Clone)]
struct Token {
    tok: Tok,
    line: usize,
    /// Inside a `#[cfg(test)] mod { .. }` body.
    in_test: bool,
}

#[derive(Debug)]
struct Allow {
    line: usize,
    id: String,
    reason_ok: bool,
}

/// A scanned source file: token stream + allow annotations + raw lines.
struct Scanned {
    rel: String,
    toks: Vec<Token>,
    allows: Vec<Allow>,
    lines: Vec<String>,
}

impl Scanned {
    fn snippet(&self, line: usize) -> String {
        self.lines.get(line.saturating_sub(1)).map(|l| l.trim().to_string()).unwrap_or_default()
    }

    /// Is a finding of `id` at `line` suppressed by an allow annotation on
    /// the same line or the line above?
    fn allowed(&self, id: &str, line: usize) -> bool {
        self.allows
            .iter()
            .any(|a| a.reason_ok && a.id == id && (a.line == line || a.line + 1 == line))
    }
}

/// Parse `hatlint: allow(<id>) <reason>` out of a comment body.
fn parse_allow(comment: &str, line: usize) -> Option<Allow> {
    let at = comment.find("hatlint:")?;
    let rest = comment[at + "hatlint:".len()..].trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.find(')')?;
    let id = rest[..close].trim().to_string();
    let reason = rest[close + 1..].trim();
    Some(Allow { line, id, reason_ok: !reason.is_empty() })
}

/// Tokenize Rust-ish source: comments stripped (but mined for allow
/// annotations), string/char literals and lifetimes handled, `#[cfg(test)]
/// mod` bodies flagged.
fn scan_rust(rel: &str, src: &str) -> Scanned {
    let chars: Vec<char> = src.chars().collect();
    let mut toks: Vec<Token> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let n = chars.len();

    while i < n {
        let c = chars[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < n && chars[i + 1] == '/' => {
                let start = i + 2;
                let mut j = start;
                while j < n && chars[j] != '\n' {
                    j += 1;
                }
                let body: String = chars[start..j].iter().collect();
                if let Some(a) = parse_allow(&body, line) {
                    allows.push(a);
                }
                i = j;
            }
            '/' if i + 1 < n && chars[i + 1] == '*' => {
                // Nested block comments, tracking newlines.
                let start_line = line;
                let mut depth = 1usize;
                let mut j = i + 2;
                let mut body = String::new();
                while j < n && depth > 0 {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                        depth += 1;
                        j += 2;
                    } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        body.push(chars[j]);
                        j += 1;
                    }
                }
                if let Some(a) = parse_allow(&body, start_line) {
                    allows.push(a);
                }
                i = j;
            }
            '"' => {
                let (s, j, nl) = read_string(&chars, i);
                toks.push(Token { tok: Tok::Str(s), line, in_test: false });
                line += nl;
                i = j;
            }
            'r' | 'b' if starts_raw_or_byte_string(&chars, i) => {
                let (s, j, nl) = read_raw_or_byte_string(&chars, i);
                toks.push(Token { tok: Tok::Str(s), line, in_test: false });
                line += nl;
                i = j;
            }
            '\'' => {
                // Lifetime (`'a`) vs char literal (`'a'`, `'\n'`).
                let mut j = i + 1;
                if j < n && (chars[j].is_alphabetic() || chars[j] == '_') && chars[j] != '\\' {
                    let mut k = j;
                    while k < n && (chars[k].is_alphanumeric() || chars[k] == '_') {
                        k += 1;
                    }
                    if k < n && chars[k] == '\'' {
                        // Single-char literal like 'a'.
                        i = k + 1;
                    } else {
                        // Lifetime: skip the ident.
                        i = k;
                    }
                } else {
                    // Escaped or punctuation char literal.
                    if j < n && chars[j] == '\\' {
                        j += 2;
                    } else {
                        j += 1;
                    }
                    while j < n && chars[j] != '\'' {
                        j += 1;
                    }
                    i = (j + 1).min(n);
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let mut j = i;
                while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                    j += 1;
                }
                let id: String = chars[i..j].iter().collect();
                toks.push(Token { tok: Tok::Ident(id), line, in_test: false });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let mut j = i;
                while j < n && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
                    j += 1;
                }
                // Fractional part — but never eat `..` (range syntax).
                if j + 1 < n && chars[j] == '.' && chars[j + 1].is_ascii_digit() {
                    j += 1;
                    while j < n && (chars[j].is_ascii_alphanumeric() || chars[j] == '_') {
                        j += 1;
                    }
                }
                i = j;
            }
            c => {
                toks.push(Token { tok: Tok::Punct(c), line, in_test: false });
                i += 1;
            }
        }
    }

    mark_test_regions(&mut toks);
    Scanned {
        rel: rel.to_string(),
        toks,
        allows,
        lines: src.lines().map(|l| l.to_string()).collect(),
    }
}

fn starts_raw_or_byte_string(chars: &[char], i: usize) -> bool {
    // r"  r#"  br"  b"  br#"
    let n = chars.len();
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if j < n && chars[j] == 'r' {
        j += 1;
        while j < n && chars[j] == '#' {
            j += 1;
        }
        return j < n && chars[j] == '"';
    }
    // b"..." (byte string, no r)
    chars[i] == 'b' && j < n && chars[j] == '"'
}

fn read_string(chars: &[char], start: usize) -> (String, usize, usize) {
    // Plain "..." with escapes; returns (content, next index, newlines seen).
    let n = chars.len();
    let mut j = start + 1;
    let mut out = String::new();
    let mut nl = 0usize;
    while j < n {
        match chars[j] {
            '\\' if j + 1 < n => {
                out.push(chars[j]);
                out.push(chars[j + 1]);
                if chars[j + 1] == '\n' {
                    nl += 1;
                }
                j += 2;
            }
            '"' => return (out, j + 1, nl),
            c => {
                if c == '\n' {
                    nl += 1;
                }
                out.push(c);
                j += 1;
            }
        }
    }
    (out, n, nl)
}

fn read_raw_or_byte_string(chars: &[char], start: usize) -> (String, usize, usize) {
    let n = chars.len();
    let mut j = start;
    if chars[j] == 'b' {
        j += 1;
    }
    if j < n && chars[j] != 'r' {
        // b"..." — plain byte string.
        let (s, k, nl) = read_string(chars, j);
        return (s, k, nl);
    }
    j += 1; // past 'r'
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // past opening quote
    let mut out = String::new();
    let mut nl = 0usize;
    while j < n {
        if chars[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && chars[k] == '#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return (out, k, nl);
            }
        }
        if chars[j] == '\n' {
            nl += 1;
        }
        out.push(chars[j]);
        j += 1;
    }
    (out, n, nl)
}

/// Mark tokens inside `#[cfg(test)] mod name { ... }` bodies.  (The repo
/// convention is test *modules*; `#[cfg(test)]` on single items outside a
/// module is not tracked.)
fn mark_test_regions(toks: &mut [Token]) {
    let is = |t: &Token, want: &Tok| -> bool { &t.tok == want };
    let mut i = 0usize;
    while i + 6 < toks.len() {
        let hit = is(&toks[i], &Tok::Punct('#'))
            && is(&toks[i + 1], &Tok::Punct('['))
            && toks[i + 2].tok == Tok::Ident("cfg".into())
            && is(&toks[i + 3], &Tok::Punct('('))
            && toks[i + 4].tok == Tok::Ident("test".into())
            && is(&toks[i + 5], &Tok::Punct(')'))
            && is(&toks[i + 6], &Tok::Punct(']'));
        if !hit {
            i += 1;
            continue;
        }
        // Find the opening brace of whatever item follows the attribute.
        let mut j = i + 7;
        while j < toks.len() && toks[j].tok != Tok::Punct('{') {
            // `#[cfg(test)] use ...;` — no body, nothing to mark.
            if toks[j].tok == Tok::Punct(';') {
                break;
            }
            j += 1;
        }
        if j >= toks.len() || toks[j].tok != Tok::Punct('{') {
            i = j;
            continue;
        }
        let mut depth = 0isize;
        let start = j;
        while j < toks.len() {
            match toks[j].tok {
                Tok::Punct('{') => depth += 1,
                Tok::Punct('}') => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        for t in toks.iter_mut().take(j.min(toks.len() - 1) + 1).skip(start) {
            t.in_test = true;
        }
        i = j + 1;
    }
}

// ---------------------------------------------------------------------------
// Repo model + lint driver
// ---------------------------------------------------------------------------

/// Locate the repo root: `start` or the nearest ancestor containing
/// `rust/src`.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(d) = cur {
        if d.join("rust/src").is_dir() {
            return Some(d);
        }
        cur = d.parent().map(|p| p.to_path_buf());
    }
    None
}

fn rust_src_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let src = root.join("rust/src");
    if src.is_dir() {
        collect_rs(&src, &mut out)?;
    }
    out.sort();
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_of(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

/// Run every lint pass over the repo at `root`.  Returns all un-suppressed
/// findings, sorted by (file, line).  Files a pass depends on (e.g.
/// `config/parser.rs` for the drift lints) are skipped gracefully when
/// absent, so the fixtures can be minimal trees.
pub fn run_lints(root: &Path) -> io::Result<Vec<Finding>> {
    let mut findings: Vec<Finding> = Vec::new();

    let files = rust_src_files(root)?;
    let mut scanned: Vec<Scanned> = Vec::new();
    for p in &files {
        let src = fs::read_to_string(p)?;
        scanned.push(scan_rust(&rel_of(root, p), &src));
    }

    let readme = fs::read_to_string(root.join("README.md")).unwrap_or_default();

    check_bad_allows(&scanned, &mut findings);
    check_seam_xla(&scanned, &mut findings);
    check_seam_backend(&scanned, &mut findings);
    check_seam_kv(&scanned, &mut findings);
    check_seam_pool(&scanned, &mut findings);
    check_seam_conn(&scanned, &mut findings);
    check_panic_path(&scanned, &mut findings);
    check_lock_unwrap(&scanned, &mut findings);
    check_config_drift(&scanned, &readme, &mut findings);
    check_stats_doc_drift(&scanned, &mut findings);
    check_cli_readme_drift(&scanned, &readme, &mut findings);
    check_manifests(root, &mut findings)?;

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(findings)
}

fn push(
    findings: &mut Vec<Finding>,
    f: &Scanned,
    line: usize,
    id: &'static str,
    message: String,
) {
    if f.allowed(id, line) {
        return;
    }
    findings.push(Finding { file: f.rel.clone(), line, id, message, snippet: f.snippet(line) });
}

// -- bad-allow ---------------------------------------------------------------

fn check_bad_allows(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        for a in &f.allows {
            if !a.reason_ok {
                findings.push(Finding {
                    file: f.rel.clone(),
                    line: a.line,
                    id: "bad-allow",
                    message: format!(
                        "allow({}) without a reason — the reason is mandatory and the \
                         annotation suppresses nothing",
                        a.id
                    ),
                    snippet: f.snippet(a.line),
                });
            } else if !LINT_IDS.contains(&a.id.as_str()) {
                findings.push(Finding {
                    file: f.rel.clone(),
                    line: a.line,
                    id: "bad-allow",
                    message: format!("allow({}) names an unknown lint id", a.id),
                    snippet: f.snippet(a.line),
                });
            }
        }
    }
}

// -- seam lints --------------------------------------------------------------

fn check_seam_xla(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        if f.rel == "rust/src/backend/pjrt.rs" {
            continue;
        }
        for w in f.toks.windows(3) {
            if w[0].tok == Tok::Ident("xla".into())
                && w[1].tok == Tok::Punct(':')
                && w[2].tok == Tok::Punct(':')
            {
                push(
                    findings,
                    f,
                    w[0].line,
                    "seam-xla",
                    "`xla::` outside backend/pjrt.rs — the XLA binding seam is \
                     backend/pjrt.rs only"
                        .to_string(),
                );
            }
        }
    }
}

/// Concrete backend type names: `struct *Backend` declared under
/// `rust/src/backend/`.
fn concrete_backend_names(scanned: &[Scanned]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for f in scanned {
        if !f.rel.starts_with("rust/src/backend/") {
            continue;
        }
        for w in f.toks.windows(2) {
            if w[0].tok == Tok::Ident("struct".into()) {
                if let Tok::Ident(name) = &w[1].tok {
                    if name.ends_with("Backend") {
                        names.insert(name.clone());
                    }
                }
            }
        }
    }
    names
}

fn check_seam_backend(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    let names = concrete_backend_names(scanned);
    if names.is_empty() {
        return;
    }
    let sealed = ["rust/src/engine/", "rust/src/specdec/", "rust/src/server/"];
    for f in scanned {
        if !sealed.iter().any(|p| f.rel.starts_with(p)) {
            continue;
        }
        for t in &f.toks {
            if let Tok::Ident(id) = &t.tok {
                if names.contains(id) {
                    push(
                        findings,
                        f,
                        t.line,
                        "seam-backend",
                        format!(
                            "concrete backend type `{id}` named above the ExecBackend seam \
                             (engine/specdec/server must stay backend-agnostic)"
                        ),
                    );
                }
            }
        }
    }
}

/// Raw KV data-plane accessors: methods that read or write a cache's
/// tensor storage row-by-row.  Everything above the backend seam must
/// hold block-table *handles* only — the paged-KV analogue of
/// `seam-backend`.
const KV_DATA_PLANE: &[&str] =
    &["write_row", "write_row_accumulate", "prefix_sum", "gather_dense", "scatter_rows"];

fn check_seam_kv(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        if f.rel.starts_with("rust/src/backend/") || f.rel.starts_with("rust/src/kv/") {
            continue;
        }
        for w in f.toks.windows(3) {
            if w[1].in_test {
                continue;
            }
            let (Tok::Punct('.'), Tok::Ident(name), Tok::Punct('(')) =
                (&w[0].tok, &w[1].tok, &w[2].tok)
            else {
                continue;
            };
            if KV_DATA_PLANE.contains(&name.as_str()) {
                push(
                    findings,
                    f,
                    w[1].line,
                    "seam-kv",
                    format!(
                        "raw KV data-plane accessor `.{name}(` above the backend seam — \
                         only backend/ and kv/ may touch KV tensor storage; everything \
                         else threads block-table handles"
                    ),
                );
            }
        }
    }
}

/// ExecBackend execution entry points.  Scheduler/pool code admits,
/// batches and hands sessions off; actually *running* an artifact is the
/// Session/Engine layer's job.  A pool that calls the backend directly
/// bypasses the g^t monitors, KV accounting and speculative-decode state
/// that make pool handoff lossless.
const EXEC_ENTRY_POINTS: &[&str] = &["run", "run_batch", "run_paged", "run_batch_paged"];

fn check_seam_pool(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        if !f.rel.starts_with("rust/src/server/") {
            continue;
        }
        for w in f.toks.windows(3) {
            if w[1].in_test {
                continue;
            }
            let (Tok::Punct('.'), Tok::Ident(name), Tok::Punct('(')) =
                (&w[0].tok, &w[1].tok, &w[2].tok)
            else {
                continue;
            };
            if EXEC_ENTRY_POINTS.contains(&name.as_str()) {
                push(
                    findings,
                    f,
                    w[1].line,
                    "seam-pool",
                    format!(
                        "direct ExecBackend execution call `.{name}(` in server/ — \
                         pool and scheduler code must drive Session/Engine, never \
                         the backend itself"
                    ),
                );
            }
        }
    }
}

/// Blocking socket entry points and thread hand-offs.  The serve front
/// end is a single non-blocking event loop owning listener, connections
/// and engine; `server/conn.rs` is its one sanctioned home.  A
/// `thread::spawn` or a blocking socket call anywhere else in `server/`
/// reintroduces the thread-per-connection model the event loop replaced
/// (and with it the reply channels and timeout-bounded disconnect
/// probes the refactor deleted).
const BLOCKING_SOCKET_CALLS: &[&str] = &[
    "accept",
    "read_line",
    "read_to_end",
    "read_to_string",
    "read_exact",
    "write_all",
    "set_read_timeout",
    "set_write_timeout",
    "spawn",
];

fn check_seam_conn(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        if !f.rel.starts_with("rust/src/server/") || f.rel.ends_with("/conn.rs") {
            continue;
        }
        for w in f.toks.windows(4) {
            if w[0].in_test {
                continue;
            }
            if let (Tok::Ident(a), Tok::Punct(':'), Tok::Punct(':'), Tok::Ident(b)) =
                (&w[0].tok, &w[1].tok, &w[2].tok, &w[3].tok)
            {
                if a == "thread" && b == "spawn" {
                    push(
                        findings,
                        f,
                        w[3].line,
                        "seam-conn",
                        "`thread::spawn` in server/ outside conn.rs — the serve front \
                         end is one event loop on the engine-owning thread; connection \
                         concurrency belongs in server/conn.rs"
                            .to_string(),
                    );
                }
            }
        }
        for w in f.toks.windows(3) {
            if w[1].in_test {
                continue;
            }
            let (Tok::Punct('.'), Tok::Ident(name), Tok::Punct('(')) =
                (&w[0].tok, &w[1].tok, &w[2].tok)
            else {
                continue;
            };
            if BLOCKING_SOCKET_CALLS.contains(&name.as_str()) {
                push(
                    findings,
                    f,
                    w[1].line,
                    "seam-conn",
                    format!(
                        "blocking socket call `.{name}(` in server/ outside conn.rs — \
                         socket I/O lives in the conn.rs event loop (non-blocking), \
                         nowhere else in the server tree"
                    ),
                );
            }
        }
    }
}

// -- panic-freedom -----------------------------------------------------------

fn in_panic_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/server/")
        || rel == "rust/src/cloud/batcher.rs"
        || rel == "rust/src/specdec/mod.rs"
}

const PANIC_MACROS: &[&str] =
    &["panic", "unreachable", "todo", "unimplemented", "assert", "assert_eq", "assert_ne"];

fn check_panic_path(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        if !in_panic_scope(&f.rel) {
            continue;
        }
        let toks = &f.toks;
        for i in 0..toks.len() {
            if toks[i].in_test {
                continue;
            }
            if let Tok::Ident(id) = &toks[i].tok {
                // Macros: `panic!`, `assert!`, ...
                if PANIC_MACROS.contains(&id.as_str())
                    && toks.get(i + 1).map(|t| &t.tok) == Some(&Tok::Punct('!'))
                {
                    push(
                        findings,
                        f,
                        toks[i].line,
                        "panic-path",
                        format!(
                            "`{id}!` in the serve hot path — degrade, don't crash \
                             (return an Err and let the lane fail with an ERR reply)"
                        ),
                    );
                }
                // Methods: `.unwrap()`, `.expect(` — skip `.lock().unwrap()`,
                // which the dedicated lock-unwrap lint owns.
                if (id == "unwrap" || id == "expect")
                    && i >= 1
                    && toks[i - 1].tok == Tok::Punct('.')
                    && toks.get(i + 1).map(|t| &t.tok) == Some(&Tok::Punct('('))
                    && !is_lock_chain(toks, i)
                {
                    push(
                        findings,
                        f,
                        toks[i].line,
                        "panic-path",
                        format!(
                            "`.{id}(` in the serve hot path — propagate the error \
                             (Result) instead of panicking the worker"
                        ),
                    );
                }
            }
        }
    }
}

/// Is token `i` (an `unwrap`/`expect` ident) preceded by `.lock()`?
fn is_lock_chain(toks: &[Token], i: usize) -> bool {
    i >= 4
        && toks[i - 1].tok == Tok::Punct('.')
        && toks[i - 2].tok == Tok::Punct(')')
        && toks[i - 3].tok == Tok::Punct('(')
        && toks[i - 4].tok == Tok::Ident("lock".into())
}

fn check_lock_unwrap(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    for f in scanned {
        let toks = &f.toks;
        for i in 0..toks.len() {
            if toks[i].in_test {
                continue;
            }
            if let Tok::Ident(id) = &toks[i].tok {
                if (id == "unwrap" || id == "expect") && is_lock_chain(toks, i) {
                    push(
                        findings,
                        f,
                        toks[i].line,
                        "lock-unwrap",
                        "`.lock().unwrap()` — a panicking lane poisons the lock and \
                         cascades to every co-batched session; recover the guard or \
                         tear the session down with an ERR"
                            .to_string(),
                    );
                }
            }
        }
    }
}

// -- drift lints -------------------------------------------------------------

fn find_scanned<'a>(scanned: &'a [Scanned], rel: &str) -> Option<&'a Scanned> {
    scanned.iter().find(|f| f.rel == rel)
}

/// Keys parsed by `config/parser.rs`: string-literal match-arm patterns
/// (`"a.b" =>` / `"a" | "b" =>`), with the literal's line for reporting.
fn parser_keys(parser: &Scanned) -> Vec<(String, usize)> {
    let mut keys = Vec::new();
    let toks = &parser.toks;
    for i in 0..toks.len() {
        let Tok::Str(s) = &toks[i].tok else { continue };
        if s.is_empty() || !s.chars().all(|c| c.is_ascii_lowercase() || c == '_' || c == '.') {
            continue;
        }
        let next = toks.get(i + 1).map(|t| &t.tok);
        let next2 = toks.get(i + 2).map(|t| &t.tok);
        let arm = matches!(next, Some(Tok::Punct('|')))
            || (matches!(next, Some(Tok::Punct('='))) && matches!(next2, Some(Tok::Punct('>'))));
        if arm {
            keys.push((s.clone(), toks[i].line));
        }
    }
    keys
}

/// The token range of `fn <name>`'s body in `f`, as (start, end) indices.
fn fn_body_range(f: &Scanned, name: &str) -> Option<(usize, usize)> {
    let toks = &f.toks;
    let mut i = 0usize;
    while i + 1 < toks.len() {
        if toks[i].tok == Tok::Ident("fn".into()) && toks[i + 1].tok == Tok::Ident(name.into()) {
            let mut j = i + 2;
            while j < toks.len() && toks[j].tok != Tok::Punct('{') {
                j += 1;
            }
            let start = j;
            let mut depth = 0isize;
            while j < toks.len() {
                match toks[j].tok {
                    Tok::Punct('{') => depth += 1,
                    Tok::Punct('}') => {
                        depth -= 1;
                        if depth == 0 {
                            return Some((start, j));
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
            return Some((start, toks.len()));
        }
        i += 1;
    }
    None
}

/// Does the token range reference dotted key `a.b` (ident path `a . b`) or a
/// string literal containing it?  Single-segment keys match a bare ident.
fn range_mentions_key(toks: &[Token], key: &str) -> bool {
    let parts: Vec<&str> = key.split('.').collect();
    for i in 0..toks.len() {
        if let Tok::Str(s) = &toks[i].tok {
            if s.contains(key) {
                return true;
            }
        }
        if let Tok::Ident(id) = &toks[i].tok {
            if id == parts[0] {
                let mut ok = true;
                let mut j = i;
                for part in &parts[1..] {
                    if toks.get(j + 1).map(|t| &t.tok) == Some(&Tok::Punct('.'))
                        && toks.get(j + 2).map(|t| &t.tok) == Some(&Tok::Ident((*part).into()))
                    {
                        j += 2;
                    } else {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    return true;
                }
            }
        }
    }
    false
}

fn check_config_drift(scanned: &[Scanned], readme: &str, findings: &mut Vec<Finding>) {
    let Some(parser) = find_scanned(scanned, "rust/src/config/parser.rs") else { return };
    let keys = parser_keys(parser);

    for (key, line) in &keys {
        if !readme.contains(key) {
            push(
                findings,
                parser,
                *line,
                "drift-config-readme",
                format!("config key `{key}` is parsed but not documented in README.md"),
            );
        }
    }

    let Some(cfg) = find_scanned(scanned, "rust/src/config/mod.rs") else { return };
    let Some((start, end)) = fn_body_range(cfg, "validate") else { return };
    let body = &cfg.toks[start..=end.min(cfg.toks.len() - 1)];
    for (key, line) in &keys {
        if !range_mentions_key(body, key) {
            push(
                findings,
                parser,
                *line,
                "drift-config-validate",
                format!(
                    "config key `{key}` is parsed but never referenced by validate() — \
                     constrain it or annotate why no constraint applies"
                ),
            );
        }
    }
}

/// Field names (`name=`) from the format string(s) inside
/// `metrics::stats_fields`.
fn stats_field_names(metrics: &Scanned) -> Vec<(String, usize)> {
    let Some((start, end)) = fn_body_range(metrics, "stats_fields") else { return Vec::new() };
    let mut out = Vec::new();
    for t in &metrics.toks[start..=end.min(metrics.toks.len() - 1)] {
        if let Tok::Str(s) = &t.tok {
            let chars: Vec<char> = s.chars().collect();
            let mut i = 0usize;
            while i < chars.len() {
                if chars[i] == '=' && i + 1 < chars.len() && chars[i + 1] == '{' {
                    let mut j = i;
                    while j > 0 && (chars[j - 1].is_ascii_lowercase() || chars[j - 1] == '_') {
                        j -= 1;
                    }
                    if j < i {
                        out.push((chars[j..i].iter().collect(), t.line));
                    }
                }
                i += 1;
            }
        }
    }
    out
}

/// The leading `//!` doc-comment block of a file, concatenated.
fn module_doc(f: &Scanned) -> String {
    let mut doc = String::new();
    for l in &f.lines {
        let t = l.trim_start();
        if let Some(rest) = t.strip_prefix("//!") {
            doc.push_str(rest);
            doc.push('\n');
        } else if !t.is_empty() {
            break;
        }
    }
    doc
}

fn check_stats_doc_drift(scanned: &[Scanned], findings: &mut Vec<Finding>) {
    let Some(metrics) = find_scanned(scanned, "rust/src/metrics/mod.rs") else { return };
    let Some(server) = find_scanned(scanned, "rust/src/server/mod.rs") else { return };
    let doc = module_doc(server);
    for (field, line) in stats_field_names(metrics) {
        if !doc.contains(&format!("{field}=")) {
            push(
                findings,
                metrics,
                line,
                "drift-stats-doc",
                format!(
                    "STATS field `{field}=` is emitted by stats_fields() but missing \
                     from the protocol doc comment (rust/src/server/mod.rs)"
                ),
            );
        }
    }
}

fn check_cli_readme_drift(scanned: &[Scanned], readme: &str, findings: &mut Vec<Finding>) {
    let getters = ["get", "get_f64", "get_usize"];
    for rel in ["rust/src/cli/mod.rs", "rust/src/server/mod.rs"] {
        let Some(f) = find_scanned(scanned, rel) else { continue };
        let toks = &f.toks;
        for i in 0..toks.len() {
            if toks[i].in_test {
                continue;
            }
            let Tok::Ident(id) = &toks[i].tok else { continue };
            if !getters.contains(&id.as_str())
                || i == 0
                || toks[i - 1].tok != Tok::Punct('.')
                || toks.get(i + 1).map(|t| &t.tok) != Some(&Tok::Punct('('))
            {
                continue;
            }
            let Some(Tok::Str(flag)) = toks.get(i + 2).map(|t| &t.tok) else { continue };
            if flag.is_empty()
                || !flag.chars().all(|c| c.is_ascii_lowercase() || c == '-' || c == '_')
            {
                continue;
            }
            if !readme.contains(&format!("--{flag}")) {
                push(
                    findings,
                    f,
                    toks[i].line,
                    "drift-cli-readme",
                    format!("CLI flag `--{flag}` is read here but not documented in README.md"),
                );
            }
        }
    }
}

// -- manifest lints ----------------------------------------------------------

fn manifest_paths(root: &Path) -> Vec<PathBuf> {
    // Every Cargo.toml in the tree except target/ build output, hidden
    // dirs, and hat-lint's own seeded-violation fixtures.
    let mut out = Vec::new();
    collect_manifests(root, &mut out);
    out.sort();
    out
}

fn collect_manifests(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        let name = entry.file_name().to_string_lossy().to_string();
        if p.is_dir() {
            if name == "target" || name == "fixtures" || name.starts_with('.') {
                continue;
            }
            collect_manifests(&p, out);
        } else if name == "Cargo.toml" {
            out.push(p);
        }
    }
}

fn check_manifests(root: &Path, findings: &mut Vec<Finding>) -> io::Result<()> {
    for p in manifest_paths(root) {
        let rel = rel_of(root, &p);
        let text = fs::read_to_string(&p)?;
        let lines: Vec<&str> = text.lines().collect();
        let mut in_deps = false;
        let mut allows: Vec<Allow> = Vec::new();
        for (idx, l) in lines.iter().enumerate() {
            if let Some(at) = l.find('#') {
                if let Some(a) = parse_allow(&l[at + 1..], idx + 1) {
                    allows.push(a);
                }
            }
        }
        for (idx, l) in lines.iter().enumerate() {
            let line = idx + 1;
            let t = l.trim();
            if t.starts_with('[') {
                in_deps = t.contains("dependencies");
                continue;
            }
            if !in_deps || t.starts_with('#') {
                continue;
            }
            let code = t.split('#').next().unwrap_or("");
            if code.contains("\"*\"") || code.contains("= \"*") {
                let allowed = allows.iter().any(|a| {
                    a.reason_ok
                        && a.id == "manifest-wildcard"
                        && (a.line == line || a.line + 1 == line)
                });
                if !allowed {
                    findings.push(Finding {
                        file: rel.clone(),
                        line,
                        id: "manifest-wildcard",
                        message: "wildcard dependency version — pin the version the code \
                                  was written against"
                            .to_string(),
                        snippet: t.to_string(),
                    });
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Scanned {
        scan_rust("rust/src/server/x.rs", src)
    }

    #[test]
    fn scanner_strips_comments_and_strings() {
        let s = toks("// xla:: in a comment\nlet x = \"xla::\"; /* xla:: */ let y = 1;");
        let idents: Vec<&str> = s
            .toks
            .iter()
            .filter_map(|t| match &t.tok {
                Tok::Ident(i) => Some(i.as_str()),
                _ => None,
            })
            .collect();
        assert_eq!(idents, ["let", "x", "let", "y"]);
    }

    #[test]
    fn scanner_handles_lifetimes_and_chars() {
        let s = toks("fn f<'e>(x: &'e str) { let c = 'a'; let nl = '\\n'; }");
        assert!(s.toks.iter().any(|t| t.tok == Tok::Ident("str".into())));
        // The char literals must not swallow the rest of the file.
        assert!(s.toks.iter().any(|t| t.tok == Tok::Ident("nl".into())));
    }

    #[test]
    fn scanner_handles_raw_strings() {
        let s = toks("let x = r#\"has \"quotes\" and xla:: inside\"#; let y = 2;");
        assert!(s.toks.iter().any(|t| t.tok == Tok::Ident("y".into())));
        assert!(s.toks.iter().any(|t| matches!(&t.tok, Tok::Str(v) if v.contains("xla::"))));
    }

    #[test]
    fn allow_annotation_requires_reason() {
        let s = toks("// hatlint: allow(panic-path) tested invariant\nx.unwrap();");
        assert!(s.allowed("panic-path", 2));
        let s = toks("// hatlint: allow(panic-path)\nx.unwrap();");
        assert!(!s.allowed("panic-path", 2));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn prod() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n";
        let s = toks(src);
        let unwraps: Vec<bool> = s
            .toks
            .iter()
            .filter(|t| t.tok == Tok::Ident("unwrap".into()))
            .map(|t| t.in_test)
            .collect();
        assert_eq!(unwraps, [false, true]);
    }

    #[test]
    fn number_scan_does_not_eat_ranges() {
        let s = toks("for i in 0..k { a[i * 4..(i + 1) * 4].x(); }");
        assert!(s.toks.iter().any(|t| t.tok == Tok::Ident("k".into())));
        assert!(s.toks.iter().any(|t| t.tok == Tok::Ident("x".into())));
    }
}
