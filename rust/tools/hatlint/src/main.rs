//! hat-lint CLI.
//!
//! ```text
//! cargo run -p hatlint              # lint the enclosing repo, human output
//! cargo run -p hatlint -- --json    # machine-readable findings
//! cargo run -p hatlint -- --root D  # lint an explicit tree (fixtures)
//! ```
//!
//! Exit codes: 0 clean, 1 violations found, 2 usage / IO error.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("error: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                eprintln!("usage: hatlint [--root DIR] [--json]");
                eprintln!("lints: {}", hatlint::LINT_IDS.join(", "));
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("error: unknown argument '{other}'");
                return ExitCode::from(2);
            }
        }
    }

    let root = match root {
        Some(r) => r,
        None => {
            let cwd = match std::env::current_dir() {
                Ok(d) => d,
                Err(e) => {
                    eprintln!("error: cwd: {e}");
                    return ExitCode::from(2);
                }
            };
            match hatlint::find_root(&cwd) {
                Some(r) => r,
                None => {
                    eprintln!("error: no repo root (dir containing rust/src) above {cwd:?}");
                    return ExitCode::from(2);
                }
            }
        }
    };

    let findings = match hatlint::run_lints(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: scanning {root:?}: {e}");
            return ExitCode::from(2);
        }
    };

    if json {
        let objs: Vec<String> = findings.iter().map(|f| f.to_json()).collect();
        println!("[{}]", objs.join(","));
    } else {
        for f in &findings {
            print!("{}", f.render());
        }
        if findings.is_empty() {
            println!("hat-lint: clean");
        } else {
            println!("hat-lint: {} violation(s)", findings.len());
        }
    }

    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
