//! §Perf — hot-path microbenchmarks for the optimization pass
//! (EXPERIMENTS.md §Perf).  Hand-rolled harness (criterion is not in the
//! offline crate set): median-of-N wall-clock with warmup.
//!
//! L3: event-queue throughput, fleet-sim end-to-end event rate, chunker
//!     solve, batcher formation.
//! Runtime: backend execute latency per artifact bucket, tensor staging.

// Benches measure real wall time: the util::clock choke point is for the
// runtime, not for measurement harnesses.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use hat::cloud::{optimal_chunk, Batcher, Job, JobKind};
use hat::config::{Dataset, ExperimentConfig, Framework, GModel, ServeConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::frameworks::run_experiment;
use hat::server::conn::ReplySink;
use hat::server::generate;
use hat::server::scheduler::{Request, Scheduler};
use hat::sim::{EventQueue, SimTime};
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn bench<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) -> (f64, u64) {
    // warmup
    let mut sink = 0u64;
    sink ^= f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink ^= f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    println!("{name:<44} {med:>10.3} ms (median of {iters})");
    (med, sink)
}

fn main() {
    section("Perf: L3 hot paths");
    let mut results = Vec::new();

    // Event queue: schedule+pop 100k events.
    let (eq_ms, _) = bench("event_queue: 100k schedule+pop", 9, || {
        let mut q = EventQueue::new();
        let mut acc = 0u64;
        for i in 0..100_000u64 {
            q.schedule_at(SimTime(i * 7 % 1_000_003), i);
        }
        while let Some((_, e)) = q.pop() {
            acc = acc.wrapping_add(e);
        }
        acc
    });
    results.push(("event_queue_100k_ms", eq_ms));

    // Chunk-size optimizer (Eq. 3 bisection).
    let g = GModel::vicuna7b();
    let (ch_ms, _) = bench("chunker: 10k optimal_chunk solves", 9, || {
        let mut acc = 0u64;
        for i in 0..10_000 {
            acc += optimal_chunk(
                8192.0,
                5_000.0 + (i % 100) as f64 * 50.0,
                |b| g.eval(b),
                (i % 2048) as f64,
                1 + (i % 8),
                (16, 512),
            ) as u64;
        }
        acc
    });
    results.push(("chunker_10k_ms", ch_ms));

    // Batcher: 10k jobs through form_batch.
    let (bt_ms, _) = bench("batcher: 10k jobs push+form", 9, || {
        let mut b = Batcher::new();
        let mut acc = 0u64;
        for i in 0..10_000usize {
            let kind = if i % 3 == 0 { JobKind::PrefillChunk } else { JobKind::Decode };
            b.push(Job { req: i, kind, tokens: 1 + i % 300, epoch: 0 });
            if i % 8 == 0 {
                acc += b.form_batch(2048).len() as u64;
            }
        }
        while !b.is_empty() {
            acc += b.form_batch(2048).len() as u64;
        }
        acc
    });
    results.push(("batcher_10k_ms", bt_ms));

    // Fleet sim end-to-end: events/second of virtual workload.
    let profile = SdProfile::default_table();
    let mut cfg = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
    cfg.workload.n_requests = 200;
    let t0 = Instant::now();
    let rec = run_experiment(&cfg, &profile);
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = rec.requests.iter().map(|r| r.tokens_generated()).sum();
    println!(
        "fleet_sim: 200 reqs, {tokens} tokens in {:.2}s wall ({:.0} virtual-tokens/s)",
        wall,
        tokens as f64 / wall
    );
    results.push(("fleet_sim_200req_s", wall * 1e3));

    // Runtime: backend execute latency per bucket (synthetic reference
    // model when artifacts are not built, so this always runs).
    let dir = hat::runtime::ArtifactRegistry::default_dir();
    let reg = hat::runtime::ArtifactRegistry::load_or_synthetic(&dir).unwrap();
    section(&format!("Perf: runtime ({} backend) per-call latency", reg.backend_name()));
    let spec = reg.model().clone();
    for t in [1usize, 4, 16, 64, 256] {
        let name = format!("cloud_middle_{t}");
        if reg.manifest().artifact(&name).is_none() {
            continue;
        }
        let hidden = vec![0.1f32; t * spec.hidden];
        let mkv = hat::runtime::zeros_tensor(&spec.middle_kv_dims());
        let (ms, _) = bench(&format!("{name} execute"), 15, || {
            let h = hat::runtime::f32_tensor_padded(&hidden, spec.hidden, t).unwrap();
            let pos = hat::runtime::pos_tensor(0);
            let outs = reg.run(&name, &[&h, &mkv, &pos]).unwrap();
            outs.len() as u64
        });
        results.push((Box::leak(format!("cloud_middle_{t}_ms").into_boxed_str()) as &str, ms));
    }
    let s = reg.stats();
    println!(
        "runtime totals: {} compiles ({:.0} ms), {} executes ({:.1} ms avg)",
        s.compiles,
        s.compile_ms,
        s.executions,
        s.execute_ms / s.executions.max(1) as f64
    );

    let out = obj(results.iter().map(|(k, v)| (*k, Value::Num(*v))).collect());
    let p = write_json("perf_hotpath", &out);
    println!("\nwrote {}", p.display());

    // Serve path: batched scheduler vs sequential per-request generate()
    // over the same request set.  Greedy losslessness makes the outputs
    // identical, and on the reference backend the per-token arithmetic is
    // identical too — the batched path's structural win is issuing one
    // engine call per job group (mean_batch_occupancy > 1), which becomes
    // a throughput win on backends whose per-call overhead or kernel
    // launch dominates; wall_ratio on the reference backend mostly
    // reflects scheduler/validation amortization, not fused compute.
    section("Perf: serve scheduler (batched) vs serial generate()");
    let spec = SpecDecConfig::default();
    let reqs: Vec<(Vec<u32>, usize)> = (0..8usize)
        .map(|i| {
            let plen = 24 + 13 * i;
            let prompt = (0..plen).map(|j| ((j * 7 + 3 * i + 1) % 256) as u32).collect();
            (prompt, 12 + 2 * i)
        })
        .collect();

    let serial_engine = Engine::synthetic();
    let t0 = Instant::now();
    for (p, m) in &reqs {
        generate(&serial_engine, p, *m, &spec).unwrap();
    }
    let serial_ms = t0.elapsed().as_secs_f64() * 1e3;

    let batch_engine = Engine::synthetic();
    let cfg = ServeConfig { max_sessions: reqs.len(), ..ServeConfig::default() };
    let mut sched = Scheduler::new(&batch_engine, spec, cfg);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for (i, (p, m)) in reqs.iter().enumerate() {
        let rx = ReplySink::new();
        sched.submit(Request {
            id: (i + 1) as u64,
            prompt: p.clone(),
            max_new: *m,
            reply: rx.clone(),
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    let mut guard = 0u32;
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 100_000, "serve bench failed to drain");
    }
    let batched_ms = t0.elapsed().as_secs_f64() * 1e3;
    let ok = rxs.iter().filter(|rx| rx.try_recv().is_ok_and(|l| l.starts_with("OK "))).count();
    // The CI smoke run leans on this: timings of a broken serve path are
    // worse than no timings at all.
    assert_eq!(ok, reqs.len(), "serve bench: {ok}/{} requests completed OK", reqs.len());
    let occupancy = batch_engine.reg.stats().mean_batch_occupancy();
    println!(
        "serve: {} reqs — serial {serial_ms:.1} ms, batched {batched_ms:.1} ms \
         (engine occupancy {occupancy:.2}, {ok} ok)",
        reqs.len()
    );
    // Greedy vs stochastic sampling (temperature 0.8): same request set
    // through serial generate(), recording wall-time and the per-proposal
    // acceptance rate of each mode.  Stochastic verification accepts a
    // proposal only when the target *sample* matches (coupled mode), so
    // its accept rate is expected to sit below greedy's — the measured gap
    // is the paper-relevant cost of lossless sampled speculative decoding.
    section("Perf: serve greedy vs stochastic sampling (temperature 0.8)");
    let run_mode = |spec: &SpecDecConfig| -> (f64, f64) {
        let e = Engine::synthetic();
        let t0 = Instant::now();
        let (mut acc, mut prop) = (0usize, 0usize);
        for (p, m) in &reqs {
            let g = generate(&e, p, *m, spec).unwrap();
            acc += g.accepted;
            prop += g.proposed;
        }
        (t0.elapsed().as_secs_f64() * 1e3, hat::metrics::accept_rate(acc, prop))
    };
    let (greedy_ms, greedy_accept) = run_mode(&SpecDecConfig::default());
    let stoch_spec =
        SpecDecConfig { temperature: 0.8, seed: 42, ..SpecDecConfig::default() };
    let (stoch_ms, stoch_accept) = run_mode(&stoch_spec);
    println!(
        "greedy: {greedy_ms:.1} ms accept {greedy_accept:.3} | \
         temperature 0.8: {stoch_ms:.1} ms accept {stoch_accept:.3}"
    );

    let serve = obj(vec![
        ("n_requests", Value::Num(reqs.len() as f64)),
        ("serial_ms", Value::Num(serial_ms)),
        ("batched_ms", Value::Num(batched_ms)),
        ("wall_ratio_serial_over_batched", Value::Num(serial_ms / batched_ms.max(1e-9))),
        ("mean_batch_occupancy", Value::Num(occupancy)),
        ("completed_ok", Value::Num(ok as f64)),
        ("greedy_serial_ms", Value::Num(greedy_ms)),
        ("greedy_accept_rate", Value::Num(greedy_accept)),
        ("stochastic_temperature", Value::Num(0.8)),
        ("stochastic_serial_ms", Value::Num(stoch_ms)),
        ("stochastic_accept_rate", Value::Num(stoch_accept)),
    ]);
    let p = write_json("BENCH_serve", &serve);
    println!("wrote {}", p.display());
}
