//! §Perf — hot-path microbenchmarks for the optimization pass
//! (EXPERIMENTS.md §Perf).  Hand-rolled harness (criterion is not in the
//! offline crate set): median-of-N wall-clock with warmup.
//!
//! L3: event-queue throughput, fleet-sim end-to-end event rate, chunker
//!     solve, batcher formation.
//! Runtime: backend execute latency per artifact bucket, tensor staging.

use std::time::Instant;

use hat::cloud::{optimal_chunk, Batcher, Job, JobKind};
use hat::config::{Dataset, ExperimentConfig, Framework, GModel};
use hat::frameworks::run_experiment;
use hat::sim::{EventQueue, SimTime};
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn bench<F: FnMut() -> u64>(name: &str, iters: usize, mut f: F) -> (f64, u64) {
    // warmup
    let mut sink = 0u64;
    sink ^= f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        sink ^= f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[samples.len() / 2];
    println!("{name:<44} {med:>10.3} ms (median of {iters})");
    (med, sink)
}

fn main() {
    section("Perf: L3 hot paths");
    let mut results = Vec::new();

    // Event queue: schedule+pop 100k events.
    let (eq_ms, _) = bench("event_queue: 100k schedule+pop", 9, || {
        let mut q = EventQueue::new();
        let mut acc = 0u64;
        for i in 0..100_000u64 {
            q.schedule_at(SimTime(i * 7 % 1_000_003), i);
        }
        while let Some((_, e)) = q.pop() {
            acc = acc.wrapping_add(e);
        }
        acc
    });
    results.push(("event_queue_100k_ms", eq_ms));

    // Chunk-size optimizer (Eq. 3 bisection).
    let g = GModel::vicuna7b();
    let (ch_ms, _) = bench("chunker: 10k optimal_chunk solves", 9, || {
        let mut acc = 0u64;
        for i in 0..10_000 {
            acc += optimal_chunk(
                8192.0,
                5_000.0 + (i % 100) as f64 * 50.0,
                |b| g.eval(b),
                (i % 2048) as f64,
                1 + (i % 8),
                (16, 512),
            ) as u64;
        }
        acc
    });
    results.push(("chunker_10k_ms", ch_ms));

    // Batcher: 10k jobs through form_batch.
    let (bt_ms, _) = bench("batcher: 10k jobs push+form", 9, || {
        let mut b = Batcher::new();
        let mut acc = 0u64;
        for i in 0..10_000usize {
            let kind = if i % 3 == 0 { JobKind::PrefillChunk } else { JobKind::Decode };
            b.push(Job { req: i, kind, tokens: 1 + i % 300, tag: 0 });
            if i % 8 == 0 {
                acc += b.form_batch(2048).len() as u64;
            }
        }
        while !b.is_empty() {
            acc += b.form_batch(2048).len() as u64;
        }
        acc
    });
    results.push(("batcher_10k_ms", bt_ms));

    // Fleet sim end-to-end: events/second of virtual workload.
    let profile = SdProfile::default_table();
    let mut cfg = ExperimentConfig::preset(Framework::Hat, Dataset::SpecBench);
    cfg.workload.n_requests = 200;
    let t0 = Instant::now();
    let rec = run_experiment(&cfg, &profile);
    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = rec.requests.iter().map(|r| r.tokens_generated()).sum();
    println!(
        "fleet_sim: 200 reqs, {tokens} tokens in {:.2}s wall ({:.0} virtual-tokens/s)",
        wall,
        tokens as f64 / wall
    );
    results.push(("fleet_sim_200req_s", wall * 1e3));

    // Runtime: backend execute latency per bucket (synthetic reference
    // model when artifacts are not built, so this always runs).
    let dir = hat::runtime::ArtifactRegistry::default_dir();
    let reg = hat::runtime::ArtifactRegistry::load_or_synthetic(&dir).unwrap();
    section(&format!("Perf: runtime ({} backend) per-call latency", reg.backend_name()));
    let spec = reg.model().clone();
    for t in [1usize, 4, 16, 64, 256] {
        let name = format!("cloud_middle_{t}");
        if reg.manifest().artifact(&name).is_none() {
            continue;
        }
        let hidden = vec![0.1f32; t * spec.hidden];
        let mkv = hat::runtime::zeros_tensor(&spec.middle_kv_dims());
        let (ms, _) = bench(&format!("{name} execute"), 15, || {
            let h = hat::runtime::f32_tensor_padded(&hidden, spec.hidden, t).unwrap();
            let pos = hat::runtime::pos_tensor(0);
            let outs = reg.run(&name, &[&h, &mkv, &pos]).unwrap();
            outs.len() as u64
        });
        results.push((Box::leak(format!("cloud_middle_{t}_ms").into_boxed_str()) as &str, ms));
    }
    let s = reg.stats();
    println!(
        "runtime totals: {} compiles ({:.0} ms), {} executes ({:.1} ms avg)",
        s.compiles,
        s.compile_ms,
        s.executions,
        s.execute_ms / s.executions.max(1) as f64
    );

    let out = obj(results.iter().map(|(k, v)| (*k, Value::Num(*v))).collect());
    let p = write_json("perf_hotpath", &out);
    println!("\nwrote {}", p.display());
}
