//! §PD — prefill/decode disaggregation benchmark (EXPERIMENTS.md §Perf).
//!
//! Workload: `N_INTERACTIVE` short-prompt interactive streams decoding
//! `INTERACTIVE_MAX_NEW` tokens each, with `N_AGGRESSOR` long-prompt
//! aggressors (`AGGRESSOR_PROMPT`-token prompts, tiny generations)
//! submitted once the interactive fleet is live.  Run twice over the
//! identical request set:
//!
//! - **baseline** — one single-pool [`Scheduler`] with
//!   `N_INTERACTIVE + N_AGGRESSOR` slots: every iteration co-batches the
//!   aggressors' 256-token prefill chunks with the interactive decode
//!   rounds, so each chunk's wall time lands between two tokens of every
//!   live stream;
//! - **pools** — a [`PdScheduler`] (`PF_WORKERS` prefill slots,
//!   `N_INTERACTIVE` decode slots): the decode pool is saturated by the
//!   interactive fleet, so aggressor chunks are deferred to the
//!   starvation-bounded forced steps instead of riding every iteration.
//!
//! Reported: per-request mean-TBT p99 over the interactive streams in
//! both modes (the disaggregation win), aggressor completion, handoff
//! count and per-pool occupancy.  Both modes must be byte-identical to
//! serial `generate()` — losslessness is asserted before any number is
//! reported.  Writes `BENCH_pd.json`.

// Benches measure real wall time: the util::clock choke point is for the
// runtime, not for measurement harnesses.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use hat::config::{ServeConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::runtime::ArtifactRegistry;
use hat::server::conn::ReplySink;
use hat::server::generate;
use hat::server::pools::{PdScheduler, ServeExec};
use hat::server::scheduler::{Request, Scheduler};
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};
use hat::util::stats::quantile;

const N_INTERACTIVE: usize = 12;
const N_AGGRESSOR: usize = 3;
const INTERACTIVE_MAX_NEW: usize = 24;
const AGGRESSOR_PROMPT: usize = 600;
const AGGRESSOR_MAX_NEW: usize = 4;
const PF_WORKERS: usize = 2;
/// Interactive ids are 1-based; aggressors live at `AGGRESSOR_ID_BASE+`.
const AGGRESSOR_ID_BASE: u64 = 1000;

fn interactive_reqs() -> Vec<(Vec<u32>, usize)> {
    (0..N_INTERACTIVE)
        .map(|i| {
            let plen = 6 + i % 5;
            let prompt = (0..plen).map(|j| ((j * 7 + 3 * i + 1) % 256) as u32).collect();
            (prompt, INTERACTIVE_MAX_NEW)
        })
        .collect()
}

fn aggressor_reqs() -> Vec<(Vec<u32>, usize)> {
    (0..N_AGGRESSOR)
        .map(|i| {
            let prompt =
                (0..AGGRESSOR_PROMPT).map(|j| ((j * 11 + 5 * i + 2) % 256) as u32).collect();
            (prompt, AGGRESSOR_MAX_NEW)
        })
        .collect()
}

/// How many iterations the interactive fleet decodes alone before the
/// aggressors arrive — long enough to have every baseline session in a
/// slot, short enough that every stream is still mid-decode (identical
/// arrival schedule in both modes).
const WARM_ITERS: usize = 2;

struct ModeRun {
    interactive_tbt: Vec<f64>,
    wall_ms: f64,
    replies: Vec<(u64, String)>,
}

/// Drive one mode over the shared workload: interactive fleet first,
/// aggressors after [`WARM_ITERS`] iterations (their staggered arrival is
/// what makes the aggressor prefill chunks compete with live decode
/// rounds).  `interactive_tbt` is filled by the caller from the mode's
/// per-request TBT attribution.
fn run_mode(sched: &mut dyn ServeExec) -> ModeRun {
    let mut rxs: Vec<(u64, ReplySink)> = Vec::new();
    for (i, (p, m)) in interactive_reqs().iter().enumerate() {
        let rx = ReplySink::new();
        sched.submit(Request {
            id: (i + 1) as u64,
            prompt: p.clone(),
            max_new: *m,
            reply: rx.clone(),
            enqueued: Instant::now(),
        });
        rxs.push(((i + 1) as u64, rx));
    }
    let t0 = Instant::now();
    let mut guard = 0u32;
    for _ in 0..WARM_ITERS {
        assert!(sched.step() > 0, "idle before fleet admission completed");
        guard += 1;
    }
    assert!(sched.live_sessions() > 0, "no interactive stream went live");
    for (i, (p, m)) in aggressor_reqs().iter().enumerate() {
        let rx = ReplySink::new();
        sched.submit(Request {
            id: AGGRESSOR_ID_BASE + i as u64,
            prompt: p.clone(),
            max_new: *m,
            reply: rx.clone(),
            enqueued: Instant::now(),
        });
        rxs.push((AGGRESSOR_ID_BASE + i as u64, rx));
    }
    while sched.has_work() {
        assert!(sched.step() > 0, "scheduler idle with pending work");
        guard += 1;
        assert!(guard < 200_000, "pd bench failed to drain");
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let replies: Vec<(u64, String)> =
        rxs.iter().map(|(id, rx)| (*id, rx.recv().expect("reply"))).collect();
    ModeRun { interactive_tbt: Vec::new(), wall_ms, replies }
}

fn interactive_only(tbt: &[(u64, f64)]) -> Vec<f64> {
    tbt.iter().filter(|(id, _)| *id < AGGRESSOR_ID_BASE).map(|(_, t)| *t).collect()
}

fn main() {
    section("PD: interactive TBT under long-prompt aggressors — pools vs single pool");
    let spec = SpecDecConfig::default();

    // Serial references (losslessness oracle for both modes).
    let oracle = Engine::synthetic();
    let mut want: Vec<(u64, String)> = Vec::new();
    for (i, (p, m)) in interactive_reqs().iter().enumerate() {
        want.push(((i + 1) as u64, generate(&oracle, p, *m, &spec).unwrap().reply_line()));
    }
    for (i, (p, m)) in aggressor_reqs().iter().enumerate() {
        want.push((
            AGGRESSOR_ID_BASE + i as u64,
            generate(&oracle, p, *m, &spec).unwrap().reply_line(),
        ));
    }

    // Baseline: one pool wide enough for everything.
    let base_engine = Engine::synthetic();
    let base_cfg = ServeConfig {
        max_sessions: N_INTERACTIVE + N_AGGRESSOR,
        ..ServeConfig::default()
    };
    let mut base = Scheduler::new(&base_engine, spec.clone(), base_cfg);
    let mut baseline = run_mode(&mut base);
    baseline.interactive_tbt = interactive_only(&base.stats.tbt_by_request);

    // Disaggregated: prefill pool + decode pool over one shared KV pool.
    let pf_engine = Engine::synthetic();
    let dc_engine =
        Engine::with_registry_shared(ArtifactRegistry::synthetic(), pf_engine.kv_pool())
            .expect("sibling engine over the shared pool");
    let pd_cfg = ServeConfig {
        prefill_workers: PF_WORKERS,
        decode_workers: N_INTERACTIVE,
        ..ServeConfig::default()
    };
    let mut pd = PdScheduler::new(&pf_engine, &dc_engine, spec, pd_cfg).unwrap();
    let mut pools = run_mode(&mut pd);
    let handoffs = pd.handoffs();
    let pd_stats = pd.merged_stats();
    pools.interactive_tbt = interactive_only(&pd_stats.tbt_by_request);

    // Losslessness gate: every stream in both modes byte-identical to the
    // serial oracle.  Timings of a lossy serve path are worse than none.
    for run in [&baseline, &pools] {
        for ((id, got), (wid, w)) in run.replies.iter().zip(&want) {
            assert_eq!(id, wid, "reply order drifted");
            assert_eq!(got, w, "request {id}: stream differs from serial generate()");
        }
    }
    assert_eq!(
        handoffs,
        (N_INTERACTIVE + N_AGGRESSOR) as u64,
        "every multi-token request must cross the pool seam exactly once"
    );
    assert!(pf_engine.kv_pool().quiesced(), "pool leaked KV blocks");

    let base_p99 = quantile(&baseline.interactive_tbt, 0.99);
    let pd_p99 = quantile(&pools.interactive_tbt, 0.99);
    let base_mean = baseline.interactive_tbt.iter().sum::<f64>() / N_INTERACTIVE as f64;
    let pd_mean = pools.interactive_tbt.iter().sum::<f64>() / N_INTERACTIVE as f64;
    println!(
        "baseline: interactive TBT p99 {base_p99:>8.3} ms (mean {base_mean:.3}) wall {:>8.1} ms",
        baseline.wall_ms
    );
    println!(
        "pools:    interactive TBT p99 {pd_p99:>8.3} ms (mean {pd_mean:.3}) wall {:>8.1} ms \
         ({handoffs} handoffs, pf_occ {:.2}, dc_occ {:.2})",
        pools.wall_ms,
        pd_stats.prefill_occ.mean(),
        pd_stats.decode_occ.mean(),
    );
    // The CI run leans on this: the disaggregation's whole point is that
    // aggressor prefill chunks stop inflating interactive tail TBT.
    assert!(
        pd_p99 < base_p99,
        "pools must improve interactive TBT p99 ({pd_p99:.3} vs {base_p99:.3} ms)"
    );
    println!("interactive TBT p99 improvement: {:.2}x", base_p99 / pd_p99.max(1e-9));

    let out = obj(vec![
        ("n_interactive", Value::Num(N_INTERACTIVE as f64)),
        ("n_aggressor", Value::Num(N_AGGRESSOR as f64)),
        ("interactive_max_new", Value::Num(INTERACTIVE_MAX_NEW as f64)),
        ("aggressor_prompt_tokens", Value::Num(AGGRESSOR_PROMPT as f64)),
        ("prefill_workers", Value::Num(PF_WORKERS as f64)),
        ("decode_workers", Value::Num(N_INTERACTIVE as f64)),
        ("baseline_tbt_p99_ms", Value::Num(base_p99)),
        ("baseline_tbt_mean_ms", Value::Num(base_mean)),
        ("baseline_wall_ms", Value::Num(baseline.wall_ms)),
        ("pools_tbt_p99_ms", Value::Num(pd_p99)),
        ("pools_tbt_mean_ms", Value::Num(pd_mean)),
        ("pools_wall_ms", Value::Num(pools.wall_ms)),
        ("tbt_p99_improvement", Value::Num(base_p99 / pd_p99.max(1e-9))),
        ("handoffs", Value::Num(handoffs as f64)),
        ("prefill_occ_mean", Value::Num(pd_stats.prefill_occ.mean())),
        ("decode_occ_mean", Value::Num(pd_stats.decode_occ.mean())),
    ]);
    let p = write_json("BENCH_pd", &out);
    println!("wrote {}", p.display());
}
