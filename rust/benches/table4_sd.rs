//! Table 4 — speculative-decoding quality: trained parameters, accept
//! length, and decode speedup vs U-shape, measured on the *real* engine
//! (one device + server, no interfering load — paper §4.3).
//!
//! Paper shape: HAT beats U-Medusa on accept length with ~10× fewer
//! trained parameters, and delivers the larger decode speedup.

use hat::config::{Dataset, ExperimentConfig, Framework, SpecDecConfig};
use hat::engine::Engine;
use hat::frameworks::run_experiment;
use hat::runtime::ArtifactRegistry;
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};
use hat::workload::PromptPool;

fn main() {
    let dir = ArtifactRegistry::default_dir();
    section("Table 4: SD performance (1 device, no load)");
    let (profile, params) = if dir.join("manifest.json").exists() {
        let engine = Engine::load(&dir).expect("engine");
        let pool = PromptPool::load(&dir.join("prompts.bin")).expect("prompts");
        let cfg = SpecDecConfig::default();
        let p = SdProfile::measure(&engine, &pool, &cfg, 8, 48, 42).expect("profile");
        let tm = &engine.reg.manifest().train_meta;
        (p, (tm.lm_params, tm.adapter_params, tm.medusa_params))
    } else {
        eprintln!("artifacts/ not built — using the recorded default profile");
        (SdProfile::default_table(), (1_443_968, 65_664, 330_240))
    };

    let accept_hat = SdProfile::accept_length(&profile.hat);
    let accept_med = SdProfile::accept_length(&profile.medusa);

    // Decode speedup vs U-shape: unloaded fleet, measured on the AGX Orin
    // device (the paper's §4.3 setup pins one device + the server; our
    // device id 2 is an Orin — see devices::DeviceClass::for_device).
    let mut tbt = std::collections::BTreeMap::new();
    for fw in [Framework::UShape, Framework::UMedusa, Framework::Hat] {
        let mut cfg = ExperimentConfig::preset(fw, Dataset::SpecBench);
        cfg.workload.n_devices = 3;
        cfg.workload.rate = 0.2; // one request at a time — no queueing
        cfg.workload.n_requests = 60;
        let rec = run_experiment(&cfg, &profile);
        let orin: Vec<f64> = rec
            .finished_requests()
            .filter(|r| r.device == 2)
            .filter_map(|r| r.mean_tbt_ms())
            .collect();
        assert!(!orin.is_empty());
        tbt.insert(fw.name(), orin.iter().sum::<f64>() / orin.len() as f64);
    }
    let base = tbt["U-shape"];

    println!(
        "{:<10} {:>10} {:>8} {:>9}",
        "method", "params", "accept", "speedup"
    );
    println!("{:<10} {:>10} {:>8.2} {:>8.2}x", "U-shape", "N/A", 1.0, 1.0);
    println!(
        "{:<10} {:>10} {:>8.2} {:>8.2}x",
        "U-Medusa", params.2, accept_med, base / tbt["U-Medusa"]
    );
    println!(
        "{:<10} {:>10} {:>8.2} {:>8.2}x",
        "HAT", params.1, accept_hat, base / tbt["HAT"]
    );

    // Paper shape assertions.
    assert!(accept_hat > accept_med, "HAT accept {accept_hat:.2} vs Medusa {accept_med:.2}");
    assert!(params.1 < params.2 / 3, "Λ must be several times smaller than medusa heads");
    assert!(base / tbt["HAT"] > 1.1, "HAT decode speedup vs U-shape");
    assert!(base / tbt["HAT"] > base / tbt["U-Medusa"] * 0.98, "HAT >= Medusa speedup");

    let out = obj(vec![
        ("lm_params", Value::Num(params.0 as f64)),
        ("adapter_params", Value::Num(params.1 as f64)),
        ("medusa_params", Value::Num(params.2 as f64)),
        ("accept_hat", Value::Num(accept_hat)),
        ("accept_medusa", Value::Num(accept_med)),
        ("speedup_hat", Value::Num(base / tbt["HAT"])),
        ("speedup_medusa", Value::Num(base / tbt["U-Medusa"])),
    ]);
    let p = write_json("table4_sd", &out);
    println!("\nwrote {}", p.display());
}
