//! Table 5 — ablation of HAT's three key strategies: speculative decoding
//! (SD), prompt chunking (PC) and parallel drafting (PD), both datasets.
//!
//! Paper shape: PC is the TTFT lever (≈-40%), SD is the main TBT lever,
//! PD shaves TBT further; the full stack is best on both metrics.

use hat::config::{Dataset, ExperimentConfig, Framework};
use hat::frameworks::run_experiment;
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn main() {
    let profile = SdProfile::load_or_default(&Default::default(), 4);
    let combos: [(bool, bool, bool); 6] = [
        (false, false, false),
        (false, true, false),
        (true, false, false),
        (true, false, true),
        (true, true, false),
        (true, true, true),
    ];
    let mut rows = Vec::new();
    for dataset in [Dataset::SpecBench, Dataset::CnnDm] {
        section(&format!("Table 5: key strategies on {}", dataset.name()));
        println!("{:>4} {:>4} {:>4} {:>11} {:>10}", "SD", "PC", "PD", "TTFT(ms)", "TBT(ms)");
        let mut results = Vec::new();
        for (sd, pc, pd) in combos {
            let mut cfg = ExperimentConfig::preset(Framework::Hat, dataset);
            cfg.strategies.sd = sd;
            cfg.strategies.pc = pc;
            cfg.strategies.pd = pd;
            cfg.workload.n_requests = 250;
            let s = run_experiment(&cfg, &profile).summary();
            let mark = |b: bool| if b { "+" } else { "-" };
            println!(
                "{:>4} {:>4} {:>4} {:>11.1} {:>10.1}",
                mark(sd), mark(pc), mark(pd), s.ttft_mean_ms, s.tbt_mean_ms
            );
            results.push(((sd, pc, pd), s.ttft_mean_ms, s.tbt_mean_ms));
            rows.push(obj(vec![
                ("dataset", Value::Str(dataset.name().into())),
                ("sd", Value::Bool(sd)),
                ("pc", Value::Bool(pc)),
                ("pd", Value::Bool(pd)),
                ("ttft_ms", Value::Num(s.ttft_mean_ms)),
                ("tbt_ms", Value::Num(s.tbt_mean_ms)),
            ]));
        }
        let find = |c: (bool, bool, bool)| results.iter().find(|(x, _, _)| *x == c).unwrap();
        let baseline = find((false, false, false));
        let pc_only = find((false, true, false));
        let full = find((true, true, true));
        let no_pd = find((true, true, false));
        // Paper shape: PC cuts TTFT; full stack has the lowest TBT; PD helps.
        assert!(pc_only.1 < baseline.1, "PC should reduce TTFT");
        assert!(full.2 < baseline.2, "full HAT should beat plain U-shape on TBT");
        assert!(full.2 <= no_pd.2 * 1.02, "PD should not hurt TBT");
    }
    let p = write_json("table5_ablation", &Value::Arr(rows));
    println!("\nwrote {}", p.display());
}
