//! §Churn — disconnect-storm benchmark for the serve scheduler's
//! session-lifecycle subsystem (EXPERIMENTS.md §Perf).
//!
//! Two scenarios, both written to `BENCH_churn.json`:
//!
//! **Slot reclamation** (direct-driven scheduler): `N_DEAD` long
//! generations whose clients vanish right after their sessions take
//! slots, plus `N_LIVE` short live requests queued behind them, on a
//! 2-slot scheduler.  Run twice over identical requests:
//!
//! - **reaping on** — the disconnects are noticed (reply sinks marked
//!   dead, cancels forwarded), exactly what the serve event loop does
//!   when a read returns EOF: slots are reclaimed at the next iteration
//!   boundary;
//! - **reaping off** — the pre-lifecycle behaviour: abandoned
//!   generations run to completion into dead sinks while live clients
//!   wait for a slot.
//!
//! **Connection storm** (full TCP front end): `STORM_THREADS ×
//! STORM_PER_THREAD` = 10k connections against a real `serve_listener`
//! event loop — half connect and vanish without a byte, a quarter
//! complete a short generation, a quarter abandon a long one mid-flight.
//! Exercises accept, framing, submit, cancel-on-disconnect and loop exit
//! under churn; reports wall time, accept throughput, and the cancel
//! count read back over STATS.

// Benches measure real wall time: the util::clock choke point is for the
// runtime, not for measurement harnesses.
#![allow(clippy::disallowed_methods)]

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use hat::config::{ServeConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::server::conn::ReplySink;
use hat::server::scheduler::{Request, Scheduler};
use hat::server::serve_listener;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

const N_DEAD: usize = 2;
const N_LIVE: usize = 3;
const DEAD_MAX_NEW: usize = 200;

const STORM_THREADS: usize = 8;
const STORM_PER_THREAD: usize = 1250;

struct ChurnRun {
    iterations: usize,
    wall_ms: f64,
    live_mean_ms: f64,
    cancelled: u64,
    reaped: u64,
    stale_dropped: u64,
}

fn run(reap: bool) -> ChurnRun {
    let engine = Engine::synthetic();
    let cfg = ServeConfig { max_sessions: 2, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);

    // The storm: long generations that take both slots, clients gone.
    let mut dead = Vec::new();
    for i in 0..N_DEAD {
        let reply = ReplySink::new();
        let prompt: Vec<u32> = (0u32..80).map(|j| (j * 3 + i as u32 + 1) % 256).collect();
        sched.submit(Request {
            id: (i + 1) as u64,
            prompt,
            max_new: DEAD_MAX_NEW,
            reply: reply.clone(),
            enqueued: Instant::now(),
        });
        dead.push(((i + 1) as u64, reply));
    }
    let mut iterations = 0usize;
    sched.step(); // the storm is admitted into both slots
    iterations += 1;
    assert_eq!(sched.live_sessions(), N_DEAD, "storm must hold all slots");

    // Live clients queue behind it.
    let t0 = Instant::now();
    let mut live: Vec<(ReplySink, Instant, Option<f64>)> = Vec::new();
    for i in 0..N_LIVE {
        let rx = ReplySink::new();
        let prompt: Vec<u32> = (0u32..12).map(|j| (j * 5 + i as u32 + 2) % 256).collect();
        sched.submit(Request {
            id: (100 + i) as u64,
            prompt,
            max_new: 8,
            reply: rx.clone(),
            enqueued: Instant::now(),
        });
        live.push((rx, Instant::now(), None));
    }

    if reap {
        // What the event loop does when each dead client's read EOFs.
        for (id, reply) in &dead {
            reply.mark_dead();
            assert!(sched.cancel(*id), "slot holder must cancel");
        }
    }

    while live.iter().any(|(_, _, done)| done.is_none()) {
        assert!(sched.step() > 0, "scheduler idle with live work pending");
        iterations += 1;
        assert!(iterations < 100_000, "churn bench failed to drain");
        for (rx, submitted, done) in live.iter_mut() {
            if done.is_none() {
                if let Ok(line) = rx.try_recv() {
                    assert!(line.starts_with("OK "), "live request failed: {line}");
                    *done = Some(submitted.elapsed().as_secs_f64() * 1e3);
                }
            }
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let live_mean_ms =
        live.iter().map(|(_, _, d)| d.unwrap()).sum::<f64>() / N_LIVE as f64;
    ChurnRun {
        iterations,
        wall_ms,
        live_mean_ms,
        cancelled: sched.stats.cancelled,
        reaped: sched.stats.reaped,
        stale_dropped: sched.stats.stale_dropped,
    }
}

struct StormRun {
    conns: usize,
    live_completed: usize,
    cancelled: u64,
    wall_ms: f64,
    conns_per_sec: f64,
}

/// Pull one `key=value` integer out of a STATS reply line.
fn stats_field(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|kv| kv.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("STATS missing {key}: {line}"))
}

fn storm() -> StormRun {
    let total = STORM_THREADS * STORM_PER_THREAD;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg = ServeConfig { max_sessions: 8, ..ServeConfig::default() };
    // One extra accept: the post-storm STATS probe retires the listener.
    let server = std::thread::spawn(move || {
        serve_listener(listener, SpecDecConfig::default(), cfg, total + 1).unwrap();
    });

    let t0 = Instant::now();
    let drivers: Vec<_> = (0..STORM_THREADS)
        .map(|t| {
            std::thread::spawn(move || {
                let mut completed = 0usize;
                for i in 0..STORM_PER_THREAD {
                    match i % 4 {
                        // Half the storm: connect, vanish without a byte.
                        0 | 2 => drop(TcpStream::connect(addr).unwrap()),
                        // A quarter: complete a short generation.
                        1 => {
                            let mut s = TcpStream::connect(addr).unwrap();
                            let mut r = BufReader::new(s.try_clone().unwrap());
                            writeln!(s, "GENERATE 4 {} {} 3 1", t + 1, (i % 251) + 1).unwrap();
                            let mut line = String::new();
                            r.read_line(&mut line).unwrap();
                            assert!(line.starts_with("OK "), "storm request failed: {line}");
                            completed += 1;
                            writeln!(s, "QUIT").unwrap();
                        }
                        // A quarter: abandon a long generation mid-flight.
                        _ => {
                            let mut s = TcpStream::connect(addr).unwrap();
                            writeln!(s, "GENERATE 200 {} 7 5 3 2", t + 1).unwrap();
                        }
                    }
                }
                completed
            })
        })
        .collect();
    let live_completed: usize = drivers.into_iter().map(|d| d.join().unwrap()).sum();
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // The probe connection reads the lifecycle counters, then retires
    // the loop's last accept slot so the server exits.
    let mut s = TcpStream::connect(addr).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    writeln!(s, "STATS").unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "bad STATS reply: {line}");
    let cancelled = stats_field(&line, "cancelled");
    writeln!(s, "QUIT").unwrap();
    drop((s, r));
    server.join().unwrap();

    StormRun {
        conns: total,
        live_completed,
        cancelled,
        wall_ms,
        conns_per_sec: total as f64 / (wall_ms / 1e3),
    }
}

fn main() {
    section("Churn: disconnect storm — reaping on vs off");
    let on = run(true);
    let off = run(false);
    println!(
        "reap on:  {:>5} iterations, {:>8.1} ms wall, live mean {:>7.1} ms \
         (cancelled={} stale_dropped={})",
        on.iterations, on.wall_ms, on.live_mean_ms, on.cancelled, on.stale_dropped
    );
    println!(
        "reap off: {:>5} iterations, {:>8.1} ms wall, live mean {:>7.1} ms",
        off.iterations, off.wall_ms, off.live_mean_ms
    );
    // The CI smoke run leans on these: a lifecycle regression that stops
    // reclaiming slots makes the ON run as slow as OFF.
    assert_eq!(on.cancelled, N_DEAD as u64, "reaping on must cancel the storm");
    assert!(
        on.iterations < off.iterations,
        "reaping must finish live work in fewer iterations ({} vs {})",
        on.iterations,
        off.iterations
    );
    let speedup = off.iterations as f64 / on.iterations.max(1) as f64;
    println!("slot-reclamation speedup: {speedup:.2}x fewer iterations to serve live clients");

    section("Churn: 10k-connection storm against the event-loop front end");
    let st = storm();
    let abandoned = STORM_THREADS * (0..STORM_PER_THREAD).filter(|i| i % 4 == 3).count();
    println!(
        "{} conns in {:.1} ms ({:.0} conns/s): {} live completed, {} cancelled",
        st.conns, st.wall_ms, st.conns_per_sec, st.live_completed, st.cancelled
    );
    assert_eq!(
        st.live_completed,
        STORM_THREADS * (0..STORM_PER_THREAD).filter(|i| i % 4 == 1).count(),
        "every live storm request must complete"
    );
    assert_eq!(
        st.cancelled, abandoned as u64,
        "every abandoned storm generation must be cancelled on disconnect"
    );

    let out = obj(vec![
        ("n_dead", Value::Num(N_DEAD as f64)),
        ("n_live", Value::Num(N_LIVE as f64)),
        ("dead_max_new", Value::Num(DEAD_MAX_NEW as f64)),
        ("reap_on_iterations", Value::Num(on.iterations as f64)),
        ("reap_on_wall_ms", Value::Num(on.wall_ms)),
        ("reap_on_live_mean_ms", Value::Num(on.live_mean_ms)),
        ("reap_on_cancelled", Value::Num(on.cancelled as f64)),
        ("reap_on_reaped", Value::Num(on.reaped as f64)),
        ("reap_on_stale_dropped", Value::Num(on.stale_dropped as f64)),
        ("reap_off_iterations", Value::Num(off.iterations as f64)),
        ("reap_off_wall_ms", Value::Num(off.wall_ms)),
        ("reap_off_live_mean_ms", Value::Num(off.live_mean_ms)),
        ("iteration_speedup", Value::Num(speedup)),
        ("storm_conns", Value::Num(st.conns as f64)),
        ("storm_live_completed", Value::Num(st.live_completed as f64)),
        ("storm_cancelled", Value::Num(st.cancelled as f64)),
        ("storm_wall_ms", Value::Num(st.wall_ms)),
        ("storm_conns_per_sec", Value::Num(st.conns_per_sec)),
    ]);
    let p = write_json("BENCH_churn", &out);
    println!("wrote {}", p.display());
}
