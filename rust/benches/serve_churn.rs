//! §Churn — disconnect-storm benchmark for the serve scheduler's
//! session-lifecycle subsystem (EXPERIMENTS.md §Perf).
//!
//! Workload: `N_DEAD` long generations whose clients vanish right after
//! their sessions take slots, plus `N_LIVE` short live requests queued
//! behind them, on a 2-slot scheduler.  Run twice over identical
//! requests:
//!
//! - **reaping on** — the disconnects are noticed (reply handles marked
//!   dead, cancels forwarded), exactly what `server::handle_conn`'s reply
//!   wait does: slots are reclaimed at the next iteration boundary;
//! - **reaping off** — the pre-lifecycle behaviour: abandoned
//!   generations run to completion into dead channels while live clients
//!   wait for a slot.
//!
//! Reported: scheduler iterations and wall ms until every live request
//! completes, mean live-client completion latency, and the ON-mode
//! lifecycle counters.  Writes `BENCH_churn.json`.

// Benches measure real wall time: the util::clock choke point is for the
// runtime, not for measurement harnesses.
#![allow(clippy::disallowed_methods)]

use std::sync::mpsc;
use std::time::Instant;

use hat::config::{ServeConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::server::scheduler::{ReplyHandle, Request, Scheduler};
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

const N_DEAD: usize = 2;
const N_LIVE: usize = 3;
const DEAD_MAX_NEW: usize = 200;

struct ChurnRun {
    iterations: usize,
    wall_ms: f64,
    live_mean_ms: f64,
    cancelled: u64,
    reaped: u64,
    stale_dropped: u64,
}

fn run(reap: bool) -> ChurnRun {
    let engine = Engine::synthetic();
    let cfg = ServeConfig { max_sessions: 2, ..ServeConfig::default() };
    let mut sched = Scheduler::new(&engine, SpecDecConfig::default(), cfg);

    // The storm: long generations that take both slots, clients gone.
    let mut dead = Vec::new();
    for i in 0..N_DEAD {
        let (tx, rx) = mpsc::channel();
        let reply = ReplyHandle::new(tx);
        let prompt: Vec<u32> = (0u32..80).map(|j| (j * 3 + i as u32 + 1) % 256).collect();
        sched.submit(Request {
            id: (i + 1) as u64,
            prompt,
            max_new: DEAD_MAX_NEW,
            reply: reply.clone(),
            enqueued: Instant::now(),
        });
        drop(rx); // client disconnects immediately after submitting
        dead.push(((i + 1) as u64, reply));
    }
    let mut iterations = 0usize;
    sched.step(); // the storm is admitted into both slots
    iterations += 1;
    assert_eq!(sched.live_sessions(), N_DEAD, "storm must hold all slots");

    // Live clients queue behind it.
    let t0 = Instant::now();
    let mut live: Vec<(mpsc::Receiver<String>, Instant, Option<f64>)> = Vec::new();
    for i in 0..N_LIVE {
        let (tx, rx) = mpsc::channel();
        let prompt: Vec<u32> = (0u32..12).map(|j| (j * 5 + i as u32 + 2) % 256).collect();
        sched.submit(Request {
            id: (100 + i) as u64,
            prompt,
            max_new: 8,
            reply: ReplyHandle::new(tx),
            enqueued: Instant::now(),
        });
        live.push((rx, Instant::now(), None));
    }

    if reap {
        // What each dead client's connection thread would do on EOF.
        for (id, reply) in &dead {
            reply.mark_dead();
            assert!(sched.cancel(*id), "slot holder must cancel");
        }
    }

    while live.iter().any(|(_, _, done)| done.is_none()) {
        assert!(sched.step() > 0, "scheduler idle with live work pending");
        iterations += 1;
        assert!(iterations < 100_000, "churn bench failed to drain");
        for (rx, submitted, done) in live.iter_mut() {
            if done.is_none() {
                if let Ok(line) = rx.try_recv() {
                    assert!(line.starts_with("OK "), "live request failed: {line}");
                    *done = Some(submitted.elapsed().as_secs_f64() * 1e3);
                }
            }
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let live_mean_ms =
        live.iter().map(|(_, _, d)| d.unwrap()).sum::<f64>() / N_LIVE as f64;
    ChurnRun {
        iterations,
        wall_ms,
        live_mean_ms,
        cancelled: sched.stats.cancelled,
        reaped: sched.stats.reaped,
        stale_dropped: sched.stats.stale_dropped,
    }
}

fn main() {
    section("Churn: disconnect storm — reaping on vs off");
    let on = run(true);
    let off = run(false);
    println!(
        "reap on:  {:>5} iterations, {:>8.1} ms wall, live mean {:>7.1} ms \
         (cancelled={} stale_dropped={})",
        on.iterations, on.wall_ms, on.live_mean_ms, on.cancelled, on.stale_dropped
    );
    println!(
        "reap off: {:>5} iterations, {:>8.1} ms wall, live mean {:>7.1} ms",
        off.iterations, off.wall_ms, off.live_mean_ms
    );
    // The CI smoke run leans on these: a lifecycle regression that stops
    // reclaiming slots makes the ON run as slow as OFF.
    assert_eq!(on.cancelled, N_DEAD as u64, "reaping on must cancel the storm");
    assert!(
        on.iterations < off.iterations,
        "reaping must finish live work in fewer iterations ({} vs {})",
        on.iterations,
        off.iterations
    );
    let speedup = off.iterations as f64 / on.iterations.max(1) as f64;
    println!("slot-reclamation speedup: {speedup:.2}x fewer iterations to serve live clients");

    let out = obj(vec![
        ("n_dead", Value::Num(N_DEAD as f64)),
        ("n_live", Value::Num(N_LIVE as f64)),
        ("dead_max_new", Value::Num(DEAD_MAX_NEW as f64)),
        ("reap_on_iterations", Value::Num(on.iterations as f64)),
        ("reap_on_wall_ms", Value::Num(on.wall_ms)),
        ("reap_on_live_mean_ms", Value::Num(on.live_mean_ms)),
        ("reap_on_cancelled", Value::Num(on.cancelled as f64)),
        ("reap_on_reaped", Value::Num(on.reaped as f64)),
        ("reap_on_stale_dropped", Value::Num(on.stale_dropped as f64)),
        ("reap_off_iterations", Value::Num(off.iterations as f64)),
        ("reap_off_wall_ms", Value::Num(off.wall_ms)),
        ("reap_off_live_mean_ms", Value::Num(off.live_mean_ms)),
        ("iteration_speedup", Value::Num(speedup)),
    ]);
    let p = write_json("BENCH_churn", &out);
    println!("wrote {}", p.display());
}
