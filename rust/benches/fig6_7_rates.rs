//! Figs. 6–7 — TTFT and TBT vs request generation rate, all four
//! frameworks, both datasets (30 devices, P=4, Poisson arrivals).
//!
//! Paper shape to reproduce: HAT lowest TTFT and TBT everywhere; HAT and
//! U-Sarathi degrade gently with rate (chunking isolates decode from long
//! prompts) while U-Medusa and U-shape degrade sharply.

use hat::config::{Dataset, ExperimentConfig, Framework};
use hat::frameworks::run_experiment;
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn main() {
    let profile = SdProfile::load_or_default(&Default::default(), 4);
    let mut out_rows = Vec::new();

    for (dataset, rates) in [
        (Dataset::SpecBench, vec![4.0, 5.0, 6.0, 7.0, 8.0, 9.0]),
        (Dataset::CnnDm, vec![2.0, 2.5, 3.0, 3.5, 4.0, 4.5]),
    ] {
        section(&format!("Fig {}: {} (P=4, 30 devices)",
            if dataset == Dataset::SpecBench { 6 } else { 7 }, dataset.name()));
        println!("{:>6} {:>11} {:>11} {:>11} {:>11}   metric", "rate", "HAT", "U-Sarathi", "U-Medusa", "U-shape");
        let mut per_rate: Vec<(f64, Vec<(f64, f64)>)> = Vec::new();
        for &rate in &rates {
            let mut cells = Vec::new();
            for fw in Framework::all() {
                let mut cfg = ExperimentConfig::preset(fw, dataset);
                cfg.workload.rate = rate;
                cfg.workload.n_requests = 250;
                let s = run_experiment(&cfg, &profile).summary();
                cells.push((s.ttft_mean_ms, s.tbt_mean_ms));
                out_rows.push(obj(vec![
                    ("dataset", Value::Str(dataset.name().into())),
                    ("framework", Value::Str(fw.name().into())),
                    ("rate", Value::Num(rate)),
                    ("ttft_ms", Value::Num(s.ttft_mean_ms)),
                    ("tbt_ms", Value::Num(s.tbt_mean_ms)),
                ]));
            }
            println!(
                "{rate:>6.1} {:>11.1} {:>11.1} {:>11.1} {:>11.1}   TTFT(ms)",
                cells[0].0, cells[1].0, cells[2].0, cells[3].0
            );
            println!(
                "{:>6} {:>11.1} {:>11.1} {:>11.1} {:>11.1}   TBT(ms)",
                "", cells[0].1, cells[1].1, cells[2].1, cells[3].1
            );
            per_rate.push((rate, cells));
        }
        // Paper shape: HAT has the lowest TTFT and TBT at every rate.
        for (rate, cells) in &per_rate {
            let (hat_ttft, hat_tbt) = cells[0];
            for (i, &(ttft, tbt)) in cells.iter().enumerate().skip(1) {
                assert!(
                    hat_ttft <= ttft * 1.02,
                    "rate {rate}: HAT TTFT {hat_ttft:.1} vs {} {ttft:.1}",
                    Framework::all()[i].name()
                );
                assert!(
                    hat_tbt <= tbt * 1.02,
                    "rate {rate}: HAT TBT {hat_tbt:.1} vs {} {tbt:.1}",
                    Framework::all()[i].name()
                );
            }
        }
    }
    let p = write_json("fig6_7_rates", &Value::Arr(out_rows));
    println!("\nwrote {}", p.display());
}
