//! §KV — paged-KV memory and latency benchmark (EXPERIMENTS.md §Perf).
//!
//! Two comparisons, dense vs paged, on the synthetic reference model:
//!
//! - **Sessions per GB** — the contiguous pre-paged layout reserved
//!   `max_seq` rows for all three caches of every session up front; the
//!   paged pool allocates 64-token blocks on demand and content-shares
//!   sealed prefix blocks.  Measured by prefilling + decoding a small
//!   fleet and reading the pool census, once with independent prompts and
//!   once with a shared 512-token system prompt.
//! - **TTFT** — time to first token through the paged-native reference
//!   backend vs the same backend stripped of its `run_paged` overrides,
//!   so every call pays the trait's dense gather/scatter shim (the data
//!   path a dense-only backend takes).  A shared-prefix admission is
//!   timed separately: CoW dedup saves memory, not prefill compute, and
//!   the number proves it stays in the same band instead of regressing.
//!
//! The streams themselves are asserted byte-identical across the two
//! data paths before any number is reported.  Writes `BENCH_kv.json`.

// Benches measure real wall time: the util::clock choke point is for the
// runtime, not for measurement harnesses.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use hat::backend::reference::ReferenceBackend;
use hat::backend::{ExecBackend, RuntimeStats, Tensor};
use hat::config::{KvConfig, SpecDecConfig};
use hat::engine::Engine;
use hat::runtime::{ArtifactRegistry, Manifest};
use hat::specdec::{chunk_sizes, Session};
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};
use hat::util::rng::Rng;

const PREFIX: usize = 512;
const TAIL: usize = 8;
const GEN: usize = 12;
const FLEET: usize = 4;
const CHUNK: usize = 64;

/// Reference backend stripped of its paged-native overrides: `run_paged`
/// and `run_batch_paged` fall back to the trait's dense shim — gather the
/// whole KV tensor, splice, execute, scatter — reproducing the
/// pre-paged contiguous data path on identical arithmetic.
struct DenseShimBackend(ReferenceBackend);

impl ExecBackend for DenseShimBackend {
    fn name(&self) -> &'static str {
        "dense-shim-reference"
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_weights(&mut self) -> anyhow::Result<()> {
        self.0.load_weights()
    }
    fn compile(&self, name: &str) -> anyhow::Result<()> {
        self.0.compile(name)
    }
    fn run(&self, name: &str, inputs: &[&Tensor]) -> anyhow::Result<Vec<Tensor>> {
        self.0.run(name, inputs)
    }
    fn run_batch(&self, name: &str, inputs: &[Vec<&Tensor>]) -> anyhow::Result<Vec<Vec<Tensor>>> {
        self.0.run_batch(name, inputs)
    }
    fn weight(&self, name: &str) -> Option<Tensor> {
        self.0.weight(name)
    }
    fn stats(&self) -> RuntimeStats {
        self.0.stats()
    }
    // No run_paged / run_batch_paged overrides: the dense shim applies.
}

fn dense_engine() -> Engine {
    let be = DenseShimBackend(ReferenceBackend::synthetic(42));
    Engine::with_registry(ArtifactRegistry::with_backend(Box::new(be)).unwrap()).unwrap()
}

fn toks(rng: &mut Rng, n: usize, vocab: usize) -> Vec<u32> {
    (0..n).map(|_| rng.below(vocab) as u32).collect()
}

/// Prefill + decode `GEN` tokens; returns (ttft_ms, context).
fn drive(e: &Engine, prompt: &[u32]) -> (f64, Vec<u32>) {
    let mut s = Session::new(e, SpecDecConfig::default()).unwrap();
    let t0 = Instant::now();
    s.prefill(prompt, &chunk_sizes(prompt.len(), CHUNK)).unwrap();
    let ttft = t0.elapsed().as_secs_f64() * 1e3;
    while s.generated() < GEN {
        s.hat_round(true, 4).unwrap();
    }
    (ttft, s.ctx.clone())
}

/// Prefill + decode a whole fleet concurrently, return the pool census at
/// peak residency (all sessions alive).
fn fleet_blocks(e: &Engine, prompts: &[Vec<u32>]) -> (usize, usize) {
    let mut sessions = Vec::new();
    for p in prompts {
        let mut s = Session::new(e, SpecDecConfig::default()).unwrap();
        s.prefill(p, &chunk_sizes(p.len(), CHUNK)).unwrap();
        while s.generated() < GEN {
            s.hat_round(true, 4).unwrap();
        }
        sessions.push(s);
    }
    let st = e.kv_pool().stats();
    (st.blocks_in_use, st.shared_blocks)
}

fn main() {
    section("KV: paged pool vs dense reservation — memory and TTFT");
    let kv = KvConfig::default();
    let paged = Engine::synthetic();
    let spec = paged.spec().clone();
    let vocab = spec.vocab;
    let mut rng = Rng::new(17);

    // Byte-identity gate: the dense shim and the paged-native path must
    // produce the same stream before their timings mean anything.
    let probe = toks(&mut rng, 48, vocab);
    let dense = dense_engine();
    let (ttft_dense_ms, ctx_dense) = drive(&dense, &probe);
    let (ttft_probe_paged, ctx_paged) = drive(&paged, &probe);
    assert_eq!(ctx_dense, ctx_paged, "dense shim and paged-native streams diverged");
    let _ = ttft_probe_paged;

    // TTFT on the 520-token system-prompt workload.
    let system = toks(&mut rng, PREFIX, vocab);
    let long_prompt: Vec<u32> =
        system.iter().copied().chain(toks(&mut rng, TAIL, vocab)).collect();
    let (ttft_long_dense_ms, _) = drive(&dense_engine(), &long_prompt);
    let cold = Engine::synthetic();
    let (ttft_long_paged_ms, _) = drive(&cold, &long_prompt);
    // Shared-prefix admission: the prefix blocks are already resident.
    let mut warm_tail: Vec<u32> = system.clone();
    warm_tail.extend(toks(&mut rng, TAIL, vocab));
    let mut holder = Session::new(&cold, SpecDecConfig::default()).unwrap();
    holder.prefill(&long_prompt, &chunk_sizes(long_prompt.len(), CHUNK)).unwrap();
    let (ttft_shared_paged_ms, _) = drive(&cold, &warm_tail);
    drop(holder);

    // Fleet census: shared system prompt vs fully independent prompts.
    let shared_prompts: Vec<Vec<u32>> = (0..FLEET)
        .map(|_| {
            let mut p = system.clone();
            p.extend(toks(&mut rng, TAIL, vocab));
            p
        })
        .collect();
    let indep_prompts: Vec<Vec<u32>> =
        (0..FLEET).map(|_| toks(&mut rng, PREFIX + TAIL, vocab)).collect();
    let e_shared = Engine::synthetic();
    let (blocks_shared, aliased) = fleet_blocks(&e_shared, &shared_prompts);
    let e_indep = Engine::synthetic();
    let (blocks_indep, _) = fleet_blocks(&e_indep, &indep_prompts);
    assert!(
        blocks_shared < blocks_indep,
        "shared-prefix fleet must use fewer blocks ({blocks_shared} vs {blocks_indep})"
    );
    assert!(aliased > 0, "shared system prompt produced no aliased blocks");

    // Memory accounting.  Dense reservation: three max_seq × hidden f32
    // tensors per session, allocated up front.  Paged: measured census.
    let block_bytes = (kv.block_tokens * spec.hidden * 4) as f64;
    let dense_bytes = (3 * spec.max_seq * spec.hidden * 4) as f64;
    let paged_bytes = blocks_indep as f64 * block_bytes / FLEET as f64;
    let shared_bytes = blocks_shared as f64 * block_bytes / FLEET as f64;
    let gb = 1e9;
    let per_gb = |b: f64| gb / b;
    assert!(
        per_gb(paged_bytes) > per_gb(dense_bytes),
        "paged sessions/GB must beat the dense reservation"
    );

    println!(
        "memory:  dense {:>8.0} B/session ({:>6.0}/GB)   paged {:>8.0} B ({:>6.0}/GB)   \
         shared-prefix {:>8.0} B ({:>6.0}/GB, {} aliased blocks)",
        dense_bytes,
        per_gb(dense_bytes),
        paged_bytes,
        per_gb(paged_bytes),
        shared_bytes,
        per_gb(shared_bytes),
        aliased
    );
    println!(
        "ttft:    dense shim {ttft_long_dense_ms:>7.2} ms   paged cold \
         {ttft_long_paged_ms:>7.2} ms   paged shared-prefix {ttft_shared_paged_ms:>7.2} ms \
         ({PREFIX}-token system prompt)"
    );
    println!("probe:   dense shim {ttft_dense_ms:.2} ms TTFT, streams byte-identical");

    let out = obj(vec![
        ("block_tokens", Value::Num(kv.block_tokens as f64)),
        ("kv_blocks", Value::Num(kv.kv_blocks as f64)),
        ("hidden", Value::Num(spec.hidden as f64)),
        ("max_seq", Value::Num(spec.max_seq as f64)),
        ("fleet", Value::Num(FLEET as f64)),
        ("prefix_tokens", Value::Num(PREFIX as f64)),
        ("dense_bytes_per_session", Value::Num(dense_bytes)),
        ("sessions_per_gb_dense", Value::Num(per_gb(dense_bytes))),
        ("paged_bytes_per_session", Value::Num(paged_bytes)),
        ("sessions_per_gb_paged", Value::Num(per_gb(paged_bytes))),
        ("shared_bytes_per_session", Value::Num(shared_bytes)),
        ("sessions_per_gb_paged_shared", Value::Num(per_gb(shared_bytes))),
        ("fleet_blocks_independent", Value::Num(blocks_indep as f64)),
        ("fleet_blocks_shared", Value::Num(blocks_shared as f64)),
        ("aliased_blocks", Value::Num(aliased as f64)),
        ("ttft_dense_ms", Value::Num(ttft_long_dense_ms)),
        ("ttft_paged_ms", Value::Num(ttft_long_paged_ms)),
        ("ttft_paged_shared_ms", Value::Num(ttft_shared_paged_ms)),
    ]);
    let p = write_json("BENCH_kv", &out);
    println!("wrote {}", p.display());
}
