//! Figs. 11–12 — TTFT/TBT vs the server's pipeline length P ∈ {1,2,4,8}.
//!
//! Paper shape: all frameworks improve with P (shorter per-stage time →
//! less admission waiting); HAT stays lowest everywhere; at P=1 the
//! baselines blow up (request accumulation) while HAT degrades gracefully.

use hat::config::{Dataset, ExperimentConfig, Framework};
use hat::frameworks::run_experiment;
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn main() {
    let profile = SdProfile::load_or_default(&Default::default(), 4);
    let mut rows = Vec::new();
    for (dataset, rate) in [(Dataset::SpecBench, 4.0), (Dataset::CnnDm, 2.0)] {
        section(&format!(
            "Fig {}: {} (rate {rate}/s)",
            if dataset == Dataset::SpecBench { 11 } else { 12 },
            dataset.name()
        ));
        println!("{:>4} {:>11} {:>11} {:>11} {:>11}   metric", "P", "HAT", "U-Sarathi", "U-Medusa", "U-shape");
        let mut hat_by_p = Vec::new();
        for p in [1usize, 2, 4, 8] {
            let mut cells = Vec::new();
            for fw in Framework::all() {
                let mut cfg = ExperimentConfig::preset(fw, dataset);
                cfg.cloud.pipeline_len = p;
                cfg.workload.rate = rate;
                cfg.workload.n_requests = 200;
                let s = run_experiment(&cfg, &profile).summary();
                cells.push((s.ttft_mean_ms, s.tbt_mean_ms));
                rows.push(obj(vec![
                    ("dataset", Value::Str(dataset.name().into())),
                    ("framework", Value::Str(fw.name().into())),
                    ("pipeline", Value::Num(p as f64)),
                    ("ttft_ms", Value::Num(s.ttft_mean_ms)),
                    ("tbt_ms", Value::Num(s.tbt_mean_ms)),
                ]));
            }
            println!(
                "{p:>4} {:>11.1} {:>11.1} {:>11.1} {:>11.1}   TTFT(ms)",
                cells[0].0, cells[1].0, cells[2].0, cells[3].0
            );
            println!(
                "{:>4} {:>11.1} {:>11.1} {:>11.1} {:>11.1}   TBT(ms)",
                "", cells[0].1, cells[1].1, cells[2].1, cells[3].1
            );
            hat_by_p.push(cells[0]);
            // HAT lowest at every P.
            for (i, &(ttft, tbt)) in cells.iter().enumerate().skip(1) {
                assert!(cells[0].0 <= ttft * 1.02, "P={p}: HAT TTFT vs {}", Framework::all()[i].name());
                assert!(cells[0].1 <= tbt * 1.02, "P={p}: HAT TBT vs {}", Framework::all()[i].name());
            }
        }
        // Longer pipelines help (TBT non-increasing from P=1 to P=8).
        assert!(
            hat_by_p.last().unwrap().1 <= hat_by_p[0].1 * 1.05,
            "HAT TBT should not grow with P"
        );
    }
    let p = write_json("fig11_12_pipeline", &Value::Arr(rows));
    println!("\nwrote {}", p.display());
}
