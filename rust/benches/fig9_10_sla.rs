//! Figs. 9–10 — SLA compliance CDFs at pipeline length P=1.
//!
//! Prefill SLA: delay per 128 prompt tokens; decode SLA: delay per 10
//! generated tokens (paper §4.2).  We print compliance at an SLA grid and
//! the SLA each framework needs for 50% / 90% compliance ("50% of requests
//! in HAT meet a decode SLA of X ms").
//!
//! Paper shape: HAT reaches any given compliance rate at the tightest SLA.

use hat::config::{Dataset, ExperimentConfig, Framework};
use hat::frameworks::run_experiment;
use hat::metrics::Recorder;
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn main() {
    let profile = SdProfile::load_or_default(&Default::default(), 4);
    let mut rows = Vec::new();
    for (dataset, rate) in [(Dataset::SpecBench, 3.0), (Dataset::CnnDm, 1.5)] {
        section(&format!("Figs 9-10: SLA compliance, {} (P=1, rate {rate}/s)", dataset.name()));
        let mut samples = Vec::new();
        for fw in Framework::all() {
            let mut cfg = ExperimentConfig::preset(fw, dataset);
            cfg.cloud.pipeline_len = 1;
            cfg.workload.rate = rate;
            cfg.workload.n_requests = 200;
            let rec = run_experiment(&cfg, &profile);
            samples.push((fw, rec.prefill_sla_sample(), rec.decode_sla_sample()));
        }

        for (label, idx) in [("prefill (per 128 prompt tokens)", 1usize), ("decode (per 10 tokens)", 2)] {
            println!("\n-- {label} --");
            print!("{:<12}", "SLA(ms)");
            for (fw, _, _) in &samples {
                print!(" {:>10}", fw.name());
            }
            println!();
            let grid: Vec<f64> = if idx == 1 {
                vec![200.0, 300.0, 400.0, 600.0, 900.0, 1400.0]
            } else {
                vec![300.0, 450.0, 600.0, 900.0, 1400.0, 2000.0]
            };
            for &sla in &grid {
                print!("{sla:<12.0}");
                for (_, pre, dec) in &samples {
                    let s = if idx == 1 { pre } else { dec };
                    print!(" {:>9.1}%", 100.0 * Recorder::compliance(s, sla));
                }
                println!();
            }
            for q in [0.5, 0.9] {
                print!("{:<12}", format!("SLA@{:.0}%", q * 100.0));
                for (_, pre, dec) in &samples {
                    let s = if idx == 1 { pre } else { dec };
                    print!(" {:>10.1}", Recorder::sla_at_quantile(s, q));
                }
                println!();
            }
        }

        // Paper shape: HAT needs the tightest decode SLA for 50% compliance.
        let hat_q50 = Recorder::sla_at_quantile(&samples[0].2, 0.5);
        for (fw, _, dec) in samples.iter().skip(1) {
            let q50 = Recorder::sla_at_quantile(dec, 0.5);
            assert!(
                hat_q50 <= q50 * 1.05,
                "{}: decode SLA@50% {q50:.0} tighter than HAT {hat_q50:.0}",
                fw.name()
            );
        }
        for (fw, pre, dec) in &samples {
            rows.push(obj(vec![
                ("dataset", Value::Str(dataset.name().into())),
                ("framework", Value::Str(fw.name().into())),
                ("prefill_sla_p50", Value::Num(Recorder::sla_at_quantile(pre, 0.5))),
                ("prefill_sla_p90", Value::Num(Recorder::sla_at_quantile(pre, 0.9))),
                ("decode_sla_p50", Value::Num(Recorder::sla_at_quantile(dec, 0.5))),
                ("decode_sla_p90", Value::Num(Recorder::sla_at_quantile(dec, 0.9))),
            ]));
        }
    }
    let p = write_json("fig9_10_sla", &Value::Arr(rows));
    println!("\nwrote {}", p.display());
}
