//! Fig. 1 — the paper's preliminary experiments (§2.3), regenerated from
//! the calibrated testbed models (Vicuna-7B / A6000 / WiFi scale):
//!
//! (a) TTFT/TBT component breakdown per framework (cloud-based, SD,
//!     U-shape) for a 128-token prompt;
//! (b) U-shape TTFT vs prompt length 128→2k with component shares;
//! (c) in-cloud computation delay vs prefill prompt length when batched
//!     with 9 decode requests;
//! (d) prompt-chunking effect on total computation delay and TTFT for a
//!     2k prompt over 64 consecutive steps.

use hat::config::{Dataset, GModel};
use hat::devices::DeviceClass;
use hat::net::{hidden_state_bytes, token_bytes};
use hat::util::json::{arr_f64, obj, Value};
use hat::util::report::{section, write_json};

const UP_BPMS: f64 = 7_500.0; // 7.5 MB/s uplink
const DOWN_BPMS: f64 = 12_500.0;
const LAT_MS: f64 = 2.5;

struct Parts {
    local: f64,
    comm: f64,
    cloud: f64,
}

impl Parts {
    fn total(&self) -> f64 {
        self.local + self.comm + self.cloud
    }
}

fn main() {
    let g = GModel::for_dataset(Dataset::SpecBench);
    let hidden = Dataset::SpecBench.paper_hidden();
    let dev = DeviceClass::AgxOrin; // the preliminary testbed used Orin
    let gamma = dev.draft_ms_per_token(0);
    let up = |bytes: usize| LAT_MS + bytes as f64 / UP_BPMS;
    let down = |bytes: usize| LAT_MS + bytes as f64 / DOWN_BPMS;

    // ---------- (a) framework breakdown, 128-token prompt ------------------
    section("Fig 1(a): TTFT/TBT breakdown, 128-token prompt");
    let p = 128usize;
    // cloud-based: raw tokens up, full model in cloud, token back.
    let cloud_ttft = Parts {
        local: 0.5,
        comm: up(token_bytes(p)) + down(token_bytes(1)),
        cloud: g.eval(p as f64),
    };
    let cloud_tbt = Parts { local: 0.1, comm: 0.0, cloud: g.eval(1.0) };
    // SD (token-level, non-private): draft k tokens locally, verify once;
    // per-token costs divide by the accept length.
    let k = 2.5f64;
    let sd_tbt = Parts {
        local: gamma, // k+1 draft steps per k+1 emitted tokens
        comm: (up(token_bytes(3)) + down(token_bytes(3))) / (k + 1.0),
        cloud: g.eval(k + 1.0) / (k + 1.0),
    };
    // U-shape: hidden states cross the boundary every step.
    let ushape_ttft = Parts {
        local: dev.prefill_ms(0, p),
        comm: up(hidden_state_bytes(p, hidden)) + down(hidden_state_bytes(1, hidden)),
        cloud: g.eval(p as f64),
    };
    let ushape_tbt = Parts {
        local: dev.prefill_ms(0, 1) + dev.head_ms(0, 1),
        comm: up(hidden_state_bytes(1, hidden)) + down(hidden_state_bytes(1, hidden)),
        cloud: g.eval(1.0),
    };
    println!(
        "{:<12} {:>10} {:>8} {:>10} {:>8}",
        "framework", "TTFT(ms)", "comm%", "TBT(ms)", "comm%"
    );
    let rows = [
        ("cloud", &cloud_ttft, &cloud_tbt),
        ("SD", &cloud_ttft, &sd_tbt),
        ("U-shape", &ushape_ttft, &ushape_tbt),
    ];
    for (name, t, b) in rows {
        println!(
            "{:<12} {:>10.1} {:>7.0}% {:>10.1} {:>7.0}%",
            name,
            t.total(),
            100.0 * t.comm / t.total(),
            b.total(),
            100.0 * b.comm / b.total()
        );
    }
    // Paper shape: SD fastest TBT; U-shape slowest with comm-heavy TTFT.
    assert!(sd_tbt.total() < cloud_tbt.total());
    assert!(ushape_ttft.total() > cloud_ttft.total());

    // ---------- (b) U-shape TTFT vs prompt length ---------------------------
    section("Fig 1(b): U-shape TTFT vs prompt length");
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>10} {:>7}",
        "prompt", "TTFT(ms)", "local", "cloud", "comm", "comm%"
    );
    let mut lens = vec![];
    let mut ttfts = vec![];
    let mut comm_shares = vec![];
    for plen in [128usize, 256, 512, 1024, 2048] {
        let local = DeviceClass::AgxOrin.prefill_ms(0, plen);
        let comm = up(hidden_state_bytes(plen, hidden)) + down(hidden_state_bytes(1, hidden));
        let cloud = g.eval(plen as f64);
        let ttft = local + comm + cloud;
        println!(
            "{plen:>8} {ttft:>10.1} {local:>10.1} {cloud:>10.1} {comm:>10.1} {:>6.1}%",
            100.0 * comm / ttft
        );
        lens.push(plen as f64);
        ttfts.push(ttft);
        comm_shares.push(comm / ttft);
    }
    // Paper: comm ≈ 89.6% of TTFT at 2k tokens; TTFT grows ~linearly.
    assert!(comm_shares[4] > 0.7, "comm should dominate at 2k tokens");
    assert!(ttfts[4] / ttfts[0] > 5.0, "TTFT must grow ~linearly with prompt");

    // ---------- (c) in-cloud delay vs prefill length in a mixed batch ------
    section("Fig 1(c): in-cloud delay, batch = 1 prefill + 9 decode");
    println!("{:>8} {:>12} {:>10}", "prefill", "delay(ms)", "vs 1-tok");
    let base = g.eval(10.0);
    let mut fig1c = vec![];
    for plen in [1usize, 32, 128, 512, 1024, 2048] {
        let d = g.eval((plen + 9) as f64);
        println!("{plen:>8} {d:>12.1} {:>9.2}x", d / base);
        fig1c.push(d);
    }
    assert!((fig1c[1] / fig1c[0] - 1.0) < 0.15, "32-tok batch should be cheap");
    assert!(fig1c[5] / fig1c[3] > 2.5, "post-saturation linear growth");

    // ---------- (d) chunking a 2k prompt over 64 steps ----------------------
    section("Fig 1(d): chunking effect, 2k prompt, 64-step window");
    let plen = 2048usize;
    let steps = 64usize;
    let total_unchunked = g.eval((plen + 9) as f64) + (steps - 1) as f64 * g.eval(9.0);
    let ttft_unchunked = up(hidden_state_bytes(plen, hidden)) + g.eval(plen as f64);
    println!(
        "{:>8} {:>14} {:>14} {:>12}",
        "chunk", "Δtotal(ms)", "TTFT(ms)", "TTFT vs none"
    );
    let mut chunks_out = vec![];
    let mut last_ratio = 0.0;
    for chunk in [32usize, 64, 128, 256, 512, 1024, 2048] {
        let n_chunks = plen.div_ceil(chunk);
        let mixed_steps = n_chunks.min(steps);
        let total = mixed_steps as f64 * g.eval((chunk + 9) as f64)
            + (steps - mixed_steps) as f64 * g.eval(9.0);
        // U-Sarathi-style server chunking (no upload overlap): the full
        // prompt uploads first, then chunks run across consecutive steps —
        // this is what Fig. 1(d) measured (the motivation *for* HAT's
        // device-side overlap).
        let ttft = up(hidden_state_bytes(plen, hidden))
            + n_chunks as f64 * g.eval((chunk + 9) as f64);
        println!(
            "{chunk:>8} {:>14.1} {ttft:>14.1} {:>11.2}x",
            total_unchunked - total,
            ttft / ttft_unchunked
        );
        chunks_out.push(obj(vec![
            ("chunk", Value::Num(chunk as f64)),
            ("total_reduction_ms", Value::Num(total_unchunked - total)),
            ("ttft_ms", Value::Num(ttft)),
        ]));
        last_ratio = ttft / ttft_unchunked;
    }
    // Paper: smaller chunks reduce total delay but inflate TTFT sharply.
    assert!(last_ratio <= 1.2, "unchunked ratio should be ~1");

    let out = obj(vec![
        ("fig1b_prompt_lens", arr_f64(&lens)),
        ("fig1b_ttft_ms", arr_f64(&ttfts)),
        ("fig1b_comm_share", arr_f64(&comm_shares)),
        ("fig1c_delay_ms", arr_f64(&fig1c)),
        ("fig1d", Value::Arr(chunks_out)),
    ]);
    let p = write_json("fig1_prelim", &out);
    println!("\nwrote {}", p.display());
}
