//! Fig. 8 — per-GPU computation delay (mean ± std) per framework, both
//! datasets, at the Fig. 6/7 operating point.
//!
//! Paper shape: HAT and U-Sarathi achieve low *and stable* per-GPU delay
//! (chunking bounds the token size of any step); U-Medusa and U-shape show
//! higher means and much larger standard deviations (long prompts saturate
//! whole steps).

use hat::config::{Dataset, ExperimentConfig, Framework};
use hat::frameworks::run_experiment;
use hat::specdec::profile::SdProfile;
use hat::util::json::{obj, Value};
use hat::util::report::{section, write_json};

fn main() {
    let profile = SdProfile::load_or_default(&Default::default(), 4);
    let mut rows = Vec::new();
    for dataset in [Dataset::SpecBench, Dataset::CnnDm] {
        section(&format!("Fig 8: per-GPU computation delay, {}", dataset.name()));
        println!("{:<12} {:>10} {:>10} {:>8}", "framework", "mean(ms)", "std(ms)", "steps");
        let mut stats = Vec::new();
        for fw in Framework::all() {
            let mut cfg = ExperimentConfig::preset(fw, dataset);
            cfg.workload.n_requests = 250;
            let rec = run_experiment(&cfg, &profile);
            let (mean, std) = rec.gpu_delay_stats();
            println!("{:<12} {:>10.2} {:>10.2} {:>8}", fw.name(), mean, std, rec.gpu_step_delays.len());
            stats.push((fw, mean, std));
            rows.push(obj(vec![
                ("dataset", Value::Str(dataset.name().into())),
                ("framework", Value::Str(fw.name().into())),
                ("gpu_mean_ms", Value::Num(mean)),
                ("gpu_std_ms", Value::Num(std)),
            ]));
        }
        // Paper shape: chunking frameworks (HAT, U-Sarathi) have far lower
        // delay variance than the unchunked ones (U-Medusa, U-shape).
        let std_of = |f: Framework| stats.iter().find(|(fw, _, _)| *fw == f).unwrap().2;
        assert!(std_of(Framework::Hat) < std_of(Framework::UShape) * 0.6);
        assert!(std_of(Framework::USarathi) < std_of(Framework::UMedusa) * 0.6);
    }
    let p = write_json("fig8_compdelay", &Value::Arr(rows));
    println!("\nwrote {}", p.display());
}
