//! End-to-end driver (the repo's headline validation run): serve a real
//! small model under a batched multi-device workload and report the
//! paper's metrics.
//!
//! Phase 1 — REAL: load the model (AOT artifacts when built, otherwise
//! the reference backend's synthetic model), run batched requests
//! back-to-back through the full HAT protocol, measuring wall-clock
//! latency/throughput and the SD round shapes.
//!
//! Phase 2 — FLEET: replay the measured round shapes through the
//! calibrated 30-device testbed simulator at the paper's operating point
//! (Fig. 6: SpecBench, P=4, 6 req/s) for HAT and all three baselines.
//!
//! The combination proves all layers compose: Pallas kernels → split
//! transformer artifacts → PJRT runtime → SD protocol → coordinator.
//! Results are recorded in EXPERIMENTS.md.

use hat::config::{Dataset, ExperimentConfig, Framework, SpecDecConfig};
use hat::engine::Engine;
use hat::frameworks::run_experiment;
use hat::metrics::RunSummary;
use hat::runtime::ArtifactRegistry;
use hat::server::generate;
use hat::specdec::profile::SdProfile;
use hat::util::rng::Rng;
use hat::util::stats::Summary;
use hat::workload::PromptPool;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactRegistry::default_dir();
    let engine = Engine::load_default()?;
    println!(
        "=== Phase 1: real batched serving ({} backend) ===",
        engine.reg.backend_name()
    );
    let pool = match PromptPool::load(&dir.join(&engine.reg.manifest().prompts_file)) {
        Ok(p) => p,
        Err(_) => PromptPool::synthetic(engine.spec().vocab, 16, 256, 11),
    };
    let mut rng = Rng::new(11);
    let n_requests = 12;
    let gen_len = 32;
    let mut latencies = Vec::new();
    let mut tokens_out = 0usize;
    let t_all = hat::util::clock::now();
    for i in 0..n_requests {
        let plen = 48 + (i * 37) % 128;
        let prompt = pool.sample(plen, &mut rng);
        let t0 = hat::util::clock::now();
        let gen = generate(&engine, &prompt, gen_len, &SpecDecConfig::default())?;
        let dt = t0.elapsed().as_secs_f64();
        latencies.push(dt * 1e3);
        tokens_out += gen.tokens.len();
        if i < 3 {
            println!(
                "  req {i}: prompt {plen} tok -> {} tok in {:.0} ms ({} rounds, accept {:.3})",
                gen.tokens.len(),
                dt * 1e3,
                gen.rounds,
                gen.accept_rate()
            );
        }
    }
    let wall = t_all.elapsed().as_secs_f64();
    let lat = Summary::of(&latencies);
    println!(
        "served {n_requests} requests, {tokens_out} tokens in {wall:.1}s — \
         {:.1} tok/s, latency p50 {:.0} ms p90 {:.0} ms (host CPU, real numerics)",
        tokens_out as f64 / wall,
        lat.p50,
        lat.p90
    );

    // Measure SD round shapes on the same engine for the simulator.
    println!("\nmeasuring SD round shapes (real engine)...");
    let profile = SdProfile::measure(&engine, &pool, &SpecDecConfig::default(), 6, 40, 42)?;
    println!(
        "  HAT accept length {:.2} ({} rounds) | U-Medusa {:.2} ({} rounds)",
        SdProfile::accept_length(&profile.hat),
        profile.hat.len(),
        SdProfile::accept_length(&profile.medusa),
        profile.medusa.len()
    );

    // ---------------- Phase 2: testbed-scale fleet simulation -------------
    println!("\n=== Phase 2: 30-device testbed simulation (Fig. 6 operating point) ===");
    println!("{}", RunSummary::header());
    let mut rows = Vec::new();
    for fw in Framework::all() {
        let mut cfg = ExperimentConfig::preset(fw, Dataset::SpecBench);
        cfg.workload.n_requests = 300;
        let s = run_experiment(&cfg, &profile).summary();
        println!("{}", s.row(fw.name()));
        rows.push((fw, s));
    }
    let hat = &rows[0].1;
    let ushape = &rows[3].1;
    println!(
        "\nHAT vs U-shape: TTFT -{:.0}%, TBT -{:.0}%  (paper: -41–54% TTFT, -41–77% TBT)",
        100.0 * (1.0 - hat.ttft_mean_ms / ushape.ttft_mean_ms),
        100.0 * (1.0 - hat.tbt_mean_ms / ushape.tbt_mean_ms)
    );
    Ok(())
}
