//! Quickstart: one request through the full HAT protocol, for real.
//!
//! Loads the AOT artifacts when built (`make artifacts`), otherwise the
//! reference backend's synthetic model; picks an in-distribution prompt,
//! then runs chunked prefill + speculative decoding with parallel
//! drafting — the same code path `hat serve` exposes over TCP.
//!
//!     cargo run --release --example quickstart

use hat::config::SpecDecConfig;
use hat::engine::Engine;
use hat::runtime::ArtifactRegistry;
use hat::specdec::{chunk_sizes, Session};
use hat::util::rng::Rng;
use hat::workload::PromptPool;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactRegistry::default_dir();
    let t0 = hat::util::clock::now();
    let engine = Engine::load_default()?;
    println!(
        "loaded {} backend ({} artifacts, {} LLM params, Λ {} params) in {:.1}s",
        engine.reg.backend_name(),
        engine.reg.manifest().artifacts.len(),
        engine.reg.manifest().train_meta.lm_params,
        engine.reg.manifest().train_meta.adapter_params,
        t0.elapsed().as_secs_f64()
    );

    let pool = match PromptPool::load(&dir.join(&engine.reg.manifest().prompts_file)) {
        Ok(p) => p,
        Err(_) => PromptPool::synthetic(engine.spec().vocab, 16, 256, 7),
    };
    let mut rng = Rng::new(7);
    let prompt = pool.sample(96, &mut rng);
    println!("prompt: {} tokens", prompt.len());

    let mut session = Session::new(&engine, SpecDecConfig::default())?;
    // Dynamic chunking would ask the cloud's Eq. 3 optimizer; standalone we
    // chunk at 32 (what the optimizer picks for a mid-load cloud).
    let chunks = chunk_sizes(prompt.len(), 32);
    let t0 = hat::util::clock::now();
    let first = session.prefill(&prompt, &chunks)?;
    println!(
        "prefill: {} chunks -> first token {first} in {:.0} ms (real CPU time)",
        chunks.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );

    let mut generated = vec![first];
    let mut rounds = 0;
    let mut pd_hits = 0;
    let t0 = hat::util::clock::now();
    while generated.len() < 48 {
        let r = session.hat_round(true, 4)?;
        generated.extend_from_slice(&r.emitted);
        rounds += 1;
        pd_hits += r.pd_hit as usize;
    }
    let dt = t0.elapsed().as_secs_f64();
    generated.truncate(48);
    println!("generated {} tokens: {:?}...", generated.len(), &generated[..12.min(generated.len())]);
    println!(
        "decode: {rounds} verification rounds, accept length {:.2}, {} parallel-drafting hits",
        (generated.len() - 1) as f64 / rounds as f64,
        pd_hits
    );
    println!(
        "real CPU decode time {:.2}s ({:.0} ms/token on this host; testbed-scale \
         latency comes from the fleet simulator — see `hat simulate`)",
        dt,
        dt * 1e3 / generated.len() as f64
    );
    Ok(())
}
