//! Privacy audit: what actually crosses the device-cloud boundary in HAT,
//! and how hard is it to invert?
//!
//! The U-shaped split exists so raw tokens never leave the device (§2.2).
//! This example quantifies that on the real artifacts:
//!
//! 1. payload inventory — the only uplink payloads are f32 hidden-state
//!    matrices (per-token wire cost A = hidden×4 B here), never token ids;
//! 2. inversion attack — a curious cloud tries the classic
//!    nearest-embedding attack on the uploaded shallow hidden states and
//!    on raw embeddings (what a split *before* layer 1 would leak):
//!    embeddings invert ~100%, post-layer-1 states far less.

use hat::engine::Engine;
use hat::runtime::ArtifactRegistry;
use hat::util::rng::Rng;
use hat::workload::PromptPool;

fn main() -> anyhow::Result<()> {
    let dir = ArtifactRegistry::default_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts not found — run `make artifacts` first"
    );
    let engine = Engine::load(&dir)?;
    // The audit's numbers are only meaningful on the *trained* model: the
    // default reference backend would run the attack against seeded
    // pseudo-weights and report noise.  Fail fast instead.
    anyhow::ensure!(
        engine.reg.backend_name() == "pjrt",
        "privacy_audit needs the trained model: build with --features pjrt and set HAT_BACKEND=pjrt"
    );
    let spec = engine.spec().clone();
    let pool = PromptPool::load(&dir.join("prompts.bin"))?;
    let mut rng = Rng::new(5);
    let prompt = pool.sample(128, &mut rng);

    // What the device uploads in prefill: shallow hidden states.
    let mut dev = engine.new_device_stream();
    let hidden = engine.device_input(&mut dev, &prompt)?;
    println!("=== payload inventory (prefill, {}-token prompt) ===", prompt.len());
    println!(
        "uplink payload: f32[{}, {}] hidden states = {} bytes ({} B/token)",
        prompt.len(),
        spec.hidden,
        hidden.len() * 4,
        spec.hidden * 4
    );
    println!("token ids on the wire: 0 (tokens never leave the device)\n");

    // The attack: cloud knows the public embedding table; tries nearest
    // neighbour against (a) raw embeddings, (b) the actual upload.
    let embed = engine
        .reg
        .weight("embed")
        .map(|t| t.data)
        .ok_or_else(|| anyhow::anyhow!("embed weights missing"))?;
    let v = spec.vocab;
    let h = spec.hidden;

    let nearest = |row: &[f32]| -> u32 {
        let mut best = 0usize;
        let mut best_d = f32::MAX;
        for t in 0..v {
            let e = &embed[t * h..(t + 1) * h];
            let d: f32 = row.iter().zip(e).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < best_d {
                best_d = d;
                best = t;
            }
        }
        best as u32
    };

    let recover_rate = |rows: &[f32]| -> f64 {
        let n = rows.len() / h;
        let hits = (0..n)
            .filter(|&i| nearest(&rows[i * h..(i + 1) * h]) == prompt[i])
            .count();
        hits as f64 / n as f64
    };

    // (a) raw embeddings — what a layer-0 split would upload.
    let raw: Vec<f32> = prompt.iter().flat_map(|&t| embed[t as usize * h..(t as usize + 1) * h].to_vec()).collect();
    let r_raw = recover_rate(&raw);
    // (b) the actual upload (after m decoder layers).
    let r_upload = recover_rate(&hidden);

    println!("=== nearest-embedding inversion attack ===");
    println!("raw embeddings (split before layer 1):  {:>5.1}% tokens recovered", r_raw * 100.0);
    println!("HAT upload (after {} device layer(s)):  {:>5.1}% tokens recovered", spec.shallow_layers, r_upload * 100.0);
    anyhow::ensure!(r_raw > 0.95, "embeddings should invert trivially");
    anyhow::ensure!(
        r_upload < r_raw * 0.6,
        "the decoder layer should substantially obscure token identity"
    );
    println!(
        "\nthe on-device decoder layer{} cut naive inversion by {:.0}% — and the\n\
         output submodel keeps generated tokens device-side symmetrically.",
        if spec.shallow_layers > 1 { "s" } else { "" },
        100.0 * (1.0 - r_upload / r_raw)
    );
    Ok(())
}
