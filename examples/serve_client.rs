//! Serve + client demo: starts the TCP serving mode in-process, connects
//! as a client, and issues GENERATE/STATS requests over the line protocol.
//!
//!     cargo run --release --example serve_client
//!
//! Or point it at an already-running `hat serve`:
//!
//!     cargo run --release --example serve_client -- --addr 127.0.0.1:7071

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use hat::cli::parse_flags;
use hat::runtime::ArtifactRegistry;
use hat::util::rng::Rng;
use hat::workload::PromptPool;

fn main() -> anyhow::Result<()> {
    let flags = parse_flags(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let addr = match flags.get("addr") {
        Some(a) => a.to_string(),
        None => {
            // Self-contained: run the server on a background thread.
            let addr = "127.0.0.1:7171".to_string();
            let a2 = addr.clone();
            std::thread::spawn(move || {
                let f = parse_flags(
                    ["--addr", &a2, "--max-conns", "2"].iter().map(|s| s.to_string()),
                )
                .unwrap();
                if let Err(e) = hat::server::cmd_serve(&f) {
                    eprintln!("server: {e}");
                }
            });
            addr
        }
    };

    // Wait for the engine to come up (artifact compilation takes seconds).
    let mut stream = None;
    for _ in 0..600 {
        match TcpStream::connect(&addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(100)),
        }
    }
    let stream = stream.ok_or_else(|| anyhow::anyhow!("server at {addr} never came up"))?;
    println!("connected to {addr}");
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;

    let dir = ArtifactRegistry::default_dir();
    // Token ids < 256 are valid for both the synthetic reference model
    // (vocab 256) and the trained artifacts (vocab 512).
    let pool = match PromptPool::load(&dir.join("prompts.bin")) {
        Ok(p) => p,
        Err(_) => PromptPool::synthetic(256, 8, 160, 3),
    };
    let mut rng = Rng::new(3);

    for (i, plen) in [40usize, 80, 120].iter().enumerate() {
        let prompt = pool.sample(*plen, &mut rng);
        let words: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        writeln!(stream, "GENERATE 24 {}", words.join(" "))?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let short = if line.len() > 110 { &line[..110] } else { line.trim_end() };
        println!("req {i} (prompt {plen} tok): {short}...");
        anyhow::ensure!(line.starts_with("OK"), "server error: {line}");
    }

    writeln!(stream, "STATS")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    println!("server stats: {}", line.trim_end());

    writeln!(stream, "QUIT")?;
    Ok(())
}
