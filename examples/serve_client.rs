//! Serve + client demo: starts the TCP serving mode in-process, connects
//! as a client, and issues GENERATE/STATS requests over the line protocol
//! — including two *concurrent* connections to show the
//! continuous-batching scheduler interleaving sessions.
//!
//!     cargo run --release --example serve_client
//!
//! Or point it at an already-running `hat serve`:
//!
//!     cargo run --release --example serve_client -- --addr 127.0.0.1:7071

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use hat::cli::parse_flags;
use hat::runtime::ArtifactRegistry;
use hat::util::rng::Rng;
use hat::workload::PromptPool;

fn request(addr: &str, max_new: usize, prompt: &[u32]) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let words: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    writeln!(stream, "GENERATE {max_new} {}", words.join(" "))?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    writeln!(stream, "QUIT")?;
    anyhow::ensure!(line.starts_with("OK"), "server error: {line}");
    Ok(line.trim_end().to_string())
}

fn main() -> anyhow::Result<()> {
    let flags = parse_flags(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let addr = match flags.get("addr") {
        Some(a) => a.to_string(),
        None => {
            // Self-contained: run the server on a background thread.
            let addr = "127.0.0.1:7171".to_string();
            let a2 = addr.clone();
            std::thread::spawn(move || {
                let f = parse_flags(
                    // 1 probe + 3 serial + 2 concurrent + 1 stats connection
                    ["--addr", &a2, "--max-conns", "8", "--max-sessions", "4"]
                        .iter()
                        .map(|s| s.to_string()),
                )
                .unwrap();
                if let Err(e) = hat::server::cmd_serve(&f) {
                    eprintln!("server: {e}");
                }
            });
            addr
        }
    };

    // Wait for the background server thread to bind its listener.  The
    // engine loads before the accept loop starts, so once connect
    // succeeds, early requests simply queue in the TCP backlog.
    let mut up = false;
    for _ in 0..600 {
        if TcpStream::connect(&addr).is_ok() {
            up = true;
            break;
        }
        hat::util::clock::sleep(std::time::Duration::from_millis(100));
    }
    anyhow::ensure!(up, "server at {addr} never came up");
    println!("connected to {addr}");

    let dir = ArtifactRegistry::default_dir();
    // Token ids < 256 are valid for both the synthetic reference model
    // (vocab 256) and the trained artifacts (vocab 512).
    let pool = match PromptPool::load(&dir.join("prompts.bin")) {
        Ok(p) => p,
        Err(_) => PromptPool::synthetic(256, 8, 160, 3),
    };
    let mut rng = Rng::new(3);

    for (i, plen) in [40usize, 80, 120].iter().enumerate() {
        let prompt = pool.sample(*plen, &mut rng);
        let line = request(&addr, 24, &prompt)?;
        let short = if line.len() > 110 { &line[..110] } else { &line[..] };
        println!("req {i} (prompt {plen} tok): {short}...");
    }

    // Two concurrent connections: the scheduler interleaves their prefill
    // chunks and verify rounds in one engine worker.
    println!("issuing 2 concurrent GENERATEs...");
    let handles: Vec<_> = [64usize, 96]
        .iter()
        .map(|&plen| {
            let addr = addr.clone();
            let prompt = pool.sample(plen, &mut rng);
            std::thread::spawn(move || request(&addr, 24, &prompt))
        })
        .collect();
    for (i, h) in handles.into_iter().enumerate() {
        let line = h.join().expect("client thread panicked")?;
        let short = if line.len() > 110 { &line[..110] } else { &line[..] };
        println!("concurrent req {i}: {short}...");
    }

    let mut stream = TcpStream::connect(&addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    writeln!(stream, "STATS")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    println!("server stats: {}", line.trim_end());
    writeln!(stream, "QUIT")?;
    Ok(())
}
