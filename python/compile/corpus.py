"""Synthetic PCFG corpus for the tiny LM.

The paper trains/evaluates on ShareGPT + SpecBench/CNN-DM with Vicuna.
None of that is available offline, so we substitute a probabilistic
context-free grammar over a 512-token vocabulary (see DESIGN.md §3).  The
grammar is designed to mirror the statistical property speculative decoding
exploits in natural language: a mix of *highly predictable* tokens
(function words, punctuation, templated continuations — these are what the
SLM drafts successfully) and *contentful* low-predictability tokens (these
are where drafts get rejected).

Token map (vocab = 512):
    0            BOS
    1            EOS
    2..9         punctuation   (very high predictability)
    10..41       determiners / qualifiers (32)
    42..105      subjects (64)
    106..233     verbs (128)
    234..361     objects (128)
    362..425     adverbs (64)
    426..489     adjectives (64)
    490..511     connectives (22)
"""

from __future__ import annotations

import numpy as np

VOCAB = 512
BOS, EOS = 0, 1
PUNCT = list(range(2, 10))
DET = list(range(10, 42))
SUBJ = list(range(42, 106))
VERB = list(range(106, 234))
OBJ = list(range(234, 362))
ADV = list(range(362, 426))
ADJ = list(range(426, 490))
CONN = list(range(490, 512))


class CorpusGenerator:
    """Seeded PCFG sentence generator.

    Each "sentence" is  DET [ADJ] SUBJ VERB DET [ADJ] OBJ [ADV] PUNCT,
    optionally extended with CONN + another clause.  Crucially, several
    productions are *deterministic given the previous token* (e.g. each
    subject strongly prefers a small set of verbs; each verb selects its
    object class), so a well-trained draft model achieves a meaningful
    accept length, as in natural text.
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        # Deterministic-ish bigram preferences: map each subject to 4
        # preferred verbs, each verb to 4 preferred objects, each object to
        # 4 preferred adverbs.  Built from a fixed seed so python and any
        # other consumer agree.
        g = np.random.default_rng(1234)
        self.subj2verb = g.choice(VERB, size=(len(SUBJ), 4))
        self.verb2obj = g.choice(OBJ, size=(len(VERB), 4))
        self.obj2adv = g.choice(ADV, size=(len(OBJ), 4))

    def _pick(self, arr, p_first=0.7):
        """Pick arr[0] with prob p_first else uniform among the rest."""
        if self.rng.random() < p_first:
            return int(arr[0])
        return int(self.rng.choice(arr[1:]))

    def sentence(self) -> list[int]:
        toks: list[int] = []
        toks.append(int(self.rng.choice(DET)))
        if self.rng.random() < 0.3:
            toks.append(int(self.rng.choice(ADJ)))
        s = int(self.rng.choice(SUBJ))
        toks.append(s)
        v = self._pick(self.subj2verb[s - SUBJ[0]])
        toks.append(v)
        toks.append(int(self.rng.choice(DET)))
        if self.rng.random() < 0.2:
            toks.append(int(self.rng.choice(ADJ)))
        o = self._pick(self.verb2obj[v - VERB[0]])
        toks.append(o)
        if self.rng.random() < 0.5:
            toks.append(self._pick(self.obj2adv[o - OBJ[0]]))
        if self.rng.random() < 0.25:
            toks.append(int(self.rng.choice(CONN)))
            toks.extend(self.sentence())
            return toks
        toks.append(int(self.rng.choice(PUNCT[:2], p=[0.8, 0.2])))
        return toks

    def document(self, min_len: int, max_len: int | None = None) -> list[int]:
        """A BOS-prefixed token stream of at least ``min_len`` tokens."""
        max_len = max_len or min_len
        toks = [BOS]
        while len(toks) < min_len:
            toks.extend(self.sentence())
        return toks[:max_len] if max_len else toks

    def stream(self, n_tokens: int) -> np.ndarray:
        """A single contiguous training stream of exactly n_tokens tokens."""
        out: list[int] = [BOS]
        while len(out) < n_tokens:
            out.extend(self.sentence())
        return np.asarray(out[:n_tokens], dtype=np.int32)


def training_batches(seed: int, n_tokens: int, batch: int, seqlen: int):
    """Yield (inputs, targets) int32 arrays of shape [batch, seqlen] forever."""
    gen = CorpusGenerator(seed)
    data = gen.stream(n_tokens)
    rng = np.random.default_rng(seed + 1)
    n = len(data) - seqlen - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        x = np.stack([data[i : i + seqlen] for i in idx])
        y = np.stack([data[i + 1 : i + seqlen + 1] for i in idx])
        yield x, y


def sample_prompts(seed: int, lengths: list[int]) -> list[np.ndarray]:
    """Generate one in-distribution prompt per requested length."""
    gen = CorpusGenerator(seed)
    return [np.asarray(gen.document(l, l), dtype=np.int32) for l in lengths]
