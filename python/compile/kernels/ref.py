"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: python/tests/test_kernel.py sweeps
shapes/dtypes with hypothesis and asserts the Pallas kernels match these to
numerical tolerance.  They are also what the *training* path uses (the
Pallas kernels only run on the AOT inference path — pallas interpret mode
has no efficient autodiff).
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k_cache, v_cache, pos):
    """Cached causal multi-head attention.

    Args:
      q:        [T, nh, hd]  queries for T new tokens at absolute positions
                pos..pos+T-1.
      k_cache:  [S, nh, hd]  key cache; positions >= pos+T hold garbage.
      v_cache:  [S, nh, hd]  value cache.
      pos:      scalar int32, number of tokens already in the cache.

    Returns:
      [T, nh, hd] attention output.

    Key j is visible to query i iff j <= pos + i (causal over the absolute
    position), which also masks the garbage tail of the cache.
    """
    T, nh, hd = q.shape
    S = k_cache.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    # [nh, T, S]
    scores = jnp.einsum("tnh,snh->nts", q, k_cache) * scale
    qpos = pos + jnp.arange(T)[:, None]          # [T, 1]
    kpos = jnp.arange(S)[None, :]                # [1, S]
    mask = kpos <= qpos                          # [T, S]
    scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("nts,snh->tnh", probs, v_cache)


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU feed-forward: (silu(x @ w_gate) * (x @ w_up)) @ w_down.

    Args:
      x:       [T, H]
      w_gate:  [H, F]
      w_up:    [H, F]
      w_down:  [F, H]
    Returns:
      [T, H]
    """
    g = x @ w_gate
    u = x @ w_up
    act = g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u  # silu(g) * u
    return act @ w_down


def rmsnorm_ref(x, w, eps=1e-5):
    """RMSNorm over the last axis: x * w / rms(x)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jnp.reciprocal(jnp.sqrt(var + eps)) * w
