"""L1 Pallas kernels: cached causal flash-attention and fused SwiGLU.

These are the compute hot-spots of the middle submodel (the cloud side of
HAT) and of the on-device draft model.  They are written TPU-style:

- the attention kernel holds one head's query tile in VMEM and streams the
  KV cache through it in ``block_k``-sized tiles with a running
  (max, sum, acc) online-softmax state — the Pallas expression of the
  HBM↔VMEM schedule FlashAttention/FlashInfer implement with CUDA
  threadblocks (see DESIGN.md §4);
- block sizes are multiples of the head dim so q·kᵀ and p·v land on
  MXU-shaped matmuls;
- ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls, so the kernels lower to plain HLO through the interpreter.
  Real-TPU perf is *estimated* from VMEM footprint + MXU utilization in
  EXPERIMENTS.md §Perf.

Correctness oracle: ``kernels.ref`` (pure jnp), enforced by
python/tests/test_kernel.py under hypothesis shape sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, block_k: int, s_total: int):
    """One grid cell = one attention head.

    q_ref: [1, T, hd] VMEM tile; k_ref/v_ref: [1, S, hd]; pos_ref: [1] i32.
    Streams the S axis in block_k tiles, maintaining the online-softmax
    carry (m, l, acc) — numerically identical to a full softmax.
    """
    q = q_ref[0]                                    # [T, hd]
    pos = pos_ref[0]
    t, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    qpos = pos + jax.lax.iota(jnp.int32, t)         # absolute query positions

    n_blocks = s_total // block_k

    def body(b, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.ds(b * block_k, block_k), slice(None)))  # [BK, hd]
        v = pl.load(v_ref, (0, pl.ds(b * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T) * scale                 # [T, BK] — MXU matmul
        kpos = b * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = kpos[None, :] <= qpos[:, None]       # causal + garbage-tail mask
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = alpha * l + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m0 = jnp.full((t,), NEG_INF, q.dtype)
    l0 = jnp.zeros((t,), q.dtype)
    acc0 = jnp.zeros((t, hd), q.dtype)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    # Every query attends at least to its own key (written before the call),
    # so l > 0 always.
    o_ref[0] = acc / l[:, None]


def attention(q, k_cache, v_cache, pos, *, block_k: int = 128, interpret: bool = True):
    """Cached causal MHA via the flash kernel.  Same contract as
    ``ref.attention_ref``: q [T, nh, hd], caches [S, nh, hd], pos scalar.

    S must be a multiple of ``block_k`` (the AOT model config guarantees
    this; the test suite checks the error path).
    """
    t, nh, hd = q.shape
    s = k_cache.shape[0]
    if s % block_k != 0:
        raise ValueError(f"cache length {s} not a multiple of block_k {block_k}")
    qh = jnp.transpose(q, (1, 0, 2))               # [nh, T, hd]
    kh = jnp.transpose(k_cache, (1, 0, 2))         # [nh, S, hd]
    vh = jnp.transpose(v_cache, (1, 0, 2))
    pos_arr = jnp.reshape(pos, (1,)).astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_k=block_k, s_total=s),
        grid=(nh,),
        in_specs=[
            pl.BlockSpec((1, t, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, s, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((1, t, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nh, t, hd), q.dtype),
        interpret=interpret,
    )(qh, kh, vh, pos_arr)
    return jnp.transpose(out, (1, 0, 2))


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, block_f: int, f_total: int):
    """Fused SwiGLU: accumulates down-projected tiles over the F axis so the
    [T, F] intermediate never materializes beyond one VMEM tile."""
    x = x_ref[...]                                  # [T, H]
    t, h = x.shape

    def body(b, acc):
        wg = pl.load(wg_ref, (slice(None), pl.ds(b * block_f, block_f)))  # [H, BF]
        wu = pl.load(wu_ref, (slice(None), pl.ds(b * block_f, block_f)))
        wd = pl.load(wd_ref, (pl.ds(b * block_f, block_f), slice(None)))  # [BF, H]
        g = jnp.dot(x, wg)                          # [T, BF]
        u = jnp.dot(x, wu)
        act = g * jax.nn.sigmoid(g) * u             # silu(g) * u
        return acc + jnp.dot(act, wd)

    acc0 = jnp.zeros((t, h), x.dtype)
    o_ref[...] = jax.lax.fori_loop(0, f_total // block_f, body, acc0)


def swiglu(x, w_gate, w_up, w_down, *, block_f: int = 128, interpret: bool = True):
    """Fused SwiGLU FFN.  Same contract as ``ref.swiglu_ref``."""
    t, h = x.shape
    f = w_gate.shape[1]
    if f % block_f != 0:
        raise ValueError(f"ffn dim {f} not a multiple of block_f {block_f}")
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, block_f=block_f, f_total=f),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((t, h), lambda i: (0, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((t, h), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)


def vmem_footprint_bytes(t: int, s: int, hd: int, block_k: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one attention grid cell — used by the
    §Perf block-shape sweep (structure-level optimization; interpret-mode
    wallclock is CPU-numpy and not a TPU proxy)."""
    q = t * hd
    kv_tiles = 2 * block_k * hd
    carry = t * (hd + 2)
    out = t * hd
    return (q + kv_tiles + carry + out) * dtype_bytes


def mxu_utilization_estimate(t: int, hd: int, block_k: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes used by the q·kᵀ tile matmul (t×hd @ hd×block_k).
    The systolic array is mxu×mxu; utilization is the product of the
    fill ratios of each dimension (capped at 1)."""
    fill = lambda d: min(d, mxu) / mxu
    return fill(t) * fill(hd) * fill(block_k)
