"""Build-time training: the tiny LM, the adapter Λ (Eq. 4 distillation),
and the Medusa heads (U-Medusa baseline).

This is the stand-in for the paper's training pipeline (Vicuna checkpoints
+ ShareGPT distillation): same objectives, tiny scale, pure JAX with a
hand-rolled Adam (optax is not available offline).  Runs once from
``aot.py``; results are cached in artifacts/weights.npz.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import (Config, draft_train_forward, full_forward, init_adapter,
                    init_medusa, init_params, medusa_forward, param_count)

# ---------------------------------------------------------------------------
# Hand-rolled Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                                 params, mhat, vhat)
    return new, {"m": m, "v": v, "t": t}


def _warmup(step, base_lr, warmup=20):
    return base_lr * jnp.minimum(1.0, (step + 1) / warmup)


# ---------------------------------------------------------------------------
# Stage 1: LM pre-training (next-token CE on the PCFG corpus)
# ---------------------------------------------------------------------------


def cross_entropy(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def train_lm(cfg: Config, steps: int, seed: int = 0, batch: int = 8,
             seqlen: int = 128, lr: float = 1e-3, log_every: int = 100):
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt = adam_init(params)
    batches = corpus.training_batches(seed, n_tokens=200_000, batch=batch, seqlen=seqlen)

    def loss_fn(p, x, y):
        logits = jax.vmap(lambda toks: full_forward(p, toks, cfg)[0])(x)
        return cross_entropy(logits, y)

    @jax.jit
    def step_fn(p, o, x, y, step):
        loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
        p, o = adam_update(p, grads, o, _warmup(step, lr))
        return p, o, loss

    t0, losses = time.time(), []
    for i in range(steps):
        x, y = next(batches)
        params, opt, loss = step_fn(params, opt, x, y, i)
        losses.append(float(loss))
        if i % log_every == 0 or i == steps - 1:
            print(f"[train_lm] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    print(f"[train_lm] {param_count(params):,} params, final loss {losses[-1]:.4f}")
    return params, losses


# ---------------------------------------------------------------------------
# Stage 2: adapter Λ distillation (paper Eq. 4)
# ---------------------------------------------------------------------------


def smooth_l1(x, y, beta: float = 1.0):
    d = jnp.abs(x - y)
    return jnp.where(d < beta, 0.5 * d * d / beta, d - 0.5 * beta).mean()


def soft_ce(teacher_logits, student_logits):
    """CE between the teacher's output distribution and the student's —
    the L_ce(H_L(f^L), H_L(f^S)) term."""
    t = jax.nn.softmax(teacher_logits, axis=-1)
    return -(t * jax.nn.log_softmax(student_logits, axis=-1)).sum(-1).mean()


def distill_adapter(params, cfg: Config, steps: int, seed: int = 1, batch: int = 8,
                    seqlen: int = 128, lr: float = 1e-3, w_ce: float = 0.1,
                    log_every: int = 100):
    """Train Λ so that H_L∘Λ∘w_L^m matches the full model (Eq. 4):
        Loss = SmoothL1(f^L, f^S) + w_ce · CE(H_L(f^L), H_L(f^S))
    Only Λ's parameters receive gradients; the LM is frozen (the paper
    freezes the Vicuna weights and trains the 67M/105M adapter)."""
    adapter = init_adapter(jax.random.PRNGKey(seed + 100), cfg)
    opt = adam_init(adapter)
    batches = corpus.training_batches(seed + 7, n_tokens=200_000, batch=batch, seqlen=seqlen)

    def loss_fn(ap, x):
        def one(toks):
            t_logits, _, f_l = full_forward(params, toks, cfg)       # teacher
            s_logits, f_s = draft_train_forward(params, ap, toks, cfg)
            return smooth_l1(f_l, f_s) + w_ce * soft_ce(t_logits, s_logits)
        return jax.vmap(one)(x).mean()

    @jax.jit
    def step_fn(ap, o, x, step):
        loss, grads = jax.value_and_grad(loss_fn)(ap, x)
        ap, o = adam_update(ap, grads, o, _warmup(step, lr))
        return ap, o, loss

    t0 = time.time()
    loss = jnp.inf
    for i in range(steps):
        x, _ = next(batches)
        adapter, opt, loss = step_fn(adapter, opt, x, i)
        if i % log_every == 0 or i == steps - 1:
            print(f"[distill] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    print(f"[distill] Λ params: {param_count(adapter):,}")
    return adapter, float(loss)


# ---------------------------------------------------------------------------
# Stage 3: Medusa heads (baseline)
# ---------------------------------------------------------------------------


def train_medusa(params, cfg: Config, steps: int, seed: int = 2, batch: int = 8,
                 seqlen: int = 128, lr: float = 1e-3, log_every: int = 100):
    """Head j learns P(token_{i+j+2} | deep hidden_i); the base LM head
    covers +1.  Trained with CE on the corpus, LM frozen (as in Medusa-1)."""
    mheads = init_medusa(jax.random.PRNGKey(seed + 200), cfg)
    opt = adam_init(mheads)
    batches = corpus.training_batches(seed + 13, n_tokens=200_000, batch=batch, seqlen=seqlen)
    n = cfg.n_medusa

    def loss_fn(mh, x, y):
        def one(toks, targets):
            _, _, f_l = full_forward(params, toks, cfg)
            logits = medusa_forward(mh, f_l, params)       # [n, T, V]
            total = 0.0
            t = toks.shape[0]
            for j in range(n):
                # head j at position i predicts targets[i + j + 1]
                valid = t - (j + 1)
                total = total + cross_entropy(logits[j, :valid], targets[j + 1:])
            return total / n
        return jax.vmap(one)(x, y).mean()

    @jax.jit
    def step_fn(mh, o, x, y, step):
        loss, grads = jax.value_and_grad(loss_fn)(mh, x, y)
        mh, o = adam_update(mh, grads, o, _warmup(step, lr))
        return mh, o, loss

    t0 = time.time()
    loss = jnp.inf
    for i in range(steps):
        x, y = next(batches)
        mheads, opt, loss = step_fn(mheads, opt, x, y, i)
        if i % log_every == 0 or i == steps - 1:
            print(f"[medusa] step {i:4d} loss {float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
    print(f"[medusa] heads params: {param_count(mheads):,}")
    return mheads, float(loss)


# ---------------------------------------------------------------------------
# Acceptance probe (sanity metric recorded in the manifest)
# ---------------------------------------------------------------------------


def measure_accept_length(params, adapter, cfg: Config, n_docs: int = 8,
                          prompt_len: int = 64, gen_len: int = 48,
                          max_draft: int = 8, threshold: float = 0.6,
                          seed: int = 99) -> float:
    """Greedy speculative decoding on held-out docs; returns the mean number
    of tokens produced per verification round (accepted + bonus), the
    paper's "accept length" metric (Table 4)."""
    gen = corpus.CorpusGenerator(seed)
    rounds, produced = 0, 0

    @jax.jit
    def lm_logits(toks):
        return full_forward(params, toks, cfg)[0]

    @jax.jit
    def draft_logits(toks):
        return draft_train_forward(params, adapter, toks, cfg)[0]

    for _ in range(n_docs):
        doc = jnp.asarray(gen.document(prompt_len, prompt_len), jnp.int32)
        ctx = list(np.asarray(doc))
        # first token from the full model
        ctx.append(int(jnp.argmax(lm_logits(jnp.asarray(ctx, jnp.int32))[-1])))
        produced_doc = 1
        while produced_doc < gen_len:
            # draft with threshold stopping (Eq. 5)
            draft: list[int] = []
            cur = list(ctx)
            for _ in range(max_draft):
                lg = draft_logits(jnp.asarray(cur, jnp.int32))[-1]
                p = jax.nn.softmax(lg)
                tok = int(jnp.argmax(lg))
                draft.append(tok)
                cur.append(tok)
                if float(p[tok]) < threshold:
                    break
            # verify: full model over ctx + draft
            lg = lm_logits(jnp.asarray(ctx + draft, jnp.int32))
            base = len(ctx) - 1
            accepted = 0
            for j, d in enumerate(draft):
                if int(jnp.argmax(lg[base + j])) == d:
                    accepted += 1
                else:
                    break
            bonus = int(jnp.argmax(lg[base + accepted]))
            ctx.extend(draft[:accepted] + [bonus])
            rounds += 1
            produced_doc += accepted + 1
        produced += produced_doc - 1
    return produced / max(rounds, 1)
